"""Scalar-prefetch scan entry points (ISSUE 8) — the qbuf kernels that
replaced the host-side ``q_pad[qbuf]`` / ``lut_pad[qbuf]`` expansion.

Covers: parity of ``ops.l2_topk_qbuf`` / ``ops.pq_adc_topk_qbuf`` against
their dense-gather ref oracles across {f32, pq, residual_pq} × {ref,
interpret} — including ragged caps that are not multiples of the stream tile,
empty buckets (every slot ``q_row``), and degenerate k > cap pools; the
autotuner's cache-key path; and the bytes-accounting gates: the staged
operand footprint no longer scales with occupied dispatch slots, and the
traced quantized scan contains no ``[b_loc, q_cap, m, ks]`` intermediate.
"""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, ops, ref
from repro.serving import scan

B, S, QR, CAP, D, M, KS, K = 5, 7, 11, 37, 16, 8, 16, 9


@pytest.fixture(scope="module")
def qbuf_inputs():
    """Deliberately hostile dispatch shapes: CAP=37 is no multiple of any
    stream tile, bucket 0 is fully empty, bucket 1 half-empty, bucket 2
    ragged (tail slots padded with id -1)."""
    rng = np.random.default_rng(0)
    q_pad = rng.standard_normal((QR + 1, D)).astype(np.float32)
    q_pad[QR] = 1e9                       # sentinel row for empty slots
    qbuf = rng.integers(0, QR, (B, S)).astype(np.int32)
    qbuf[0, :] = QR                       # empty bucket
    qbuf[1, 3:] = QR                      # partially empty bucket
    cands = rng.standard_normal((B, CAP, D)).astype(np.float32)
    cid = rng.integers(0, 500, (B, CAP)).astype(np.int32)
    cid[2, 20:] = -1                      # ragged bucket
    lut_pad = rng.standard_normal((QR + 1, M, KS)).astype(np.float32)
    lut_pad[QR] = 0.0
    codes = rng.integers(0, KS, (B, CAP, M)).astype(np.int32)
    coff = rng.standard_normal((B, CAP)).astype(np.float32)
    qoff = rng.standard_normal((B, S)).astype(np.float32)
    occ = qbuf < QR
    as_j = jnp.asarray
    return dict(q_pad=as_j(q_pad), qbuf=as_j(qbuf), cands=as_j(cands),
                cid=as_j(cid), lut_pad=as_j(lut_pad), codes=as_j(codes),
                coff=as_j(coff), qoff=as_j(qoff), occ=occ)


def _assert_occupied_match(occ, d_a, i_a, d_b, i_b, *, bitwise_dists):
    """Empty slots hold garbage by contract — compare occupied rows only.
    Dists compare bitwise (or as sorted sets when only selection matters);
    ids compare as sets per row (tie order is impl-defined)."""
    d_a, i_a = np.asarray(d_a), np.asarray(i_a)
    d_b, i_b = np.asarray(d_b), np.asarray(i_b)
    if bitwise_dists:
        np.testing.assert_array_equal(d_a[occ], d_b[occ])
    for b in range(occ.shape[0]):
        for s in range(occ.shape[1]):
            if occ[b, s]:
                assert set(i_a[b, s].tolist()) == set(i_b[b, s].tolist()), (b, s)


@pytest.mark.parametrize("tc", [16, 64])
def test_l2_qbuf_matches_dense_gather_oracle(qbuf_inputs, tc):
    x = qbuf_inputs
    d_ref, i_ref = ops.l2_topk_qbuf(x["q_pad"], x["qbuf"], x["cands"],
                                    x["cid"], K, impl="ref")
    d_int, i_int = ops.l2_topk_qbuf(x["q_pad"], x["qbuf"], x["cands"],
                                    x["cid"], K, impl="interpret", tc=tc)
    # kernel-vs-jnp matmul rounding is the pre-existing tolerance of the
    # batched kernels; selection (ids) must agree exactly
    _assert_occupied_match(x["occ"], d_ref, i_ref, d_int, i_int,
                           bitwise_dists=False)
    occ = x["occ"]
    np.testing.assert_allclose(np.asarray(d_ref)[occ], np.asarray(d_int)[occ],
                               rtol=1e-5, atol=1e-5)


def test_l2_qbuf_bitwise_equals_retired_expansion_path(qbuf_inputs):
    """The acceptance anchor: the qbuf kernel is bit-identical to the batched
    kernel fed the host-expanded ``q_pad[qbuf]`` stack it replaced — the
    rewrite changed operand staging, not a single arithmetic bit."""
    x = qbuf_inputs
    qg = x["q_pad"][x["qbuf"]]
    d_old, i_old = ops.l2_topk_batched(qg, x["cands"], x["cid"], K,
                                       impl="interpret", tq=8, tc=16)
    d_new, i_new = ops.l2_topk_qbuf(x["q_pad"], x["qbuf"], x["cands"],
                                    x["cid"], K, impl="interpret", tc=16)
    occ = x["occ"]
    np.testing.assert_array_equal(np.asarray(d_old)[occ], np.asarray(d_new)[occ])
    np.testing.assert_array_equal(np.asarray(i_old)[occ], np.asarray(i_new)[occ])


@pytest.mark.parametrize("residual", [False, True])
@pytest.mark.parametrize("tn", [16, 64])
def test_adc_qbuf_matches_dense_gather_oracle(qbuf_inputs, residual, tn):
    x = qbuf_inputs
    kw = dict(cand_off=x["coff"], q_off=x["qoff"]) if residual else {}
    d_ref, i_ref = ops.pq_adc_topk_qbuf(x["lut_pad"], x["qbuf"], x["codes"],
                                        x["cid"], K, impl="ref", **kw)
    d_int, i_int = ops.pq_adc_topk_qbuf(x["lut_pad"], x["qbuf"], x["codes"],
                                        x["cid"], K, impl="interpret", tn=tn,
                                        **kw)
    _assert_occupied_match(x["occ"], d_ref, i_ref, d_int, i_int,
                           bitwise_dists=False)
    occ = x["occ"]
    np.testing.assert_allclose(np.asarray(d_ref)[occ], np.asarray(d_int)[occ],
                               rtol=1e-5, atol=1e-5)


def test_adc_qbuf_bitwise_equals_retired_expansion_path(qbuf_inputs):
    x = qbuf_inputs
    lq = x["lut_pad"][x["qbuf"]]
    d_old, i_old = ops.pq_adc_topk_batched(
        lq, x["codes"], x["cid"], K, cand_off=x["coff"],
        q_off=x["qoff"], impl="interpret", tq=8, tn=16)
    d_new, i_new = ops.pq_adc_topk_qbuf(
        x["lut_pad"], x["qbuf"], x["codes"], x["cid"], K,
        cand_off=x["coff"], q_off=x["qoff"], impl="interpret", tn=16)
    occ = x["occ"]
    np.testing.assert_array_equal(np.asarray(d_old)[occ], np.asarray(d_new)[occ])
    np.testing.assert_array_equal(np.asarray(i_old)[occ], np.asarray(i_new)[occ])


def test_adc_qbuf_degenerate_k_exceeds_cap(qbuf_inputs):
    x = qbuf_inputs
    k_big = CAP + 13
    d_ref, i_ref = ops.pq_adc_topk_qbuf(x["lut_pad"], x["qbuf"], x["codes"],
                                        x["cid"], k_big, impl="ref")
    d_int, i_int = ops.pq_adc_topk_qbuf(x["lut_pad"], x["qbuf"], x["codes"],
                                        x["cid"], k_big, impl="interpret",
                                        tn=16)
    occ = x["occ"]
    # the slots beyond the pool flush as inf/-1 in both impls
    np.testing.assert_array_equal(np.asarray(i_ref)[occ] < 0,
                                  np.asarray(i_int)[occ] < 0)
    _assert_occupied_match(occ, d_ref, i_ref, d_int, i_int,
                           bitwise_dists=False)


def test_empty_bucket_rows_are_garbage_but_finite_shape(qbuf_inputs):
    """Empty buckets (all slots q_row) must not crash the gather loop; their
    output rows are garbage by contract but the occupied buckets around them
    stay exact."""
    x = qbuf_inputs
    qbuf_all_empty = jnp.full_like(x["qbuf"], QR)
    d, i = ops.pq_adc_topk_qbuf(x["lut_pad"], qbuf_all_empty, x["codes"],
                                x["cid"], K, impl="interpret", tn=16)
    assert d.shape == (B, S, K) and i.shape == (B, S, K)


# ------------------------------------------------------------------ autotune

def test_autotune_cache_key_path():
    autotune.clear()
    try:
        t1 = autotune.autotune_pq_adc_qbuf(32, 2, 16, 4, candidates=(8, 16),
                                           b_loc=2, q_cap=4, q_row=6)
        assert t1 in (8, 16)
        recs = autotune.records()
        assert len(recs) == 1 and recs[0]["cached"] is False
        assert set(recs[0]["timings_s"]) == {"8", "16"}
        # same store shape → cache hit, no re-sweep, recorded as cached
        t2 = autotune.autotune_pq_adc_qbuf(32, 2, 16, 4, candidates=(8, 16),
                                           b_loc=2, q_cap=4, q_row=6)
        assert t2 == t1
        recs = autotune.records()
        assert len(recs) == 2 and recs[1]["cached"] is True
        # the ops wrapper resolves tn=None through the same cache
        assert autotune.lookup(autotune.pq_adc_key(32, 2, 16, 4)) == t1
        # an unseen shape falls back to the kernel default
        assert autotune.lookup(autotune.pq_adc_key(999, 2, 16, 4)) == 128
        assert autotune.lookup(autotune.l2_key(999, 16, 4)) == 256
    finally:
        autotune.clear()


def test_autotune_l2_sweep_records():
    autotune.clear()
    try:
        t = autotune.autotune_l2_qbuf(32, 8, 4, candidates=(8, 16),
                                      b_loc=2, q_cap=4, q_row=6)
        assert t in (8, 16)
        assert autotune.lookup(autotune.l2_key(32, 8, 4)) == t
    finally:
        autotune.clear()


# ----------------------------------------------------------- bytes accounting

def test_staged_operand_bytes_independent_of_slots():
    """The point of the rewrite: compact staging is flat in dispatch fan-out
    while the retired expansion grew linearly with occupied slots."""
    lut_pad = jax.ShapeDtypeStruct((QR + 1, M, KS), jnp.float32)
    small = scan.staged_operand_bytes(jax.ShapeDtypeStruct((B, 4), jnp.int32),
                                      lut_pad)
    big = scan.staged_operand_bytes(jax.ShapeDtypeStruct((B, 64), jnp.int32),
                                    lut_pad)
    row = M * KS * 4
    # expanded: one plane row per slot; compact: the plane + int32 indices
    assert small["expanded_bytes"] == B * 4 * row
    assert big["expanded_bytes"] == B * 64 * row
    assert small["compact_bytes"] == (QR + 1) * row + B * 4 * 4
    # compact grows only by the 4-byte indices (16× fan-out → +B·60·4 bytes,
    # not +B·60·row)
    assert big["compact_bytes"] - small["compact_bytes"] == B * 60 * 4
    assert big["compact_bytes"] < big["expanded_bytes"]


def test_quantized_scan_traces_without_expanded_lut(qbuf_inputs):
    """Structural gate: the traced quantized scan must not contain ANY
    ``[b_loc, q_cap, m, ks]`` f32 intermediate — the amplified operand the
    old host-side ``lut_pad[qbuf]`` gather materialized."""
    x = qbuf_inputs
    jaxpr = jax.make_jaxpr(
        lambda qb, qp, v, i, lp, c: scan.run(
            "interpret", qb, qp, v, i, K, lut_pad=lp, codes_loc=c, rk=K)
    )(x["qbuf"], x["q_pad"], x["cands"], x["cid"], x["lut_pad"], x["codes"])
    expanded = re.escape(f"f32[{B},{S},{M},{KS}]")
    assert not re.search(expanded, str(jaxpr)), (
        "quantized scan re-materializes the per-slot LUT expansion")
    # while the compact plane is still there
    assert f"f32[{QR + 1},{M},{KS}]" in str(jaxpr)
