"""Cluster serving tests (repro.serving.cluster) — ISSUE 10.

The load-bearing gate is cross-shard merge parity: an S-shard × R-replica
``LiraCluster`` (each shard its own k-means/probing model/tier store) must
serve bit-identical distances and set-identical ids vs a single-engine
oracle built over the union corpus. Exactness conditions: σ=-1 probes every
partition on both sides (per-shard probing models become irrelevant), and
rerank·k ≥ capacity makes the PQ tiers' shortlist cover whole partitions so
their exact f32 rerank sees every row — then per-shard answers are exact
over each shard's rows and the dedup_topk merge of per-shard top-k equals
the global top-k. η>0 is on throughout, so replica dedup rides the same
gate.

Control-plane tests (routing, hedging, heartbeat failover, in-flight
replay) run on re-wrapped clusters: fresh routers/mitigators over the
module-scoped built engines — engines hold no control-plane state, so
re-wrapping is free and keeps fault injection away from the parity
fixtures. All time is FakeClock; service is ``fixed_service_s`` — no
wall-clock anywhere.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs.base import FrontendConfig
from repro.data import make_vector_dataset
from repro.launch.mesh import make_test_mesh
from repro.obs import MetricsRegistry
from repro.serving import (
    BuildConfig,
    ClusterConfig,
    LiraCluster,
    LiraEngine,
    SearchRequest,
    plan_shards,
)
from repro.utils.clock import FakeClock

N, NQ, DIM, K = 360, 16, 16, 5
B_SHARD, B_ORACLE = 4, 8
PQ_M, PQ_KS, RERANK = 4, 32, 64   # rerank·k = 320 ≥ any partition capacity
TIERS = ("f32", "pq", "residual_pq")
SERVICE_S = 1e-3                  # deterministic virtual service time


def _bc(tier, n_partitions=B_SHARD):
    return BuildConfig(
        n_partitions=n_partitions, k=K, eta=0.05, train_frac=0.5, epochs=2,
        nprobe_max=n_partitions, tier=tier, pq_m=PQ_M, pq_ks=PQ_KS,
        rerank=RERANK, seed=9)


@pytest.fixture(scope="module")
def ds():
    return make_vector_dataset(n=N, n_queries=NQ, dim=DIM, n_modes=8, seed=3)


@pytest.fixture(scope="module")
def rigs(ds):
    """Per tier: (2-shard × 2-replica cluster, union-corpus oracle engine).
    Parity tests treat these as read-only; fault tests re-wrap the engines."""
    mesh = make_test_mesh()
    out = {}
    for tier in TIERS:
        cluster = LiraCluster.build(
            mesh, ds.base, _bc(tier),
            ClusterConfig(n_shards=2, n_replicas=2, seed=1),
            clock=FakeClock(), fixed_service_s=SERVICE_S)
        oracle = LiraEngine.build(mesh, ds.base, _bc(tier, B_ORACLE))
        out[tier] = (cluster, oracle)
    return out


def _rewrap(cluster, ccfg, **kwargs):
    """Fresh control plane (routers/mitigators/members) over already-built
    shard engines — how fault tests isolate their injected state."""
    return LiraCluster([g.engine for g in cluster.groups],
                       [g.row_ids for g in cluster.groups],
                       dataclasses.replace(ccfg, n_shards=len(cluster.groups)),
                       **kwargs)


def _ids_set_equal(a, b):
    return all(set(ra[ra >= 0]) == set(rb[rb >= 0]) for ra, rb in zip(a, b))


# ------------------------------------------------------------------ parity

@pytest.mark.parametrize("tier", TIERS)
@pytest.mark.parametrize("impl", ("ref", "interpret"))
def test_cluster_matches_union_oracle(rigs, ds, tier, impl):
    cluster, oracle = rigs[tier]
    rc = cluster.search(SearchRequest(queries=ds.queries, sigma=-1.0,
                                      impl=impl))
    ro = oracle.search(SearchRequest(queries=ds.queries, sigma=-1.0,
                                     impl=impl))
    np.testing.assert_array_equal(rc.dists, ro.dists)
    assert _ids_set_equal(rc.ids, ro.ids)
    # η>0 replica dedup held through both merge levels: no duplicate ids
    for row in rc.ids:
        valid = row[row >= 0]
        assert len(set(valid)) == len(valid)


def test_merged_answer_speaks_global_ids(rigs, ds):
    cluster, _ = rigs["f32"]
    res = cluster.search(SearchRequest(queries=ds.queries, sigma=-1.0))
    owner = {}
    for g in cluster.groups:
        for gid in g.row_ids:
            owner[int(gid)] = g.sid
    for row, routes in zip(res.ids, [res.stats.routes] * len(res.ids)):
        for gid in row[row >= 0]:
            assert int(gid) in owner  # every id is a real global id
    assert len(res.stats.routes) == len(cluster.groups)


def test_cross_shard_merge_dedups_overlapping_shards(rigs, ds):
    """Two shards holding the SAME rows: the pool carries every id twice and
    the merge must collapse each to its best distance — the η>0 mechanism at
    cluster level, made deterministic."""
    cluster, _ = rigs["f32"]
    g = cluster.groups[0]
    twin = LiraCluster([g.engine, g.engine], [g.row_ids, g.row_ids],
                       ClusterConfig(n_shards=2, n_replicas=1, seed=0),
                       clock=FakeClock(), fixed_service_s=SERVICE_S)
    solo = g.engine.search(SearchRequest(queries=ds.queries, sigma=-1.0))
    both = twin.search(SearchRequest(queries=ds.queries, sigma=-1.0))
    np.testing.assert_array_equal(both.dists, solo.dists)
    gids = np.where(solo.ids >= 0,
                    g.row_ids[np.clip(solo.ids, 0, None)], -1)
    np.testing.assert_array_equal(both.ids, gids)
    assert both.stats.dedup_hits >= NQ * K  # every candidate was duplicated


@pytest.mark.parametrize("tier", TIERS)
def test_midstream_replica_failure_preserves_answers(rigs, ds, tier):
    """A replica dies with a batch in flight: the batch replays on its
    sibling and every answer still matches the oracle — zero lost queries,
    recall (in fact bit-identical results) preserved."""
    cluster, oracle = rigs[tier]
    cl = _rewrap(cluster, ClusterConfig(n_replicas=2, seed=1),
                 clock=FakeClock(), fixed_service_s=SERVICE_S,
                 metrics=MetricsRegistry())
    cl.fail_replica(0, 0, inflight=True)
    want = oracle.search(SearchRequest(queries=ds.queries, sigma=-1.0))
    n_batches = 6
    for _ in range(n_batches):
        got = cl.search(SearchRequest(queries=ds.queries, sigma=-1.0))
        np.testing.assert_array_equal(got.dists, want.dists)
        assert _ids_set_equal(got.ids, want.ids)
    router = cl.groups[0].router
    assert router.requeued == 1          # exactly the in-flight batch
    assert not router.replicas[0].healthy
    # every batch served exactly once despite the death
    assert sum(r.served for r in router.replicas) >= n_batches
    assert cl.metrics.counter("lira_failovers_total").total() == 1.0


# ----------------------------------------------------------- shard planning

def test_plan_shards_hash_covers_and_balances():
    x = np.random.default_rng(0).normal(size=(400, 8)).astype(np.float32)
    plan = plan_shards(x, 4, mode="hash")
    assert plan.assign.shape == (400,) and plan.centroids is None
    counts = np.bincount(plan.assign, minlength=4)
    assert counts.sum() == 400 and counts.min() > 0
    assert counts.max() < 2.0 * counts.mean()  # hash balance, loose bound
    # stable: same ids → same shards
    np.testing.assert_array_equal(plan.assign,
                                  plan_shards(x, 4, mode="hash").assign)


def test_plan_shards_kmeans_respects_balance_cap():
    rng = np.random.default_rng(1)
    # adversarial: one tight blob, so unconstrained k-means would put
    # everything in one shard — the cap must force a spill
    x = (rng.normal(size=(40, 4)) * 0.01).astype(np.float32)
    plan = plan_shards(x, 2, mode="kmeans", seed=5, balance_slack=1.2)
    cap = int(np.ceil(40 / 2 * 1.2))
    counts = np.bincount(plan.assign, minlength=2)
    assert counts.sum() == 40 and counts.max() <= cap
    assert plan.centroids.shape == (2, 4)


def test_plan_shards_validates():
    x = np.zeros((10, 4), np.float32)
    with pytest.raises(ValueError, match="n_shards"):
        plan_shards(x, 0)
    with pytest.raises(ValueError, match="unknown shard mode"):
        plan_shards(x, 2, mode="range")


# ------------------------------------------------------------ control plane

def test_routing_spreads_load_across_replicas(rigs, ds):
    cluster, _ = rigs["f32"]
    cl = _rewrap(cluster, ClusterConfig(n_replicas=2, seed=3),
                 clock=FakeClock(), fixed_service_s=SERVICE_S)
    for _ in range(24):
        cl.search(SearchRequest(queries=ds.queries[:8], sigma=-1.0))
    for g in cl.groups:
        served = [r.served for r in g.router.replicas]
        assert sum(served) == 24 and min(served) > 0


def test_hedging_caps_straggler_latency(rigs, ds):
    cluster, _ = rigs["f32"]
    reg = MetricsRegistry()
    cl = _rewrap(cluster,
                 ClusterConfig(n_replicas=2, seed=2, hedge_warmup=4),
                 clock=FakeClock(), fixed_service_s=SERVICE_S, metrics=reg)
    req = SearchRequest(queries=ds.queries[:8], sigma=-1.0)
    for _ in range(4):                     # healthy warmup history
        cl.search(req)
    for g in cl.groups:                    # replica 0 becomes a straggler
        g.router.replicas[0].latency_scale = 50.0
    lats = [cl.search(req).stats.latency_ms for _ in range(20)]
    assert reg.counter("lira_hedges_total").total() > 0
    # hedged calls complete at deadline (3× median ≈ 3ms) + healthy service,
    # never at the straggler's 50ms
    assert max(lats) < 50.0 * SERVICE_S * 1e3
    assert reg.counter("lira_hedge_wins_total").total() > 0


def test_hedging_off_serves_at_straggler_latency(rigs, ds):
    cluster, _ = rigs["f32"]
    cl = _rewrap(cluster,
                 ClusterConfig(n_replicas=2, seed=2, hedging=False),
                 clock=FakeClock(), fixed_service_s=SERVICE_S,
                 metrics=MetricsRegistry())
    for g in cl.groups:
        g.router.replicas[0].latency_scale = 50.0
    lats = [cl.search(SearchRequest(queries=ds.queries[:8], sigma=-1.0))
            .stats.latency_ms for _ in range(20)]
    assert cl.metrics.counter("lira_hedges_total").total() == 0
    assert max(lats) == pytest.approx(50.0 * SERVICE_S * 1e3)


def test_heartbeat_stall_detected_and_routed_around(rigs, ds):
    cluster, _ = rigs["f32"]
    clock = FakeClock()
    cl = _rewrap(cluster,
                 ClusterConfig(n_replicas=2, seed=1, heartbeat_timeout_s=5.0),
                 clock=clock, fixed_service_s=SERVICE_S)
    cl.stall_replica(0, 1)
    clock.advance(10.0)
    failed = cl.tick()
    assert failed == [(0, 1, 0)]
    assert not cl.groups[0].router.replicas[1].healthy
    for _ in range(6):                     # traffic never lands on the corpse
        res = cl.search(SearchRequest(queries=ds.queries[:8], sigma=-1.0))
        assert res.stats.routes[0][1] == 0
    cl.recover_replica(0, 1)
    assert cl.groups[0].router.replicas[1].healthy


def test_whole_group_dead_raises(rigs, ds):
    cluster, _ = rigs["f32"]
    cl = _rewrap(cluster, ClusterConfig(n_replicas=2, seed=1),
                 clock=FakeClock(), fixed_service_s=SERVICE_S)
    cl.fail_replica(1, 0)
    cl.fail_replica(1, 1)
    with pytest.raises(RuntimeError, match="no healthy replicas"):
        cl.search(SearchRequest(queries=ds.queries[:8], sigma=-1.0))


def test_charge_service_advances_clock(rigs, ds):
    cluster, _ = rigs["f32"]
    clock = FakeClock()
    cl = _rewrap(cluster, ClusterConfig(n_replicas=1, seed=0),
                 clock=clock, fixed_service_s=SERVICE_S, charge_service=True)
    cl.search(SearchRequest(queries=ds.queries[:8], sigma=-1.0))
    assert clock() == pytest.approx(SERVICE_S)
    with pytest.raises(TypeError, match="advance"):
        _rewrap(cluster, ClusterConfig(n_replicas=1, seed=0),
                charge_service=True)


# --------------------------------------------------------- stats & surface

def test_cluster_stats_shape(rigs, ds):
    cluster, _ = rigs["f32"]
    res = cluster.search(SearchRequest(queries=ds.queries, sigma=-1.0))
    st = res.stats
    assert st.shard is None and st.replica is None
    assert len(st.routes) == 2
    for sid, rid, hedged, failovers in st.routes:
        assert 0 <= rid < 2 and isinstance(hedged, bool) and failovers == 0
    assert st.latency_ms == pytest.approx(SERVICE_S * 1e3)
    assert st.bucket >= NQ and st.failovers == 0 and not st.hedged
    assert res.nprobe_eff.shape == (NQ,)
    table = cluster.replica_table()
    assert len(table) == 4 and all(row["healthy"] for row in table)


def test_search_accepts_raw_arrays_and_rejects_mixed(rigs, ds):
    cluster, _ = rigs["f32"]
    a = cluster.search(ds.queries[:8], sigma=-1.0)
    b = cluster.search(SearchRequest(queries=ds.queries[:8], sigma=-1.0))
    np.testing.assert_array_equal(a.dists, b.dists)
    one = cluster.search(ds.queries[0], sigma=-1.0)
    assert one.dists.shape == (1, K)
    with pytest.raises(TypeError, match="not both"):
        cluster.search(SearchRequest(queries=ds.queries[:8]), sigma=-1.0)


def test_constructor_validates():
    with pytest.raises(ValueError, match="row_ids"):
        LiraCluster([], [])
    eng = object()
    with pytest.raises(ValueError, match="shards"):
        LiraCluster([eng], [np.arange(3)], ClusterConfig(n_shards=2))


def test_frontend_over_cluster_is_bit_identical(rigs, ds):
    """The front-end routing hook: single-query traffic batches through
    ``ServingFrontend`` onto the cluster; scattered rows must equal a direct
    cluster batch search (same exactness story as frontend-over-engine)."""
    cluster, _ = rigs["f32"]
    cl = _rewrap(cluster, ClusterConfig(n_replicas=2, seed=1),
                 clock=FakeClock(), fixed_service_s=SERVICE_S)
    fe = cl.attach_frontend(
        FrontendConfig(max_batch=8, max_wait_ms=5.0, max_queue=64),
        clock=FakeClock(), metrics=MetricsRegistry())
    try:
        pend = [fe.submit(SearchRequest(queries=ds.queries[i], sigma=-1.0))
                for i in range(3)]
        last = cl.search_one(SearchRequest(queries=ds.queries[3], sigma=-1.0))
        fe.drain()
        direct = cl.search(SearchRequest(queries=ds.queries[:4], sigma=-1.0))
        rows = [p.result() for p in pend] + [last]
        for i, r in enumerate(rows):
            np.testing.assert_array_equal(r.dists[0], direct.dists[i])
            np.testing.assert_array_equal(r.ids[0], direct.ids[i])
            assert not r.stats.shed
    finally:
        cl.frontend = None
