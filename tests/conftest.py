import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def small_dataset():
    from repro.data import make_vector_dataset

    return make_vector_dataset(n=6000, n_queries=100, dim=32, n_modes=24, seed=3)


@pytest.fixture(scope="session")
def small_index(small_dataset):
    """(store, assign, centroids, gt_ids, k) shared across core tests."""
    import jax
    import jax.numpy as jnp

    from repro.core import build_store, kmeans_fit
    from repro.core import ground_truth as gt

    ds = small_dataset
    k, b = 10, 16
    st = kmeans_fit(jax.random.PRNGKey(0), jnp.asarray(ds.base), n_clusters=b, n_iters=12)
    assign = np.asarray(st.assign)
    cents = np.asarray(st.centroids)
    ids = np.arange(len(ds.base), dtype=np.int32)
    store = build_store(ds.base, ids, assign, cents)
    _, gti = gt.exact_knn(ds.queries, ds.base, k)
    return store, assign, cents, gti, k
