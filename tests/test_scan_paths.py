"""Parity suite for the backend-dispatched partition-scan layer (ISSUE 4).

Three implementations must agree on every tier:
  * serving/scan.py impl="ref"        — portable jnp paths (the oracle),
  * serving/scan.py impl="interpret"  — the grid-batched Pallas kernels
                                        through the interpreter,
  * tests/_scan_oracle.scan_np        — pure-numpy twin.

Unit level: scan.run on synthetic dispatch buffers (random + empty slots +
-1 id padding). End-to-end: LiraEngine.search over random + clustered stores,
f32/quantized/residual × η ∈ {0, 0.03}, asserting bit-identical distances and
set-identical ids per query — plus regression tests for the two dispatch
bugfixes (padded queries masked out of dispatch, q_cap overflow reported).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _scan_oracle import scan_np

from repro.configs.base import LiraSystemConfig
from repro.core import probing
from repro.data import make_vector_dataset
from repro.launch.mesh import make_test_mesh
from repro.serving import scan
from repro.serving.engine import LiraEngine, make_serve_step
from repro.serving.quantized import build_quantized_store


def _assert_scan_matches_np(d_jax, i_jax, d_np, i_np, qbuf, q_row):
    """Occupied slots: same id set and same sorted distances (numpy runs in
    float64, so allclose; the jnp-vs-kernel comparison is exact elsewhere)."""
    occupied = np.asarray(qbuf) < q_row
    d_jax, i_jax = np.asarray(d_jax), np.asarray(i_jax)
    for b, s in zip(*np.nonzero(occupied)):
        fin = np.isfinite(d_np[b, s])
        assert set(i_jax[b, s][np.isfinite(d_jax[b, s])].tolist()) == \
            set(i_np[b, s][fin].tolist()), (b, s)
        np.testing.assert_allclose(d_jax[b, s][np.isfinite(d_jax[b, s])],
                                   d_np[b, s][fin], rtol=1e-4, atol=1e-4)


@pytest.fixture(scope="module")
def scan_inputs():
    """Synthetic dispatch state: random store with -1 id padding, random qbuf
    with empty (q_row) slots — the exact shapes the serve step hands scan.run."""
    host = np.random.default_rng(11)
    b_loc, cap, q_row, q_cap, dim = 6, 40, 12, 8, 16
    vecs = host.normal(0, 1, (b_loc, cap, dim)).astype(np.float32)
    ids = np.arange(b_loc * cap, dtype=np.int32).reshape(b_loc, cap)
    ids[:, -5:] = -1                      # store padding
    ids[2, :] = -1                        # one fully-empty partition
    qbuf = host.integers(0, q_row + 1, (b_loc, q_cap)).astype(np.int32)
    qbuf[:, -1] = q_row                   # guaranteed empty slots
    q = host.normal(0, 1, (q_row, dim)).astype(np.float32)
    q_pad = np.concatenate([q, np.full((1, dim), 1e9, np.float32)], 0)
    return qbuf, q_pad, vecs, ids


@pytest.mark.parametrize("impl", ["ref", "interpret", "pallas"])
def test_scan_f32_matches_numpy_twin(scan_inputs, impl):
    qbuf, q_pad, vecs, ids = scan_inputs
    k = 7
    d, i = scan.run(impl, jnp.asarray(qbuf), jnp.asarray(q_pad),
                    jnp.asarray(vecs), jnp.asarray(ids), k)
    d_np, i_np = scan_np(qbuf, q_pad, vecs, ids, k)
    _assert_scan_matches_np(d, i, d_np, i_np, qbuf, q_pad.shape[0] - 1)


def test_scan_f32_kernel_bit_identical_to_ref(scan_inputs):
    qbuf, q_pad, vecs, ids = scan_inputs
    args = (jnp.asarray(qbuf), jnp.asarray(q_pad), jnp.asarray(vecs),
            jnp.asarray(ids), 7)
    d_ref, i_ref = scan.run("ref", *args)
    d_ker, i_ker = scan.run("interpret", *args)
    occupied = qbuf < q_pad.shape[0] - 1
    np.testing.assert_array_equal(np.asarray(d_ref)[occupied], np.asarray(d_ker)[occupied])
    np.testing.assert_array_equal(np.asarray(i_ref)[occupied], np.asarray(i_ker)[occupied])


@pytest.mark.parametrize("residual", [False, True])
@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_scan_quantized_matches_numpy_twin(scan_inputs, impl, residual):
    qbuf, q_pad, vecs, ids = scan_inputs
    host = np.random.default_rng(12)
    b_loc, cap, _ = vecs.shape
    q_row = q_pad.shape[0] - 1
    m, ks, k, rk = 4, 16, 5, 12
    codes = host.integers(0, ks, (b_loc, cap, m)).astype(np.uint8)
    lut_pad = np.concatenate([host.normal(0, 1, (q_row, m, ks)) ** 2,
                              np.zeros((1, m, ks))], 0).astype(np.float32)
    cterm = off = None
    kwargs = {}
    if residual:
        cterm = host.normal(0, 1, (b_loc, cap)).astype(np.float32)
        off = np.concatenate([host.normal(0, 1, (b_loc, q_row)),
                              np.zeros((b_loc, 1))], 1).astype(np.float32)
        kwargs = {"cterm_loc": jnp.asarray(cterm), "off_loc": jnp.asarray(off)}
    d, i = scan.run(impl, jnp.asarray(qbuf), jnp.asarray(q_pad),
                    jnp.asarray(vecs), jnp.asarray(ids), k,
                    lut_pad=jnp.asarray(lut_pad), codes_loc=jnp.asarray(codes),
                    rk=rk, **kwargs)
    d_np, i_np = scan_np(qbuf, q_pad, vecs, ids, k, lut_pad=lut_pad,
                         codes=codes, rk=rk, cterm=cterm, off=off)
    _assert_scan_matches_np(d, i, d_np, i_np, qbuf, q_row)


def test_l2_topk_k_larger_than_pool_consistent_across_impls():
    """cap < k degenerate pools: every impl (flat + batched) returns the same
    inf/-1-filled shape instead of ref crashing in top_k."""
    from repro.kernels import ops as kops

    host = np.random.default_rng(13)
    q = jnp.asarray(host.normal(0, 1, (3, 4, 8)).astype(np.float32))
    c = jnp.asarray(host.normal(0, 1, (3, 5, 8)).astype(np.float32))
    ids = jnp.asarray(np.tile(np.arange(5, dtype=np.int32), (3, 1)))
    k = 7
    outs = {impl: kops.l2_topk_batched(q, c, ids, k, impl=impl)
            for impl in ("ref", "interpret")}
    for impl, (d, i) in outs.items():
        assert d.shape == (3, 4, k) and i.shape == (3, 4, k), impl
        assert not np.isfinite(np.asarray(d)[..., 5:]).any(), impl
        assert (np.asarray(i)[..., 5:] == -1).all(), impl
    np.testing.assert_array_equal(np.asarray(outs["ref"][1]),
                                  np.asarray(outs["interpret"][1]))
    df, ifl = kops.l2_topk(q[0], c[0], ids[0], k, impl="ref")
    d2, i2 = kops.l2_topk(q[0], c[0], ids[0], k, impl="interpret")
    assert df.shape == d2.shape == (4, k)
    np.testing.assert_array_equal(np.asarray(ifl), np.asarray(i2))


def test_serve_cache_normalizes_impl_aliases(tiny_serving):
    """None, "auto" and the resolved backend name must share one compiled
    serve step (no redundant jit compiles during σ sweeps)."""
    store, params, q, vecs = tiny_serving
    b, cap, dim = vecs.shape
    cfg = LiraSystemConfig(arch="t", dim=dim, n_partitions=b, capacity=cap,
                           k=5, nprobe_max=b)
    eng = LiraEngine(cfg=cfg, params=params, store=store,
                     mesh=make_test_mesh(), sigma=-1.0)
    eng.search(q[:8])
    eng.search(q[:8], impl="auto")
    eng.search(q[:8], impl=scan.resolve_impl("auto"))
    assert len(eng._serve_cache) == 1
    eng.search(q[:8], impl="interpret")
    assert len(eng._serve_cache) == 2


def test_scan_rejects_unknown_impl(scan_inputs):
    qbuf, q_pad, vecs, ids = scan_inputs
    with pytest.raises(ValueError, match="unknown scan impl"):
        scan.run("cuda", jnp.asarray(qbuf), jnp.asarray(q_pad),
                 jnp.asarray(vecs), jnp.asarray(ids), 5)
    assert scan.resolve_impl("auto") in scan.IMPLS
    assert scan.resolve_impl(None) in scan.IMPLS


# --------------------------------------------------------------- end to end

N, NQ, DIM, B, ETA_ON = 1200, 16, 16, 8, 0.03


@pytest.fixture(scope="module", params=["random", "clustered"])
def tier_engines(request):
    """Per dataset: {η: (engine_nonres, engine_res)} — one build per η, the
    residual engine reuses the partitions/probing model with residual codes."""
    if request.param == "clustered":
        ds = make_vector_dataset("clustered", n=N, n_queries=NQ, dim=DIM,
                                 n_modes=B, center_scale=8.0, spread=0.5,
                                 boundary_frac=0.05, noise_frac=0.0, seed=21)
    else:
        host = np.random.default_rng(22)
        from repro.data.synthetic import VectorDataset

        ds = VectorDataset(
            base=host.normal(0, 1, (N, DIM)).astype(np.float32),
            queries=host.normal(0, 1, (NQ, DIM)).astype(np.float32), name="random")
    mesh = make_test_mesh()
    engines = {}
    for eta in (0.0, ETA_ON):
        eng = LiraEngine.build(mesh, ds.base, n_partitions=B, k=10, eta=eta,
                               train_frac=0.5, epochs=2, nprobe_max=B,
                               tier="pq", pq_m=4, pq_ks=32, rerank=4)
        qs = build_quantized_store(jax.random.PRNGKey(9), eng.store["vectors"],
                                   eng.store["ids"], m=4, ks=eng.cfg.pq_ks,
                                   residual=True, centroids=eng.store["centroids"])
        store_r = {**eng.store, "codes": qs.codes, "codebooks": qs.codebooks,
                   "cterm": qs.cterm}
        eng_r = LiraEngine(cfg=dataclasses.replace(eng.cfg, tier="residual_pq"),
                           params=eng.params, store=store_r, mesh=mesh)
        engines[eta] = (eng, eng_r)
    return engines, ds


@pytest.mark.parametrize("eta", [0.0, ETA_ON])
@pytest.mark.parametrize("tier", ["f32", "quantized", "residual"])
def test_engine_kernel_path_matches_ref(tier_engines, tier, eta):
    """The acceptance gate: impl="ref" and the interpret-mode kernel path must
    return bit-identical distances and set-identical ids on every tier."""
    engines, ds = tier_engines
    eng = engines[eta][1 if tier == "residual" else 0]
    tier_name = {"f32": "f32", "quantized": "pq", "residual": "residual_pq"}[tier]
    r_ref = eng.search(ds.queries, sigma=0.3, tier=tier_name, impl="ref")
    r_ker = eng.search(ds.queries, sigma=0.3, tier=tier_name, impl="interpret")
    d_ref, i_ref, np_ref, ov_ref = (r_ref.dists, r_ref.ids, r_ref.nprobe_eff,
                                    r_ref.overflow)
    d_ker, i_ker, np_ker, ov_ker = (r_ker.dists, r_ker.ids, r_ker.nprobe_eff,
                                    r_ker.overflow)
    np.testing.assert_array_equal(d_ref, d_ker)
    np.testing.assert_array_equal(np_ref, np_ker)
    assert ov_ref == ov_ker
    for r in range(NQ):
        fin = np.isfinite(d_ref[r])
        assert set(i_ref[r][fin].tolist()) == set(i_ker[r][fin].tolist()), r


# ------------------------------------------------- dispatch bugfix regressions

@pytest.fixture(scope="module")
def tiny_serving():
    host = np.random.default_rng(5)
    b, cap, dim = 4, 48, 16
    vecs = host.normal(0, 1, (b, cap, dim)).astype(np.float32)
    ids = np.arange(b * cap, dtype=np.int32).reshape(b, cap)
    store = {"centroids": jnp.asarray(vecs.mean(1)), "vectors": jnp.asarray(vecs),
             "ids": jnp.asarray(ids)}
    params = probing.init(jax.random.PRNGKey(0),
                          probing.ProbingConfig(dim=dim, n_partitions=b))
    q = host.normal(0, 1, (32, dim)).astype(np.float32)
    return store, params, q, vecs


def test_padded_batch_identical_to_unpadded(tiny_serving):
    """Bugfix regression: batch-padding rows are masked out of dispatch, so an
    nq=5 search (padded to the 8-bucket) returns exactly what an unpadded
    nq=5 serve step returns — pad rows neither probe partitions, steal q_cap
    slots, nor inflate the overflow count."""
    store, params, q, vecs = tiny_serving
    mesh = make_test_mesh()
    b, cap, dim = vecs.shape
    # tight q_cap: unmasked pad rows would occupy slots and report phantom
    # overflow (σ=-1 makes every row probe all partitions)
    cfg = LiraSystemConfig(arch="t", dim=dim, n_partitions=b, capacity=cap,
                           k=5, nprobe_max=b, q_cap_factor=1.0)
    eng = LiraEngine(cfg=cfg, params=params, store=store, mesh=mesh, sigma=-1.0)
    r_pad = eng.search(q[:5])
    d_pad, i_pad, np_pad, ovf_pad = (r_pad.dists, r_pad.ids, r_pad.nprobe_eff,
                                     r_pad.overflow)
    fn = make_serve_step(cfg, mesh, 5, sigma=-1.0)
    with mesh:
        d_un, i_un, np_un, ovf_un = jax.jit(fn)(params, store, jnp.asarray(q[:5]))
    np.testing.assert_array_equal(d_pad, np.asarray(d_un))
    np.testing.assert_array_equal(i_pad, np.asarray(i_un))
    np.testing.assert_array_equal(np_pad, np.asarray(np_un))
    assert ovf_pad == int(np.asarray(ovf_un).sum()) == 0
    # and the padded result matches the exact brute force (5 real rows only)
    exact = ((q[:5, None] - vecs.reshape(-1, dim)[None]) ** 2).sum(-1)
    want = np.argsort(exact, 1)[:, :5]
    for r in range(5):
        assert set(i_pad[r].tolist()) == set(want[r].tolist()), r


def test_qcap_overflow_is_reported_not_swallowed(tiny_serving):
    """Bugfix regression: a skewed workload (every query probes every
    partition, q_cap sized for the mean) must REPORT its dropped probes."""
    store, params, q, vecs = tiny_serving
    mesh = make_test_mesh()
    b, cap, dim = vecs.shape
    nq = len(q)
    cfg = LiraSystemConfig(arch="t", dim=dim, n_partitions=b, capacity=cap,
                           k=5, nprobe_max=b, q_cap_factor=0.25)
    eng = LiraEngine(cfg=cfg, params=params, store=store, mesh=mesh, sigma=-1.0)
    res = eng.search(q)
    d, i, npb, overflow = res.dists, res.ids, res.nprobe_eff, res.overflow
    # σ=-1: nq·b probes requested, q_cap = nq·b/b · 0.25 per partition kept
    q_cap = max(8, int(nq * b / b * 0.25))
    assert overflow == (nq - q_cap) * b > 0
    assert (npb == b).all()  # nprobe_eff still reports requested probes
    # the same workload with enough slack reports zero
    cfg_ok = dataclasses.replace(cfg, q_cap_factor=float(nq))
    eng_ok = LiraEngine(cfg=cfg_ok, params=params, store=store, mesh=mesh,
                        sigma=-1.0)
    overflow_ok = eng_ok.search(q).overflow
    assert overflow_ok == 0
