"""Pure-numpy twin of serving/scan.py — the third implementation in the
parity triangle (Pallas kernel vs jnp ref vs numpy). Loops per (partition,
slot) with float64 accumulation: slow, obviously correct, shared by the unit
parity tests and the bench smoke."""
import numpy as np


def scan_np(qbuf, q_pad, vecs, ids, k, lut_pad=None, codes=None, rk=None,
            cterm=None, off=None):
    """Numpy mirror of scan.run: ([b_loc, q_cap, k] dists, ids).

    Same contract: ``qbuf`` slots equal to ``q_row`` are empty (their output
    rows are unspecified — compare only occupied slots), ids < 0 are padding,
    and the quantized path shortlists ``rk`` slots by ADC before the exact
    rerank. Distances accumulate in float64 — set-level comparisons only.
    """
    b_loc, q_cap = qbuf.shape
    q_row = q_pad.shape[0] - 1
    quantized = lut_pad is not None
    out_d = np.full((b_loc, q_cap, k), np.inf, np.float64)
    out_i = np.full((b_loc, q_cap, k), -1, np.int32)
    for b in range(b_loc):
        valid = ids[b] >= 0
        for s in range(q_cap):
            qi = int(qbuf[b, s])
            if qi >= q_row:
                continue  # empty slot
            qv = q_pad[qi].astype(np.float64)
            if quantized:
                ad = lut_pad[qi][np.arange(codes.shape[-1]),
                                 codes[b].astype(np.int64)].sum(-1).astype(np.float64)
                if cterm is not None:
                    ad = ad + off[b, qi] + cterm[b].astype(np.float64)
                ad = np.where(valid, ad, np.inf)
                sl = np.argsort(ad, kind="stable")[:rk]
                cand = vecs[b][sl].astype(np.float64)
                cid = ids[b][sl]
                d2 = ((qv[None, :] - cand) ** 2).sum(-1)
                d2 = np.where(cid >= 0, d2, np.inf)
            else:
                d2 = ((qv[None, :] - vecs[b].astype(np.float64)) ** 2).sum(-1)
                d2 = np.where(valid, d2, np.inf)
                cid = ids[b]
            top = np.argsort(d2, kind="stable")[:k]
            out_d[b, s, : len(top)] = d2[top]
            out_i[b, s, : len(top)] = np.where(np.isfinite(d2[top]), cid[top], -1)
    return out_d, out_i
