"""Serving front-end scheduler tests (ISSUE 6) — all wall-clock-free.

Every test drives the scheduler with an injectable FakeClock: deadlines fire
because the test advances time, never because anything slept. Covered:
size-triggered flush, deadline-triggered flush (incl. per-request
deadline_ms), pow2 bucket rounding of the size trigger, incompatible-request
splitting (different k/σ/tier and alias coalescing), admission-control
shedding with priority displacement, telemetry quantiles/QPS, and the
acceptance gate: coalesced-batch results bit-identical to solo
``engine.search`` calls across {f32, pq, residual_pq} × {ref, interpret}.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FrontendConfig, LiraSystemConfig
from repro.core import probing
from repro.data import make_vector_dataset
from repro.launch.mesh import make_test_mesh
from repro.serving import (FakeClock, LiraEngine, SearchRequest,
                           ServingFrontend, simulate_open_loop)
from repro.serving.engine import make_serve_step
from repro.serving.quantized import build_quantized_store


@pytest.fixture(scope="module")
def tiny_engine():
    """Direct-store engine (no build pass): cheap enough that every scheduler
    test gets a fresh frontend over a shared engine + query pool."""
    host = np.random.default_rng(5)
    b, cap, dim = 4, 48, 16
    vecs = host.normal(0, 1, (b, cap, dim)).astype(np.float32)
    ids = np.arange(b * cap, dtype=np.int32).reshape(b, cap)
    store = {"centroids": jnp.asarray(vecs.mean(1)),
             "vectors": jnp.asarray(vecs), "ids": jnp.asarray(ids)}
    params = probing.init(jax.random.PRNGKey(0),
                          probing.ProbingConfig(dim=dim, n_partitions=b))
    cfg = LiraSystemConfig(arch="t", dim=dim, n_partitions=b, capacity=cap,
                           k=5, nprobe_max=b)
    eng = LiraEngine(cfg=cfg, params=params, store=store,
                     mesh=make_test_mesh(), sigma=-1.0)
    q = host.normal(0, 1, (64, dim)).astype(np.float32)
    return eng, q


def _frontend(eng, **cfg_kw):
    clock = FakeClock()
    defaults = dict(max_batch=8, max_wait_ms=2.0, max_queue=16)
    defaults.update(cfg_kw)
    fe = ServingFrontend(eng, FrontendConfig(**defaults), clock=clock)
    return fe, clock


# ------------------------------------------------------------------ flushes

def test_size_triggered_flush(tiny_engine):
    eng, q = tiny_engine
    fe, clock = _frontend(eng, max_batch=8)
    pends = [fe.submit(SearchRequest(queries=q[i])) for i in range(8)]
    # the 8th submit crossed max_batch: everything served, clock never moved
    assert all(p.done() for p in pends)
    assert clock() == 0.0
    assert fe.stats().batches == 1
    for p in pends:
        assert p.result().stats.batch_size == 8
        assert p.result().stats.queue_ms == 0.0


def test_deadline_triggered_flush(tiny_engine):
    eng, q = tiny_engine
    fe, clock = _frontend(eng, max_wait_ms=2.0)
    pends = [fe.submit(SearchRequest(queries=q[i])) for i in range(3)]
    assert not any(p.done() for p in pends)
    clock.advance(1.9e-3)
    assert fe.poll() == 0                   # deadline not reached yet
    assert fe.next_deadline() == pytest.approx(2.0e-3)
    clock.advance(0.2e-3)
    assert fe.poll() == 1                   # one coalesced serve call
    assert all(p.done() for p in pends)
    res = pends[0].result()
    assert res.stats.batch_size == 3
    assert res.stats.queue_ms == pytest.approx(2.1)


def test_per_request_deadline_tightens_window(tiny_engine):
    """deadline_ms is an SLO: the flush window becomes min(max_wait, SLO) —
    an urgent request pulls its group's flush forward, but a lax SLO never
    stretches the batching window beyond max_wait_ms."""
    eng, q = tiny_engine
    fe, clock = _frontend(eng, max_wait_ms=5.0)
    slow = fe.submit(SearchRequest(queries=q[0]))
    lax = fe.submit(SearchRequest(queries=q[2], deadline_ms=50.0))
    assert lax.flush_by == pytest.approx(5e-3)     # min() caps at max_wait
    fast = fe.submit(SearchRequest(queries=q[1], deadline_ms=0.5))
    assert fe.next_deadline() == pytest.approx(0.5e-3)
    clock.advance(0.6e-3)
    fe.poll()
    # the urgent deadline flushed its GROUP: all compatible requests rode
    # the same batch rather than splitting traffic
    assert fast.done() and slow.done() and lax.done()
    assert fast.result().stats.batch_size == 3


def test_result_demands_flush(tiny_engine):
    """A caller blocking on result() is itself a deadline — the group is
    flushed early instead of deadlocking a never-polled queue."""
    eng, q = tiny_engine
    fe, _ = _frontend(eng)
    p0 = fe.submit(SearchRequest(queries=q[0]))
    p1 = fe.submit(SearchRequest(queries=q[1]))
    assert not p0.done()
    res = p0.result()
    assert res.stats.batch_size == 2        # coalesced with the waiting peer
    assert p1.done()
    assert fe.depth() == 0


def test_allow_batching_false_bypasses_queue(tiny_engine):
    eng, q = tiny_engine
    fe, _ = _frontend(eng)
    queued = fe.submit(SearchRequest(queries=q[0]))
    solo = fe.submit(SearchRequest(queries=q[1], allow_batching=False))
    assert solo.done() and not queued.done()     # queue untouched
    assert solo.result().stats.batch_size == 1
    assert fe.depth() == 1


def test_bypass_request_with_expired_deadline_is_shed(tiny_engine):
    """allow_batching=False must not skip the dead-on-arrival check: a bypass
    request whose explicit deadline_ms already passed sheds with reason doa,
    exactly like the queued path — serving provably-late traffic burns drain
    capacity either way."""
    eng, q = tiny_engine
    fe, clock = _frontend(eng)
    clock.advance(1.0)
    doa = fe.submit(SearchRequest(queries=q[0], deadline_ms=1.0,
                                  allow_batching=False), t_arrival=0.0)
    assert doa.done()
    res = doa.result()
    assert res.stats.shed and res.stats.batch_size == 0
    # a live deadline still bypasses straight to a solo batch
    live = fe.submit(SearchRequest(queries=q[1], deadline_ms=1e4,
                                   allow_batching=False))
    assert live.done() and not live.result().stats.shed
    assert live.result().stats.batch_size == 1
    assert fe.depth() == 0


# ---------------------------------------------------------- bucket rounding

def test_size_trigger_rounds_into_jit_buckets(tiny_engine):
    """max_batch rounds up to the engine's pow2 jit-cache bucket, so size
    flushes always land on a compiled step with zero padding waste."""
    eng, q = tiny_engine
    fe, _ = _frontend(eng, max_batch=5)
    assert fe.max_batch == eng._batch_bucket(5) == 8
    pends = [fe.submit(SearchRequest(queries=q[i])) for i in range(8)]
    assert all(p.done() for p in pends)
    assert pends[0].result().stats.bucket == 8


def test_deadline_flush_bucket_matches_engine(tiny_engine):
    eng, q = tiny_engine
    fe, clock = _frontend(eng)
    pends = [fe.submit(SearchRequest(queries=q[i])) for i in range(3)]
    clock.advance(5e-3)
    fe.poll()
    # a 3-row deadline flush serves through the engine's 8-bucket
    assert pends[0].result().stats.bucket == eng._batch_bucket(3) == 8


# ----------------------------------------------------------- group splitting

def test_incompatible_requests_split_into_groups(tiny_engine):
    eng, q = tiny_engine
    fe, clock = _frontend(eng)
    a = fe.submit(SearchRequest(queries=q[0]))                  # defaults
    b = fe.submit(SearchRequest(queries=q[1], k=3))             # different k
    c = fe.submit(SearchRequest(queries=q[2], sigma=0.9))       # different σ
    d = fe.submit(SearchRequest(queries=q[3], tier="f32"))      # same (default)
    assert len(fe._groups) == 3
    clock.advance(5e-3)
    assert fe.poll() == 3                   # one serve call per group
    assert a.result().stats.batch_size == 2 and d.result().stats.batch_size == 2
    assert b.result().stats.batch_size == 1 and b.result().dists.shape[1] == 3
    assert c.result().stats.batch_size == 1
    assert c.result().stats.sigma == pytest.approx(0.9)


def test_alias_and_default_requests_coalesce(tiny_engine):
    """Tier aliases, impl="auto" and None must land in one group — they hit
    the same compiled step (mirrors serve_fn's cache-key normalization)."""
    eng, q = tiny_engine
    fe, _ = _frontend(eng)
    fe.submit(SearchRequest(queries=q[0]))
    fe.submit(SearchRequest(queries=q[1], tier="exact"))        # alias of f32
    fe.submit(SearchRequest(queries=q[2], tier="f32", impl="auto"))
    assert len(fe._groups) == 1


# ------------------------------------------------------- admission control

def test_admission_control_sheds_beyond_max_queue(tiny_engine):
    eng, q = tiny_engine
    fe, clock = _frontend(eng, max_queue=2, max_batch=64)
    admitted = [fe.submit(SearchRequest(queries=q[i])) for i in range(2)]
    shed = [fe.submit(SearchRequest(queries=q[2 + i])) for i in range(3)]
    for p in shed:                          # resolved immediately, marked shed
        assert p.done()
        res = p.result()
        assert res.stats.shed and res.stats.batch_size == 0
        assert (res.ids == -1).all() and not np.isfinite(res.dists).any()
        assert (res.nprobe_eff == 0).all()
    stats = fe.stats()
    assert stats.shed == 3 and stats.depth == 2
    clock.advance(5e-3)
    fe.poll()
    for p in admitted:                      # admitted traffic still correct
        assert not p.result().stats.shed
        assert p.result().stats.batch_size == 2
    assert fe.stats().served == 2


def test_priority_displaces_lower_priority_queued(tiny_engine):
    eng, q = tiny_engine
    fe, clock = _frontend(eng, max_queue=1, max_batch=64)
    low = fe.submit(SearchRequest(queries=q[0], priority=0))
    high = fe.submit(SearchRequest(queries=q[1], priority=1))
    # the queued low-priority request was shed to admit the newcomer
    assert low.done() and low.result().stats.shed
    assert not high.done()
    # an equal-priority newcomer is shed itself (no churn on ties)
    equal = fe.submit(SearchRequest(queries=q[2], priority=1))
    assert equal.done() and equal.result().stats.shed
    clock.advance(5e-3)
    fe.poll()
    assert not high.result().stats.shed


def test_priority_orders_oversized_group_flush(tiny_engine):
    """A group larger than max_batch rows (multi-row requests) flushes as
    several serve calls, higher-priority requests riding the first one."""
    eng, q = tiny_engine
    fe, _ = _frontend(eng, max_queue=64, max_batch=4)
    assert fe.max_batch == 8                # 4 rounds up to the 8-bucket
    low = fe.submit(SearchRequest(queries=q[:6], priority=0))   # 6 rows
    high = fe.submit(SearchRequest(queries=q[6:10], priority=1))  # 4 rows
    # 10 rows ≥ 8 triggered the flush: high went first and low no longer fit
    assert fe.stats().batches == 2 and fe.depth() == 0
    assert high.result().stats.batch_size == 4
    assert low.result().stats.batch_size == 6
    # multi-row scatter slices the right rows back per request
    for j in range(6):
        solo = eng.search(SearchRequest(queries=q[j:j + 1]))
        np.testing.assert_array_equal(low.result().dists[j], solo.dists[0])


# ------------------------------------------------------------- telemetry

def test_frontend_stats_quantiles_and_qps(tiny_engine):
    eng, q = tiny_engine
    fe, clock = _frontend(eng, max_wait_ms=1.0, max_batch=64)
    for wave in range(4):                   # 4 deadline flushes, 2 reqs each
        fe.submit(SearchRequest(queries=q[2 * wave]))
        fe.submit(SearchRequest(queries=q[2 * wave + 1]))
        clock.advance(1.1e-3)
        fe.poll()
    stats = fe.stats()
    assert stats.submitted == stats.served == 8
    assert stats.batches == 4 and stats.mean_batch == 2.0
    # every request waited exactly 1.1 virtual ms — degenerate quantiles
    assert stats.p50_ms == pytest.approx(1.1)
    assert stats.p99_ms == pytest.approx(1.1)
    # 8 queries over the 4.4ms span from first submit to last completion
    assert stats.qps == pytest.approx(8 / 4.4e-3, rel=1e-6)
    assert stats.depth == 0 and stats.shed == 0


def test_charged_service_time_lands_in_latency(tiny_engine):
    """charge_service couples measured engine wall time onto the virtual
    clock — latency telemetry then reflects real serve cost."""
    eng, q = tiny_engine
    clock = FakeClock()
    fe = ServingFrontend(
        eng, FrontendConfig(max_batch=8, max_wait_ms=2.0), clock=clock,
        charge_service=True)
    pends = [fe.submit(SearchRequest(queries=q[i])) for i in range(8)]
    assert clock() > 0.0                    # the serve call charged the clock
    assert pends[0].result().stats.queue_ms == 0.0
    assert fe.stats().p50_ms > 0.0


def test_charge_service_requires_advanceable_clock(tiny_engine):
    eng, _ = tiny_engine
    import time

    with pytest.raises(TypeError, match="advance"):
        ServingFrontend(eng, charge_service=True, clock=time.monotonic)
    fe = ServingFrontend(eng)               # wall clock, no charging: fine
    with pytest.raises(TypeError, match="advanceable"):
        simulate_open_loop(fe, np.zeros((1, 16), np.float32),
                           rate_qps=1.0, n_requests=1)


def test_fake_clock_monotonic():
    clock = FakeClock(10.0)
    assert clock() == 10.0
    clock.advance(0.5)
    assert clock() == 10.5
    with pytest.raises(ValueError, match="backwards"):
        clock.advance(-1.0)


def test_backdated_arrival_expired_deadline_is_shed(tiny_engine):
    """A backdated submit whose EXPLICIT deadline already passed is shed
    outright (dead on arrival) — serving provably-late traffic would burn
    drain capacity. Without an explicit deadline_ms there is no SLO to blow:
    a stale backdated submit still queues (merely late), and an on-time one
    queues with its true arrival driving queue_ms."""
    eng, q = tiny_engine
    fe, clock = _frontend(eng, max_wait_ms=2.0)
    clock.advance(10e-3)
    dead = fe.submit(SearchRequest(queries=q[0], deadline_ms=5.0),
                     t_arrival=0.0)
    assert dead.done() and dead.result().stats.shed
    # same staleness, no explicit SLO → admitted, not shed
    stale = fe.submit(SearchRequest(queries=q[2]), t_arrival=0.0)
    assert not stale.done()
    live = fe.submit(SearchRequest(queries=q[1]), t_arrival=9e-3)
    assert not live.done()
    assert live.flush_by == pytest.approx(11e-3)
    # the stale request's window expired long ago: next poll flushes both
    assert fe.poll() == 1
    assert stale.done() and live.done()
    # queue wait measured from the true arrival, not the submit call
    assert live.result().stats.queue_ms == pytest.approx(1.0)
    assert stale.result().stats.queue_ms == pytest.approx(10.0)


# ------------------------------------------------------------ open loop sim

def test_open_loop_low_load_sheds_nothing(tiny_engine):
    eng, q = tiny_engine
    clock = FakeClock()
    fe = ServingFrontend(eng, FrontendConfig(max_batch=8, max_wait_ms=2.0,
                                             max_queue=32), clock=clock)
    stats, pendings = simulate_open_loop(fe, q, rate_qps=2000.0, n_requests=40)
    assert stats.shed == 0 and stats.served == 40
    assert all(p.done() for p in pendings)
    # no service charging: every latency is pure queue wait ≤ the window
    assert stats.p99_ms <= 2.0 + 1e-9
    assert stats.depth == 0


def test_open_loop_overload_sheds_and_serves_rest(tiny_engine):
    eng, q = tiny_engine
    clock = FakeClock()
    fe = ServingFrontend(
        eng, FrontendConfig(max_batch=64, max_wait_ms=50.0, max_queue=8),
        clock=clock)
    # 30 arrivals inside one 50ms window with an 8-deep queue: exactly the
    # overflow beyond max_queue is shed, everything admitted still answers
    stats, pendings = simulate_open_loop(fe, q, rate_qps=10_000.0,
                                         n_requests=30)
    assert stats.shed > 0 and stats.served == 30 - stats.shed
    served = [p for p in pendings if not p.result().stats.shed]
    assert len(served) == stats.served
    for p in served:
        assert np.isfinite(p.result().dists[:, 0]).all()


# --------------------------------------------------- batched-vs-solo parity

N, NQ, DIM, B = 1200, 12, 16, 8


@pytest.fixture(scope="module")
def parity_engines():
    """One η>0 build serving all three tiers (pq engine + derived residual
    engine), mirroring tests/test_scan_paths.py's e2e fixture."""
    ds = make_vector_dataset("clustered", n=N, n_queries=NQ, dim=DIM,
                             n_modes=B, center_scale=8.0, spread=0.5,
                             boundary_frac=0.05, noise_frac=0.0, seed=33)
    mesh = make_test_mesh()
    eng = LiraEngine.build(mesh, ds.base, n_partitions=B, k=10, eta=0.03,
                           train_frac=0.5, epochs=2, nprobe_max=B,
                           tier="pq", pq_m=4, pq_ks=32, rerank=4)
    qs = build_quantized_store(jax.random.PRNGKey(9), eng.store["vectors"],
                               eng.store["ids"], m=4, ks=eng.cfg.pq_ks,
                               residual=True, centroids=eng.store["centroids"])
    store_r = {**eng.store, "codes": qs.codes, "codebooks": qs.codebooks,
               "cterm": qs.cterm}
    eng_r = LiraEngine(cfg=dataclasses.replace(eng.cfg, tier="residual_pq"),
                       params=eng.params, store=store_r, mesh=mesh)
    return eng, eng_r, ds


@pytest.mark.parametrize("impl", ["ref", "interpret"])
@pytest.mark.parametrize("tier", ["f32", "pq", "residual_pq"])
def test_coalesced_batch_bit_identical_to_solo(parity_engines, tier, impl):
    """The acceptance gate: results scattered out of a front-end-coalesced
    batch must be bit-identical to per-request solo ``engine.search`` calls —
    batching is an optimization, never a semantics change. Note the batch
    serves through a different jit bucket (12→16) and q_cap than the solo
    calls (1→8), so this pins row independence of the whole serve step."""
    eng, eng_r, ds = parity_engines
    engine = eng_r if tier == "residual_pq" else eng
    solo = [engine.search(SearchRequest(queries=ds.queries[i:i + 1],
                                        sigma=0.3, tier=tier, impl=impl))
            for i in range(NQ)]
    fe = ServingFrontend(engine, FrontendConfig(max_batch=16, max_wait_ms=1.0,
                                                max_queue=64),
                         clock=FakeClock())
    pends = [fe.submit(SearchRequest(queries=ds.queries[i], sigma=0.3,
                                     tier=tier, impl=impl))
             for i in range(NQ)]
    fe.drain()
    assert fe.stats().batches == 1          # one coalesced serve call
    for i, p in enumerate(pends):
        res = p.result()
        assert res.stats.batch_size == NQ and not res.stats.shed
        np.testing.assert_array_equal(res.dists, solo[i].dists, err_msg=str(i))
        np.testing.assert_array_equal(res.ids, solo[i].ids, err_msg=str(i))
        np.testing.assert_array_equal(res.nprobe_eff, solo[i].nprobe_eff)
        assert solo[i].overflow == 0        # parity precondition: no drops


def test_search_one_matches_search_with_and_without_frontend(parity_engines):
    eng, _, ds = parity_engines
    want = eng.search(SearchRequest(queries=ds.queries[:1], sigma=0.3))
    eng.frontend = None
    direct = eng.search_one(SearchRequest(queries=ds.queries[0], sigma=0.3))
    np.testing.assert_array_equal(direct.dists, want.dists)
    np.testing.assert_array_equal(direct.ids, want.ids)
    try:
        fe = eng.attach_frontend(FrontendConfig(max_batch=16), clock=FakeClock())
        routed = eng.search_one(SearchRequest(queries=ds.queries[0], sigma=0.3))
        assert fe.stats().submitted == 1    # went through the queue
        np.testing.assert_array_equal(routed.dists, want.dists)
        np.testing.assert_array_equal(routed.ids, want.ids)
        assert routed.stats.batch_size == 1
    finally:
        eng.frontend = None                 # module-scoped engine: detach


def test_search_one_rejects_batches_and_raw_arrays(parity_engines):
    eng, _, ds = parity_engines
    with pytest.raises(TypeError, match="SearchRequest"):
        eng.search_one(ds.queries[0])
    with pytest.raises(ValueError, match="exactly one query"):
        eng.search_one(SearchRequest(queries=ds.queries[:2]))


def test_unpadded_serve_step_matches_frontend_rows(tiny_engine):
    """Belt-and-braces: a frontend-served row equals the raw unjitted serve
    step's row for the same batch (ties the front-end scatter to the
    shard_map path, not just to engine.search)."""
    eng, q = tiny_engine
    fe, _ = _frontend(eng, max_batch=8)
    pends = [fe.submit(SearchRequest(queries=q[i])) for i in range(8)]
    fn = make_serve_step(eng.cfg, eng.mesh, 8, sigma=-1.0)
    with eng.mesh:
        d, i, _, _ = jax.jit(fn)(eng.params, eng.store, jnp.asarray(q[:8]))
    for r, p in enumerate(pends):
        np.testing.assert_array_equal(p.result().dists[0], np.asarray(d)[r])
        np.testing.assert_array_equal(p.result().ids[0], np.asarray(i)[r])
