"""Tier-registry redesign tests (ISSUE 5).

Covers the acceptance criteria of the typed serving surface:
  * extensibility: a toy tier registered via serving/tiers.py ONLY (no
    engine.py edits) builds and serves end-to-end;
  * the dormant ``store_dtype`` knob wired end-to-end — a bfloat16 f32-tier
    store serves with recall parity vs float32;
  * adaptive q_cap: ``auto_q_cap`` grows ``q_cap_factor`` until the overflow
    counter returns to zero, recompiling on the way;
  * engine persistence: ``LiraEngine.save``/``load`` round-trips params +
    store + config through repro.ckpt, across tiers and store dtypes;
  * registry hygiene: specs/pspecs delegation, alias resolution, fail-fast on
    unknown tiers.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LiraSystemConfig
from repro.core import ground_truth as gt
from repro.core.metrics import recall_at_k
from repro.core import probing
from repro.data import make_vector_dataset
from repro.launch.mesh import make_test_mesh
from repro.models.api import sds
from repro.serving import BuildConfig, LiraEngine, SearchRequest, tiers
from repro.serving.engine import store_specs, store_pspecs


@pytest.fixture(scope="module")
def dataset():
    return make_vector_dataset(n=2000, n_queries=32, dim=16, n_modes=8, seed=17)


@pytest.fixture(scope="module")
def f32_engine(dataset):
    return LiraEngine.build(make_test_mesh(), dataset.base, BuildConfig(
        n_partitions=8, k=10, eta=0.03, train_frac=0.4, epochs=2,
        nprobe_max=8))


@pytest.fixture(scope="module")
def gti(dataset):
    _, i = gt.exact_knn(dataset.queries, dataset.base, 10)
    return i


# ------------------------------------------------------------ registry

def test_registry_resolves_names_and_aliases():
    assert tiers.resolve("f32").name == "f32"
    assert tiers.resolve("quantized").name == "pq"
    assert tiers.resolve("residual").name == "residual_pq"
    t = tiers.resolve("pq")
    assert tiers.resolve(t) is t  # already-resolved passthrough
    assert set(tiers.names()) >= {"f32", "pq", "residual_pq"}


def test_config_tier_aliases_match_registry():
    """configs/base.py cannot import the registry (cycle), so it carries its
    own builtin alias map — this pins the two together: every registered
    builtin alias canonicalizes identically in LiraSystemConfig, keeping the
    derived quantized/residual_pq booleans honest for alias spellings."""
    from repro.configs.base import _TIER_ALIASES

    for alias, canonical in _TIER_ALIASES.items():
        assert tiers.resolve(alias).name == canonical
    for name, tier in tiers._REGISTRY.items():
        cfg = LiraSystemConfig(arch="t", dim=16, n_partitions=4, capacity=32,
                               k=5, nprobe_max=4, tier=name)
        assert cfg.tier == tier.name, name
        assert cfg.quantized == (tier.name in ("pq", "residual_pq")), name
        assert cfg.residual_pq == (tier.name == "residual_pq"), name


def test_unknown_tier_fails_fast(f32_engine):
    with pytest.raises(ValueError, match="unknown serving tier"):
        tiers.resolve("int4")
    with pytest.raises(ValueError, match="unknown serving tier"):
        f32_engine.search(SearchRequest(queries=np.zeros((4, 16), np.float32),
                                        tier="int4"))


def test_store_specs_delegate_to_tier():
    cfg = LiraSystemConfig(arch="t", dim=16, n_partitions=4, capacity=32, k=5,
                           nprobe_max=4, tier="residual_pq", pq_m=4, pq_ks=16)
    specs = store_specs(cfg)
    assert list(specs) == ["centroids", "vectors", "ids", "occupancy",
                           "codes", "codebooks", "cterm"]
    sp = store_pspecs(None, cfg)
    assert set(sp) == set(specs)
    cfg_f = dataclasses.replace(cfg, tier="f32")
    assert list(store_specs(cfg_f)) == ["centroids", "vectors", "ids",
                                        "occupancy"]
    # per-slot planes (what mutations move together) exclude the replicated
    # operands — codebooks ride per subspace, centroids per partition
    assert tiers.resolve("residual_pq").slot_fields(cfg) == (
        "vectors", "ids", "occupancy", "codes", "cterm")
    assert tiers.resolve("f32").slot_fields(cfg_f) == (
        "vectors", "ids", "occupancy")


def test_missing_store_fields_rejected(f32_engine):
    # an f32-built engine has no codes plane: serving the pq tier must fail
    # with the field list, not a shape error deep inside shard_map
    with pytest.raises(ValueError, match="codes"):
        f32_engine.search(np.zeros((4, 16), np.float32), tier="pq")


def test_pq_tier_refuses_residual_codes(dataset):
    """Residual-built codes encode x − centroid; the shared-LUT-only pq tier
    would silently rank by distance-to-residual, so the request is rejected
    (the fields exist — presence checks can't catch this)."""
    eng = LiraEngine.build(make_test_mesh(), dataset.base, BuildConfig(
        n_partitions=8, k=10, eta=0.0, train_frac=0.4, epochs=1,
        nprobe_max=8, tier="residual_pq", pq_m=4, pq_ks=16))
    with pytest.raises(ValueError, match="residual-encoded"):
        eng.search(dataset.queries, tier="pq")
    # the two correct servable tiers still work
    eng.search(dataset.queries, tier="residual_pq")
    eng.search(dataset.queries, tier="f32")


# ------------------------------------------- extensibility (acceptance gate)

class _Bf16ToyTier(tiers.F32Tier):
    """Toy tier for the zero-engine-edits gate: the f32 scan over a bfloat16
    vector plane, declared entirely through the registry interface."""

    name = "bf16_toy"
    aliases = ()

    def store_specs(self, cfg):
        specs = super().store_specs(cfg)
        specs["vectors"] = sds(specs["vectors"].shape, jnp.bfloat16)
        return specs

    def build_store(self, rng, cfg, store_h):
        store, cfg = super().build_store(rng, cfg, store_h)
        store["vectors"] = store["vectors"].astype(jnp.bfloat16)
        return store, cfg


@pytest.fixture()
def toy_tier():
    tiers.register(_Bf16ToyTier)
    yield
    tiers._REGISTRY.pop("bf16_toy", None)


def test_toy_tier_serves_without_engine_edits(dataset, gti, toy_tier):
    """The ISSUE 5 acceptance gate: registering a tier is sufficient for
    build + serve — LiraEngine/make_serve_step never branch on it."""
    eng = LiraEngine.build(make_test_mesh(), dataset.base, BuildConfig(
        n_partitions=8, k=10, eta=0.03, train_frac=0.4, epochs=2,
        nprobe_max=8, tier="bf16_toy"))
    assert eng.cfg.tier == "bf16_toy"
    assert eng.store["vectors"].dtype == jnp.bfloat16
    res = eng.search(SearchRequest(queries=dataset.queries, sigma=-1.0))
    assert res.stats.tier == "bf16_toy"
    assert recall_at_k(res.ids, gti, 10) >= 0.95  # bf16 rounding only
    # legacy boolean aliases derive sanely for tiers the config cannot know
    assert not eng.cfg.quantized and not eng.cfg.residual_pq


# --------------------------------------------------- store_dtype end-to-end

def test_bf16_store_dtype_recall_parity(dataset, f32_engine, gti):
    """Satellite: BuildConfig(store_dtype="bfloat16") halves the scan-read
    plane; with probe-all σ the f32 engine is exact, and the bf16 one must
    stay within rounding distance of it."""
    eng16 = LiraEngine.build(make_test_mesh(), dataset.base, BuildConfig(
        n_partitions=8, k=10, eta=0.03, train_frac=0.4, epochs=2,
        nprobe_max=8, store_dtype="bfloat16"))
    assert eng16.cfg.tier == "f32"
    assert eng16.store["vectors"].dtype == jnp.bfloat16
    assert store_specs(eng16.cfg)["vectors"].dtype == jnp.bfloat16
    r32 = f32_engine.search(dataset.queries, sigma=-1.0)
    r16 = eng16.search(dataset.queries, sigma=-1.0)
    rec32 = recall_at_k(r32.ids, gti, 10)
    rec16 = recall_at_k(r16.ids, gti, 10)
    assert rec32 == pytest.approx(1.0, abs=1e-6)  # probe-all f32 is exact
    assert rec16 >= rec32 - 0.02, (rec16, rec32)
    # the store really is half the bytes
    assert (eng16.store["vectors"].dtype.itemsize * 2
            == np.dtype(np.float32).itemsize)


def test_bf16_store_parity_across_scan_impls(dataset):
    """ref and interpret kernels must agree bitwise on the bf16 store too —
    both paths upcast to f32 at the same point."""
    eng16 = LiraEngine.build(make_test_mesh(), dataset.base, BuildConfig(
        n_partitions=8, k=10, eta=0.0, train_frac=0.4, epochs=2,
        nprobe_max=8, store_dtype="bfloat16"))
    r_ref = eng16.search(dataset.queries, sigma=0.3, impl="ref")
    r_ker = eng16.search(dataset.queries, sigma=0.3, impl="interpret")
    np.testing.assert_array_equal(r_ref.dists, r_ker.dists)
    for r in range(len(dataset.queries)):
        fin = np.isfinite(r_ref.dists[r])
        assert set(r_ref.ids[r][fin].tolist()) == set(r_ker.ids[r][fin].tolist())


# ----------------------------------------------------------- adaptive q_cap

def _tiny_engine(auto_q_cap, q_cap_factor=0.25):
    host = np.random.default_rng(5)
    b, cap, dim = 4, 48, 16
    vecs = host.normal(0, 1, (b, cap, dim)).astype(np.float32)
    ids = np.arange(b * cap, dtype=np.int32).reshape(b, cap)
    store = {"centroids": jnp.asarray(vecs.mean(1)),
             "vectors": jnp.asarray(vecs), "ids": jnp.asarray(ids)}
    params = probing.init(jax.random.PRNGKey(0),
                          probing.ProbingConfig(dim=dim, n_partitions=b))
    cfg = LiraSystemConfig(arch="t", dim=dim, n_partitions=b, capacity=cap,
                           k=5, nprobe_max=b, q_cap_factor=q_cap_factor,
                           auto_q_cap=auto_q_cap)
    q = host.normal(0, 1, (32, dim)).astype(np.float32)
    return LiraEngine(cfg=cfg, params=params, store=store,
                      mesh=make_test_mesh(), sigma=-1.0), q


def test_auto_q_cap_grows_until_overflow_clears():
    """Satellite: with auto_q_cap the engine closes the loop on the overflow
    counter — q_cap_factor doubles after persistent overflow and the serve
    cache is dropped so the next call compiles wider dispatch buckets."""
    eng, q = _tiny_engine(auto_q_cap=True)
    overflows = []
    for _ in range(8):
        res = eng.search(q)  # σ=-1: every query probes every partition
        overflows.append(res.overflow)
        if res.overflow == 0:
            break
    assert overflows[0] > 0, "workload must overflow the starved q_cap"
    assert overflows[-1] == 0, overflows
    assert eng.cfg.q_cap_factor > 0.25
    # converged: the bumped factor serves the same workload without drops,
    # from the rebuilt cache
    res = eng.search(q)
    assert res.overflow == 0 and res.stats.cache_hit


def test_auto_q_cap_off_never_mutates_config():
    eng, q = _tiny_engine(auto_q_cap=False)
    for _ in range(3):
        res = eng.search(q)
        assert res.overflow > 0  # reported, untouched
    assert eng.cfg.q_cap_factor == 0.25


def test_auto_q_cap_result_parity_with_slack_engine():
    """The adaptive engine must converge to what a generously-provisioned
    engine returns on the same workload."""
    eng, q = _tiny_engine(auto_q_cap=True)
    eng_ok, _ = _tiny_engine(auto_q_cap=False, q_cap_factor=32.0)
    want = eng_ok.search(q)
    got = None
    for _ in range(8):
        got = eng.search(q)
        if got.overflow == 0:
            break
    np.testing.assert_array_equal(got.dists, want.dists)
    np.testing.assert_array_equal(got.ids, want.ids)


# ------------------------------------------------------------- persistence

@pytest.mark.parametrize("tier", ["residual_pq", "f32"])
def test_engine_save_load_roundtrip(dataset, tmp_path, tier):
    """Satellite: params + store + config survive repro.ckpt so indexes stop
    being rebuilt per process; the loaded engine serves identically."""
    eng = LiraEngine.build(make_test_mesh(), dataset.base, BuildConfig(
        n_partitions=8, k=10, eta=0.03, train_frac=0.4, epochs=2,
        nprobe_max=8, tier=tier, pq_m=4, pq_ks=32, rerank=4, sigma=0.35))
    eng.save(tmp_path / "engine")
    loaded = LiraEngine.load(tmp_path / "engine", make_test_mesh())
    assert loaded.cfg == eng.cfg
    assert loaded.sigma == eng.sigma
    assert set(loaded.store) == set(eng.store)
    for name in eng.store:
        np.testing.assert_array_equal(np.asarray(loaded.store[name]),
                                      np.asarray(eng.store[name]))
    want = eng.search(dataset.queries)
    got = loaded.search(dataset.queries)
    np.testing.assert_array_equal(want.dists, got.dists)
    np.testing.assert_array_equal(want.ids, got.ids)
    np.testing.assert_array_equal(want.nprobe_eff, got.nprobe_eff)
    assert want.overflow == got.overflow


def test_engine_save_load_restores_bf16_plane(dataset, tmp_path):
    """bfloat16 planes upcast to f32 on disk (npy has no bf16) and come back
    in the tier dtype with identical serving results."""
    eng = LiraEngine.build(make_test_mesh(), dataset.base, BuildConfig(
        n_partitions=8, k=10, eta=0.0, train_frac=0.4, epochs=1,
        nprobe_max=8, store_dtype="bfloat16"))
    eng.save(tmp_path / "e16")
    loaded = LiraEngine.load(tmp_path / "e16", make_test_mesh())
    assert loaded.store["vectors"].dtype == jnp.bfloat16
    want, got = eng.search(dataset.queries), loaded.search(dataset.queries)
    np.testing.assert_array_equal(want.dists, got.dists)
    np.testing.assert_array_equal(want.ids, got.ids)


def test_engine_load_missing_directory_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        LiraEngine.load(tmp_path / "nope", make_test_mesh())
    # a typo'd path must not leave an empty directory tree behind
    assert not (tmp_path / "nope").exists()
