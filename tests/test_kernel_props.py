"""Hypothesis property sweeps for the kernels. hypothesis is an optional dev
dep — importorskip makes a missing install skip this module instead of
breaking tier-1 collection."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")

import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from _dedup_oracle import naive_dedup_topk
from repro.kernels import ops


@settings(max_examples=20, deadline=None)
@given(
    qn=st.integers(1, 16),
    cn=st.integers(8, 128),
    d=st.integers(2, 64),
    k=st.integers(1, 8),
)
def test_l2_topk_properties(qn, cn, d, k):
    """Invariants: outputs sorted ascending, ids valid, dists non-negative,
    and top-1 equals exact argmin."""
    k = min(k, cn)
    rng = np.random.default_rng(qn + cn * 1000 + d)
    q = jnp.asarray(rng.normal(size=(qn, d)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(cn, d)).astype(np.float32))
    ids = jnp.asarray(np.arange(cn, dtype=np.int32))
    dd, ii = ops.l2_topk(q, c, ids, k, impl="ref")
    dd, ii = np.asarray(dd), np.asarray(ii)
    assert (np.diff(dd, axis=1) >= -1e-5).all()
    assert ((ii >= 0) & (ii < cn)).all()
    assert (dd >= -1e-4).all()
    exact = ((np.asarray(q)[:, None] - np.asarray(c)[None]) ** 2).sum(-1)
    np.testing.assert_array_equal(ii[:, 0], exact.argmin(1))


@settings(max_examples=40, deadline=None)
@given(
    qn=st.integers(1, 8),
    p=st.integers(1, 96),
    k=st.integers(1, 24),
    n_ids=st.integers(1, 48),
    frac_pad=st.floats(0.0, 0.6),
    frac_inf=st.floats(0.0, 0.6),
    impl=st.sampled_from(["ref", "interpret"]),
    seed=st.integers(0, 10**6),
)
def test_dedup_topk_matches_set_oracle(qn, p, k, n_ids, frac_pad, frac_inf, impl, seed):
    """Against a naive dict oracle across random replica rates (small n_ids →
    heavy id collisions), PAD_ID padding, and inf-masked distances."""
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, n_ids, (qn, p)).astype(np.int32)
    # per-row permutation of 0..p-1: all finite distances distinct, so the
    # (dist, id) order is unambiguous and the comparison is exact
    d = rng.permuted(np.tile(np.arange(p, dtype=np.float32), (qn, 1)), axis=1)
    ids[rng.random((qn, p)) < frac_pad] = -1
    d[rng.random((qn, p)) < frac_inf] = np.inf
    d0, i0 = naive_dedup_topk(d, ids, k)
    d1, i1 = ops.dedup_topk(jnp.asarray(d), jnp.asarray(ids), k, impl=impl)
    np.testing.assert_allclose(np.asarray(d1), d0, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(i1), i0)
