"""Substrate tests: optimizer, checkpointing, pipeline determinism, fault
tolerance (crash/restart), replica failover, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.utils.compat import make_mesh

from repro.ckpt import CheckpointManager
from repro.data.pipeline import PipelineSpec, TokenPipeline
from repro.distributed.fault import ReplicaRouter, StragglerMitigator
from repro.train import optimizer as opt
from repro.train.trainer import Trainer


def _quadratic_problem():
    """min ||w - target||² — closed-form checkable."""
    target = jnp.asarray(np.random.default_rng(0).normal(size=(8, 4)).astype(np.float32))

    def step_fn(state, batch):
        params, opt_state = state

        def loss(p):
            return jnp.sum((p["w"] - target) ** 2)

        l, g = jax.value_and_grad(loss)(params)
        tx = opt.adamw(1e-1)
        updates, opt_state = tx.update(g, opt_state, params)
        params = opt.apply_updates(params, updates)
        return (params, opt_state), {"loss": l}

    params = {"w": jnp.zeros((8, 4))}
    tx = opt.adamw(1e-1)
    return step_fn, (params, tx.init(params)), target


class _ConstPipeline:
    def batch_at(self, step):
        return {"x": np.zeros(1, np.float32)}


def test_adamw_converges():
    step_fn, state, target = _quadratic_problem()
    jstep = jax.jit(step_fn)
    for _ in range(300):
        state, m = jstep(state, None)
    np.testing.assert_allclose(np.asarray(state[0]["w"]), np.asarray(target), atol=1e-2)


def test_adamw_weight_decay_mask():
    tx = opt.adamw(1e-2, weight_decay=0.1)
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    state = tx.init(params)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    updates, _ = tx.update(zero_g, state, params)
    assert float(jnp.abs(updates["w"]).sum()) > 0    # 2-D decayed
    assert float(jnp.abs(updates["b"]).sum()) == 0   # 1-D not decayed


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = opt.clip_by_global_norm(g, 1.0)
    from repro.utils.tree import global_norm

    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    assert float(norm) == pytest.approx(100.0 * np.sqrt(10), rel=1e-5)


def test_checkpoint_roundtrip(tmp_path):
    cm = CheckpointManager(tmp_path, keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32), "b": {"c": jnp.ones(4, jnp.int32)}}
    cm.save(10, tree, extra={"note": "x"})
    cm.save(20, tree)
    cm.save(30, tree)
    assert cm.all_steps() == [20, 30]  # keep=2 GC'd step 10
    restored, step, extra = cm.restore(tree)
    assert step == 30
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))


def test_checkpoint_ignores_partial_writes(tmp_path):
    cm = CheckpointManager(tmp_path)
    tree = {"a": jnp.ones(3)}
    cm.save(1, tree)
    # simulate crash mid-save: orphan tmp dir + step dir without manifest
    (tmp_path / "step_0000000002.tmp").mkdir()
    (tmp_path / "step_0000000003").mkdir()
    assert cm.latest_step() == 1


def test_pipeline_deterministic_resume():
    spec = PipelineSpec(global_batch=8, seed=42)
    p1 = TokenPipeline(spec, seq_len=16, vocab=100)
    p2 = TokenPipeline(spec, seq_len=16, vocab=100)
    for step in (0, 5, 17):
        np.testing.assert_array_equal(p1.batch_at(step)["tokens"], p2.batch_at(step)["tokens"])
    assert not np.array_equal(p1.batch_at(0)["tokens"], p1.batch_at(1)["tokens"])


def test_trainer_crash_restart_is_exact(tmp_path):
    """Gold-standard fault-tolerance test: a run that crashes at step 7 and
    restarts must end bit-identical to an uninterrupted run."""
    step_fn, state0, _ = _quadratic_problem()

    t_gold = Trainer(step_fn, state0, _ConstPipeline(), ckpt_manager=None)
    gold_state, _ = t_gold.run(12)

    cm = CheckpointManager(tmp_path / "ck", keep=3)
    t1 = Trainer(step_fn, state0, _ConstPipeline(), ckpt_manager=cm, ckpt_every=5)
    with pytest.raises(RuntimeError, match="simulated failure"):
        t1.run(12, fail_at=7)
    # restart: auto-resumes from step 5 checkpoint, replays 6..12
    t2 = Trainer(step_fn, state0, _ConstPipeline(), ckpt_manager=cm, ckpt_every=5)
    assert t2.start_step == 5
    state2, _ = t2.run(12)
    np.testing.assert_array_equal(np.asarray(gold_state[0]["w"]), np.asarray(state2[0]["w"]))


def test_replica_failover_serves_everything():
    r = ReplicaRouter(4, seed=1)
    served = r.dispatch(100, fail_at=(30, 2))
    assert sum(served.values()) == 100
    assert served[2] < 100 and not r.replicas[2].healthy
    assert r.requeued >= 1


def test_straggler_hedging_cuts_tail():
    rng = np.random.default_rng(0)
    r = ReplicaRouter(4, seed=0)
    r.replicas[3].latency_scale = 20.0  # one bad node
    mit = StragglerMitigator(r, hedge_factor=3.0)
    lats = [mit.serve(float(rng.lognormal(0, 0.2))) for _ in range(400)]
    p99 = np.quantile(lats, 0.99)
    assert mit.hedges > 0
    assert p99 < 20.0  # un-hedged p99 would be ≈ 20× base latency


def test_grad_compression_error_feedback():
    """Compressed psum over pod axis: single-step is lossy, but error feedback
    makes the RUNNING SUM converge to the true gradient sum."""
    from repro.train.grad_compress import compressed_psum_pod, init_error_buffers

    mesh = make_mesh((1, 1, 1), ("pod", "data", "model"))
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64,)).astype(np.float32))}
    err = init_error_buffers(g)
    total = jnp.zeros((64,))
    with mesh:
        for step in range(20):
            out, err = compressed_psum_pod(g, err, mesh)
            total = total + out["w"]
    # after N steps the accumulated compressed sum ≈ N * g (error feedback)
    np.testing.assert_allclose(np.asarray(total) / 20, np.asarray(g["w"]), atol=0.02)


def test_neighbor_sampler_fanout():
    from repro.data.graph import NeighborSampler
    from repro.data.synthetic import make_geometric_graph

    rng = np.random.default_rng(0)
    pos, feat, ei = make_geometric_graph(rng, 200, 8, 4)
    s = NeighborSampler(200, ei, fanout=(5, 3), seed=0)
    nodes, edges = s.sample(step=0, batch_nodes=16)
    assert len(nodes) <= 16 * (1 + 5 + 15) and len(nodes) > 16
    assert edges.shape[0] == 2
    # determinism
    nodes2, edges2 = s.sample(step=0, batch_nodes=16)
    np.testing.assert_array_equal(nodes, nodes2)
