"""Regression tests for the serving engine's replica dedup (deterministic, no
hypothesis): with redundancy (η>0) the same id lives in several partitions, and
before the dedup_topk merge LiraEngine.search returned it multiple times,
silently inflating recall@k."""
import jax
import numpy as np
import pytest

from repro.configs.base import LiraSystemConfig
from repro.core import build_store, probing
from repro.core import ground_truth as gt
from repro.core import retrieval as ret
from repro.core.redundancy import RedundancyPlan, replica_rows
from repro.launch.mesh import make_test_mesh
from repro.serving.engine import LiraEngine


@pytest.fixture(scope="module")
def replicated_engine():
    """Engine over a store with a 25% replica rate built through the real
    redundancy machinery (RedundancyPlan → replica_rows → build_store)."""
    b, dim, n = 4, 16, 512
    host = np.random.default_rng(0)
    x = host.normal(size=(n, dim)).astype(np.float32)
    assign = (np.arange(n) % b).astype(np.int32)
    cents = np.stack([x[assign == p].mean(0) for p in range(b)]).astype(np.float32)
    ids = np.arange(n, dtype=np.int32)
    picked = np.sort(host.choice(n, n // 4, replace=False))
    targets = ((assign[picked] + 1) % b).astype(np.int32)[:, None]
    plan = RedundancyPlan(picked=picked, targets=targets,
                          pred_nprobe=np.zeros(n, np.int32))
    extra = replica_rows(plan, x, ids)
    store_h = build_store(x, ids, assign, cents, extra=extra)
    cfg = LiraSystemConfig(arch="lira", dim=dim, n_partitions=b,
                           capacity=store_h.capacity, k=10, nprobe_max=b)
    store = {"centroids": store_h.centroids, "vectors": store_h.vectors,
             "ids": store_h.ids}
    params = probing.init(jax.random.PRNGKey(0),
                          probing.ProbingConfig(dim=dim, n_partitions=b))
    # σ=-1 probes all nprobe_max=B partitions: every replica pair is visited,
    # which is exactly the case where the merge must dedup
    eng = LiraEngine(cfg=cfg, params=params, store=store, mesh=make_test_mesh(),
                     sigma=-1.0)
    q = host.normal(size=(32, dim)).astype(np.float32)
    return eng, store_h, x, q


def test_engine_search_has_no_duplicate_ids(replicated_engine):
    eng, _, _, q = replicated_engine
    i = eng.search(q).ids
    for r in range(len(q)):
        row = i[r][i[r] >= 0].tolist()
        assert len(row) == len(set(row)), f"query {r} returned duplicate ids: {row}"


def test_engine_search_matches_bruteforce_and_eval_path(replicated_engine):
    """Full probe: dedup'd engine top-k == exact kNN of the (unique) base, and
    the recall matches the numpy evaluation engine within 1e-6."""
    eng, store_h, x, q = replicated_engine
    k = eng.cfg.k
    res = eng.search(q)
    d, i, npb = res.dists, res.ids, res.nprobe_eff
    assert (npb == eng.cfg.n_partitions).all()
    _, gti = gt.exact_knn(q, x, k)
    per_hits = np.array([len(set(i[r].tolist()) & set(gti[r].tolist()))
                         for r in range(len(q))], np.float64)
    engine_recall = float((per_hits / k).mean())
    assert engine_recall == pytest.approx(1.0)
    # distances ascending over the valid prefix
    for r in range(len(q)):
        dr = d[r][np.isfinite(d[r])]
        assert (np.diff(dr) >= -1e-5).all()

    ptk = ret.partition_topk(store_h, q, k)
    mask = np.ones((len(q), store_h.n_partitions), bool)
    res = ret.evaluate_probe(ptk, mask, gti, k, dedup_pool=store_h.capacity)
    assert abs(res.recall - engine_recall) < 1e-6


def test_merge_topk_matches_engine(replicated_engine):
    """merge_topk (host evaluation merge, serving-shaped output) must agree
    with the distributed engine on the same full-probe workload."""
    eng, store_h, x, q = replicated_engine
    k = eng.cfg.k
    res = eng.search(q)
    d_eng, i_eng = res.dists, res.ids
    ptk = ret.partition_topk(store_h, q, k)
    mask = np.ones((len(q), store_h.n_partitions), bool)
    d_host, i_host = ret.merge_topk(ptk, mask, k, dedup_pool=store_h.capacity)
    np.testing.assert_array_equal(i_host, i_eng)
    np.testing.assert_allclose(d_host, d_eng, rtol=1e-5, atol=1e-5)
    assert (np.diff(d_host, axis=1) >= -1e-6).all()


def test_evaluate_probe_matches_setloop_oracle():
    """The vectorized evaluate_probe must reproduce the seed's per-query
    set-loop recall exactly on a replica-heavy synthetic workload."""
    from _dedup_oracle import naive_pool_recall

    rng = np.random.default_rng(3)
    qn, b, kk, k = 64, 8, 16, 16
    n_ids = int(b * kk * 0.8)  # ~20% replica collisions
    ids = rng.integers(0, n_ids, (qn, b, kk)).astype(np.int32)
    dists = np.sort(
        rng.permuted(np.tile(np.arange(b * kk, dtype=np.float32), (qn, 1)), axis=1)
        .reshape(qn, b, kk), axis=-1)
    ptk = ret.PartitionTopK(dists, ids, np.full(b, kk, np.int32))
    mask = rng.random((qn, b)) < 0.5
    mask[:, 0] = True
    gti = np.argsort(rng.random((qn, n_ids)), axis=1)[:, :k].astype(np.int32)

    res = ret.evaluate_probe(ptk, mask, gti, k)
    pool = min(2 * k, b * kk)
    masked = np.where(mask[:, :, None], dists, np.inf).reshape(qn, b * kk)
    part = np.argpartition(masked, pool - 1, axis=1)[:, :pool]
    want = naive_pool_recall(np.take_along_axis(masked, part, 1),
                             np.take_along_axis(ids.reshape(qn, b * kk), part, 1),
                             gti, k)
    np.testing.assert_allclose(res.per_query_recall, want, atol=1e-12)
