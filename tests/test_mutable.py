"""Mutable-index tests (ISSUE 9) — streaming inserts/deletes, staleness-driven
re-partitioning, epoch-safe serving.

Covers the acceptance criteria end to end:
  * sustained churn: ≥20% of rows deleted + fresh rows inserted with periodic
    ``maybe_repartition``, recall@10 within ε=0.02 of a FRESH rebuild over the
    surviving logical set, at equal fixed fanout (σ=-1), across
    {f32, pq, residual_pq};
  * tombstone holes compose with batch-padding ``valid`` masking: deleted ids
    never surface (odd, non-bucket nq so padding rows are in play), and after
    ``compact()`` — the dense rebuild of the survivors — dists and ids are
    bit-identical, across tiers × {ref, interpret};
  * same-shape mutations are ZERO-recompile: the jit-cache hit counter keeps
    hitting after insert/delete, while epoch bumps stay observable
    (``lira_engine_epoch_bumps_total`` counter + ``lira_engine_epoch`` gauge,
    ``SearchStats.epoch``);
  * shape-changing mutations (insert-driven grow, shrinking compact) DO
    invalidate compiled serve steps, counted separately;
  * save/load round-trips a mutated store bit-identically (occupancy +
    staleness counters + epoch);
  * front-end epoch atomicity: mutations drain in-flight coalesced batches,
    so every batch is served wholly within one epoch;
  * host-side planning unit tests (serving/mutable.py).
"""
import jax.numpy as jnp
import numpy as np
import pytest

import jax

from repro.configs.base import FrontendConfig, LiraSystemConfig
from repro.core import ground_truth as gt
from repro.core.metrics import recall_at_k
from repro.core import probing
from repro.data import make_vector_dataset
from repro.launch.mesh import make_test_mesh
from repro.obs.metrics import MetricsRegistry
from repro.serving import BuildConfig, FakeClock, LiraEngine, SearchRequest, tiers
from repro.serving import mutable


# ------------------------------------------------------- host-side planning

def test_plan_insert_prefers_nearest_free_slot():
    occ = np.array([[True, True], [True, False], [False, False]])
    # row 0 is nearest partition 0 (full) -> spills to its 2nd choice (1);
    # row 1 is nearest partition 1 and fits its remaining slot... unless row 0
    # claimed it first — rows are placed in input order.
    dist = np.array([[0.0, 1.0, 2.0],
                     [5.0, 0.0, 1.0]])
    plan = mutable.plan_insert(occ, dist)
    assert plan.parts.tolist() == [1, 1] or plan.parts.tolist() == [1, 2]
    assert plan.ok.all()
    # row 0 landed off its argmin partition -> misassigned; wherever row 1
    # landed, partition 1's single free slot went to exactly one of them
    assert bool(plan.misassigned[0])
    p, s = plan.parts, plan.slots
    assert len({(int(a), int(b)) for a, b in zip(p, s)}) == 2  # distinct slots
    assert not occ[1, 1]  # input occupancy not modified


def test_plan_insert_window_limits_spill_and_reports_failures():
    occ = np.array([[True], [True], [False]])
    dist = np.array([[0.0, 1.0, 2.0]])
    # window=2: only partitions {0, 1} are tried, both full -> no slot
    plan = mutable.plan_insert(occ, dist, window=2)
    assert not plan.ok.any()
    assert plan.parts.tolist() == [-1]
    # default window reaches partition 2
    plan = mutable.plan_insert(occ, dist)
    assert plan.ok.all() and plan.parts.tolist() == [2]
    assert bool(plan.misassigned[0])


def test_grow_store_pads_sentinels_and_refuses_shrink():
    planes = {
        "vectors": np.zeros((2, 3, 4), np.float32),
        "ids": np.arange(6, dtype=np.int32).reshape(2, 3),
        "occupancy": np.ones((2, 3), bool),
        "codes": np.ones((2, 3, 2), np.uint8),
    }
    out = mutable.grow_store(planes, 5)
    assert out["vectors"].shape == (2, 5, 4)
    assert (out["vectors"][:, 3:] == 1e6).all()          # top-k-safe sentinel
    assert (out["ids"][:, 3:] == -1).all()               # scan invalid marker
    assert not out["occupancy"][:, 3:].any()
    assert (out["codes"][:, 3:] == 0).all()              # unnamed planes zero
    assert (out["ids"][:, :3] == planes["ids"]).all()
    with pytest.raises(ValueError, match="cannot shrink"):
        mutable.grow_store(planes, 2)


def test_compact_store_packs_live_rows_and_resets_dead_tail():
    occ = np.array([[False, True, False, True],
                    [True, False, False, False]])
    ids = np.array([[7, 1, 9, 2],
                    [3, -1, -1, -1]], np.int32)
    vecs = np.arange(8, dtype=np.float32).reshape(2, 4, 1)
    planes, new_cap = mutable.compact_store(
        {"ids": ids, "vectors": vecs, "occupancy": occ}, occ)
    assert new_cap == 2                                   # max live count
    assert planes["ids"].tolist() == [[1, 2], [3, -1]]    # stable order, healed
    assert planes["occupancy"].tolist() == [[True, True], [True, False]]
    assert planes["vectors"][0, :, 0].tolist() == [1.0, 3.0]
    assert planes["vectors"][1, 1, 0] == 1e6              # dead tail sentinel
    # min_capacity floors the shrink (the scan's top-k needs k candidates)
    _, cap_floored = mutable.compact_store({"occupancy": occ}, occ,
                                           min_capacity=7)
    assert cap_floored == 7


def test_layout_rows_is_contiguous_and_stable():
    assign = np.array([2, 0, 2, 2, 0])
    slots, counts = mutable.layout_rows(assign, 4)
    assert counts.tolist() == [2, 0, 3, 0]
    assert slots.tolist() == [0, 0, 1, 2, 1]              # input order kept


# ----------------------------------------------------------- tiny raw engine

def _raw_engine(b=4, cap=24, dim=16, live_per_part=18, seed=3, metrics=None):
    """Direct-store f32 engine (no build pass) with genuinely free tail
    slots, so same-shape inserts have somewhere to land."""
    host = np.random.default_rng(seed)
    vecs = np.full((b, cap, dim), 1e6, np.float32)
    ids = np.full((b, cap), -1, np.int32)
    # spread centroids out so row->partition argmin is unambiguous
    cents = host.normal(0, 1, (b, dim)).astype(np.float32) * 8.0
    for p in range(b):
        vecs[p, :live_per_part] = cents[p] + host.normal(
            0, 0.2, (live_per_part, dim)).astype(np.float32)
        ids[p, :live_per_part] = np.arange(live_per_part) + p * live_per_part
    store = {"centroids": jnp.asarray(cents), "vectors": jnp.asarray(vecs),
             "ids": jnp.asarray(ids), "occupancy": jnp.asarray(ids >= 0)}
    params = probing.init(jax.random.PRNGKey(0),
                          probing.ProbingConfig(dim=dim, n_partitions=b))
    cfg = LiraSystemConfig(arch="t", dim=dim, n_partitions=b, capacity=cap,
                           k=5, nprobe_max=b)
    eng = LiraEngine(cfg=cfg, params=params, store=store,
                     mesh=make_test_mesh(), sigma=-1.0, metrics=metrics)
    return eng, cents, host


# ------------------------------------------------- epochs & the jit cache

def test_same_shape_mutations_zero_recompiles():
    """The acceptance gate: insert/delete that keep the store shape MUST keep
    hitting the compiled serve step — epoch bumps are bookkeeping, not
    recompiles — and every bump is observable in the metrics registry."""
    reg = MetricsRegistry()
    eng, cents, host = _raw_engine(metrics=reg)
    q = cents[:2] + 0.01
    r0 = eng.search(q)
    assert r0.stats.epoch == 0 and not r0.stats.cache_hit
    assert reg.counter("lira_engine_jit_cache_misses_total").total() == 1

    assert eng.delete([0, 1, 19]) == 3                    # same-shape
    x_new = cents[1] + host.normal(0, 0.2, (4, 16)).astype(np.float32)
    assert eng.insert(x_new, np.arange(4) + 500) == 4     # fits free slots
    assert reg.counter("lira_engine_capacity_grows_total").total() == 0

    r1 = eng.search(q)
    assert r1.stats.cache_hit and r1.stats.epoch == 2
    assert reg.counter("lira_engine_jit_cache_hits_total").total() == 1
    assert reg.counter("lira_engine_jit_cache_misses_total").total() == 1
    assert reg.counter("lira_engine_epoch_bumps_total").total() == 2
    assert reg.counter("lira_engine_shape_epoch_bumps_total").total() == 0
    assert reg.gauge("lira_engine_epoch").value() == float(eng.epoch) == 2.0
    # store gauges reflect the tombstones delete left behind
    assert reg.gauge("lira_engine_tombstone_slots").value() > 0
    assert reg.gauge("lira_engine_live_slots").value() == 4 * 18 - 3 + 4
    # deleted ids are gone, inserted ids findable
    assert not np.isin([0, 1, 19], r1.ids).any()
    hit = eng.search(x_new[:2])
    assert 500 in hit.ids[0]


def test_insert_grow_is_a_shape_epoch_and_invalidates_compiled_steps():
    reg = MetricsRegistry()
    eng, cents, host = _raw_engine(live_per_part=24, metrics=reg)  # full
    q = cents[:2] + 0.01
    eng.search(q)
    old_cap = eng.cfg.capacity
    x_new = cents[0] + host.normal(0, 0.2, (3, 16)).astype(np.float32)
    eng.insert(x_new, [900, 901, 902])
    assert eng.cfg.capacity > old_cap
    assert reg.counter("lira_engine_capacity_grows_total").total() == 1
    assert reg.counter("lira_engine_shape_epoch_bumps_total").total() == 1
    r = eng.search(q)
    assert not r.stats.cache_hit                          # step invalidated
    assert 900 in eng.search(x_new[:2]).ids[0]


def test_delete_unknown_ids_is_a_noop_without_epoch_bump():
    eng, _, _ = _raw_engine(metrics=MetricsRegistry())
    assert eng.delete([99999, 88888]) == 0
    assert eng.epoch == 0


def test_compact_reclaims_tombstones_and_floors_at_k():
    reg = MetricsRegistry()
    eng, cents, _ = _raw_engine(metrics=reg)
    eng.delete(np.arange(10))                             # partition 0 thins
    old_cap = eng.cfg.capacity
    reclaimed = eng.compact()
    assert reclaimed == (old_cap - eng.cfg.capacity) * eng.cfg.n_partitions
    assert eng.cfg.capacity == 18                          # max live count
    assert reg.counter("lira_engine_compactions_total").total() == 1
    occ = np.asarray(eng.store["occupancy"])
    ids = np.asarray(eng.store["ids"])
    assert not (~occ & (ids >= 0)).any()                  # tombstones healed
    # shrink floors at cfg.k: deleting everything cannot starve the top-k
    eng.delete(np.asarray(ids[occ]))
    eng.compact()
    assert eng.cfg.capacity == eng.cfg.k


def test_staleness_gates_repartition_and_resets():
    reg = MetricsRegistry()
    eng, cents, host = _raw_engine(metrics=reg)
    assert eng.staleness() == 0.0
    assert not eng.maybe_repartition()                    # below threshold
    # plant drift: rows that belong to partition 0 but sit in partition 1
    # (their argmin slot space is full), plus tombstones
    eng.delete(np.arange(30))
    assert eng.staleness() >= eng.cfg.repartition_threshold
    assert eng.maybe_repartition()
    assert eng.staleness() == 0.0                         # drift repaired
    assert reg.counter("lira_engine_repartitions_total").total() == 1
    h = reg.histogram("lira_engine_partition_staleness")
    assert h.count() >= eng.cfg.n_partitions              # observed per check
    # after the pass every live row sits in its argmin partition
    occ = np.asarray(eng.store["occupancy"])
    vecs = np.asarray(eng.store["vectors"], np.float32)
    pb, ps = np.nonzero(occ)
    x = vecs[pb, ps]
    d2 = ((x * x).sum(1)[:, None] - 2.0 * x @ cents.T
          + (cents * cents).sum(1)[None, :])
    assert (d2.argmin(1) == pb).all()


def test_misassigned_inserts_count_toward_staleness():
    eng, cents, host = _raw_engine(live_per_part=24)      # every slot full...
    eng.delete(np.asarray([24 * 1 + 0]))                  # ...except one in p1
    x = cents[0] + host.normal(0, 0.1, (1, 16)).astype(np.float32)
    eng.insert(x, [777])                                  # argmin p0 is full
    assert int(eng._staleness_counters().sum()) == 1
    # the row is live and findable even though it spilled off its partition
    assert 777 in eng.search(np.concatenate([x, x])).ids[0]


# ------------------------------------------------------------ churn gate

CHURN_TIERS = ["f32", "pq", "residual_pq"]


def _build(x, tier, **kw):
    cfg = dict(n_partitions=8, k=10, eta=0.03, train_frac=0.4, epochs=2,
               nprobe_max=8, pq_m=4, pq_ks=32, tier=tier)
    cfg.update(kw)
    return LiraEngine.build(make_test_mesh(), x, BuildConfig(**cfg))


@pytest.mark.parametrize("tier", CHURN_TIERS)
def test_sustained_churn_recall_matches_fresh_rebuild(tier):
    """≥20% of the base churned (deletes + inserts) with periodic
    ``maybe_repartition``: recall@10 must stay within ε=0.02 of an index
    freshly rebuilt over the surviving logical set, at equal fixed fanout
    (σ=-1 probes all partitions on both sides)."""
    ds = make_vector_dataset(n=2000, n_queries=32, dim=16, n_modes=8, seed=17)
    host = np.random.default_rng(23)
    eng = _build(ds.base, tier)

    n = len(ds.base)
    doomed = host.choice(n, 300, replace=False)
    new_x = ds.base[host.choice(n, 250, replace=False)] + host.normal(
        0, 0.05, (250, ds.base.shape[1])).astype(np.float32)
    new_ids = np.arange(250, dtype=np.int32) + 10_000
    assert (len(doomed) + len(new_x)) / n >= 0.20         # the churn floor

    # interleave deletes / inserts / repartition checks like a live stream
    for i in range(5):
        eng.delete(doomed[i * 60:(i + 1) * 60])
        eng.insert(new_x[i * 50:(i + 1) * 50], new_ids[i * 50:(i + 1) * 50])
        eng.maybe_repartition()
    eng.maybe_repartition(force=True)                     # final settle

    keep = np.setdiff1d(np.arange(n), doomed)
    all_x = np.concatenate([ds.base[keep], new_x], 0)
    all_ids = np.concatenate([keep.astype(np.int32), new_ids], 0)
    fresh = _build(all_x, tier)

    _, gti = gt.exact_knn(ds.queries, all_x, 10)
    gt_ids = all_ids[gti]
    r_churn = eng.search(ds.queries, sigma=-1.0)
    r_fresh = fresh.search(ds.queries, sigma=-1.0)
    rec_churn = recall_at_k(np.asarray(r_churn.ids), gt_ids, 10)
    rec_fresh = recall_at_k(all_ids[np.asarray(r_fresh.ids)], gt_ids, 10)
    assert not np.isin(doomed, r_churn.ids).any()         # the dead stay dead
    assert rec_churn >= rec_fresh - 0.02, (rec_churn, rec_fresh)


# -------------------------------------- tombstones × padding valid masking

@pytest.mark.parametrize("impl", ["ref", "interpret"])
@pytest.mark.parametrize("tier", CHURN_TIERS)
def test_tombstone_holes_compose_with_padding_masking(tier, impl):
    """Property: after deletes, holes must never surface ids NOR perturb the
    survivors' distances — searching the tombstoned store is bit-identical to
    searching its dense ``compact()``-ed rebuild. nq=13 pads to bucket 16, so
    batch-padding rows are in play at the same time as the holes."""
    ds = make_vector_dataset(n=800, n_queries=13, dim=16, n_modes=8, seed=29)
    eng = _build(ds.base, tier, epochs=1, train_frac=0.5)
    host = np.random.default_rng(31)
    dead = host.choice(len(ds.base), 160, replace=False)
    eng.delete(dead)

    holey = eng.search(SearchRequest(queries=ds.queries, sigma=-1.0,
                                     impl=impl))
    assert not np.isin(dead, holey.ids).any()
    assert holey.ids.shape == (13, eng.cfg.k)
    live = np.setdiff1d(np.arange(len(ds.base)), dead)
    assert np.isin(holey.ids[holey.ids >= 0], live).all()

    eng.compact()                                          # dense survivors
    dense = eng.search(SearchRequest(queries=ds.queries, sigma=-1.0,
                                     impl=impl))
    np.testing.assert_array_equal(holey.ids, dense.ids)
    np.testing.assert_array_equal(np.asarray(holey.dists),
                                  np.asarray(dense.dists))


def test_residual_encode_rows_reproduces_build_encoding():
    """Re-encoding a stored vector at its own partition must reproduce the
    build-time codes and cterm bit-identically — otherwise repartition would
    silently re-rank unmoved rows."""
    ds = make_vector_dataset(n=600, n_queries=4, dim=16, n_modes=8, seed=41)
    eng = _build(ds.base, "residual_pq", epochs=1, train_frac=0.5, eta=0.0)
    tier = tiers.resolve("residual_pq")
    occ = np.asarray(eng.store["occupancy"])
    pb, ps = np.nonzero(occ)
    pick = np.random.default_rng(0).choice(len(pb), 50, replace=False)
    pb, ps = pb[pick], ps[pick]
    x = np.asarray(eng.store["vectors"])[pb, ps].astype(np.float32)
    rows = tier.encode_rows(eng.cfg, eng.store, x, pb)
    np.testing.assert_array_equal(
        np.asarray(rows["codes"]), np.asarray(eng.store["codes"])[pb, ps])
    np.testing.assert_array_equal(
        np.asarray(rows["cterm"]), np.asarray(eng.store["cterm"])[pb, ps])


# ------------------------------------------------------------- persistence

def test_save_load_roundtrips_mutated_store(tmp_path):
    eng, cents, host = _raw_engine()
    eng.delete([0, 5, 40])
    x_new = cents[2] + host.normal(0, 0.2, (3, 16)).astype(np.float32)
    eng.insert(x_new, [600, 601, 602])
    eng._staleness_counters()[1] = 4                      # nonzero drift state
    eng.save(tmp_path, step=3)

    back = LiraEngine.load(tmp_path, make_test_mesh())
    assert back.epoch == eng.epoch == 2
    np.testing.assert_array_equal(back._staleness_counters(),
                                  eng._staleness_counters())
    for name in eng.store:
        np.testing.assert_array_equal(
            np.asarray(back.store[name]), np.asarray(eng.store[name]),
            err_msg=name)
    q = cents + 0.01
    a, b = eng.search(q), back.search(q)
    np.testing.assert_array_equal(a.ids, b.ids)
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))
    assert b.stats.epoch == 2


# ------------------------------------------------------ front-end atomicity

def test_mutations_drain_frontend_and_swap_epochs_atomically():
    eng, cents, host = _raw_engine()
    clock = FakeClock()
    fe = eng.attach_frontend(FrontendConfig(max_batch=64, max_wait_ms=50.0),
                             clock=clock)
    q = (cents[:3] + 0.01).astype(np.float32)
    pending = [fe.submit(SearchRequest(queries=q[i:i + 1])) for i in range(3)]
    assert not any(p.done() for p in pending)             # still coalescing

    eng.delete([2, 3])                                    # quiesces first
    for p in pending:                                     # served pre-swap...
        res = p.result()
        assert res.stats.epoch == 0                       # ...wholly epoch 0
        assert res.stats.batch_size == 3                  # one coalesced batch
    after = fe.submit(SearchRequest(queries=q[:1])).result()
    assert after.stats.epoch == 1                         # bumped atomically
    assert eng.epoch == 1
