"""Tests for the quantized two-stage serving tier (ISSUE 2): PQ code dtypes,
kernel-vs-oracle ADC parity on uint8 codes, the fused LUT-shortlist kernel,
end-to-end quantized recall vs the exact f32 path (incl. η>0 replica dedup),
and the serve-step jit cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LiraSystemConfig
from repro.core import build_store, pq as pqmod, probing
from repro.core import ground_truth as gt
from repro.core.redundancy import RedundancyPlan, replica_rows
from repro.kernels import ops, ref
from repro.launch.mesh import make_test_mesh
from repro.serving.engine import LiraEngine
from repro.serving.quantized import build_quantized_store, scan_store_bytes


# ----------------------------------------------------------- pq.py dtypes

def test_encode_emits_narrow_dtype_and_decode_accepts_it():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(512, 16)).astype(np.float32)
    pq = pqmod.train_pq(jax.random.PRNGKey(0), x, m=4, ks=32, n_iters=4)
    codes = pqmod.encode(pq, x)
    assert codes.dtype == np.uint8  # ks=32 ≤ 256
    recon8 = pqmod.decode(pq, codes)
    recon32 = pqmod.decode(pq, codes.astype(np.int32))
    np.testing.assert_array_equal(recon8, recon32)
    q = jnp.asarray(x[:8])
    a8 = np.asarray(pqmod.adc_distances(pq, q, jnp.asarray(codes)))
    a32 = np.asarray(pqmod.adc_distances(pq, q, jnp.asarray(codes.astype(np.int32))))
    np.testing.assert_allclose(a8, a32, rtol=1e-6)


def test_code_dtype_widths():
    assert pqmod.code_dtype(256) == np.uint8
    assert pqmod.code_dtype(257) == np.uint16
    assert pqmod.code_dtype(1 << 17) == np.int32


# ----------------------------------------------- kernel vs adc_distances oracle

@pytest.mark.parametrize("qn,n,m,ks", [(8, 64, 4, 16), (13, 200, 8, 32), (3, 70, 2, 256)])
def test_pq_adc_kernel_matches_adc_distances_on_uint8(qn, n, m, ks):
    """End-to-end oracle parity: the Pallas kernel fed a real LUT over uint8
    codes must reproduce core.pq.adc_distances (incl. unaligned Q/N, which
    exercises the kernel's internal padding)."""
    rng = np.random.default_rng(qn * n + m)
    d = m * 8
    x = rng.normal(size=(max(4 * ks, 256), d)).astype(np.float32)
    pq = pqmod.train_pq(jax.random.PRNGKey(1), x, m=m, ks=ks, n_iters=3)
    codes = pqmod.encode(pq, x[:n])
    assert codes.dtype == np.uint8
    q = jnp.asarray(rng.normal(size=(qn, d)).astype(np.float32))
    lut = pqmod.adc_lut(pq, q)
    want = np.asarray(pqmod.adc_distances(pq, q, jnp.asarray(codes)))
    got = np.asarray(ops.pq_adc(lut, jnp.asarray(codes), impl="interpret", tq=8, tn=32))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("qn,n,m,ks,k", [(8, 64, 4, 16, 5), (5, 130, 8, 32, 16),
                                         (12, 40, 2, 64, 50)])
def test_pq_adc_topk_matches_ref(qn, n, m, ks, k):
    """Fused LUT-shortlist kernel vs the jnp oracle, incl. -1 padded ids,
    unaligned N, and k > N degenerate pools."""
    rng = np.random.default_rng(qn + n + k)
    lut = jnp.asarray(rng.normal(size=(qn, m, ks)).astype(np.float32) ** 2)
    codes = jnp.asarray(rng.integers(0, ks, size=(n, m)).astype(np.uint8))
    ids = np.arange(n, dtype=np.int32)
    ids[rng.random(n) < 0.15] = -1
    ids = jnp.asarray(ids)
    d1, i1 = ops.pq_adc_topk(lut, codes, ids, k, impl="interpret", tq=8, tn=32)
    d2, i2 = ref.pq_adc_topk_ref(lut, codes, ids, k)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-4, atol=1e-4)
    # ids must agree as sets per row wherever distances are finite (tie order free)
    for r in range(qn):
        fin = np.isfinite(np.asarray(d1)[r])
        assert set(np.asarray(i1)[r][fin].tolist()) == set(np.asarray(i2)[r][fin].tolist())
        assert (np.asarray(i1)[r][~fin] == -1).all()


def test_pq_adc_topk_property_sweep():
    """Hypothesis sweep: kernel == oracle for arbitrary shapes/paddings."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(qn=st.integers(1, 20), n=st.integers(1, 150), m=st.sampled_from([2, 4, 8]),
           ks=st.sampled_from([8, 16, 32]), k=st.integers(1, 20),
           seed=st.integers(0, 10**6))
    def inner(qn, n, m, ks, k, seed):
        rng = np.random.default_rng(seed)
        lut = jnp.asarray(rng.normal(size=(qn, m, ks)).astype(np.float32))
        codes = jnp.asarray(rng.integers(0, ks, size=(n, m)).astype(np.uint8))
        ids = np.arange(n, dtype=np.int32)
        ids[rng.random(n) < 0.2] = -1
        ids = jnp.asarray(ids)
        d1, i1 = ops.pq_adc_topk(lut, codes, ids, k, impl="interpret", tq=8, tn=16)
        d2, i2 = ref.pq_adc_topk_ref(lut, codes, ids, k)
        np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-4, atol=1e-4)

    inner()


# ----------------------------------------------------------- end-to-end tier

@pytest.fixture(scope="module")
def smoke_engines():
    """One engine over a clustered smoke dataset with η>0 replicas, serving
    both tiers from the same store (codes ride next to the f32 vectors)."""
    from repro.data import make_vector_dataset

    ds = make_vector_dataset(n=3000, n_queries=64, dim=32, n_modes=24, seed=7)
    eng = LiraEngine.build(make_test_mesh(), ds.base, n_partitions=8, k=10,
                           eta=0.05, train_frac=0.4, epochs=3, nprobe_max=8,
                           tier="pq", pq_m=8, pq_ks=256, rerank=8)
    _, gti = gt.exact_knn(ds.queries, ds.base, 10)
    return eng, ds, gti


def test_quantized_recall_within_2pct_of_f32(smoke_engines):
    from repro.core.metrics import recall_at_k

    eng, ds, gti = smoke_engines
    i_f = eng.search(ds.queries, sigma=-1.0, tier="f32").ids
    i_q = eng.search(ds.queries, sigma=-1.0, tier="pq").ids
    r_f, r_q = recall_at_k(i_f, gti, 10), recall_at_k(i_q, gti, 10)
    assert r_f == pytest.approx(1.0, abs=1e-6)  # full probe f32 is exact
    assert r_q >= r_f - 0.02, (r_q, r_f)


def test_quantized_replica_dedup_no_duplicate_ids():
    """η>0 built through the real redundancy machinery: the quantized tier's
    merges must dedup replica ids exactly like the f32 path."""
    b, dim, n, k = 4, 16, 512, 10
    host = np.random.default_rng(0)
    x = host.normal(size=(n, dim)).astype(np.float32)
    assign = (np.arange(n) % b).astype(np.int32)
    cents = np.stack([x[assign == p].mean(0) for p in range(b)]).astype(np.float32)
    ids = np.arange(n, dtype=np.int32)
    picked = np.sort(host.choice(n, n // 4, replace=False))
    targets = ((assign[picked] + 1) % b).astype(np.int32)[:, None]
    plan = RedundancyPlan(picked=picked, targets=targets,
                          pred_nprobe=np.zeros(n, np.int32))
    store_h = build_store(x, ids, assign, cents, extra=replica_rows(plan, x, ids))
    qs = build_quantized_store(jax.random.PRNGKey(2), store_h.vectors, store_h.ids,
                               m=4, ks=64)
    cfg = LiraSystemConfig(arch="lira", dim=dim, n_partitions=b,
                           capacity=store_h.capacity, k=k, nprobe_max=b,
                           tier="pq", pq_m=4, pq_ks=qs.ks, rerank=8)
    store = {"centroids": store_h.centroids, "vectors": store_h.vectors,
             "ids": store_h.ids, "codes": qs.codes, "codebooks": qs.codebooks}
    params = probing.init(jax.random.PRNGKey(0),
                          probing.ProbingConfig(dim=dim, n_partitions=b))
    eng = LiraEngine(cfg=cfg, params=params, store=store, mesh=make_test_mesh(),
                     sigma=-1.0)  # σ=-1: every replica pair is visited
    q = host.normal(size=(16, dim)).astype(np.float32)
    res = eng.search(q)
    d, i, npb = res.dists, res.ids, res.nprobe_eff
    assert (npb == b).all()
    for r in range(len(q)):
        row = i[r][i[r] >= 0].tolist()
        assert len(row) == len(set(row)), f"query {r} returned duplicates: {row}"
        dr = d[r][np.isfinite(d[r])]
        assert (np.diff(dr) >= -1e-5).all()


def test_quantized_store_bytes_at_least_8x_smaller(smoke_engines):
    eng, _, _ = smoke_engines
    sb = scan_store_bytes(eng.store)
    assert sb["ratio"] >= 8.0, sb  # dim=32 f32 vs m=8 uint8 codes = 16×


def test_search_jit_cache_buckets(smoke_engines):
    """Repeated searches must reuse the cached jitted step: same bucket → one
    cache entry; results are sliced back to the true batch size."""
    eng, ds, _ = smoke_engines
    eng._serve_cache.clear()
    r5 = eng.search(ds.queries[:5], sigma=0.4)
    r7 = eng.search(ds.queries[:7], sigma=0.4)
    d5, i5, d7, i7, n7 = r5.dists, r5.ids, r7.dists, r7.ids, r7.nprobe_eff
    assert d5.shape == (5, 10) and d7.shape == (7, 10) and n7.shape == (7,)
    assert len(eng._serve_cache) == 1  # 5 and 7 share the 8-bucket
    assert not r5.stats.cache_hit and r7.stats.cache_hit  # bucket reuse surfaced
    assert r5.stats.bucket == r7.stats.bucket == 8
    eng.search(ds.queries[:20], sigma=0.4)
    assert len(eng._serve_cache) == 2  # 32-bucket
    # padded rows must not disturb real queries: prefix results identical
    np.testing.assert_array_equal(i5, i7[:5])
    np.testing.assert_allclose(d5, d7[:5], rtol=1e-6)
