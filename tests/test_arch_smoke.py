"""Per-architecture smoke tests (deliverable f): REDUCED same-family configs,
one forward/train step on CPU, asserting output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.utils.compat import make_mesh

from repro.configs import ARCH_IDS, get_smoke
from repro.data.smoke import make_smoke_inputs
from repro.models import build_bundle
from repro.train import optimizer as opt


@pytest.fixture(scope="module")
def mesh():
    # single CPU device, both mesh axes size 1 — same code path as the pod
    return make_mesh((1, 1), ("data", "model"))


def _finite(tree):
    return all(bool(jnp.isfinite(jnp.asarray(x, jnp.float32)).all())
               for x in jax.tree.leaves(tree) if hasattr(x, "dtype") and jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke(arch, mesh):
    smoke, shapes = get_smoke(arch)
    assert shapes, f"no smoke shapes for {arch}"
    bundle = build_bundle(smoke, mesh)
    for shape in shapes:
        sd = bundle.step(shape)
        params = bundle.init(jax.random.PRNGKey(0), shape)
        inputs = make_smoke_inputs(smoke, shape, mesh, seed=1)
        with mesh:
            if shape.kind in ("train", "graph_train", "rec_train", "lira_train"):
                tx = opt.adamw(1e-3)
                state = (params, tx.init(params))
                # bundle steps embed their own tx; just run the step fn
                new_state, metrics = jax.jit(sd.fn)(state, inputs["batch"])
                loss = float(metrics["loss"])
                assert np.isfinite(loss), f"{arch}/{shape.name} loss={loss}"
                # params actually changed
                changed = jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, new_state[0])
                assert any(jax.tree.leaves(changed)), f"{arch}/{shape.name}: no param updated"
            elif shape.kind == "decode":
                out = jax.jit(sd.fn)(params, inputs["cache"], inputs["tokens"], inputs["pos"])
                nt, cache = out
                assert nt.shape == (shape["global_batch"],)
                assert _finite(cache), f"{arch}/{shape.name} cache NaN"
            elif shape.kind == "prefill":
                logits, cache = jax.jit(sd.fn)(params, inputs["tokens"])
                assert logits.shape[0] == shape["global_batch"]
                assert _finite(logits)
            elif shape.kind == "rec_serve":
                score = jax.jit(sd.fn)(params, inputs["batch"])
                assert score.shape == (shape["batch"],)
                assert _finite(score)
            elif shape.kind == "lira_serve":
                d, i, npb, ovf = jax.jit(sd.fn)(params, inputs["store"], inputs["queries"])
                assert d.shape == (shape["n_queries"], smoke.k)
                assert i.shape == (shape["n_queries"], smoke.k)
                assert float(npb.mean()) >= 1.0
                # overflow is a per-batch-shard int32 count (bprod=1 here)
                ovf = jnp.asarray(ovf)
                assert ovf.shape == (1,) and ovf.dtype == jnp.int32
                assert int(ovf.sum()) >= 0
            else:
                raise AssertionError(shape.kind)


def test_lira_serve_matches_bruteforce(mesh):
    """The distributed serve_step must agree with brute force when every
    partition is probed (σ=0 ⇒ nprobe_max partitions probed)."""
    from repro.configs.base import LiraSystemConfig, ShapeSpec
    from repro.serving.engine import make_serve_step
    from repro.core import probing

    cfg = LiraSystemConfig(arch="t", dim=8, n_partitions=4, capacity=32, k=5, nprobe_max=4)
    host = np.random.default_rng(0)
    vecs = host.normal(0, 1, (4, 32, 8)).astype(np.float32)
    ids = np.arange(128, dtype=np.int32).reshape(4, 32)
    store = {"centroids": jnp.asarray(vecs.mean(1)), "vectors": jnp.asarray(vecs),
             "ids": jnp.asarray(ids)}
    pc = probing.ProbingConfig(dim=8, n_partitions=4)
    params = probing.init(jax.random.PRNGKey(1), pc)
    q = host.normal(0, 1, (16, 8)).astype(np.float32)
    fn = make_serve_step(cfg, mesh, 16, sigma=-1.0, q_cap_factor=8.0)  # probe all
    with mesh:
        d, i, npb, _ = jax.jit(fn)(params, store, jnp.asarray(q))
    flat = vecs.reshape(-1, 8)
    exact = ((q[:, None] - flat[None]) ** 2).sum(-1)
    gt_ids = np.argsort(exact, 1)[:, :5]
    for r in range(16):
        assert set(np.asarray(i)[r].tolist()) == set(gt_ids[r].tolist()), r
    assert float(np.asarray(npb).mean()) == 4.0
