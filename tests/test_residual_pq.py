"""Residual-PQ quantized tier (ISSUE 3): differential test harness.

The quantized stack keeps three synchronized forms of the ADC scan — the
Pallas kernel, the jnp oracle (kernels/ref.py) and a numpy twin (here) — and
this module pins them to each other and to the exact f32 math:

  * the residual ADC identity (core/pq.py): shared LUT + per-(query,
    partition) offset + per-slot cross term == exact L2 to the reconstruction
    centroid + decode(code), on random AND clustered data;
  * pq.encode/pq.decode roundtrip across the uint8 / uint16 / int32 branches
    of code_dtype (parametrized locally, hypothesis-swept in CI);
  * kernel-vs-oracle-vs-numpy parity for the new offset operands of
    pq_adc_topk in both ref and interpret dispatch;
  * η>0 end-to-end serving through the residual tier (replica dedup + recall
    within 2% of the f32 path);
  * the tier-1 recall-regression gate: residual ≥ non-residual recall@10 at
    equal code size on a clustered workload.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LiraSystemConfig
from repro.core import build_store, pq as pqmod, probing
from repro.core import ground_truth as gt
from repro.core.metrics import recall_at_k
from repro.core.redundancy import RedundancyPlan, replica_rows
from repro.data import make_vector_dataset
from repro.kernels import ops, ref
from repro.launch.mesh import make_test_mesh
from repro.serving.engine import LiraEngine
from repro.serving.quantized import build_quantized_store, scan_store_bytes


def _clustered(n, dim, n_modes, seed, *, rng_scale=3.0):
    """Far-apart tight clusters — the regime where non-residual PQ spends its
    budget on centroids (the paper's hard case)."""
    rng = np.random.default_rng(seed)
    cents = rng.normal(0, rng_scale, (n_modes, dim)).astype(np.float32)
    assign = rng.integers(0, n_modes, n)
    x = cents[assign] + rng.normal(0, 0.4, (n, dim)).astype(np.float32)
    return x, assign.astype(np.int32), cents


# ------------------------------------------------- residual ADC invariant

@pytest.mark.parametrize("kind", ["random", "clustered"])
def test_residual_adc_equals_exact_l2_to_reconstruction(kind):
    """The fact core/pq.py's docstring relies on, asserted for the residual
    case: shared-LUT ADC + query offset + cross term == ‖q − (c_b + r̂)‖²
    within fp32 tolerance."""
    rng = np.random.default_rng(0 if kind == "random" else 1)
    B, n, d, m, ks, qn = 6, 400, 16, 4, 32, 7
    if kind == "clustered":
        x, assign, cents = _clustered(n, d, B, seed=1)
    else:
        x = rng.normal(size=(n, d)).astype(np.float32)
        assign = rng.integers(0, B, n).astype(np.int32)
        cents = np.stack([x[assign == b].mean(0) for b in range(B)])
    res = x - cents[assign]
    pq = pqmod.train_pq(jax.random.PRNGKey(0), res, m=m, ks=ks, n_iters=5)
    codes = pqmod.encode(pq, res)
    recon = cents[assign] + pqmod.decode(pq, codes)
    q = rng.normal(0, 1, (qn, d)).astype(np.float32)

    adc = np.asarray(pqmod.adc_distances(pq, jnp.asarray(q), jnp.asarray(codes)))
    off = np.asarray(pqmod.residual_query_offsets(jnp.asarray(cents), jnp.asarray(q)))
    ct = pqmod.residual_cross_terms(pq, cents[assign], codes)
    got = adc + off[:, assign] + ct[None, :]
    want = ((q[:, None] - recon[None]) ** 2).sum(-1)
    scale = max(1.0, float(np.abs(want).max()))
    np.testing.assert_allclose(got, want, atol=2e-5 * scale, rtol=1e-5)
    # the serve step derives the same scalar from its probing cd matrix
    # (engine.py: off = cd − ‖q‖²) — pin the two forms to each other
    cd = ((q[:, None] - cents[None]) ** 2).sum(-1)
    np.testing.assert_allclose(off, cd - (q * q).sum(-1)[:, None],
                               atol=2e-5 * scale, rtol=1e-4)


# -------------------------------------- kernel / oracle / numpy twin parity

def _numpy_adc_topk(lut, codes, ids, k, cand_off, q_off):
    """Numpy twin of pq_adc_topk with offsets: the third synchronized form."""
    lut, codes, ids = np.asarray(lut), np.asarray(codes, np.int64), np.asarray(ids)
    qn, m, _ = lut.shape
    d = np.stack([lut[r, np.arange(m)[:, None], codes.T].sum(0) for r in range(qn)])
    d = d + np.asarray(cand_off)[None, :] + np.asarray(q_off)[:, None]
    d = np.where(ids[None, :] < 0, np.inf, d)
    out_d = np.sort(d, axis=1)[:, :k]
    out_i = np.take_along_axis(ids[None].repeat(qn, 0), np.argsort(d, axis=1), 1)[:, :k]
    out_i = np.where(np.isfinite(out_d), out_i, -1)
    out_d = np.where(np.isfinite(out_d), out_d, np.inf)
    return out_d, out_i


@pytest.mark.parametrize("impl", ["ref", "interpret"])
@pytest.mark.parametrize("qn,n,m,ks,k", [(6, 90, 4, 16, 7), (11, 40, 2, 32, 40)])
def test_pq_adc_topk_offset_parity(impl, qn, n, m, ks, k):
    """pq_adc_topk with the residual offset operands: ref dispatch and the
    interpret (Pallas) dispatch must both match the numpy twin, incl. -1
    padded ids and negative offsets."""
    rng = np.random.default_rng(qn * 13 + n)
    lut = jnp.asarray(rng.normal(size=(qn, m, ks)).astype(np.float32) ** 2)
    codes = jnp.asarray(rng.integers(0, ks, size=(n, m)).astype(np.uint8))
    ids = np.arange(n, dtype=np.int32)
    ids[rng.random(n) < 0.15] = -1
    cand_off = rng.normal(size=n).astype(np.float32)
    q_off = rng.normal(size=qn).astype(np.float32)
    d0, i0 = _numpy_adc_topk(lut, codes, ids, k, cand_off, q_off)
    d1, i1 = ops.pq_adc_topk(lut, codes, jnp.asarray(ids), k,
                             cand_off=jnp.asarray(cand_off),
                             q_off=jnp.asarray(q_off), impl=impl, tq=8, tn=32)
    np.testing.assert_allclose(np.asarray(d1), d0, rtol=1e-4, atol=1e-4)
    for r in range(qn):
        fin = np.isfinite(d0[r])
        assert set(np.asarray(i1)[r][fin].tolist()) == set(i0[r][fin].tolist())
        assert (np.asarray(i1)[r][~fin] == -1).all()


def test_pq_adc_topk_offsets_change_ranking_consistently():
    """cand_off must re-rank (it carries the cross term); q_off must only
    shift distances, never the returned ids — in both dispatch forms."""
    rng = np.random.default_rng(3)
    qn, n, m, ks, k = 5, 64, 4, 16, 8
    lut = jnp.asarray(rng.normal(size=(qn, m, ks)).astype(np.float32) ** 2)
    codes = jnp.asarray(rng.integers(0, ks, size=(n, m)).astype(np.uint8))
    ids = jnp.asarray(np.arange(n, dtype=np.int32))
    q_off = jnp.asarray(rng.normal(size=qn).astype(np.float32))
    for impl in ("ref", "interpret"):
        d_base, i_base = ops.pq_adc_topk(lut, codes, ids, k, impl=impl, tq=8, tn=32)
        d_q, i_q = ops.pq_adc_topk(lut, codes, ids, k, q_off=q_off, impl=impl,
                                   tq=8, tn=32)
        np.testing.assert_array_equal(np.asarray(i_q), np.asarray(i_base))
        np.testing.assert_allclose(np.asarray(d_q),
                                   np.asarray(d_base) + np.asarray(q_off)[:, None],
                                   rtol=1e-4, atol=1e-4)
        # a large penalty on the current winner must evict it
        evict = np.zeros(n, np.float32)
        evict[np.asarray(i_base)[:, 0]] = 1e6
        _, i_ev = ops.pq_adc_topk(lut, codes, ids, k, cand_off=jnp.asarray(evict),
                                  impl=impl, tq=8, tn=32)
        for r in range(qn):
            assert int(np.asarray(i_base)[r, 0]) not in np.asarray(i_ev)[r].tolist()


def test_ref_oracle_matches_kernel_with_offsets_property():
    """Hypothesis sweep (CI): kernel == oracle with offset operands across
    arbitrary shapes/paddings."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(qn=st.integers(1, 16), n=st.integers(1, 120),
           m=st.sampled_from([2, 4, 8]), ks=st.sampled_from([8, 16, 32]),
           k=st.integers(1, 16), seed=st.integers(0, 10**6))
    def inner(qn, n, m, ks, k, seed):
        rng = np.random.default_rng(seed)
        lut = jnp.asarray(rng.normal(size=(qn, m, ks)).astype(np.float32))
        codes = jnp.asarray(rng.integers(0, ks, size=(n, m)).astype(np.uint8))
        ids = np.arange(n, dtype=np.int32)
        ids[rng.random(n) < 0.2] = -1
        co = jnp.asarray(rng.normal(size=n).astype(np.float32))
        qo = jnp.asarray(rng.normal(size=qn).astype(np.float32))
        d1, _ = ops.pq_adc_topk(lut, codes, jnp.asarray(ids), k, cand_off=co,
                                q_off=qo, impl="interpret", tq=8, tn=16)
        d2, _ = ref.pq_adc_topk_ref(lut, codes, jnp.asarray(ids), k,
                                    cand_off=co, q_off=qo)
        np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                                   rtol=1e-4, atol=1e-4)

    inner()


# ------------------------------------------------- encode/decode roundtrip

def _check_roundtrip(m, ks, n, seed, d_sub=8):
    """decode∘encode must be the identity on codebook points, emit the
    code_dtype(ks) dtype, and agree with a numpy argmin on arbitrary x."""
    rng = np.random.default_rng(seed)
    cb = rng.normal(size=(m, ks, d_sub)).astype(np.float32)
    pq = pqmod.PQCodebook(codebooks=jnp.asarray(cb), m=m, ks=ks)
    codes = rng.integers(0, ks, size=(n, m))
    x = pqmod.decode(pq, codes.astype(np.int64))
    got = pqmod.encode(pq, x)
    assert got.dtype == pqmod.code_dtype(ks)
    np.testing.assert_array_equal(got.astype(np.int64), codes)
    np.testing.assert_array_equal(pqmod.decode(pq, got), x)
    # arbitrary x: encode == per-subspace numpy argmin
    y = rng.normal(size=(min(n, 16), m * d_sub)).astype(np.float32)
    got_y = pqmod.encode(pq, y).astype(np.int64)
    ys = y.reshape(len(y), m, d_sub)
    want_y = ((ys[:, :, None, :] - cb[None]) ** 2).sum(-1).argmin(-1)
    np.testing.assert_array_equal(got_y, want_y)


@pytest.mark.parametrize("ks", [16, 256, 4096])
def test_encode_decode_roundtrip_code_dtypes(ks):
    """ks=16/256 exercise uint8, ks=4096 the previously-untested uint16."""
    _check_roundtrip(m=4, ks=ks, n=64, seed=ks)


def test_encode_decode_roundtrip_int32_branch():
    """ks > 65536 → int32 codes: the widest code_dtype branch, driven through
    a constructed codebook (training 2^16+ centroids is not meaningful)."""
    _check_roundtrip(m=1, ks=70_000, n=32, seed=9, d_sub=4)


def test_encode_decode_roundtrip_property():
    """Hypothesis sweep (CI) over the same helper the parametrized tests pin
    locally — shapes and all three code dtypes can't drift apart."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=12, deadline=None)
    @given(m=st.sampled_from([1, 2, 4]), ks=st.sampled_from([16, 256, 4096]),
           n=st.integers(1, 80), seed=st.integers(0, 10**6))
    def inner(m, ks, n, seed):
        _check_roundtrip(m=m, ks=ks, n=n, seed=seed)

    inner()


# ------------------------------------------------- end-to-end residual tier

@pytest.fixture(scope="module")
def clustered_engines():
    """One clustered index served three ways — exact f32, non-residual PQ,
    residual PQ — with the SAME partitions, probing model and (m, ks), so the
    only difference is what the codes encode."""
    ds = make_vector_dataset("clustered", n=4000, n_queries=64, dim=32,
                             n_modes=8, center_scale=10.0, spread=0.5,
                             boundary_frac=0.0, noise_frac=0.0, seed=5)
    eng_nr = LiraEngine.build(make_test_mesh(), ds.base, n_partitions=8, k=10,
                              eta=0.05, train_frac=0.3, epochs=3, nprobe_max=8,
                              tier="pq", pq_m=8, pq_ks=32, rerank=2)
    qs = build_quantized_store(jax.random.PRNGKey(1), eng_nr.store["vectors"],
                               eng_nr.store["ids"], m=8, ks=32, residual=True,
                               centroids=eng_nr.store["centroids"])
    assert qs.residual and qs.ks == eng_nr.cfg.pq_ks  # equal code size
    store_r = {**eng_nr.store, "codes": qs.codes, "codebooks": qs.codebooks,
               "cterm": qs.cterm}
    eng_r = LiraEngine(cfg=dataclasses.replace(eng_nr.cfg, tier="residual_pq"),
                       params=eng_nr.params, store=store_r, mesh=eng_nr.mesh)
    _, gti = gt.exact_knn(ds.queries, ds.base, 10)
    return eng_nr, eng_r, ds, gti


def test_residual_recall_gate_on_clustered_data(clustered_engines):
    """Tier-1 regression gate: at equal code size (same pq_m/pq_ks) residual
    recall@10 must be ≥ non-residual on clustered data — the reason this PR
    exists. The margin on this workload is ~15 points, far above seed noise."""
    eng_nr, eng_r, ds, gti = clustered_engines
    i_nr = eng_nr.search(ds.queries, sigma=-1.0, tier="pq").ids
    i_r = eng_r.search(ds.queries, sigma=-1.0, tier="residual_pq").ids
    r_nr, r_r = recall_at_k(i_nr, gti, 10), recall_at_k(i_r, gti, 10)
    assert r_r >= r_nr, (r_r, r_nr)


def test_residual_codes_spend_budget_on_residuals(clustered_engines):
    """Reconstruction error of the residual codes must beat non-residual at
    equal code size on clustered data — the mechanism behind the gate above."""
    eng_nr, eng_r, _, _ = clustered_engines
    vec = np.asarray(eng_nr.store["vectors"], np.float32)
    ids = np.asarray(eng_nr.store["ids"])
    cents = np.asarray(eng_nr.store["centroids"], np.float32)
    b, cap, d = vec.shape
    valid = ids.reshape(-1) >= 0

    def mse(store, residual):
        m = store["codes"].shape[-1]
        pq = pqmod.PQCodebook(codebooks=store["codebooks"], m=m,
                              ks=store["codebooks"].shape[1])
        recon = pqmod.decode(pq, np.asarray(store["codes"]).reshape(-1, m))
        if residual:
            recon = recon + np.repeat(cents, cap, axis=0)
        return float(((recon - vec.reshape(-1, d)) ** 2).sum(-1)[valid].mean())

    assert mse(eng_r.store, True) < mse(eng_nr.store, False)


def test_residual_recall_within_2pct_of_f32(clustered_engines):
    """Mirror of tests/test_quantized.py's non-residual case: with probe-all
    σ the residual tier must stay within 2% of the exact path."""
    eng_nr, eng_r, ds, gti = clustered_engines
    i_f = eng_r.search(ds.queries, sigma=-1.0, tier="f32").ids
    r_f = recall_at_k(i_f, gti, 10)
    assert r_f == pytest.approx(1.0, abs=1e-6)  # full probe f32 is exact
    # rerank=2 is deliberately starved to expose the residual-vs-non-residual
    # gap; the 2% envelope of the serving contract is checked at the
    # production shortlist depth instead
    eng_deep = LiraEngine(cfg=dataclasses.replace(eng_r.cfg, rerank=16),
                          params=eng_r.params, store=eng_r.store, mesh=eng_r.mesh)
    i_q = eng_deep.search(ds.queries, sigma=-1.0, tier="residual_pq").ids
    assert recall_at_k(i_q, gti, 10) >= r_f - 0.02


def test_residual_store_bytes_counts_cterm(clustered_engines):
    """The residual tier's honest cost: the cterm plane is part of the scan
    read traffic, so the bytes ratio must reflect it."""
    eng_nr, eng_r, _, _ = clustered_engines
    sb_nr, sb_r = scan_store_bytes(eng_nr.store), scan_store_bytes(eng_r.store)
    cterm_bytes = eng_r.store["cterm"].size * eng_r.store["cterm"].dtype.itemsize
    assert sb_r["quantized"] == sb_nr["quantized"] + cterm_bytes
    assert sb_r["ratio"] < sb_nr["ratio"]


def test_residual_replica_dedup_no_duplicate_ids_eta_pos():
    """η>0 through the real redundancy machinery on the RESIDUAL tier: replica
    ids must dedup through local and cross-shard merges exactly like the f32
    and non-residual paths (mirror of tests/test_quantized.py)."""
    b, dim, n, k = 4, 16, 512, 10
    host = np.random.default_rng(0)
    x = host.normal(size=(n, dim)).astype(np.float32)
    assign = (np.arange(n) % b).astype(np.int32)
    cents = np.stack([x[assign == p].mean(0) for p in range(b)]).astype(np.float32)
    ids = np.arange(n, dtype=np.int32)
    picked = np.sort(host.choice(n, n // 4, replace=False))
    targets = ((assign[picked] + 1) % b).astype(np.int32)[:, None]
    plan = RedundancyPlan(picked=picked, targets=targets,
                          pred_nprobe=np.zeros(n, np.int32))
    store_h = build_store(x, ids, assign, cents, extra=replica_rows(plan, x, ids))
    qs = build_quantized_store(jax.random.PRNGKey(2), store_h.vectors,
                               store_h.ids, m=4, ks=64, residual=True,
                               centroids=store_h.centroids)
    assert qs.cterm is not None and qs.cterm.shape == store_h.ids.shape
    cfg = LiraSystemConfig(arch="lira", dim=dim, n_partitions=b,
                           capacity=store_h.capacity, k=k, nprobe_max=b,
                           tier="residual_pq", pq_m=4, pq_ks=qs.ks, rerank=8)
    store = {"centroids": store_h.centroids, "vectors": store_h.vectors,
             "ids": store_h.ids, "codes": qs.codes, "codebooks": qs.codebooks,
             "cterm": qs.cterm}
    params = probing.init(jax.random.PRNGKey(0),
                          probing.ProbingConfig(dim=dim, n_partitions=b))
    eng = LiraEngine(cfg=cfg, params=params, store=store, mesh=make_test_mesh(),
                     sigma=-1.0)  # σ=-1: every replica pair is visited
    q = host.normal(size=(16, dim)).astype(np.float32)
    res = eng.search(q)
    d, i, npb = res.dists, res.ids, res.nprobe_eff
    assert (npb == b).all()
    _, gti = gt.exact_knn(q, x, k)
    assert recall_at_k(i, gti, k) >= 0.98  # probe-all + deep rerank ≈ exact
    for r in range(len(q)):
        row = i[r][i[r] >= 0].tolist()
        assert len(row) == len(set(row)), f"query {r} returned duplicates: {row}"
        dr = d[r][np.isfinite(d[r])]
        assert (np.diff(dr) >= -1e-5).all()
