"""Failure & straggler simulation tests (repro.distributed.fault).

The pod-replicated control plane (DESIGN.md §5) simulated without hardware:
power-of-two-choices routing, heartbeat-loss failover with in-flight replay,
and hedged-request tail mitigation. Every test seeds its RNG — the policies
are sampling-based, the assertions are exact.
"""
import pytest

from repro.distributed.fault import (
    Replica,
    ReplicaFailure,
    ReplicaRouter,
    StragglerMitigator,
)
from repro.obs import MetricsRegistry
from repro.utils.clock import FakeClock


# ---------------------------------------------------------------- routing

def test_pick_prefers_lower_inflight_of_two_choices():
    router = ReplicaRouter(n_replicas=2, seed=0)
    router.replicas[0].inflight = 10
    # with only two replicas the two sampled choices are always {0, 1}, so
    # the less-loaded replica must win every draw
    for _ in range(50):
        assert router.pick().rid == 1


def test_pick_single_healthy_replica_needs_no_sampling():
    router = ReplicaRouter(n_replicas=3, seed=1)
    router.mark_failed(0)
    router.mark_failed(2)
    for _ in range(10):
        assert router.pick().rid == 1


def test_pick_with_no_healthy_replicas_raises():
    router = ReplicaRouter(n_replicas=2, seed=0)
    router.mark_failed(0)
    router.mark_failed(1)
    with pytest.raises(RuntimeError, match="no healthy replicas"):
        router.pick()


def test_pick_is_deterministic_under_seed():
    ra, rb = ReplicaRouter(8, seed=7), ReplicaRouter(8, seed=7)
    assert [ra.pick().rid for _ in range(32)] == [rb.pick().rid
                                                 for _ in range(32)]


def test_pick_spreads_load_across_equal_replicas():
    router = ReplicaRouter(n_replicas=4, seed=3)
    seen = set()
    for _ in range(200):
        r = router.pick()
        seen.add(r.rid)
    assert seen == {0, 1, 2, 3}


# --------------------------------------------------------------- failover

def test_mark_failed_requeues_inflight_and_recover_rejoins():
    router = ReplicaRouter(n_replicas=3, seed=0)
    router.replicas[1].inflight = 4
    lost = router.mark_failed(1)
    assert lost == 4 and router.requeued == 4
    assert router.replicas[1].inflight == 0
    assert not router.replicas[1].healthy
    assert [r.rid for r in router.healthy()] == [0, 2]
    router.recover(1)
    assert [r.rid for r in router.healthy()] == [0, 1, 2]
    # a second failure with nothing in flight replays nothing new
    assert router.mark_failed(1) == 0 and router.requeued == 4


def test_dispatch_serves_every_batch_exactly_once():
    router = ReplicaRouter(n_replicas=4, seed=11)
    served = router.dispatch(100)
    assert sum(served.values()) == 100
    assert router.requeued == 0


def test_dispatch_mid_flight_failure_replays_on_healthy_replica():
    router = ReplicaRouter(n_replicas=3, seed=5)
    served = router.dispatch(60, fail_at=(30, 2))
    # every batch still served exactly once, the doomed replica's in-flight
    # batch replayed elsewhere
    assert sum(served.values()) == 60
    assert router.requeued == 1
    assert not router.replicas[2].healthy
    # the dead replica served only what it finished before the heartbeat loss
    assert served[2] == router.replicas[2].served
    assert served[0] + served[1] >= 30


def test_dispatch_failure_spec_is_idempotent_after_death():
    """fail_at only fires while its victim is healthy — a replayed batch
    index must not re-kill (or double-count) the already-dead replica."""
    router = ReplicaRouter(n_replicas=2, seed=9)
    served = router.dispatch(10, fail_at=(0, 0))
    assert sum(served.values()) == 10
    assert router.requeued == 1
    assert served[1] == 10  # the survivor absorbed everything


# ---------------------------------------------------------------- hedging

def _warm(mit, n=30, latency=1.0):
    for _ in range(n):
        mit.serve(latency)


def test_straggler_hedge_caps_tail_latency():
    router = ReplicaRouter(n_replicas=3, seed=2)
    mit = StragglerMitigator(router, hedge_factor=3.0)
    _warm(mit, 30, 1.0)                  # healthy history, median = 1.0
    router.replicas[0].latency_scale = 100.0   # replica 0 becomes a straggler
    lats = [mit.serve(1.0) for _ in range(200)]
    assert mit.hedges > 0
    # hedged requests complete at deadline + healthy service, never at the
    # straggler's 100× latency
    assert max(lats) < 100.0
    assert max(lats) <= 3.0 * 1.0 + 1.0 + 1e-9


def test_no_hedging_before_history_warmup():
    router = ReplicaRouter(n_replicas=2, seed=4)
    router.replicas[0].latency_scale = 50.0
    mit = StragglerMitigator(router)
    lats = [mit.serve(1.0) for _ in range(19)]   # < 20-sample history
    assert mit.hedges == 0
    assert any(lat == 50.0 for lat in lats)      # straggler latency unhedged


def test_hedge_prefers_best_ewma_replica():
    router = ReplicaRouter(n_replicas=3, seed=6)
    mit = StragglerMitigator(router, hedge_factor=2.0)
    _warm(mit, 25, 1.0)
    router.replicas[0].latency_scale = 40.0
    router.replicas[1].ewma = 5.0                # known-slow alternative
    router.replicas[2].ewma = 0.5                # known-fast alternative
    # keep serving until the straggler is drawn and hedged at least once
    for _ in range(100):
        mit.serve(1.0)
    assert mit.hedges > 0
    # the fast-EWMA replica absorbed hedges: its EWMA was updated toward the
    # healthy service latency (ewma moves from 0.5 toward 1.0)
    assert router.replicas[2].ewma > 0.5


def test_hedging_deterministic_under_seed():
    def run():
        router = ReplicaRouter(n_replicas=4, seed=13)
        router.replicas[3].latency_scale = 30.0
        mit = StragglerMitigator(router)
        _warm(mit, 20, 1.0)
        return [mit.serve(1.0) for _ in range(100)], mit.hedges

    (lat_a, hedges_a), (lat_b, hedges_b) = run(), run()
    assert lat_a == lat_b and hedges_a == hedges_b


def test_replica_dataclass_defaults():
    r = Replica(rid=7)
    assert (r.healthy, r.inflight, r.served, r.latency_scale) == (
        True, 0, 0, 1.0)


# -------------------------------------------------- real dispatch (ISSUE 10)

def test_route_replays_inflight_batch_on_replica_failure():
    reg = MetricsRegistry()
    router = ReplicaRouter(2, seed=0, clock=FakeClock(), metrics=reg)
    doomed = {0}

    def fn(r):
        if r.rid in doomed:
            doomed.discard(r.rid)
            raise ReplicaFailure("connection lost mid-serve")
        return ("answer", r.rid)

    results = [router.route(fn) for _ in range(6)]
    assert all(out == ("answer", r.rid) for out, r in results)
    assert router.requeued == 1          # the one in-flight batch replayed
    assert not router.replicas[0].healthy
    assert all(r.rid == 1 for _, r in results)  # survivor absorbed traffic
    assert reg.counter("lira_failovers_total").total() == 1.0
    assert reg.gauge("lira_replica_inflight").value(
        shard="default", replica="1") == 0.0


def test_call_stamps_heartbeat_and_check_heartbeats_fails_stale():
    clock = FakeClock()
    router = ReplicaRouter(2, seed=0, clock=clock, metrics=MetricsRegistry())
    clock.advance(3.0)
    router.call(router.replicas[0], lambda r: "ok")
    assert router.replicas[0].last_heartbeat == 3.0
    clock.advance(4.0)                   # replica 1 never heartbeats
    assert router.check_heartbeats(timeout_s=5.0) == [(1, 0)]
    assert not router.replicas[1].healthy
    assert router.replicas[0].healthy    # fresh heartbeat kept it alive
    router.recover(1)
    assert router.replicas[1].last_heartbeat == clock()


def test_mitigator_run_hedge_first_response_wins():
    reg = MetricsRegistry()
    router = ReplicaRouter(2, seed=0, clock=FakeClock(), metrics=reg)
    router.replicas[1].inflight = 1      # force the straggler as primary
    mit = StragglerMitigator(router, hedge_factor=3.0)
    mit.latencies.extend([1.0] * 20)     # warm history, median = 1.0

    def fn(r):
        return (f"from{r.rid}", 9.0 if r.rid == 0 else 1.0)

    result, winner, eff, hedged = mit.run(fn)
    # primary (rid 0) blows the 3.0 deadline; the hedge to rid 1 completes
    # at deadline + 1.0 = 4.0 and wins
    assert hedged and result == "from1" and winner.rid == 1
    assert eff == pytest.approx(4.0)
    assert mit.hedges == 1 and mit.hedge_wins == 1
    assert reg.counter("lira_hedges_total").total() == 1.0
    assert reg.counter("lira_hedge_wins_total").total() == 1.0


def test_mitigator_run_slow_hedge_is_discounted():
    router = ReplicaRouter(2, seed=0, clock=FakeClock(),
                           metrics=MetricsRegistry())
    router.replicas[1].inflight = 1
    mit = StragglerMitigator(router, hedge_factor=3.0)
    mit.latencies.extend([1.0] * 20)

    def fn(r):
        return (f"from{r.rid}", 9.0 if r.rid == 0 else 50.0)

    result, winner, eff, hedged = mit.run(fn)
    assert hedged and result == "from0" and winner.rid == 0
    assert eff == pytest.approx(9.0)     # primary's completion stood
    assert mit.hedge_wins == 0


def test_mitigator_run_dead_hedge_keeps_primary_answer():
    router = ReplicaRouter(2, seed=0, clock=FakeClock(),
                           metrics=MetricsRegistry())
    router.replicas[1].inflight = 1
    mit = StragglerMitigator(router, hedge_factor=3.0)
    mit.latencies.extend([1.0] * 20)

    def fn(r):
        if r.rid == 1:
            raise ReplicaFailure("hedge target died")
        return ("primary", 9.0)

    result, winner, eff, hedged = mit.run(fn)
    assert hedged and result == "primary" and winner.rid == 0
    assert not router.replicas[1].healthy


def test_mitigator_warmup_is_configurable():
    router = ReplicaRouter(2, seed=4, clock=FakeClock(),
                           metrics=MetricsRegistry())
    mit = StragglerMitigator(router, warmup=5)
    for _ in range(5):                   # healthy history, median = 1.0
        mit.serve(1.0)
    router.replicas[0].latency_scale = 50.0
    lats = [mit.serve(1.0) for _ in range(30)]
    assert mit.hedges > 0                # hedging armed after only 5 samples
    assert max(lats) < 50.0
