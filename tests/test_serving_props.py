"""Property-based tests for the distributed serving engine's dispatch
invariants (hypothesis): results must match brute force whenever the probe
budget covers the true nearest partitions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.configs.base import LiraSystemConfig
from repro.core import probing
from repro.launch.mesh import make_test_mesh
from repro.serving.engine import make_serve_step

MESH = None


def _mesh():
    global MESH
    if MESH is None:
        MESH = make_test_mesh()
    return MESH


@settings(max_examples=10, deadline=None)
@given(
    b=st.sampled_from([2, 4, 8]),
    cap=st.sampled_from([16, 32]),
    nq=st.sampled_from([8, 16]),
    k=st.integers(1, 8),
    seed=st.integers(0, 10**6),
)
def test_full_probe_equals_bruteforce(b, cap, nq, k, seed):
    """σ=-1 probes every partition (nprobe_max=B): the distributed engine must
    return EXACTLY the brute-force top-k ids for every query."""
    dim = 8
    host = np.random.default_rng(seed)
    vecs = host.normal(0, 1, (b, cap, dim)).astype(np.float32)
    ids = np.arange(b * cap, dtype=np.int32).reshape(b, cap)
    cfg = LiraSystemConfig(arch="t", dim=dim, n_partitions=b, capacity=cap,
                           k=k, nprobe_max=b)
    store = {"centroids": jnp.asarray(vecs.mean(1)), "vectors": jnp.asarray(vecs),
             "ids": jnp.asarray(ids)}
    params = probing.init(jax.random.PRNGKey(0),
                          probing.ProbingConfig(dim=dim, n_partitions=b))
    q = host.normal(0, 1, (nq, dim)).astype(np.float32)
    fn = make_serve_step(cfg, _mesh(), nq, sigma=-1.0, q_cap_factor=float(nq))
    with _mesh():
        d, i, npb, ovf = jax.jit(fn)(params, store, jnp.asarray(q))
    flat = vecs.reshape(-1, dim)
    exact = ((q[:, None] - flat[None]) ** 2).sum(-1)
    for r in range(nq):
        want = set(np.argsort(exact[r])[:k].tolist())
        got = set(np.asarray(i)[r].tolist())
        # allow tie-order differences only: compare distance multisets too
        assert got == want or np.allclose(
            sorted(exact[r][sorted(got)]), sorted(exact[r][sorted(want)]), atol=1e-5)
    assert float(np.asarray(npb).mean()) == b
    assert int(np.asarray(ovf).sum()) == 0  # q_cap covers the full probe load


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10**6), sigma=st.floats(0.05, 0.9))
def test_partial_probe_results_are_valid_and_sorted(seed, sigma):
    """Any σ: returned ids are real (or -1 padding), distances ascending, and
    adaptive nprobe ∈ [1, nprobe_max]."""
    b, cap, dim, nq, k = 4, 16, 8, 8, 5
    host = np.random.default_rng(seed)
    vecs = host.normal(0, 1, (b, cap, dim)).astype(np.float32)
    ids = np.arange(b * cap, dtype=np.int32).reshape(b, cap)
    cfg = LiraSystemConfig(arch="t", dim=dim, n_partitions=b, capacity=cap,
                           k=k, nprobe_max=2)
    store = {"centroids": jnp.asarray(vecs.mean(1)), "vectors": jnp.asarray(vecs),
             "ids": jnp.asarray(ids)}
    params = probing.init(jax.random.PRNGKey(1),
                          probing.ProbingConfig(dim=dim, n_partitions=b))
    q = host.normal(0, 1, (nq, dim)).astype(np.float32)
    fn = make_serve_step(cfg, _mesh(), nq, sigma=float(sigma), q_cap_factor=8.0)
    with _mesh():
        d, i, npb, ovf = jax.jit(fn)(params, store, jnp.asarray(q))
    d, i, npb = np.asarray(d), np.asarray(i), np.asarray(npb)
    finite = np.isfinite(d)
    assert ((i >= -1) & (i < b * cap)).all()
    assert (i[finite] >= 0).all()
    for r in range(nq):
        dr = d[r][np.isfinite(d[r])]
        assert (np.diff(dr) >= -1e-5).all()
    assert (npb >= 1).all() and (npb <= 2).all()
