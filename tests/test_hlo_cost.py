"""Unit tests for the trip-count-aware HLO cost parser (the roofline's
foundation): while multipliers, dot flops, collective accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost
from repro.utils.compat import make_mesh, shard_map


def _compile(fn, *specs, in_shardings=None):
    jfn = jax.jit(fn) if in_shardings is None else jax.jit(fn, in_shardings=in_shardings)
    return jfn.lower(*specs).compile()


def test_while_trip_count_multiplies_flops():
    """A scanned matmul must count L× the single-layer flops (XLA's own
    cost_analysis counts it once — the bug this parser exists to fix)."""
    L, D, B = 6, 64, 8

    def step(w, x):
        def body(h, wl):
            return jnp.tanh(h @ wl), None
        h, _ = jax.lax.scan(body, x, w)
        return h.sum()

    c = _compile(step, jax.ShapeDtypeStruct((L, D, D), jnp.float32),
                 jax.ShapeDtypeStruct((B, D), jnp.float32))
    res = hlo_cost.analyze(c.as_text())
    expect = L * 2 * B * D * D
    assert res["flops"] == pytest.approx(expect, rel=0.05), (res["flops"], expect)
    xla = c.cost_analysis()
    if isinstance(xla, (list, tuple)):  # jax 0.4.x: one entry per device
        xla = xla[0]
    assert xla["flops"] < expect / 2  # demonstrates the XLA undercount


def test_unrolled_matches_scanned():
    D, B, L = 32, 4, 5

    def scanned(w, x):
        h, _ = jax.lax.scan(lambda h, wl: (h @ wl, None), x, w)
        return h.sum()

    def unrolled(w, x):
        h = x
        for i in range(L):
            h = h @ w[i]
        return h.sum()

    specs = (jax.ShapeDtypeStruct((L, D, D), jnp.float32),
             jax.ShapeDtypeStruct((B, D), jnp.float32))
    f_scan = hlo_cost.analyze(_compile(scanned, *specs).as_text())["flops"]
    f_unroll = hlo_cost.analyze(_compile(unrolled, *specs).as_text())["flops"]
    assert f_scan == pytest.approx(f_unroll, rel=0.05)


def test_collective_bytes_counted():
    mesh = make_mesh((1, 1), ("data", "model"))

    def f(x):
        return shard_map(lambda a: jax.lax.psum(a, "model"), mesh=mesh,
                             in_specs=jax.sharding.PartitionSpec(None, None),
                             out_specs=jax.sharding.PartitionSpec(None, None),
                             check_vma=False)(x)

    with mesh:
        c = _compile(f, jax.ShapeDtypeStruct((16, 16), jnp.float32))
    res = hlo_cost.analyze(c.as_text())
    # single-device mesh: psum may be elided; just assert the parser runs and
    # returns the documented keys
    for k in ("flops", "bytes", "collective_bytes", "collectives", "top_flops"):
        assert k in res


def test_shape_bytes_parsing():
    assert hlo_cost._shape_bytes("f32[4,8]{1,0}") == 128
    assert hlo_cost._shape_bytes("bf16[10]{0}") == 20
    assert hlo_cost._shape_bytes("(f32[2]{0}, s32[3]{0})") == 20
    assert hlo_cost._shape_bytes("pred[]") == 1
