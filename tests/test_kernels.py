"""Per-kernel allclose sweeps: Pallas (interpret mode) vs jnp oracle across
shapes and dtypes. Deterministic only — hypothesis property sweeps live in
test_kernel_props.py behind an importorskip guard, so a missing optional dep
skips those instead of breaking the whole tier-1 run."""
import jax.numpy as jnp
import numpy as np
import pytest

from _dedup_oracle import naive_dedup_topk
from repro.kernels import ops, ref

DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("qn,cn,d,k", [(8, 64, 16, 5), (37, 300, 48, 10), (64, 512, 128, 100), (1, 128, 96, 8)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_l2_topk_matches_ref(qn, cn, d, k, dtype):
    rng = np.random.default_rng(qn * cn)
    q = jnp.asarray(rng.normal(size=(qn, d)), dtype)
    c = jnp.asarray(rng.normal(size=(cn, d)), dtype)
    ids = jnp.asarray(np.arange(cn, dtype=np.int32)).at[cn - cn // 8 :].set(-1)
    d1, i1 = ops.l2_topk(q, c, ids, k, impl="interpret", tq=8, tc=64)
    d2, i2 = ref.l2_topk_ref(q, c, ids, k)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=tol, atol=tol)
    # id sets must match allowing ties (discrete_boundary check)
    for r in range(qn):
        assert set(np.asarray(i1)[r].tolist()) == set(np.asarray(i2)[r].tolist())


@pytest.mark.parametrize("qn,n,m,ks", [(8, 64, 8, 16), (16, 256, 16, 256), (3, 130, 4, 32)])
def test_pq_adc_matches_ref(qn, n, m, ks):
    rng = np.random.default_rng(qn * n)
    lut = jnp.asarray(rng.normal(size=(qn, m, ks)).astype(np.float32))
    codes = jnp.asarray(rng.integers(0, ks, size=(n, m)).astype(np.int32))
    a1 = ops.pq_adc(lut, codes, impl="interpret", tq=8, tn=64)
    a2 = ref.pq_adc_ref(lut, codes)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,b,d", [(64, 8, 16), (123, 40, 48), (512, 128, 128)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_kmeans_assign_matches_ref(n, b, d, dtype):
    rng = np.random.default_rng(n * b)
    x = jnp.asarray(rng.normal(size=(n, d)), dtype)
    c = jnp.asarray(rng.normal(size=(b, d)), dtype)
    a1, d1 = ops.kmeans_assign(x, c, impl="interpret", tn=16, tb=8)
    a2, d2 = ref.kmeans_assign_ref(x, c)
    tol = 1e-4 if dtype == jnp.float32 else 1e-1
    # argmin can differ under bf16 rounding only where distances tie
    close = np.isclose(np.asarray(d1), np.asarray(d2), rtol=tol, atol=tol)
    assert close.mean() > 0.99


def test_l2_topk_interpret_vs_ref_large_k_padding():
    """k larger than real candidates -> padded ids must be -1-masked."""
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
    ids = jnp.asarray(np.arange(16, dtype=np.int32)).at[8:].set(-1)
    d1, i1 = ops.l2_topk(q, c, ids, 12, impl="interpret", tq=4, tc=8)
    # only 8 valid candidates: the tail of top-12 must be padding
    assert (np.asarray(i1)[:, 8:] == -1).all()
    assert not np.isfinite(np.asarray(d1)[:, 8:]).any() or (np.asarray(d1)[:, 8:] > 1e20).all()


# ----------------------------------------------------------- dedup_topk

def _dedup_case(qn, p, n_ids, seed, frac_pad=0.1, frac_inf=0.1):
    """Random pool with replicas (id collisions), PAD_ID padding and inf-masked
    entries; distances are a per-row permutation of 0..p-1 so every entry is
    distinct and the (dist, id) order is unambiguous."""
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, n_ids, (qn, p)).astype(np.int32)
    d = rng.permuted(np.tile(np.arange(p, dtype=np.float32), (qn, 1)), axis=1)
    ids[rng.random((qn, p)) < frac_pad] = -1
    d[rng.random((qn, p)) < frac_inf] = np.inf
    return d, ids


@pytest.mark.parametrize("qn,p,k,n_ids", [(4, 16, 4, 8), (9, 100, 10, 30),
                                          (32, 256, 50, 100), (2, 8, 3, 1000)])
def test_dedup_topk_ref_matches_naive(qn, p, k, n_ids):
    d, ids = _dedup_case(qn, p, n_ids, seed=qn * p + k)
    d0, i0 = naive_dedup_topk(d, ids, k)
    d1, i1 = ops.dedup_topk(jnp.asarray(d), jnp.asarray(ids), k, impl="ref")
    np.testing.assert_allclose(np.asarray(d1), d0, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(i1), i0)


@pytest.mark.parametrize("qn,p,k,n_ids", [(8, 64, 8, 20), (5, 100, 17, 40),
                                          (16, 128, 100, 60), (3, 7, 12, 4)])
def test_dedup_topk_interpret_matches_naive(qn, p, k, n_ids):
    """Pallas bitonic kernel (interpret mode), incl. non-pow2 pools, row
    padding, and k > P degenerate cases."""
    d, ids = _dedup_case(qn, p, n_ids, seed=qn + p + k)
    d0, i0 = naive_dedup_topk(d, ids, k)
    d1, i1 = ops.dedup_topk(jnp.asarray(d), jnp.asarray(ids), k, impl="interpret")
    np.testing.assert_allclose(np.asarray(d1), d0, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(i1), i0)


@pytest.mark.parametrize("qn,p,k,n_ids", [(4, 16, 4, 8), (9, 100, 10, 30), (2, 8, 12, 5)])
def test_dedup_topk_np_matches_naive(qn, p, k, n_ids):
    """The numpy twin used by the host evaluation engine, incl. negative
    distances (exercises the IEEE-754 total-order key transform)."""
    from repro.kernels.dedup_topk import dedup_topk_np

    rng = np.random.default_rng(qn * p * k)
    ids = rng.integers(0, n_ids, (qn, p)).astype(np.int32)
    d = rng.normal(size=(qn, p)).astype(np.float32)  # negatives included
    ids[rng.random((qn, p)) < 0.15] = -1
    d[rng.random((qn, p)) < 0.15] = np.inf
    d0, i0 = naive_dedup_topk(d, ids, k)
    d1, i1 = dedup_topk_np(d, ids, k)
    np.testing.assert_allclose(d1, d0, rtol=1e-6)
    np.testing.assert_array_equal(i1, i0)


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_dedup_topk_all_invalid_rows(impl):
    """Rows with nothing valid must come back fully inf/-1 padded."""
    d = np.full((4, 16), np.inf, np.float32)
    ids = np.full((4, 16), -1, np.int32)
    ids[0] = 7  # valid ids but all distances masked out -> still invalid
    d[1] = 1.0  # finite distances but all PAD ids -> still invalid
    od, oi = ops.dedup_topk(jnp.asarray(d), jnp.asarray(ids), 5, impl=impl)
    assert not np.isfinite(np.asarray(od)).any()
    assert (np.asarray(oi) == -1).all()


def test_dedup_topk_tie_break_by_id():
    """Distinct ids with bitwise-equal distances straddling the k boundary:
    all three implementations must deterministically prefer the smaller id
    (the naive oracle's (dist, id) order)."""
    from repro.kernels.dedup_topk import dedup_topk_np

    d = np.asarray([[2.0, 1.0, 1.0, 3.0]], np.float32)
    ids = np.asarray([[5, 9, 3, 1]], np.int32)
    for impl in ("ref", "interpret"):
        od, oi = ops.dedup_topk(jnp.asarray(d), jnp.asarray(ids), 2, impl=impl)
        np.testing.assert_array_equal(np.asarray(oi), [[3, 9]])
    od, oi = dedup_topk_np(d, ids, 2)
    np.testing.assert_array_equal(oi, [[3, 9]])
    np.testing.assert_allclose(od, [[1.0, 1.0]])


def test_dedup_topk_keeps_best_replica_distance():
    """A replicated id must surface exactly once, at its minimum distance."""
    d = np.asarray([[5.0, 1.0, 3.0, 2.0]], np.float32)
    ids = np.asarray([[9, 9, 9, 4]], np.int32)
    od, oi = ops.dedup_topk(jnp.asarray(d), jnp.asarray(ids), 3, impl="ref")
    np.testing.assert_array_equal(np.asarray(oi), [[9, 4, -1]])
    np.testing.assert_allclose(np.asarray(od)[0, :2], [1.0, 2.0])
