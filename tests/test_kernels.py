"""Per-kernel allclose sweeps: Pallas (interpret mode) vs jnp oracle across
shapes and dtypes, plus property-based invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("qn,cn,d,k", [(8, 64, 16, 5), (37, 300, 48, 10), (64, 512, 128, 100), (1, 128, 96, 8)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_l2_topk_matches_ref(qn, cn, d, k, dtype):
    rng = np.random.default_rng(qn * cn)
    q = jnp.asarray(rng.normal(size=(qn, d)), dtype)
    c = jnp.asarray(rng.normal(size=(cn, d)), dtype)
    ids = jnp.asarray(np.arange(cn, dtype=np.int32)).at[cn - cn // 8 :].set(-1)
    d1, i1 = ops.l2_topk(q, c, ids, k, impl="interpret", tq=8, tc=64)
    d2, i2 = ref.l2_topk_ref(q, c, ids, k)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=tol, atol=tol)
    # id sets must match allowing ties (discrete_boundary check)
    for r in range(qn):
        assert set(np.asarray(i1)[r].tolist()) == set(np.asarray(i2)[r].tolist())


@pytest.mark.parametrize("qn,n,m,ks", [(8, 64, 8, 16), (16, 256, 16, 256), (3, 130, 4, 32)])
def test_pq_adc_matches_ref(qn, n, m, ks):
    rng = np.random.default_rng(qn * n)
    lut = jnp.asarray(rng.normal(size=(qn, m, ks)).astype(np.float32))
    codes = jnp.asarray(rng.integers(0, ks, size=(n, m)).astype(np.int32))
    a1 = ops.pq_adc(lut, codes, impl="interpret", tq=8, tn=64)
    a2 = ref.pq_adc_ref(lut, codes)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,b,d", [(64, 8, 16), (123, 40, 48), (512, 128, 128)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_kmeans_assign_matches_ref(n, b, d, dtype):
    rng = np.random.default_rng(n * b)
    x = jnp.asarray(rng.normal(size=(n, d)), dtype)
    c = jnp.asarray(rng.normal(size=(b, d)), dtype)
    a1, d1 = ops.kmeans_assign(x, c, impl="interpret", tn=16, tb=8)
    a2, d2 = ref.kmeans_assign_ref(x, c)
    tol = 1e-4 if dtype == jnp.float32 else 1e-1
    # argmin can differ under bf16 rounding only where distances tie
    close = np.isclose(np.asarray(d1), np.asarray(d2), rtol=tol, atol=tol)
    assert close.mean() > 0.99


@settings(max_examples=20, deadline=None)
@given(
    qn=st.integers(1, 16),
    cn=st.integers(8, 128),
    d=st.integers(2, 64),
    k=st.integers(1, 8),
)
def test_l2_topk_properties(qn, cn, d, k):
    """Invariants: outputs sorted ascending, ids valid, dists non-negative,
    and top-1 equals exact argmin."""
    k = min(k, cn)
    rng = np.random.default_rng(qn + cn * 1000 + d)
    q = jnp.asarray(rng.normal(size=(qn, d)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(cn, d)).astype(np.float32))
    ids = jnp.asarray(np.arange(cn, dtype=np.int32))
    dd, ii = ops.l2_topk(q, c, ids, k, impl="ref")
    dd, ii = np.asarray(dd), np.asarray(ii)
    assert (np.diff(dd, axis=1) >= -1e-5).all()
    assert ((ii >= 0) & (ii < cn)).all()
    assert (dd >= -1e-4).all()
    exact = ((np.asarray(q)[:, None] - np.asarray(c)[None]) ** 2).sum(-1)
    np.testing.assert_array_equal(ii[:, 0], exact.argmin(1))


def test_l2_topk_interpret_vs_ref_large_k_padding():
    """k larger than real candidates -> padded ids must be -1-masked."""
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
    ids = jnp.asarray(np.arange(16, dtype=np.int32)).at[8:].set(-1)
    d1, i1 = ops.l2_topk(q, c, ids, 12, impl="interpret", tq=4, tc=8)
    # only 8 valid candidates: the tail of top-12 must be padding
    assert (np.asarray(i1)[:, 8:] == -1).all()
    assert not np.isfinite(np.asarray(d1)[:, 8:]).any() or (np.asarray(d1)[:, 8:] > 1e20).all()
