"""Naive set/dict-based oracle for the replica-aware merge primitive — shared
by the deterministic kernel sweeps and the hypothesis property tests."""
import numpy as np


def naive_dedup_topk(dists: np.ndarray, ids: np.ndarray, k: int):
    """Per-row dict merge: keep each id's best finite distance, sort by
    (dist, id), take k. inf/-1 padded exactly like the kernel."""
    q, p = dists.shape
    out_d = np.full((q, k), np.inf, np.float32)
    out_i = np.full((q, k), -1, np.int32)
    for r in range(q):
        best: dict[int, float] = {}
        for c in range(p):
            idx = int(ids[r, c])
            dist = float(dists[r, c])
            if idx < 0 or not np.isfinite(dist):
                continue
            if idx not in best or dist < best[idx]:
                best[idx] = dist
        top = sorted(best.items(), key=lambda kv: (kv[1], kv[0]))[:k]
        for c, (idx, dist) in enumerate(top):
            out_d[r, c] = dist
            out_i[r, c] = idx
    return out_d, out_i


def naive_pool_recall(pool_d: np.ndarray, pool_i: np.ndarray, gt_ids: np.ndarray, k: int):
    """Per-query recall of the first-k-unique merge — the seed set-loop."""
    qn = pool_d.shape[0]
    order = np.argsort(pool_d, 1)
    pool_d = np.take_along_axis(pool_d, order, 1)
    pool_i = np.take_along_axis(pool_i, order, 1)
    hits = np.zeros(qn, np.float64)
    for r in range(qn):
        seen: set = set()
        for c in range(pool_d.shape[1]):
            i = int(pool_i[r, c])
            if i < 0 or not np.isfinite(pool_d[r, c]) or i in seen:
                continue
            seen.add(i)
            if len(seen) == k:
                break
        hits[r] = len(seen & set(gt_ids[r, :k].tolist()))
    return hits / k
