"""Deprecation-shim contract tests (ISSUE 5).

The redesign keeps two legacy surfaces alive for one release:
  * tuple-unpacking a SearchResult as (dists, ids, nprobe_eff, overflow);
  * the quantized=/residual= boolean kwargs on LiraEngine.build / search.
Both must warn EXACTLY ONCE (per result object / per process surface) and
produce results identical to the new typed API. Tier-1 runs with
``-W error::DeprecationWarning`` (pyproject filterwarnings), so this module —
the only place allowed to touch the legacy surface — carries an explicit
allowlist mark; everywhere else a deprecated call is a test failure.
"""
import warnings

import numpy as np
import pytest

from repro.data import make_vector_dataset
from repro.launch.mesh import make_test_mesh
from repro.serving import BuildConfig, LiraEngine, SearchRequest
from repro.serving import api

# the allowlist: shim tests legitimately emit DeprecationWarning
pytestmark = pytest.mark.filterwarnings("default::DeprecationWarning")

BUILD = dict(n_partitions=4, k=5, eta=0.0, train_frac=0.4, epochs=1,
             nprobe_max=4)


@pytest.fixture(scope="module")
def dataset():
    return make_vector_dataset(n=800, n_queries=16, dim=16, n_modes=8, seed=9)


@pytest.fixture(scope="module")
def engine(dataset):
    return LiraEngine.build(make_test_mesh(), dataset.base, BuildConfig(
        tier="residual_pq", pq_m=4, pq_ks=16, rerank=4, **BUILD))


def _deprecations(record):
    return [w for w in record if issubclass(w.category, DeprecationWarning)]


# ------------------------------------------------------------- tuple shim

def test_tuple_unpacking_warns_once_and_matches_fields(engine, dataset):
    res = engine.search(SearchRequest(queries=dataset.queries, sigma=-1.0))
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        d, i, npb, ovf = res           # legacy 4-tuple unpack
        d2 = res[0]                    # legacy indexing, same result object
        assert len(res) == 4
    assert len(_deprecations(rec)) == 1  # once per result, not per access
    np.testing.assert_array_equal(d, res.dists)
    np.testing.assert_array_equal(d2, res.dists)
    np.testing.assert_array_equal(i, res.ids)
    np.testing.assert_array_equal(npb, res.nprobe_eff)
    assert ovf == res.overflow
    # a fresh result re-arms the shim: each legacy call site gets its warning
    res2 = engine.search(SearchRequest(queries=dataset.queries, sigma=-1.0))
    with warnings.catch_warnings(record=True) as rec2:
        warnings.simplefilter("always")
        _, _, _, _ = res2
    assert len(_deprecations(rec2)) == 1


def test_named_field_access_never_warns(engine, dataset):
    res = engine.search(SearchRequest(queries=dataset.queries, sigma=-1.0))
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        _ = res.dists, res.ids, res.nprobe_eff, res.overflow, res.stats
    assert not _deprecations(rec)


# ----------------------------------------------------------- legacy kwargs

def test_legacy_build_kwargs_warn_once_and_match_new_api(dataset):
    api.reset_deprecation_warnings()
    mesh = make_test_mesh()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        legacy = LiraEngine.build(mesh, dataset.base, quantized=True,
                                  residual=True, pq_m=4, pq_ks=16, rerank=4,
                                  **BUILD)
        again = LiraEngine.build(mesh, dataset.base, residual=True,
                                 pq_m=4, pq_ks=16, rerank=4, **BUILD)
    assert len(_deprecations(rec)) == 1  # once per process, not per call
    new = LiraEngine.build(mesh, dataset.base, BuildConfig(
        tier="residual_pq", pq_m=4, pq_ks=16, rerank=4, **BUILD))
    assert legacy.cfg == again.cfg == new.cfg
    assert legacy.cfg.tier == "residual_pq"
    r_legacy = legacy.search(SearchRequest(queries=dataset.queries, sigma=-1.0))
    r_new = new.search(SearchRequest(queries=dataset.queries, sigma=-1.0))
    np.testing.assert_array_equal(r_legacy.dists, r_new.dists)
    np.testing.assert_array_equal(r_legacy.ids, r_new.ids)
    assert r_legacy.overflow == r_new.overflow


def test_legacy_search_kwarg_warns_once_and_matches_tier(engine, dataset):
    api.reset_deprecation_warnings()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        r_old_q = engine.search(dataset.queries, sigma=-1.0, quantized=True)
        r_old_f = engine.search(dataset.queries, sigma=-1.0, quantized=False)
    assert len(_deprecations(rec)) == 1
    # quantized=True on a residual engine meant the residual tier (old
    # semantics: the boolean picked the branch, cfg.residual_pq the mode)
    r_new_q = engine.search(SearchRequest(queries=dataset.queries, sigma=-1.0,
                                          tier="residual_pq"))
    r_new_f = engine.search(SearchRequest(queries=dataset.queries, sigma=-1.0,
                                          tier="f32"))
    np.testing.assert_array_equal(r_old_q.dists, r_new_q.dists)
    np.testing.assert_array_equal(r_old_q.ids, r_new_q.ids)
    np.testing.assert_array_equal(r_old_f.dists, r_new_f.dists)
    np.testing.assert_array_equal(r_old_f.ids, r_new_f.ids)


def test_single_query_raw_array_warns_and_matches_search_one(engine, dataset):
    """ISSUE 6 shim: raw single-query arrays + loose kwargs on ``search`` are
    deprecated in favor of ``search_one(SearchRequest(...))`` — the canonical
    entry point that routes through the batching front-end when attached.
    Both 1-row [1, dim] and bare [dim] shapes warn once per process and
    return exactly what search_one returns."""
    api.reset_deprecation_warnings()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        legacy_row = engine.search(dataset.queries[:1], sigma=-1.0)
        legacy_1d = engine.search(dataset.queries[0], sigma=-1.0)
    assert len(_deprecations(rec)) == 1  # once per process, not per call
    assert "search_one" in str(rec[0].message)
    new = engine.search_one(SearchRequest(queries=dataset.queries[0],
                                          sigma=-1.0))
    assert new.dists.shape == legacy_row.dists.shape == legacy_1d.dists.shape
    np.testing.assert_array_equal(legacy_row.dists, new.dists)
    np.testing.assert_array_equal(legacy_1d.dists, new.dists)
    np.testing.assert_array_equal(legacy_row.ids, new.ids)
    np.testing.assert_array_equal(legacy_1d.ids, new.ids)
    # multi-row raw batches stay first-class: no warning
    with warnings.catch_warnings(record=True) as rec2:
        warnings.simplefilter("always")
        engine.search(dataset.queries[:2], sigma=-1.0)
    assert not _deprecations(rec2)


def test_request_plus_kwargs_rejected(engine, dataset):
    req = SearchRequest(queries=dataset.queries)
    with pytest.raises(TypeError, match="SearchRequest"):
        engine.search(req, sigma=0.3)
    with pytest.raises(TypeError, match="BuildConfig"):
        LiraEngine.build(make_test_mesh(), dataset.base,
                         BuildConfig(**BUILD), k=5)


def test_config_boolean_aliases_derive_from_tier():
    """The config keeps quantized/residual_pq as read-only derived aliases;
    tier wins when both are present (dataclasses.replace keeps the old tier,
    so boolean 'edits' on a resolved config are no-ops by design)."""
    from repro.configs.base import LiraSystemConfig

    legacy = LiraSystemConfig(arch="t", dim=16, n_partitions=4, capacity=32,
                              k=5, nprobe_max=4, quantized=True,
                              residual_pq=True)
    assert legacy.tier == "residual_pq"
    new = LiraSystemConfig(arch="t", dim=16, n_partitions=4, capacity=32,
                           k=5, nprobe_max=4, tier="pq")
    assert new.quantized and not new.residual_pq
    # pre-redesign, residual_pq without quantized served the plain f32 scan
    # (residual was a mode OF the quantized tier) — preserved, and the stale
    # boolean re-derives to keep the aliases self-consistent with the tier
    stale = LiraSystemConfig(arch="t", dim=16, n_partitions=4, capacity=32,
                             k=5, nprobe_max=4, residual_pq=True)
    assert stale.tier == "f32" and not stale.quantized and not stale.residual_pq
