"""System-behaviour tests for the LIRA core: k-means, store, probing model,
redundancy, retrieval, baselines — the paper's pipeline end to end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, build_store, centroid_distances, kmeans_fit, probing, store_stats
from repro.core import ground_truth as gt
from repro.core import retrieval as ret
from repro.core.partitions import PAD_ID
from repro.core.redundancy import plan_redundancy, replica_rows
from repro.core.train_probing import train_probing_model


def test_kmeans_converges(small_dataset):
    ds = small_dataset
    st5 = kmeans_fit(jax.random.PRNGKey(0), jnp.asarray(ds.base), n_clusters=16, n_iters=5)
    st20 = kmeans_fit(jax.random.PRNGKey(0), jnp.asarray(ds.base), n_clusters=16, n_iters=20)
    assert float(st20.inertia) <= float(st5.inertia) * 1.001
    assert np.asarray(st20.assign).min() >= 0 and np.asarray(st20.assign).max() < 16


def test_store_roundtrip(small_index, small_dataset):
    store, assign, cents, gti, k = small_index
    ds = small_dataset
    stats = store_stats(store)
    assert stats["total"] == len(ds.base)
    # every non-pad row holds the original vector
    ids = np.asarray(store.ids)
    vecs = np.asarray(store.vectors)
    for b in [0, 5, 11]:
        for c in range(min(4, int(np.asarray(store.counts)[b]))):
            i = ids[b, c]
            assert i != PAD_ID
            np.testing.assert_array_equal(vecs[b, c], ds.base[i])
            assert assign[i] == b


def test_knn_count_distribution_sums_to_k(small_index):
    store, assign, cents, gti, k = small_index
    ncd = gt.knn_count_distribution(gti, assign, store.n_partitions)
    assert (ncd.sum(-1) == k).all()
    labels = gt.knn_partition_labels(gti, assign, store.n_partitions)
    assert ((labels == 0) | (labels == 1)).all()
    assert (gt.optimal_nprobe(labels) >= 1).all()


def test_nprobe_dist_upper_bounds_nprobe_star(small_index, small_dataset):
    """The paper's Limit 1: nprobe*_dist >= nprobe* always."""
    store, assign, cents, gti, k = small_index
    labels = gt.knn_partition_labels(gti, assign, store.n_partitions)
    nstar = gt.optimal_nprobe(labels)
    ndist = gt.nprobe_dist(gti, assign, small_dataset.queries, cents)
    assert (ndist >= nstar).all()


def test_ivf_full_probe_is_exact(small_index, small_dataset):
    """Probing ALL partitions must reach recall 1.0 (evaluation-engine check)."""
    store, assign, cents, gti, k = small_index
    ptk = ret.partition_topk(store, small_dataset.queries, k)
    mask = np.ones((len(small_dataset.queries), store.n_partitions), bool)
    res = ret.evaluate_probe(ptk, mask, gti, k)
    assert res.recall == pytest.approx(1.0)
    assert res.cmp_mean == pytest.approx(len(small_dataset.base))


def test_ivf_recall_monotone_in_nprobe(small_index, small_dataset):
    store, assign, cents, gti, k = small_index
    ptk = ret.partition_topk(store, small_dataset.queries, k)
    cd = ret.lira_inputs(store, small_dataset.queries)
    recalls = [ret.evaluate_probe(ptk, ret.probe_ivf(cd, n), gti, k).recall for n in (1, 2, 4, 8, 16)]
    assert all(b >= a - 1e-9 for a, b in zip(recalls, recalls[1:]))
    assert recalls[-1] == pytest.approx(1.0)


@pytest.fixture(scope="module")
def trained_probing(small_index, small_dataset):
    store, assign, cents, gti, k = small_index
    ds = small_dataset
    sub = np.random.default_rng(1).choice(len(ds.base), 4000, replace=False)
    xs = ds.base[sub]
    _, sti = gt.exact_knn(xs, xs, k, exclude_self=True)
    part_of = assign[sub]
    lab = np.stack([np.bincount(part_of[row], minlength=store.n_partitions) for row in sti])
    lab = (lab > 0).astype(np.float32)
    params, tlog = train_probing_model(jax.random.PRNGKey(2), xs, lab, cents, epochs=5, batch=256, lr=2e-3)
    return params, tlog


def test_probing_model_converges(trained_probing):
    """Paper Fig 11: loss decreases, partition-recall converges high. (The
    paper's own post-training hit rate is ~0.8 — σ tuning closes the rest.)"""
    params, tlog = trained_probing
    assert tlog.losses[-1] < tlog.losses[0] * 0.5
    assert tlog.recalls[-1] > 0.8


def test_lira_beats_ivf_tradeoff(small_index, small_dataset, trained_probing):
    """Core paper claim: at comparable recall, LIRA probes fewer points."""
    store, assign, cents, gti, k = small_index
    params, _ = trained_probing
    ds = small_dataset
    ptk = ret.partition_topk(store, ds.queries, k)
    cd = ret.lira_inputs(store, ds.queries)
    p_hat = np.asarray(probing.probs(params, jnp.asarray(ds.queries), jnp.asarray(cd)))

    lira = ret.evaluate_probe(ptk, ret.probe_lira(p_hat, 0.1), gti, k)
    # IVF needing >= lira recall
    for n in range(1, store.n_partitions + 1):
        ivf = ret.evaluate_probe(ptk, ret.probe_ivf(cd, n), gti, k)
        if ivf.recall >= lira.recall - 1e-9:
            break
    assert lira.recall > 0.9
    assert lira.cmp_mean < ivf.cmp_mean


def test_probe_mask_always_includes_argmax(small_index, small_dataset, trained_probing):
    """predict_probe_mask mirrors the serve step's ≥1-probe guarantee: at any
    σ every query keeps its arg-max partition, so training-time nprobe/recall
    metrics (_probe_quality) no longer understate serving behavior at high σ
    where a threshold-only mask goes empty."""
    store, assign, cents, gti, k = small_index
    params, _ = trained_probing
    ds = small_dataset
    q = jnp.asarray(ds.queries)
    cd = jnp.asarray(ret.lira_inputs(store, ds.queries))
    # σ=1: sigmoid(p̂) < 1 everywhere, so the threshold alone selects nothing
    mask, p = probing.predict_probe_mask(params, q, cd, sigma=1.0)
    mask, p = np.asarray(mask), np.asarray(p)
    assert (mask.sum(-1) >= 1).all()
    rows = np.arange(len(p))
    assert mask[rows, p.argmax(-1)].all()       # the kept partition is arg-max
    assert (np.asarray(probing.predicted_nprobe(params, q, cd, 1.0)) >= 1).all()
    # at moderate σ the forced arg-max is a superset of the raw threshold mask
    mask_mid, _ = probing.predict_probe_mask(params, q, cd, sigma=0.5)
    assert (np.asarray(mask_mid) >= (p > 0.5)).all()


def test_redundancy_reduces_nprobe(small_index, small_dataset, trained_probing):
    """Insight 2: duplicating long-tail points lowers cost at matched recall."""
    store, assign, cents, gti, k = small_index
    params, _ = trained_probing
    ds = small_dataset
    ids = np.arange(len(ds.base), dtype=np.int32)
    plan = plan_redundancy(params, ds.base, assign, cents, eta=0.15)
    extra = replica_rows(plan, ds.base, ids)
    assert len(extra[1]) == int(round(0.15 * len(ds.base)))
    # replica target differs from home partition
    assert (extra[2] != assign[plan.picked]).all()
    store_r = build_store(ds.base, ids, assign, cents, extra=extra)
    assert store_stats(store_r)["total"] == len(ds.base) + len(extra[1])


def test_ivf_fuzzy_duplicates_everything(small_dataset):
    ds = small_dataset
    store = baselines.build_ivf_fuzzy(jax.random.PRNGKey(0), ds.base, 16)
    assert store_stats(store)["total"] == 2 * len(ds.base)


def test_ivfpq_reconstruction_recall(small_dataset):
    """IVFPQ ranks by ADC == reconstruction-L2; recall well below flat (the
    paper's 'IVFPQ can hardly achieve the desired recall') but far above the
    k/N random floor, at full probe."""
    ds = small_dataset
    k = 10
    _, gti = gt.exact_knn(ds.queries, ds.base, k)
    idx = baselines.build_ivfpq(jax.random.PRNGKey(0), ds.base, 16, m=8, ks=64)
    ptk = ret.partition_topk(idx.store, ds.queries, k)
    mask = np.ones((len(ds.queries), 16), bool)
    res = ret.evaluate_probe(ptk, mask, gti, k)
    assert 0.2 < res.recall < 1.0


def test_adc_equals_reconstruction_distance(small_dataset):
    """The pq.py fact: LUT ADC == L2 to decoded vectors (non-residual PQ)."""
    from repro.core import pq as pqmod

    ds = small_dataset
    pq = pqmod.train_pq(jax.random.PRNGKey(1), ds.base[:2000], m=8, ks=32, n_iters=6)
    codes = pqmod.encode(pq, ds.base[:256])
    recon = pqmod.decode(pq, codes)
    q = jnp.asarray(ds.queries[:16])
    adc = np.asarray(pqmod.adc_distances(pq, q, jnp.asarray(codes)))
    exact = ((ds.queries[:16, None] - recon[None]) ** 2).sum(-1)
    np.testing.assert_allclose(adc, exact, rtol=2e-4, atol=2e-4)


def test_bliss_groups_route(small_dataset):
    ds = small_dataset
    k = 10
    _, gti = gt.exact_knn(ds.queries, ds.base, k)
    _, knn_ids = gt.exact_knn(ds.base[:3000], ds.base[:3000], 5, exclude_self=True)
    groups = baselines.build_bliss(jax.random.PRNGKey(3), ds.base[:3000], 8, n_groups=2,
                                   knn_ids=knn_ids, reparts=1, epochs=2)
    _, gti3 = gt.exact_knn(ds.queries, ds.base[:3000], k)
    ptks = [ret.partition_topk(g.store, ds.queries, k) for g in groups]
    masks = [ret.probe_topn(baselines.bliss_scores(g, ds.queries), 3) for g in groups]
    res = ret.merge_groups(ptks, masks, gti3, k, [g.assign for g in groups], 3000)
    assert res.recall > 0.3  # routing is learned, not random
    assert res.cmp_mean <= 3000
