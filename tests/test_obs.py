"""Observability layer tests (ISSUE 7) — registry semantics, histogram
bucket math, span nesting on FakeClock, and the serving integration gates:

  * engine/front-end counters and distributions land in the registry with
    the right labels (and several front-ends sharing one registry stay
    isolated via their auto-generated ``frontend=`` label);
  * per-request stage breakdowns sum exactly to end-to-end latency under a
    shared virtual clock;
  * the regression that keeps tracing safe to leave on: tracing-on results
    are bit-identical to tracing-off across {f32, pq, residual_pq} ×
    {ref, interpret}.

All wall-clock-free: tracers run on FakeClock (or are compared only for
structure), so nothing here can flake on a loaded CI box.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FrontendConfig, LiraSystemConfig
from repro.core import probing
from repro.launch.mesh import make_test_mesh
from repro.obs import (NOOP, MetricsRegistry, Tracer, default_registry,
                       parse_exposition)
from repro.obs.metrics import LATENCY_BUCKETS_MS, Histogram
from repro.serving import (FakeClock, LiraEngine, SearchRequest,
                           ServingFrontend)
from repro.serving.quantized import build_quantized_store

# ------------------------------------------------------------------ registry


def test_counter_inc_value_labels():
    reg = MetricsRegistry()
    c = reg.counter("hits", "help text")
    c.inc(tier="f32")
    c.inc(2, tier="pq")
    c.inc(tier="pq")
    assert c.value(tier="f32") == 1
    assert c.value(tier="pq") == 3
    assert c.value(tier="nope") == 0
    assert c.total() == 4
    assert c.total(tier="pq") == 3


def test_counter_rejects_decrease():
    c = MetricsRegistry().counter("c")
    with pytest.raises(ValueError, match="cannot decrease"):
        c.inc(-1)


def test_registry_get_or_create_and_kind_conflict():
    reg = MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")
    with pytest.raises(TypeError, match="already registered as counter"):
        reg.gauge("x")
    reg.histogram("h")
    with pytest.raises(ValueError, match="different buckets"):
        reg.histogram("h", buckets=(1.0, 2.0))
    assert reg.get("x") is reg.counter("x")
    assert reg.get("absent") is None
    assert "x" in reg.names() and "h" in reg.names()


def test_gauge_last_write_wins():
    g = MetricsRegistry().gauge("q_cap")
    g.set(2.0)
    g.set(4.0)
    assert g.value() == 4.0


def test_default_registry_is_shared():
    assert default_registry() is default_registry()


# ----------------------------------------------------------------- histogram


def test_latency_buckets_log_spaced():
    """Fixed log-spaced edges: 4 per decade, constant ratio 10^0.25, spanning
    tens of microseconds to tens of seconds of milliseconds-denominated
    latency."""
    edges = np.asarray(LATENCY_BUCKETS_MS)
    ratios = edges[1:] / edges[:-1]
    np.testing.assert_allclose(ratios, 10 ** 0.25, rtol=1e-12)
    assert edges[0] == pytest.approx(10 ** -1.5)
    assert edges[-1] == pytest.approx(10 ** 4)


def test_histogram_bucket_assignment_le_semantics():
    h = Histogram("h", buckets=(1.0, 10.0, 100.0))
    for v in (0.5, 1.0, 5.0, 10.0, 99.0, 1000.0):
        h.observe(v)
    # le-semantics: a value equal to an edge lands in that edge's bucket
    np.testing.assert_array_equal(h.counts(), [2, 2, 1, 1])
    assert h.count() == 6
    assert h.sum() == pytest.approx(0.5 + 1.0 + 5.0 + 10.0 + 99.0 + 1000.0)


def test_histogram_rejects_unsorted_buckets():
    with pytest.raises(ValueError, match="strictly increasing"):
        Histogram("h", buckets=(2.0, 1.0))


def test_histogram_quantile_degenerate_is_exact():
    """All observations equal → min == max clamps the interpolation to the
    exact value, for any q (the FrontendStats p50==p99 contract)."""
    h = Histogram("h")
    for _ in range(10):
        h.observe(1.1)
    assert h.quantile(0.5) == 1.1
    assert h.quantile(0.99) == 1.1


def test_histogram_quantile_bounded_by_observations():
    h = Histogram("h")
    vals = np.linspace(0.2, 7.7, 40)
    h.observe_many(vals)
    for q in (0.0, 0.25, 0.5, 0.9, 1.0):
        est = h.quantile(q)
        assert vals.min() <= est <= vals.max()
    # interpolation is monotone and roughly tracks the true quantile
    assert h.quantile(0.5) == pytest.approx(np.quantile(vals, 0.5), rel=0.5)
    assert h.quantile(0.25) <= h.quantile(0.75)


def test_histogram_empty_quantile_and_bad_q():
    h = Histogram("h")
    assert h.quantile(0.5) == 0.0
    h.observe(1.0)
    with pytest.raises(ValueError, match="outside"):
        h.quantile(1.5)


def test_histogram_observe_many_matches_loop():
    h1, h2 = Histogram("a"), Histogram("b")
    vals = np.random.default_rng(0).lognormal(0, 2, 200)
    h1.observe_many(vals, tier="x")
    for v in vals:
        h2.observe(v, tier="x")
    np.testing.assert_array_equal(h1.counts(tier="x"), h2.counts(tier="x"))
    assert h1.sum(tier="x") == pytest.approx(h2.sum(tier="x"))


def test_render_parse_round_trip():
    reg = MetricsRegistry()
    reg.counter("srv_total", "served").inc(3, tier="f32", impl="ref")
    reg.gauge("depth").set(7)
    h = reg.histogram("lat_ms", buckets=(1.0, 10.0))
    h.observe_many([0.5, 5.0, 50.0], frontend="fe0")
    text = reg.render()
    parsed = parse_exposition(text)
    assert parsed['srv_total{impl="ref",tier="f32"}'] == 3
    assert parsed["depth"] == 7
    assert parsed['lat_ms_bucket{frontend="fe0",le="1"}'] == 1
    assert parsed['lat_ms_bucket{frontend="fe0",le="10"}'] == 2
    assert parsed['lat_ms_bucket{frontend="fe0",le="+Inf"}'] == 3
    assert parsed['lat_ms_count{frontend="fe0"}'] == 3
    assert parsed['lat_ms_sum{frontend="fe0"}'] == pytest.approx(55.5)


def test_parse_exposition_rejects_garbage():
    with pytest.raises(ValueError, match="unparseable"):
        parse_exposition("this is { not a metric")
    with pytest.raises(ValueError, match="non-numeric"):
        parse_exposition("name notafloat")


# -------------------------------------------------------------------- tracer


def test_span_nesting_and_durations_on_fake_clock():
    clock = FakeClock()
    tr = Tracer(clock=clock)
    with tr.span("outer", tier="f32") as outer:
        clock.advance(1e-3)
        with tr.span("inner") as inner:
            clock.advance(2e-3)
        clock.advance(0.5e-3)
    assert inner.parent_id == outer.span_id
    assert outer.parent_id is None
    assert inner.duration_ms == pytest.approx(2.0)
    assert outer.duration_ms == pytest.approx(3.5)
    assert outer.attrs == {"tier": "f32"}
    # children recorded before parents (finish order), both retained
    assert [s.name for s in tr.finished()] == ["inner", "outer"]
    assert tr.children(outer) == [inner]
    assert tr.finished("inner") == [inner]


def test_span_attrs_set_inside_block():
    tr = Tracer(clock=FakeClock())
    with tr.span("s") as sp:
        sp.set(rows=32)
    assert tr.finished("s")[0].attrs == {"rows": 32}


def test_span_open_duration_is_zero():
    tr = Tracer(clock=FakeClock())
    with tr.span("s") as sp:
        assert sp.duration_ms == 0.0


def test_tracer_ring_is_bounded():
    tr = Tracer(clock=FakeClock(), max_spans=5)
    for i in range(12):
        with tr.span(f"s{i}"):
            pass
    assert [s.name for s in tr.finished()] == [f"s{i}" for i in range(7, 12)]


def test_jsonl_export_and_sink(tmp_path):
    clock = FakeClock()
    sunk = []
    tr = Tracer(clock=clock, sink=sunk.append)
    with tr.span("a"):
        clock.advance(1e-3)
    assert sunk and sunk[0]["name"] == "a"
    path = tmp_path / "spans.jsonl"
    assert tr.export_jsonl(str(path)) == 1
    rec = json.loads(path.read_text().splitlines()[0])
    assert rec["name"] == "a"
    assert rec["duration_ms"] == pytest.approx(1.0)
    assert rec["parent_id"] is None


def test_jsonl_file_sink(tmp_path):
    path = tmp_path / "stream.jsonl"
    tr = Tracer(clock=FakeClock(), sink=str(path))
    with tr.span("x"):
        pass
    with tr.span("y"):
        pass
    tr.close()
    names = [json.loads(line)["name"] for line in path.read_text().splitlines()]
    assert names == ["x", "y"]


def test_noop_tracer_is_inert():
    assert NOOP.enabled is False
    with NOOP.span("anything", tier="f32") as sp:
        sp.set(ignored=1)
        assert sp.duration_ms == 0.0
    assert NOOP.finished() == []


# --------------------------------------------------- serving integration


@pytest.fixture(scope="module")
def obs_engines():
    """Direct-store engines for all three tiers over one partition layout —
    the cheap fixture pattern from test_frontend.py, extended with PQ and
    residual-PQ code planes so the bit-identical gate covers every tier."""
    host = np.random.default_rng(11)
    b, cap, dim, k = 4, 48, 16, 5
    vecs = host.normal(0, 1, (b, cap, dim)).astype(np.float32)
    ids = np.arange(b * cap, dtype=np.int32).reshape(b, cap)
    cents = vecs.mean(1)
    params = probing.init(jax.random.PRNGKey(0),
                          probing.ProbingConfig(dim=dim, n_partitions=b))
    cfg = LiraSystemConfig(arch="t", dim=dim, n_partitions=b, capacity=cap,
                           k=k, nprobe_max=b, pq_m=4, pq_ks=16, rerank=2)
    base = {"centroids": jnp.asarray(cents), "vectors": jnp.asarray(vecs),
            "ids": jnp.asarray(ids)}
    qs = build_quantized_store(jax.random.PRNGKey(1), base["vectors"],
                               base["ids"], m=4, ks=16)
    qr = build_quantized_store(jax.random.PRNGKey(1), base["vectors"],
                               base["ids"], m=4, ks=16, residual=True,
                               centroids=base["centroids"])
    mesh = make_test_mesh()

    def eng(tier, store):
        return LiraEngine(cfg=dataclasses.replace(cfg, tier=tier),
                          params=params, store=store, mesh=mesh, sigma=-1.0)

    engines = {
        "f32": eng("f32", base),
        "pq": eng("pq", {**base, "codes": qs.codes, "codebooks": qs.codebooks}),
        "residual_pq": eng("residual_pq",
                           {**base, "codes": qr.codes,
                            "codebooks": qr.codebooks, "cterm": qr.cterm}),
    }
    q = host.normal(0, 1, (12, dim)).astype(np.float32)
    return engines, q


@pytest.mark.parametrize("tier", ["f32", "pq", "residual_pq"])
@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_tracing_is_bit_identical(obs_engines, tier, impl):
    """The regression that keeps tracing safe to leave on in production:
    attaching a tracer (and a registry) must not change a single bit of the
    answer on any tier × scan backend."""
    engines, q = obs_engines
    eng = engines[tier]
    req = SearchRequest(queries=q, impl=impl)
    eng.tracer, eng.metrics = None, None
    off = eng.search(req)
    eng.tracer, eng.metrics = Tracer(), MetricsRegistry()
    try:
        on = eng.search(req)
    finally:
        eng.tracer, eng.metrics = None, None
    np.testing.assert_array_equal(off.dists, on.dists)
    np.testing.assert_array_equal(off.ids, on.ids)
    np.testing.assert_array_equal(off.nprobe_eff, on.nprobe_eff)
    assert off.overflow == on.overflow
    assert off.stats.dedup_hits == on.stats.dedup_hits
    # and the traced call actually carried its breakdown
    assert off.stats.stages is None
    assert set(on.stats.stages) == {"prepare", "device", "post"}


def test_engine_metrics_and_stage_sum(obs_engines):
    engines, q = obs_engines
    eng = engines["f32"]
    reg = MetricsRegistry()
    eng.tracer, eng.metrics = Tracer(), reg
    try:
        res = eng.search(SearchRequest(queries=q))
        res2 = eng.search(SearchRequest(queries=q))
    finally:
        eng.tracer, eng.metrics = None, None
    lbl = {"tier": "f32", "impl": "ref"}
    assert reg.counter("lira_engine_searches_total").value(**lbl) == 2
    assert reg.counter("lira_engine_rows_total").value(**lbl) == 24
    # the serve step was warmed by other tests on the engine's own cache key,
    # but THIS registry only saw these two calls: hits + misses == 2
    hits = reg.counter("lira_engine_jit_cache_hits_total").value(**lbl)
    misses = reg.counter("lira_engine_jit_cache_misses_total").value(**lbl)
    assert hits + misses == 2
    assert reg.histogram("lira_engine_nprobe_eff").count(**lbl) == 24
    # σ=-1 probes everything: nprobe_eff == n_partitions for every query
    assert reg.histogram("lira_engine_nprobe_eff").sum(**lbl) == 24 * 4
    assert reg.counter("lira_engine_probes_total").value(**lbl) == 24 * 4
    assert eng.overflow_rate() == 0.0
    # stage breakdown sums to the traced end-to-end latency (host timers
    # around contiguous stages; the gap is span bookkeeping itself)
    for r in (res, res2):
        assert r.stats.latency_ms > 0
        assert sum(r.stats.stages.values()) <= r.stats.latency_ms
        assert sum(r.stats.stages.values()) >= 0.5 * r.stats.latency_ms


def test_overflow_rate_counts_dropped_probes_once(obs_engines):
    """Bugfix regression: ``lira_engine_probes_total`` counts ATTEMPTED
    probes (nprobe_eff sums probe_ok before q_cap drops), so the rate is
    dropped/attempted — the old ``dropped + dispatched`` denominator counted
    every dropped probe twice and under-reported the rate."""
    engines, q = obs_engines
    src = engines["f32"]
    reg = MetricsRegistry()
    # q_cap sized far below the σ=-1 fan-out → forced overflow
    eng = LiraEngine(cfg=dataclasses.replace(src.cfg, q_cap_factor=0.25),
                     params=src.params, store=src.store, mesh=src.mesh,
                     sigma=-1.0, metrics=reg)
    res = eng.search(SearchRequest(queries=q))
    dropped = reg.counter("lira_engine_overflow_probes_total").total()
    attempted = reg.counter("lira_engine_probes_total").total()
    assert dropped == res.overflow > 0
    # σ=-1 probes every partition for every row — all attempts are counted,
    # including the ones q_cap later dropped
    assert attempted == len(q) * src.cfg.n_partitions
    assert eng.overflow_rate() == pytest.approx(dropped / attempted)
    # the buggy denominator under-reported exactly like this:
    assert eng.overflow_rate() > dropped / (dropped + attempted)


def test_q_cap_bump_is_observable(obs_engines):
    engines, _ = obs_engines
    src = engines["f32"]
    reg = MetricsRegistry()
    eng = LiraEngine(cfg=dataclasses.replace(src.cfg, auto_q_cap=True),
                     params=src.params, store=src.store, mesh=src.mesh,
                     sigma=-1.0, metrics=reg)
    factor0 = eng.cfg.q_cap_factor
    eng._maybe_bump_q_cap(5)
    assert reg.counter("lira_engine_q_cap_bumps_total").total() == 0
    eng._maybe_bump_q_cap(5)    # second consecutive overflow → bump
    assert reg.counter("lira_engine_q_cap_bumps_total").total() == 1
    assert reg.gauge("lira_engine_q_cap_factor").value() == 2 * factor0
    assert eng.cfg.q_cap_factor == 2 * factor0


# ------------------------------------------------------------ front-end obs


def _traced_frontend(eng, **cfg_kw):
    clock = FakeClock()
    reg = MetricsRegistry()
    tr = Tracer(clock=clock)   # spans on the VIRTUAL clock: exact durations
    defaults = dict(max_batch=8, max_wait_ms=2.0, max_queue=16)
    defaults.update(cfg_kw)
    fe = ServingFrontend(eng, FrontendConfig(**defaults), clock=clock,
                         tracer=tr, metrics=reg)
    return fe, clock, reg, tr


def test_frontend_stage_breakdown_sums_to_latency(obs_engines):
    """Under one shared virtual clock every real-time stage is 0ms wide and
    queue wait is the whole latency — the stage sum is EXACTLY e2e."""
    engines, q = obs_engines
    eng = engines["f32"]
    fe, clock, reg, tr = _traced_frontend(eng)
    eng.tracer = tr            # engine spans nest under frontend.batch
    try:
        pends = [fe.submit(SearchRequest(queries=q[i])) for i in range(2)]
        clock.advance(2.1e-3)
        fe.poll()
    finally:
        eng.tracer = None
    for p in pends:
        st = p.result().stats
        assert st.latency_ms == pytest.approx(2.1)
        assert st.stages["queue"] == pytest.approx(2.1)
        assert sum(st.stages.values()) == pytest.approx(st.latency_ms)
        assert set(st.stages) == {"queue", "assemble", "serve.prepare",
                                  "serve.device", "serve.post"}
    # span hierarchy: engine.search is a child of frontend.batch
    batch = tr.finished("frontend.batch")[0]
    search = tr.finished("engine.search")[0]
    assert search.parent_id == batch.span_id
    # aggregated per-stage histograms landed under this frontend's label
    hs = reg.histogram("lira_frontend_stage_ms")
    assert hs.count(frontend=fe.name, stage="serve.device") == 1
    assert hs.count(frontend=fe.name, stage="assemble") == 1
    assert hs.count(frontend=fe.name, stage="scatter") == 1


def test_frontend_counters_and_isolation(obs_engines):
    """Two front-ends on ONE registry stay separate via the frontend label."""
    engines, q = obs_engines
    eng = engines["f32"]
    reg = MetricsRegistry()
    clock = FakeClock()
    fe_a = ServingFrontend(eng, FrontendConfig(max_batch=4), clock=clock,
                           metrics=reg)
    fe_b = ServingFrontend(eng, FrontendConfig(max_batch=4), clock=clock,
                           metrics=reg)
    assert fe_a.name != fe_b.name
    for i in range(4):
        fe_a.submit(SearchRequest(queries=q[i]))
    fe_a.drain()
    fe_b.submit(SearchRequest(queries=q[0]))
    fe_b.drain()
    assert fe_a.stats().served == 4
    assert fe_b.stats().served == 1
    assert fe_a.stats().batches == 1
    c = reg.counter("lira_frontend_served_total")
    assert c.value(frontend=fe_a.name) == 4
    assert c.value(frontend=fe_b.name) == 1


def test_frontend_qps_needs_two_completions(obs_engines):
    """One completion has no span to divide rows by — qps must read 0.0, not
    rows / epsilon."""
    engines, q = obs_engines
    eng = engines["f32"]
    fe, clock, reg, _ = _traced_frontend(eng)
    fe.submit(SearchRequest(queries=q[0]))
    clock.advance(5e-3)
    fe.poll()
    st = fe.stats()
    assert st.served == 1
    assert st.qps == 0.0
    assert st.p50_ms == pytest.approx(5.0)  # degenerate histogram is exact
    # a second completion establishes a span: qps becomes finite
    fe.submit(SearchRequest(queries=q[1]))
    clock.advance(5e-3)
    fe.poll()
    st = fe.stats()
    assert st.served == 2
    assert st.qps == pytest.approx(2 / 10e-3)


def test_shed_reasons_are_labeled(obs_engines):
    engines, q = obs_engines
    eng = engines["f32"]
    fe, clock, reg, _ = _traced_frontend(eng, max_queue=2, max_wait_ms=50.0)
    clock.advance(1.0)
    # dead on arrival: deadline expired before the (backdated) submit
    doa = fe.submit(SearchRequest(queries=q[0], deadline_ms=1.0),
                    t_arrival=0.0)
    assert doa.result().stats.shed
    # fill the queue, then displace with priority and reject without
    fe.submit(SearchRequest(queries=q[1]))
    fe.submit(SearchRequest(queries=q[2]))
    fe.submit(SearchRequest(queries=q[3], priority=1))    # displaces a waiter
    fe.submit(SearchRequest(queries=q[4]))                # rejected newcomer
    c = reg.counter("lira_frontend_shed_total")
    assert c.value(frontend=fe.name, reason="doa") == 1
    assert c.value(frontend=fe.name, reason="displaced") == 1
    assert c.value(frontend=fe.name, reason="rejected") == 1
    assert fe.stats().shed == 3
    fe.drain()
