"""Sustained-churn gate for the mutable index (ISSUE 9).

Streams deletes + inserts through a live ``LiraEngine`` — ≥20% of the base
churned in interleaved rounds with ``maybe_repartition`` checked after each —
then compares recall@k against an index FRESHLY rebuilt over the surviving
logical set at equal fixed fanout (σ=-1 probes every partition on both
sides, so the comparison isolates store quality from probe selection).

The CI gates (raising fails the suite, and run.py exits nonzero):
  * churned recall within ε=0.02 of the fresh rebuild, per tier
    ({f32, pq, residual_pq});
  * same-shape mutations cause ZERO serve-step recompiles (the jit-cache
    miss counter must not move across the post-churn searches);
  * compaction reclaims every tombstone and survivors' results are
    preserved (ids identical before/after compact at fixed fanout).

Emits the usual CSV rows AND returns a JSON payload that ``benchmarks/run.py
--json-out`` persists as ``BENCH_churn.json``: per-tier churned/fresh recall,
mutation throughput (insert/delete rows per wall second), repartition moves,
compaction reclaim, and epoch/recompile counts — the perf trajectory for the
mutation path starts here.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import ground_truth as gt
from repro.core.metrics import recall_at_k
from repro.data import make_vector_dataset
from repro.launch.mesh import make_test_mesh
from repro.obs import MetricsRegistry
from repro.serving import BuildConfig, LiraEngine

N, NQ, DIM, B, K = 2_000, 32, 16, 8, 10
ETA, TRAIN_FRAC, EPOCHS, SEED = 0.03, 0.4, 2, 17
PQ_M, PQ_KS = 4, 32
TIERS = ("f32", "pq", "residual_pq")
N_DELETE, N_INSERT, ROUNDS = 300, 250, 5
EPS = 0.02                     # tolerated recall gap vs the fresh rebuild
NEW_ID_BASE = 10_000


def _build(x, tier):
    return LiraEngine.build(make_test_mesh(), x, BuildConfig(
        n_partitions=B, k=K, eta=ETA, train_frac=TRAIN_FRAC, epochs=EPOCHS,
        nprobe_max=B, tier=tier, pq_m=PQ_M, pq_ks=PQ_KS))


def _churn_one(tier: str, ds, host) -> dict:
    eng = _build(ds.base, tier)
    eng.metrics = reg = MetricsRegistry()

    doomed = host.choice(N, N_DELETE, replace=False)
    new_x = (ds.base[host.choice(N, N_INSERT, replace=False)]
             + host.normal(0, 0.05, (N_INSERT, DIM)).astype(np.float32))
    new_ids = np.arange(N_INSERT, dtype=np.int32) + NEW_ID_BASE
    churn_frac = (N_DELETE + N_INSERT) / N
    assert churn_frac >= 0.20, "the bench must exercise ≥20% churn"

    del_s = ins_s = 0.0
    dpr, ipr = N_DELETE // ROUNDS, N_INSERT // ROUNDS
    for i in range(ROUNDS):
        t0 = time.perf_counter()
        eng.delete(doomed[i * dpr:(i + 1) * dpr])
        del_s += time.perf_counter() - t0
        t0 = time.perf_counter()
        eng.insert(new_x[i * ipr:(i + 1) * ipr],
                   new_ids[i * ipr:(i + 1) * ipr])
        ins_s += time.perf_counter() - t0
        eng.maybe_repartition()
    eng.maybe_repartition(force=True)

    keep = np.setdiff1d(np.arange(N), doomed)
    all_x = np.concatenate([ds.base[keep], new_x], 0)
    all_ids = np.concatenate([keep.astype(np.int32), new_ids], 0)
    _, gti = gt.exact_knn(ds.queries, all_x, K)
    gt_ids = all_ids[gti]

    # the gate comparison: churned store vs fresh rebuild, full fanout
    r_churn = eng.search(ds.queries, sigma=-1.0)
    fresh = _build(all_x, tier)
    r_fresh = fresh.search(ds.queries, sigma=-1.0)
    rec_churn = recall_at_k(np.asarray(r_churn.ids), gt_ids, K)
    rec_fresh = recall_at_k(all_ids[np.asarray(r_fresh.ids)], gt_ids, K)
    assert not np.isin(doomed, r_churn.ids).any(), \
        f"{tier}: deleted ids surfaced after churn"
    assert rec_churn >= rec_fresh - EPS, (
        f"{tier}: churned recall {rec_churn:.4f} fell more than {EPS} below "
        f"fresh rebuild {rec_fresh:.4f}")

    # same-shape zero-recompile gate: the serve step compiled above must
    # keep serving across a same-shape delete+insert round-trip
    misses_before = reg.counter("lira_engine_jit_cache_misses_total").total()
    victim = all_ids[host.integers(0, len(all_ids))]
    vrow = all_x[all_ids == victim][:1]
    eng.delete([victim])
    eng.insert(vrow, [victim])
    r_again = eng.search(ds.queries, sigma=-1.0)
    assert r_again.stats.cache_hit, "same-shape mutation caused a recompile"
    misses_after = reg.counter("lira_engine_jit_cache_misses_total").total()
    assert misses_after == misses_before, (
        f"{tier}: same-shape mutations recompiled "
        f"({misses_after - misses_before} misses)")

    # compaction gate: reclaim erases tombstones, survivors keep their answer
    cap_before = eng.cfg.capacity
    reclaimed = eng.compact()
    r_dense = eng.search(ds.queries, sigma=-1.0)
    assert np.array_equal(np.asarray(r_again.ids), np.asarray(r_dense.ids)), \
        f"{tier}: compaction changed results"

    return {
        "churn_frac": round(churn_frac, 4),
        "recall_churned": round(rec_churn, 4),
        "recall_fresh": round(rec_fresh, 4),
        "recall_gap": round(rec_fresh - rec_churn, 4),
        "insert_rows_per_s": round(N_INSERT / max(ins_s, 1e-9), 1),
        "delete_rows_per_s": round(N_DELETE / max(del_s, 1e-9), 1),
        "epochs": int(eng.epoch),
        "repartitions": int(
            reg.counter("lira_engine_repartitions_total").total()),
        "repartition_moved_rows": int(
            reg.counter("lira_engine_repartition_moved_rows_total").total()),
        "capacity_grows": int(
            reg.counter("lira_engine_capacity_grows_total").total()),
        "compaction_reclaimed_slots": int(reclaimed),
        "capacity_before_compact": int(cap_before),
        "capacity_after_compact": int(eng.cfg.capacity),
    }


def run(emit):
    ds = make_vector_dataset(n=N, n_queries=NQ, dim=DIM, n_modes=B,
                             seed=SEED)
    payload = {
        "suite": "churn",
        "config": {"n": N, "dim": DIM, "partitions": B, "k": K, "eta": ETA,
                   "n_delete": N_DELETE, "n_insert": N_INSERT,
                   "rounds": ROUNDS, "eps": EPS},
        "tiers": {},
    }
    for tier in TIERS:
        host = np.random.default_rng(23)    # identical churn stream per tier
        t0 = time.perf_counter()
        res = _churn_one(tier, ds, host)
        res["wall_s"] = round(time.perf_counter() - t0, 2)
        payload["tiers"][tier] = res
        emit(f"churn/{tier}_recall_churned", res["recall_churned"] * 1e6,
             f"fresh={res['recall_fresh']}")
        emit(f"churn/{tier}_insert_rows_per_s", res["insert_rows_per_s"],
             f"delete={res['delete_rows_per_s']}")
        emit(f"churn/{tier}_reclaimed", res["compaction_reclaimed_slots"],
             f"epochs={res['epochs']}")
    return payload
