"""Paper Fig 11 + appendix A.5: probing-model convergence — loss ↓, partition-
recall → 1, predicted nprobe → nprobe*, hit-rate high; plus the paper's
time-cost accounting (build phases)."""
from __future__ import annotations

import time

from benchmarks import _harness as H

B = 64
K = 100
DATASET = "sift-like"


def run(emit):
    t0 = time.time()
    params, tlog = H.get_probing_model(DATASET, B, K)
    dt = time.time() - t0
    n = len(tlog.losses)
    idx = {0: "start", n // 2: "mid", n - 1: "end"}
    for i, tag in idx.items():
        emit(f"fig11/{tag}", dt * 1e6 / max(n, 1),
             f"loss={tlog.losses[i]:.4f};part_recall={tlog.recalls[i]:.4f};"
             f"nprobe={tlog.nprobes[i]:.2f};hit={tlog.hit_rates[i]:.4f}")
    emit("fig11/train_seconds", tlog.seconds * 1e6, f"steps={n}")
    assert tlog.losses[-1] < tlog.losses[0]
