"""Shared benchmark context: datasets, indexes, probing models — disk-cached.

Scale note (DESIGN.md §7.4/7.5): the container is offline + 1 CPU core, so the
paper's SIFT-1M/GloVe-1M become deterministic synthetic mixtures at 100k/60k
scale with matched dimensionality; every method sees identical data/GT, so the
paper's COMPARISONS (orderings, relative margins) are preserved even though
absolute cmp values scale with N.
"""
from __future__ import annotations

import pickle
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, build_store, kmeans_fit
from repro.core import ground_truth as gt
from repro.core import probing
from repro.core import retrieval as ret
from repro.core.redundancy import plan_redundancy, replica_rows
from repro.core.train_probing import train_probing_model
from repro.data import make_vector_dataset

CACHE = pathlib.Path(__file__).resolve().parent / "results" / "cache"

DATASETS = {
    # name: (n, q, dim, n_modes, seed)  — SIFT-like / GloVe-like mixtures
    "sift-like": (100_000, 2_000, 128, 160, 0),
    "glove-like": (60_000, 1_000, 96, 120, 1),
}


def _cached(key: str, builder):
    CACHE.mkdir(parents=True, exist_ok=True)
    f = CACHE / f"{key}.pkl"
    if f.exists():
        with open(f, "rb") as fh:
            return pickle.load(fh)
    t0 = time.time()
    val = builder()
    with open(f, "wb") as fh:
        pickle.dump(val, fh)
    print(f"  [built {key} in {time.time()-t0:.0f}s]")
    return val


def get_dataset(name: str):
    n, q, dim, modes, seed = DATASETS[name]
    return _cached(f"ds_{name}", lambda: make_vector_dataset(
        name, n=n, n_queries=q, dim=dim, n_modes=modes, seed=seed))


def get_partitions(name: str, b: int):
    ds = get_dataset(name)

    def build():
        st = kmeans_fit(jax.random.PRNGKey(0), jnp.asarray(ds.base), n_clusters=b, n_iters=20)
        return np.asarray(st.assign), np.asarray(st.centroids)

    return _cached(f"km_{name}_B{b}", build)


def get_gt(name: str, k: int = 200):
    ds = get_dataset(name)
    return _cached(f"gt_{name}_k{k}", lambda: gt.exact_knn(ds.queries, ds.base, k))


def get_train_labels(name: str, b: int, k: int = 100, n_sub: int = 30_000):
    """kNN-partition labels for a training subset (paper appendix A.3)."""
    ds = get_dataset(name)
    assign, cents = get_partitions(name, b)

    def build():
        host = np.random.default_rng(7)
        sub = host.choice(len(ds.base), n_sub, replace=False)
        xs = ds.base[sub]
        _, sti = gt.exact_knn(xs, xs, k, exclude_self=True)
        part_of = assign[sub]
        lab = np.zeros((n_sub, b), np.float32)
        rows = np.repeat(np.arange(n_sub), sti.shape[1])
        np.add.at(lab, (rows, part_of[sti].reshape(-1)), 1.0)
        return sub, (lab > 0).astype(np.float32)

    return _cached(f"lab_{name}_B{b}_k{k}", build)


def get_probing_model(name: str, b: int, k: int = 100, epochs: int = 8):
    ds = get_dataset(name)
    assign, cents = get_partitions(name, b)
    sub, lab = get_train_labels(name, b, k)

    def build():
        params, tlog = train_probing_model(
            jax.random.PRNGKey(3), ds.base[sub], lab, cents, epochs=epochs, batch=512, lr=2e-3)
        return jax.tree.map(np.asarray, params), tlog

    return _cached(f"probe_{name}_B{b}_k{k}", build)


def get_stores(name: str, b: int, k: int = 100, eta: float = 0.03):
    """(ivf_store, fuzzy_store, lira_store) with shared centroids."""
    ds = get_dataset(name)
    assign, cents = get_partitions(name, b)
    params, _ = get_probing_model(name, b, k)
    ids = np.arange(len(ds.base), dtype=np.int32)

    def build():
        s_ivf = build_store(ds.base, ids, assign, cents)
        s_fuzzy = baselines.build_ivf_fuzzy(jax.random.PRNGKey(0), ds.base, b)
        plan = plan_redundancy(params, ds.base, assign, cents, eta=eta)
        extra = replica_rows(plan, ds.base, ids)
        s_lira = build_store(ds.base, ids, assign, cents, extra=extra)
        return s_ivf, s_fuzzy, s_lira

    return _cached(f"stores_{name}_B{b}_k{k}_eta{eta}", build)


def get_ptk(name: str, b: int, store_key: str, store, k: int = 100):
    """Within-partition top-k tables (the heavy pass) — cached per store."""
    ds = get_dataset(name)
    return _cached(f"ptk_{name}_B{b}_{store_key}_k{k}",
                   lambda: ret.partition_topk(store, ds.queries, k))


def lira_probs(name: str, b: int, store, k: int = 100):
    ds = get_dataset(name)
    params, _ = get_probing_model(name, b, k)
    cd = ret.lira_inputs(store, ds.queries)
    p = probing.probs(jax.tree.map(jnp.asarray, params), jnp.asarray(ds.queries), jnp.asarray(cd))
    return np.asarray(p), cd


def sweep_method(ptk, gti, k, probe_masks: dict):
    """Evaluate a dict of {setting: mask} -> list of (setting, SearchResult)."""
    return [(s, ret.evaluate_probe(ptk, m, gti, k)) for s, m in probe_masks.items()]
