"""Paper Fig 13: sensitivity of the redundancy ratio η — recall/cmp trade-off
as η grows 0 → 100% (η=0 is LIRA without redundancy; 100% ≈ IVFFuzzy budget)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks import _harness as H
from repro.core import build_store, retrieval as ret, metrics
from repro.core.redundancy import plan_redundancy, replica_rows

B = 64
K = 100
DATASET = "sift-like"


def run(emit):
    ds = H.get_dataset(DATASET)
    _, gti = H.get_gt(DATASET, 200)
    gti = gti[:, :K]
    assign, cents = H.get_partitions(DATASET, B)
    params, _ = H.get_probing_model(DATASET, B, K)
    import jax
    import jax.numpy as jnp
    params = jax.tree.map(jnp.asarray, params)
    ids = np.arange(len(ds.base), dtype=np.int32)
    p_hat, cd = H.lira_probs(DATASET, B, H.get_stores(DATASET, B)[0], K)

    for eta in (0.0, 0.01, 0.03, 0.1, 0.4, 1.0):
        def build(eta=eta):
            plan = plan_redundancy(params, ds.base, assign, cents, eta=eta)
            extra = replica_rows(plan, ds.base, ids)
            store = build_store(ds.base, ids, assign, cents, extra=extra)
            return ret.partition_topk(store, ds.queries, K)

        t0 = time.time()
        ptk = H._cached(f"fig13_{DATASET}_eta{eta}", build)
        rows = [ret.evaluate_probe(ptk, ret.probe_lira(p_hat, s), gti, K)
                for s in np.arange(0.1, 0.9, 0.1)]
        dt = time.time() - t0
        c95 = metrics.cost_at_recall([(r.cmp_mean, r.recall) for r in rows], 0.95)
        n95 = metrics.cost_at_recall([(r.nprobe_mean, r.recall) for r in rows], 0.95)
        emit(f"fig13/eta{eta}", dt * 1e6,
             f"cmp@95={c95[0]:.0f};nprobe@95={n95[0]:.2f}" if c95 and n95
             else f"best_recall={max(r.recall for r in rows):.3f}")
