"""Serving front-end under open-loop load — the repo's first persisted perf
trajectory (ISSUE 6 / ROADMAP open item 4).

Drives a synthetic open-loop single-query arrival stream (arrivals at fixed
intervals that do NOT back off when the system falls behind) through the
dynamic-batching front-end at three offered-load points relative to the
engine's measured drain rate: comfortable (0.2×), near-saturation (0.8×) and
overload (3×). The clock is virtual, but each coalesced engine call's real
wall time is charged onto it (``charge_service=True``), so p50/p99/QPS
reflect true serve cost under deterministic arrivals — reproducible queueing,
honest service times.

Emits the usual CSV rows AND returns a JSON payload that ``benchmarks/run.py
--json-out`` persists as ``BENCH_serving.json`` (p50/p99 latency, QPS, shed
rate per load point) — per-PR perf snapshots start here.

CI smoke asserts the properties that must never regress:
  * zero sheds at low load (admission control only fires under pressure);
  * low-load p99 stays within the deadline budget (max_wait plus a small
    multiple of the measured per-batch serve time — queueing, not compute,
    must dominate a lightly loaded front-end);
  * observability is affordable and honest (the PR 7 gates): re-running the
    0.8× point with span tracing + a metrics registry attached costs ≤ 5%
    p50 (plus a small absolute slack for timer noise), the per-request stage
    breakdowns sum to ≈ each request's end-to-end latency, and the exported
    metrics text round-trips through the exposition parser.
"""
from __future__ import annotations

import time

from benchmarks import _harness as H
from repro.configs.base import FrontendConfig
from repro.data import make_vector_dataset
from repro.launch.mesh import make_test_mesh
from repro.serving import SearchRequest
from repro.serving.engine import LiraEngine
from repro.serving.frontend import FakeClock, ServingFrontend, simulate_open_loop

N, NQ, DIM, B, K = 10_000, 256, 64, 16, 10
ETA, SIGMA, SEED = 0.03, 0.3, 6
NPROBE, TRAIN_FRAC, EPOCHS = 8, 0.3, 4
MAX_BATCH, MAX_WAIT_MS, MAX_QUEUE = 32, 5.0, 64
N_REQUESTS = 720
LOADS = (0.2, 0.8, 3.0)        # offered rate as a multiple of the drain rate
# per-request SLO, in measured batch service times: a request older than this
# many batches is provably late and shed dead-on-arrival. Scaling the SLO to
# the measured batch time keeps the gates machine-independent — at low load
# staleness never exceeds ~1 batch (5x margin), under overload the backlog
# grows without bound and the SLO must trip.
DEADLINE_BATCHES = 5.0
# CI gate: low-load p99 ≤ deadline window + this many measured batch times
P99_BUDGET_BATCHES = 5.0
_DS_KEY = (f"servefe_n{N}_d{DIM}_B{B}_s{SEED}_eta{ETA}_k{K}"
           f"_np{NPROBE}_tf{TRAIN_FRAC}_e{EPOCHS}")


def _engine():
    ds = H._cached(
        f"ds_{_DS_KEY}",
        lambda: make_vector_dataset("sift-like", n=N, n_queries=NQ, dim=DIM,
                                    n_modes=B * 2, seed=SEED))

    def build():
        from repro.serving import BuildConfig

        eng = LiraEngine.build(
            make_test_mesh(), ds.base, BuildConfig(
                n_partitions=B, k=K, eta=ETA, train_frac=TRAIN_FRAC,
                epochs=EPOCHS, nprobe_max=NPROBE, tier="f32"))
        return eng.cfg, eng.params, eng.store

    cfg, params, store = H._cached(f"engfe_{_DS_KEY}", build)
    return LiraEngine(cfg=cfg, params=params, store=store,
                      mesh=make_test_mesh()), ds


def _measure_drain(eng, ds):
    """Warm every jit bucket a coalesced flush can land on, then time one
    full-size batch: drain_qps = rows per wall second through the engine.
    Warming matters — a cold bucket's compile would otherwise be charged as
    service time and read as a multi-second latency spike."""
    sizes, s = [], 8
    while s <= eng._batch_bucket(MAX_BATCH):
        sizes.append(s)
        s *= 2
    for size in sizes:
        eng.search(SearchRequest(queries=ds.queries[:size], sigma=SIGMA))
    t0 = time.perf_counter()
    reps = 3
    for _ in range(reps):
        eng.search(SearchRequest(queries=ds.queries[:MAX_BATCH], sigma=SIGMA))
    batch_s = (time.perf_counter() - t0) / reps
    return MAX_BATCH / batch_s, batch_s


def run(emit):
    eng, ds = _engine()
    drain_qps, batch_s = _measure_drain(eng, ds)
    deadline_ms = DEADLINE_BATCHES * batch_s * 1e3
    emit("serving/drain_rate", batch_s * 1e6,
         f"drain_qps={drain_qps:.0f};deadline_ms={deadline_ms:.2f}")

    points = []
    for load in LOADS:
        offered = load * drain_qps
        fe = ServingFrontend(
            eng, FrontendConfig(max_batch=MAX_BATCH, max_wait_ms=MAX_WAIT_MS,
                                max_queue=MAX_QUEUE),
            clock=FakeClock(), charge_service=True)
        stats, _ = simulate_open_loop(fe, ds.queries, rate_qps=offered,
                                      n_requests=N_REQUESTS, sigma=SIGMA,
                                      deadline_ms=deadline_ms)
        shed_rate = stats.shed / stats.submitted
        point = {"offered_x_drain": load, "offered_qps": round(offered, 1),
                 "p50_ms": round(stats.p50_ms, 3),
                 "p99_ms": round(stats.p99_ms, 3),
                 "qps": round(stats.qps, 1),
                 "shed_rate": round(shed_rate, 4),
                 "served": stats.served, "shed": stats.shed,
                 "mean_batch": round(stats.mean_batch, 2)}
        points.append(point)
        emit(f"serving/load_{load:g}x", stats.p99_ms * 1e3,
             f"p50_ms={stats.p50_ms:.2f};p99_ms={stats.p99_ms:.2f};"
             f"qps={stats.qps:.0f};shed_rate={shed_rate:.3f};"
             f"mean_batch={stats.mean_batch:.1f}")

    # ---- CI smoke gates
    low = points[0]
    budget_ms = MAX_WAIT_MS + P99_BUDGET_BATCHES * batch_s * 1e3
    if low["shed"] != 0:
        raise AssertionError(
            f"admission control shed {low['shed']} requests at "
            f"{LOADS[0]}x load — shedding must only fire under pressure")
    if low["p99_ms"] >= budget_ms:
        raise AssertionError(
            f"low-load p99 {low['p99_ms']:.2f}ms exceeds the deadline budget "
            f"{budget_ms:.2f}ms (max_wait {MAX_WAIT_MS}ms + "
            f"{P99_BUDGET_BATCHES:g}x batch {batch_s * 1e3:.2f}ms)")
    emit("serving/_gates", 0.0,
         f"low_load_shed=0;p99_budget_ms={budget_ms:.2f}")

    tracing = _tracing_overhead(eng, ds, drain_qps, deadline_ms, points, emit)

    return {
        "suite": "serving",
        "tracing": tracing,
        "config": {"n": N, "dim": DIM, "partitions": B, "k": K,
                   "sigma": SIGMA, "max_batch": MAX_BATCH,
                   "max_wait_ms": MAX_WAIT_MS, "max_queue": MAX_QUEUE,
                   "n_requests": N_REQUESTS,
                   "deadline_batches": DEADLINE_BATCHES,
                   "deadline_ms": round(deadline_ms, 3)},
        "drain_qps": round(drain_qps, 1),
        "batch_service_ms": round(batch_s * 1e3, 3),
        "points": points,
    }


# ------------------------------------------------- observability gates (PR 7)

TRACING_OVERHEAD_FRAC = 0.05    # gate: tracing costs ≤ 5% p50 at 0.8× load
TRACING_OVERHEAD_SLACK_MS = 0.25  # absolute slack: timer noise on tiny p50s
STAGE_SUM_RELERR = 0.15         # gate: median |Σstages − e2e| / e2e


def _tracing_overhead(eng, ds, drain_qps, deadline_ms, points, emit):
    """Re-run the 0.8× (near-saturation) load point twice back-to-back —
    untraced, then with a Tracer and a fresh MetricsRegistry attached — and
    gate three obs-layer properties: tracing overhead vs the PAIRED untraced
    baseline, per-request stage-sum ≈ e2e latency, and a parseable metrics
    exposition. The baseline is re-measured rather than reused from the sweep
    because near-saturation queueing amplifies small service-time drift
    (cache state, CPU frequency, co-tenants) into double-digit p50 shifts;
    paired runs isolate what tracing itself costs."""
    import numpy as np

    from repro.obs import MetricsRegistry, Tracer, parse_exposition

    def _run_point(tracer, registry):
        eng.tracer, eng.metrics = tracer, registry
        try:
            fe = ServingFrontend(
                eng, FrontendConfig(max_batch=MAX_BATCH,
                                    max_wait_ms=MAX_WAIT_MS,
                                    max_queue=MAX_QUEUE),
                clock=FakeClock(), charge_service=True)
            return simulate_open_loop(
                fe, ds.queries, rate_qps=0.8 * drain_qps,
                n_requests=N_REQUESTS, sigma=SIGMA, deadline_ms=deadline_ms)
        finally:
            eng.tracer, eng.metrics = None, None

    stats_off, _ = _run_point(None, None)
    reg = MetricsRegistry()
    stats_on, pendings = _run_point(Tracer(), reg)

    p50_off, p50_on = stats_off.p50_ms, stats_on.p50_ms
    overhead = (p50_on - p50_off) / p50_off if p50_off > 0 else 0.0
    budget = p50_off * (1.0 + TRACING_OVERHEAD_FRAC) + TRACING_OVERHEAD_SLACK_MS
    if p50_on > budget:
        raise AssertionError(
            f"tracing overhead too high at 0.8x load: p50 {p50_on:.3f}ms "
            f"traced vs {p50_off:.3f}ms untraced (budget {budget:.3f}ms = "
            f"+{TRACING_OVERHEAD_FRAC:.0%} + {TRACING_OVERHEAD_SLACK_MS}ms)")

    # stage attribution: every served request carries a breakdown whose sum
    # tracks its end-to-end latency (assemble is real wall time the virtual
    # clock doesn't carry, hence a tolerance rather than equality)
    errs = []
    for p in pendings:
        st = p.result().stats
        if st.shed or st.stages is None or st.latency_ms <= 0:
            continue
        errs.append(abs(sum(st.stages.values()) - st.latency_ms)
                    / st.latency_ms)
    if not errs:
        raise AssertionError("traced run produced no stage breakdowns")
    med_err = float(np.median(errs))
    if med_err > STAGE_SUM_RELERR:
        raise AssertionError(
            f"stage latencies do not sum to e2e latency: median relative "
            f"error {med_err:.3f} > {STAGE_SUM_RELERR}")

    # exposition smoke: text parses, and the series the run must have
    # produced are present
    parsed = parse_exposition(reg.render())
    for needle in ("lira_engine_searches_total", "lira_frontend_served_total",
                   "lira_frontend_latency_ms_count"):
        if not any(key.startswith(needle) for key in parsed):
            raise AssertionError(f"metrics exposition lacks {needle} series")

    emit("serving/tracing_overhead", p50_on * 1e3,
         f"p50_off_ms={p50_off:.3f};p50_on_ms={p50_on:.3f};"
         f"overhead={overhead:+.1%};stage_sum_med_err={med_err:.3f};"
         f"metrics_series={len(parsed)}")
    return {"p50_off_ms": round(p50_off, 3), "p50_on_ms": round(p50_on, 3),
            "overhead_frac": round(overhead, 4),
            "stage_sum_median_relerr": round(med_err, 4),
            "metrics_series": len(parsed)}


if __name__ == "__main__":
    import json

    print(json.dumps(run(lambda *a: print(*a)), indent=2))
