"""Microbench for the vectorized replica-aware merge engine (ISSUE 1).

evaluate_probe on a Q=1000, B=64, k=100 synthetic workload with ~10% replica
ids: the seed's per-query Python set-loop vs the dedup_topk path. The
vectorized path must produce bit-identical per-query recall and be ≥5×
faster; a recall mismatch raises (and fails the CI smoke job via run.py).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import retrieval as ret
from repro.core.partitions import PAD_ID

Q, B, KK, K = 1000, 64, 100, 100
ETA = 0.1  # replica rate: id space is (1-ETA)·B·KK so ~10% of slots collide


def _legacy_evaluate_probe(ptk, probe_mask, gt_ids, k, dedup_pool=2):
    """Faithful copy of the seed retrieval.evaluate_probe merge loop."""
    qn, b, kk = ptk.dists.shape
    masked = np.where(probe_mask[:, :, None], ptk.dists, np.inf).reshape(qn, b * kk)
    flat_ids = np.broadcast_to(ptk.ids.reshape(qn, b * kk), masked.shape)
    pool = min(dedup_pool * k, masked.shape[1])
    part = np.argpartition(masked, pool - 1, axis=1)[:, :pool]
    pool_d = np.take_along_axis(masked, part, 1)
    pool_i = np.take_along_axis(flat_ids, part, 1)
    order = np.argsort(pool_d, 1)
    pool_d = np.take_along_axis(pool_d, order, 1)
    pool_i = np.take_along_axis(pool_i, order, 1)
    hits = np.zeros(qn, np.float64)
    for r in range(qn):
        seen: set = set()
        res = []
        for c in range(pool):
            i = int(pool_i[r, c])
            if i == PAD_ID or not np.isfinite(pool_d[r, c]) or i in seen:
                continue
            seen.add(i)
            res.append(i)
            if len(res) == k:
                break
        hits[r] = len(set(res) & set(gt_ids[r, :k].tolist()))
    return hits / k


def _workload():
    rng = np.random.default_rng(0)
    n_ids = int(B * KK * (1.0 - ETA))
    ids = rng.integers(0, n_ids, size=(Q, B, KK)).astype(np.int32)
    # distances: per-query permutation of 0..B·KK-1 (all distinct → the
    # legacy/vectorized comparison is exact, no tie ambiguity), sorted within
    # each partition like real partition_topk output
    dists = np.sort(
        rng.permuted(np.tile(np.arange(B * KK, dtype=np.float32), (Q, 1)), axis=1)
        .reshape(Q, B, KK), axis=-1)
    ptk = ret.PartitionTopK(dists, ids, np.full(B, KK, np.int32))
    mask = rng.random((Q, B)) < 0.3
    mask[:, 0] = True
    gti = np.argsort(rng.random((Q, n_ids)), axis=1)[:, :K].astype(np.int32)
    return ptk, mask, gti


def run(emit):
    ptk, mask, gti = _workload()

    # warm-up both paths (jit compile for the vectorized one), check equality
    res = ret.evaluate_probe(ptk, mask, gti, K)
    legacy = _legacy_evaluate_probe(ptk, mask, gti, K)
    if not np.allclose(res.per_query_recall, legacy, atol=1e-12):
        raise AssertionError(
            f"vectorized merge diverges from set-loop oracle: "
            f"{res.per_query_recall.mean():.6f} vs {legacy.mean():.6f}")

    t0 = time.perf_counter()
    reps_l = 3
    for _ in range(reps_l):
        _legacy_evaluate_probe(ptk, mask, gti, K)
    t_leg = (time.perf_counter() - t0) / reps_l

    t0 = time.perf_counter()
    reps_v = 10
    for _ in range(reps_v):
        ret.evaluate_probe(ptk, mask, gti, K)
    t_vec = (time.perf_counter() - t0) / reps_v

    emit("eval_merge/setloop", t_leg * 1e6, f"Q={Q};B={B};k={K};eta={ETA}")
    emit("eval_merge/vectorized", t_vec * 1e6, f"recall={res.recall:.4f};recall_match=1")
    emit("eval_merge/speedup", 0.0, f"x{t_leg / t_vec:.1f};target>=5")
