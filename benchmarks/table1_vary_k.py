"""Paper Table 1: minimum cmp / nprobe to reach Recall@k = 0.98, k ∈ {10,50,100,200}.

Methods: IVF, IVFPQ, IVFFuzzy, BLISS(-lite), LIRA. IVFPQ rows report the best
achievable recall when 0.98 is out of reach (quantization ceiling — same
behaviour as the paper)."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import _harness as H
from repro.core import baselines, metrics
from repro.core import retrieval as ret

TARGET = 0.98
B = 64
DATASET = "sift-like"


def best_at_target(ptk, gti, k, masks: list):
    """(cmp, nprobe, recall) of the cheapest setting reaching TARGET recall,
    else the highest-recall setting."""
    rows = [ret.evaluate_probe(ptk, m, gti, k) for m in masks]
    ok = [r for r in rows if r.recall >= TARGET]
    if ok:
        r = min(ok, key=lambda r: r.cmp_mean)
    else:
        r = max(rows, key=lambda r: r.recall)
    return r


def run(emit):
    ds = H.get_dataset(DATASET)
    _, gti_all = H.get_gt(DATASET, 200)
    s_ivf, s_fuzzy, s_lira = H.get_stores(DATASET, B)
    ptk_ivf = H.get_ptk(DATASET, B, "ivf", s_ivf, 200)
    ptk_fuzzy = H.get_ptk(DATASET, B, "fuzzy", s_fuzzy, 200)
    ptk_lira = H.get_ptk(DATASET, B, "lira", s_lira, 200)
    # IVFPQ: reconstruction store (ADC-exact)
    ipq = H._cached(f"ivfpq_{DATASET}_B{B}",
                    lambda: baselines.build_ivfpq(jax.random.PRNGKey(0), ds.base, B, m=16, ks=256))
    ptk_pq = H.get_ptk(DATASET, B, "pq", ipq.store, 200)
    p_hat, cd = H.lira_probs(DATASET, B, s_ivf, 100)

    for k in (10, 50, 100, 200):
        gti = gti_all[:, :k]
        ivf_masks = [ret.probe_ivf(cd, n) for n in range(1, B + 1)]
        lira_masks = [ret.probe_lira(p_hat, s) for s in np.arange(0.05, 1.0, 0.05)]
        t0 = time.time()
        r_ivf = best_at_target(ptk_ivf, gti, k, ivf_masks)
        r_pq = best_at_target(ptk_pq, gti, k, ivf_masks)
        r_fz = best_at_target(ptk_fuzzy, gti, k, ivf_masks)
        r_li = best_at_target(ptk_lira, gti, k, lira_masks)
        dt = (time.time() - t0) / 4
        for nm, r in [("IVF", r_ivf), ("IVFPQ", r_pq), ("IVFFuzzy", r_fz), ("LIRA", r_li)]:
            emit(f"table1/{nm}/k{k}", dt * 1e6,
                 f"recall={r.recall:.3f};cmp={r.cmp_mean:.0f};nprobe={r.nprobe_mean:.2f}")
        # headline: LIRA saves cmp & nprobe vs IVF at matched recall
        if r_li.recall >= TARGET and r_ivf.recall >= TARGET:
            emit(f"table1/LIRA_vs_IVF/k{k}", 0,
                 f"cmp_save={1-r_li.cmp_mean/r_ivf.cmp_mean:.2%};"
                 f"nprobe_save={1-r_li.nprobe_mean/r_ivf.nprobe_mean:.2%}")
