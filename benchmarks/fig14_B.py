"""Paper Figs 14/15: sensitivity to the partition count B ∈ {16, 64, 256} —
LIRA(-fix-nprobe) vs IVF vs IVFFuzzy, cmp@recall-0.95 per B."""
from __future__ import annotations

import time

import numpy as np

from benchmarks import _harness as H
from repro.core import metrics, retrieval as ret

K = 100
DATASET = "sift-like"


def run(emit):
    ds = H.get_dataset(DATASET)
    _, gti = H.get_gt(DATASET, 200)
    gti = gti[:, :K]
    for b in (16, 64, 256):
        t0 = time.time()
        s_ivf, s_fuzzy, s_lira = H.get_stores(DATASET, b)
        ptk_ivf = H.get_ptk(DATASET, b, "ivf", s_ivf, K)
        ptk_fuzzy = H.get_ptk(DATASET, b, "fuzzy", s_fuzzy, K)
        ptk_lira = H.get_ptk(DATASET, b, "lira", s_lira, K)
        p_hat, cd = H.lira_probs(DATASET, b, s_ivf, K)
        nps = sorted({max(1, int(b * f)) for f in (0.02, 0.05, 0.1, 0.2, 0.3, 0.5, 0.75, 1.0)})
        rows = {
            "IVF": [ret.evaluate_probe(ptk_ivf, ret.probe_ivf(cd, n), gti, K) for n in nps],
            "IVFFuzzy": [ret.evaluate_probe(ptk_fuzzy, ret.probe_ivf(cd, n), gti, K) for n in nps],
            "LIRA": [ret.evaluate_probe(ptk_lira, ret.probe_lira(p_hat, s), gti, K)
                     for s in np.arange(0.1, 0.95, 0.1)],
            "LIRA-fixnprobe": [ret.evaluate_probe(ptk_lira, ret.probe_topn(p_hat, n), gti, K)
                               for n in nps],
        }
        dt = time.time() - t0
        for name, rs in rows.items():
            c = metrics.cost_at_recall([(r.cmp_mean, r.recall) for r in rs], 0.95)
            emit(f"fig14/B{b}/{name}", dt * 1e6 / 4,
                 f"cmp@95={c[0]:.0f}" if c else f"best_recall={max(r.recall for r in rs):.3f}")
