"""Paper Figs 7+8: Recall@100 vs cmp and vs nprobe on both datasets.

IVF / IVFFuzzy sweep nprobe; LIRA sweeps the σ threshold (query-adaptive);
BLISS(-lite) sweeps per-group nprobe. The paper's claims checked here:
LIRA pareto-dominates at high recall; the gap WIDENS with recall."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import _harness as H
from repro.core import baselines, metrics
from repro.core import retrieval as ret

B = 64
K = 100


def run(emit):
    for dataset in ("sift-like", "glove-like"):
        ds = H.get_dataset(dataset)
        _, gti = H.get_gt(dataset, 200)
        gti = gti[:, :K]
        s_ivf, s_fuzzy, s_lira = H.get_stores(dataset, B)
        ptk_ivf = H.get_ptk(dataset, B, "ivf", s_ivf, 200)
        ptk_fuzzy = H.get_ptk(dataset, B, "fuzzy", s_fuzzy, 200)
        ptk_lira = H.get_ptk(dataset, B, "lira", s_lira, 200)
        p_hat, cd = H.lira_probs(dataset, B, s_ivf, K)

        curves = {}
        t0 = time.time()
        curves["IVF"] = [ret.evaluate_probe(ptk_ivf, ret.probe_ivf(cd, n), gti, K)
                         for n in (1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64)]
        curves["IVFFuzzy"] = [ret.evaluate_probe(ptk_fuzzy, ret.probe_ivf(cd, n), gti, K)
                              for n in (1, 2, 3, 4, 6, 8, 12, 16, 24, 32)]
        curves["LIRA"] = [ret.evaluate_probe(ptk_lira, ret.probe_lira(p_hat, s), gti, K)
                          for s in np.arange(0.05, 1.0, 0.05)]
        curves["LIRA-fixnprobe"] = [ret.evaluate_probe(ptk_lira, ret.probe_topn(p_hat, n), gti, K)
                                    for n in (1, 2, 3, 4, 6, 8, 12, 16)]

        # BLISS-lite (cached)
        def build_bliss():
            from repro.core import ground_truth as gt
            sub = np.random.default_rng(5).choice(len(ds.base), 20000, replace=False)
            _, knn = gt.exact_knn(ds.base[sub], ds.base[sub], 10, exclude_self=True)
            return baselines.build_bliss(jax.random.PRNGKey(9), ds.base[sub], B,
                                         n_groups=2, knn_ids=knn, reparts=2, epochs=2), sub

        groups, sub = H._cached(f"bliss_{dataset}_B{B}", build_bliss)
        from repro.core import ground_truth as gt
        _, gti_sub = H._cached(f"gt_sub_{dataset}",
                               lambda: gt.exact_knn(ds.queries, ds.base[sub], K))
        ptks = [H._cached(f"ptk_{dataset}_bliss{i}",
                          lambda g=g: ret.partition_topk(g.store, ds.queries, K))
                for i, g in enumerate(groups)]
        bl_rows = []
        for n in (1, 2, 4, 8, 16):
            masks = [ret.probe_topn(baselines.bliss_scores(g, ds.queries), n) for g in groups]
            bl_rows.append(ret.merge_groups(ptks, masks, gti_sub, K,
                                            [g.assign for g in groups], len(sub)))
        curves["BLISS"] = bl_rows
        dt = time.time() - t0

        for name, rows in curves.items():
            pts = sorted((r.cmp_mean, r.recall) for r in rows)
            frontier = metrics.pareto_frontier(pts)
            path = ";".join(f"({c:.0f},{r:.3f})" for c, r in frontier[:12])
            emit(f"fig7/{dataset}/{name}", dt * 1e6 / max(len(rows), 1), path)
            pts_n = sorted((r.nprobe_mean, r.recall) for r in rows)
            path_n = ";".join(f"({n:.2f},{r:.3f})" for n, r in metrics.pareto_frontier(pts_n)[:12])
            emit(f"fig8/{dataset}/{name}", 0, path_n)

        # headline cross-method comparison at recall 0.95
        for target in (0.90, 0.95):
            line = []
            for name, rows in curves.items():
                c = metrics.cost_at_recall([(r.cmp_mean, r.recall) for r in rows], target)
                line.append(f"{name}={c[0]:.0f}" if c else f"{name}=inf")
            emit(f"fig7/{dataset}/cmp_at_recall{target}", 0, ";".join(line))
