"""Partition-scan backend comparison + parity gate (ISSUE 4).

Serves one η>0 LIRA store through the distributed engine with the two CPU-
runnable scan backends of serving/scan.py — ``ref`` (portable jnp) and
``interpret`` (the grid-batched Pallas kernels through the interpreter) — on
all three tiers (f32, quantized, residual), reporting latency per path and
ASSERTING parity: bit-identical distances, set-identical ids, identical
nprobe/overflow counters.

This is the CI tripwire for kernel/oracle drift in the scan layer, exactly
like the PR 3 coverage floor: run.py turns any raise into a bench-smoke
failure. Latency note: on CPU the interpreter is expected to lose to the jnp
path — the row exists to track the gap, not to win it; on TPU ``pallas``
compiles natively and the kernels are the fast path.

ISSUE 8: the kernel path now consumes the compact ``q_pad``/``lut_pad``
planes directly (scalar-prefetched qbuf gather, no per-slot host expansion);
the payload records the staged-operand accounting per tier and the stream-
tile autotune sweeps, and CI's perf ratchet compares the persisted
``ceiling_fracs`` against the committed snapshot.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from benchmarks import _harness as H
from repro.data import make_vector_dataset
from repro.launch.mesh import make_test_mesh
from repro.serving.engine import LiraEngine
from repro.serving.quantized import build_quantized_store

N, NQ, DIM, B, K = 10_000, 128, 64, 16, 10
ETA, SIGMA, SEED = 0.03, 0.3, 6
PQ_M, PQ_KS, RERANK = 8, 64, 8
NPROBE, TRAIN_FRAC, EPOCHS = 8, 0.3, 4
# cached artifacts bake in the full cfg/params/store, so the key must cover
# every build parameter — a constant edit must miss the stale pickle (same
# convention as quantized_scan's cache keys)
_DS_KEY = (f"scanpaths_n{N}_d{DIM}_B{B}_s{SEED}_eta{ETA}_m{PQ_M}_ks{PQ_KS}"
           f"_k{K}_r{RERANK}_np{NPROBE}_tf{TRAIN_FRAC}_e{EPOCHS}")


def _engines():
    ds = H._cached(
        f"ds_{_DS_KEY}",
        lambda: make_vector_dataset("sift-like", n=N, n_queries=NQ, dim=DIM,
                                    n_modes=B * 2, seed=SEED))

    def build():
        from repro.serving import BuildConfig

        eng = LiraEngine.build(
            make_test_mesh(), ds.base, BuildConfig(
                n_partitions=B, k=K, eta=ETA, train_frac=TRAIN_FRAC,
                epochs=EPOCHS, nprobe_max=NPROBE, tier="pq", pq_m=PQ_M,
                pq_ks=PQ_KS, rerank=RERANK))
        qs = build_quantized_store(
            jax.random.PRNGKey(1), eng.store["vectors"], eng.store["ids"],
            m=PQ_M, ks=eng.cfg.pq_ks, residual=True,
            centroids=eng.store["centroids"])
        return eng.cfg, eng.params, eng.store, qs

    cfg, params, store, qs = H._cached(f"eng_{_DS_KEY}", build)
    eng = LiraEngine(cfg=cfg, params=params, store=store, mesh=make_test_mesh())
    store_r = {**store, "codes": qs.codes, "codebooks": qs.codebooks,
               "cterm": qs.cterm}
    eng_r = LiraEngine(cfg=dataclasses.replace(cfg, tier="residual_pq"),
                       params=params, store=store_r, mesh=eng.mesh)
    return eng, eng_r, ds


def _scan_cost(cfg, tier_name: str, n_probes: float, nq: int):
    """Analytic (flops, bytes) for one serve call's scan stage — the work the
    measured wall time is divided into for roofline-relative rates. Per
    dispatched probe the scan touches one partition of ``capacity`` slots:

      f32:        2·cap·d flops (squared-L2 MACs), cap·d·dtype + cap·4 bytes
      pq:         2·cap·m ADC lookup-adds over uint8 codes, then an exact
                  rerank of rk = min(cap, rerank·k) shortlist rows; plus a
                  per-query LUT build of 2·m·ks·d flops / m·ks·4 bytes
      residual:   pq + the cterm plane (cap·4 bytes, cap adds)

    This is a lower-bound work model (top-k and scatter excluded), so the
    roofline fractions it yields are conservative."""
    cap, d, m, ks = cfg.capacity, cfg.dim, cfg.pq_m, cfg.pq_ks
    if tier_name == "f32":
        dtype_bytes = 2 if cfg.store_dtype == "bfloat16" else 4
        return (2.0 * cap * d * n_probes,
                (cap * d * dtype_bytes + cap * 4) * n_probes)
    rk = min(cap, cfg.rerank * cfg.k)
    flops = (2.0 * cap * m + 2.0 * rk * d) * n_probes + 2.0 * m * ks * d * nq
    bytes_ = (cap * m + rk * d * 4 + cap * 4) * n_probes + m * ks * 4 * nq
    if tier_name == "residual_pq":
        flops += cap * n_probes
        bytes_ += cap * 4 * n_probes
    return flops, bytes_


def run(emit):
    from benchmarks import roofline
    from repro.kernels import autotune
    from repro.serving import scan as serving_scan

    eng, eng_r, ds = _engines()
    q = ds.queries[:NQ]
    # tune the stream tiles for this store shape before jit warm-up so the
    # interpret path below bakes the winners in; sweeps land in the payload
    cap = int(eng.cfg.capacity)
    rk = min(cap, RERANK * K)
    autotune.autotune_l2_qbuf(cap, DIM, K, candidates=(128, 256))
    autotune.autotune_pq_adc_qbuf(cap, PQ_M, PQ_KS, rk, candidates=(64, 128))
    # stage-1 staged-operand accounting per tier: the compact plane + qbuf
    # indices the scalar-prefetch kernels stage vs the retired per-slot
    # host expansion (NQ=128 is already a pow2 jit bucket → q_row = NQ)
    q_cap = max(8, int(NQ * NPROBE / B * eng.cfg.q_cap_factor))
    qbuf_sds = jax.ShapeDtypeStruct((B, q_cap), "int32")
    staged_by_tier = {
        "f32": serving_scan.staged_operand_bytes(
            qbuf_sds, jax.ShapeDtypeStruct((NQ + 1, DIM), "float32")),
        "quantized": serving_scan.staged_operand_bytes(
            qbuf_sds, jax.ShapeDtypeStruct((NQ + 1, PQ_M, PQ_KS), "float32")),
    }
    staged_by_tier["residual"] = staged_by_tier["quantized"]
    mismatches = []
    payload_tiers = {}
    for tier, engine, tier_name in (("f32", eng, "f32"),
                                    ("quantized", eng, "pq"),
                                    ("residual", eng_r, "residual_pq")):
        results = {}
        rows = {}
        for impl in ("ref", "interpret"):
            engine.search(q, sigma=SIGMA, tier=tier_name, impl=impl)  # warm jit
            t0 = time.perf_counter()
            res = engine.search(q, sigma=SIGMA, tier=tier_name, impl=impl)
            dt = time.perf_counter() - t0
            d, ids, npb, ovf = (res.dists, res.ids, res.nprobe_eff,
                                res.overflow)
            results[impl] = (dt, d, ids, npb, ovf)
            # dispatched probes = σ-selected minus q_cap-dropped
            flops, bytes_ = _scan_cost(engine.cfg, tier_name,
                                       float(npb.sum()) - ovf, NQ)
            rows[impl] = {
                "seconds": dt, "qps": NQ / dt,
                "nprobe_mean": float(npb.mean()), "overflow": int(ovf),
                "dedup_hits": int(res.stats.dedup_hits),
                **roofline.ceiling_fracs(flops / dt, bytes_ / dt),
            }
            emit(f"scan_paths/{tier}_{impl}", dt * 1e6,
                 f"qps={NQ/dt:.0f};nprobe={npb.mean():.2f};overflow={ovf}")
        (t_r, d_r, i_r, np_r, o_r), (t_k, d_k, i_k, np_k, o_k) = \
            results["ref"], results["interpret"]
        bit_d = np.array_equal(d_r, d_k)
        same_i = all(
            set(i_r[r][np.isfinite(d_r[r])].tolist())
            == set(i_k[r][np.isfinite(d_k[r])].tolist())
            for r in range(NQ))
        same_ct = np.array_equal(np_r, np_k) and o_r == o_k
        emit(f"scan_paths/{tier}_parity", 0.0,
             f"dists_bit_identical={bit_d};ids_set_identical={same_i};"
             f"counters_identical={same_ct};kernel_over_ref=x{t_k/t_r:.2f}")
        if not (bit_d and same_i and same_ct):
            mismatches.append(tier)
        staged = staged_by_tier[tier]
        payload_tiers[tier] = {
            **rows, "parity": {"dists_bit_identical": bit_d,
                               "ids_set_identical": same_i,
                               "counters_identical": same_ct},
            "kernel_over_ref": t_k / t_r,
            "staged_operand_bytes": {
                **staged,
                "amplification_removed":
                    staged["expanded_bytes"] / staged["compact_bytes"]},
        }
    if mismatches:
        raise AssertionError(
            f"scan kernel/oracle drift on tier(s) {','.join(mismatches)}: "
            "serving/scan.py impls disagree — see scan_paths/*_parity rows")
    return {
        "suite": "scan_paths",
        "config": {"n": N, "n_queries": NQ, "dim": DIM, "partitions": B,
                   "k": K, "sigma": SIGMA, "eta": ETA, "pq_m": PQ_M,
                   "pq_ks": PQ_KS, "rerank": RERANK, "nprobe_max": NPROBE},
        "roofline_ceilings": {"peak_flops": roofline.PEAK,
                              "hbm_bytes_per_s": roofline.HBM},
        "tiers": payload_tiers,
        "autotune": autotune.records(),
    }


if __name__ == "__main__":
    run(lambda *a: print(*a))
