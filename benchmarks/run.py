"""Benchmark driver: one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV rows (and tees them to results/bench.csv).
Suites whose ``run`` returns a dict produce a per-PR perf snapshot:
``--json-out DIR`` writes each as ``DIR/BENCH_<suite>.json``, stamped with
``schema_version`` so downstream trajectory tooling can detect payload shape
changes (serving, scan_paths and quantized_scan all snapshot; the kernel
suites carry roofline-relative ops/s + bytes/s). ``--metrics-out FILE``
additionally dumps the process metrics registry (repro.obs) as a text
exposition, and ``--profile-dir DIR`` wraps the whole run in a jax.profiler
capture for TensorBoard (README "Observability").

    PYTHONPATH=src python -m benchmarks.run [--only table1,fig7] [--json-out .]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

# bump when the shape of any BENCH_*.json payload changes incompatibly
SCHEMA_VERSION = 1

SUITES = [
    ("eval_merge", "benchmarks.eval_merge"),
    ("quantized_scan", "benchmarks.quantized_scan"),
    ("scan_paths", "benchmarks.scan_paths"),
    ("serving", "benchmarks.serving_frontend"),
    ("churn", "benchmarks.churn"),
    ("cluster", "benchmarks.cluster"),
    ("fig2", "benchmarks.fig2_motivation"),
    ("fig11", "benchmarks.fig11_convergence"),
    ("table1", "benchmarks.table1_vary_k"),
    ("fig7", "benchmarks.fig7_8_tradeoff"),
    ("fig13", "benchmarks.fig13_eta"),
    ("fig14", "benchmarks.fig14_B"),
    ("table2", "benchmarks.table2_large_scale"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated suite names")
    ap.add_argument("--json-out", default="",
                    help="directory to write BENCH_<suite>.json perf "
                         "snapshots for suites that produce one")
    ap.add_argument("--metrics-out", default="",
                    help="file to write the metrics-registry exposition "
                         "(repro.obs) accumulated across the run")
    ap.add_argument("--profile-dir", default="",
                    help="capture a jax.profiler trace of the whole run into "
                         "this directory (TensorBoard profile plugin)")
    args = ap.parse_args()
    only = {s for s in args.only.split(",") if s}
    unknown = only - {tag for tag, _ in SUITES}
    if unknown:  # a typo'd --only must not pass vacuously in CI
        print(f"unknown suite(s): {','.join(sorted(unknown))}", file=sys.stderr)
        sys.exit(2)

    out_path = pathlib.Path(__file__).resolve().parent / "results" / "bench.csv"
    out_path.parent.mkdir(parents=True, exist_ok=True)
    rows = ["name,us_per_call,derived"]
    print(rows[0])

    def emit(name: str, us: float, derived: str):
        line = f"{name},{us:.1f},{derived}"
        rows.append(line)
        print(line, flush=True)

    import importlib

    from repro.obs import profile_capture

    failed: list[str] = []
    payloads: dict[str, dict] = {}
    t_all = time.time()
    with profile_capture(args.profile_dir):
        for tag, mod_name in SUITES:
            if only and tag not in only:
                continue
            t0 = time.time()
            try:
                mod = importlib.import_module(mod_name)
                payload = mod.run(emit)
                if isinstance(payload, dict):
                    payload.setdefault("schema_version", SCHEMA_VERSION)
                    payloads[tag] = payload
                emit(f"{tag}/_suite_seconds", (time.time() - t0) * 1e6, "ok")
            except Exception as e:  # keep the harness going; record the failure
                failed.append(tag)
                emit(f"{tag}/_suite_seconds", (time.time() - t0) * 1e6, f"FAIL:{type(e).__name__}:{e}")
                import traceback

                traceback.print_exc()
    emit("_total_seconds", (time.time() - t_all) * 1e6, "")
    out_path.write_text("\n".join(rows) + "\n")
    if args.json_out:
        outdir = pathlib.Path(args.json_out)
        outdir.mkdir(parents=True, exist_ok=True)
        for tag, payload in payloads.items():
            f = outdir / f"BENCH_{tag}.json"
            f.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
            print(f"wrote {f}", file=sys.stderr)
    if args.metrics_out:
        from repro.obs import default_registry, parse_exposition

        text = default_registry().render()
        parse_exposition(text)  # malformed exposition must fail the run
        mp = pathlib.Path(args.metrics_out)
        mp.parent.mkdir(parents=True, exist_ok=True)
        mp.write_text(text)
        print(f"wrote {mp}", file=sys.stderr)
    if failed:  # a half-run must not look green (CI smoke relies on this)
        print(f"FAILED suites: {','.join(failed)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
