"""Roofline analysis (deliverable g): three terms per (arch × shape × mesh)
from the dry-run artifacts.

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s          (197e12 bf16)
  memory term     = HLO_bytes_per_device / HBM_bw               (819e9 B/s)
  collective term = collective_bytes_per_device / link_bw       (50e9 B/s)

HLO terms come from the trip-count-aware HLO parser (repro.launch.hlo_cost) —
XLA's own cost_analysis counts while bodies once and is reported only as a
cross-check. The dominant term is the bottleneck the §Perf loop iterates on.
MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active params for MoE;
the useful-ratio MODEL/HLO exposes remat + masked-attention waste.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--mesh single] [--md]
"""
from __future__ import annotations

import argparse
import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parent / "results" / "dryrun"
PEAK = 197e12
HBM = 819e9
ICI = 50e9


def ceiling_fracs(ops_per_s: float, bytes_per_s: float) -> dict:
    """Roofline-relative achieved rates for a measured suite: the fraction of
    the bf16 compute peak and of HBM bandwidth a kernel actually sustained.
    The kernel BENCH_*.json snapshots (scan_paths, quantized_scan) persist
    these so the perf campaign (ROADMAP item 4) can read each PR's headroom
    directly — a scan at 2% of HBM is a streaming bug, one at 80% is done."""
    return {
        "ops_per_s": ops_per_s,
        "bytes_per_s": bytes_per_s,
        "frac_of_peak_flops": ops_per_s / PEAK,
        "frac_of_hbm_bw": bytes_per_s / HBM,
    }


def load_cells(mesh: str = "single", variant: str = "baseline"):
    cells = []
    for p in sorted(RESULTS.glob(f"*__{mesh}__{variant}.json")):
        cells.append(json.load(open(p)))
    return cells


def roofline_row(d: dict) -> dict:
    hlo = d["hlo"]
    t_comp = hlo["flops_per_device"] / PEAK
    t_mem = hlo["bytes_per_device"] / HBM
    t_coll = hlo["collective_bytes_per_device"] / ICI
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(t_comp, t_mem, t_coll)
    mf_dev = d["model_flops_per_device"]
    return {
        "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"], "variant": d["variant"],
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dom,
        "step_time_lb_s": bound,                       # max-term lower bound
        "model_flops_per_device": mf_dev,
        "useful_flop_ratio": mf_dev / max(hlo["flops_per_device"], 1.0),
        # achievable MFU if the dominant term is the critical path:
        "mfu_bound": mf_dev / PEAK / max(bound, 1e-12),
        "mem_gib": d["memory"].get("per_device_tpu_adjusted", d["memory"]["per_device_total"]) / 2**30,
        "fits": d["memory"]["fits_16g"],
    }


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:7.2f}s "
    if x >= 1e-3:
        return f"{x*1e3:7.2f}ms"
    return f"{x*1e6:7.2f}us"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--md", action="store_true", help="emit markdown table")
    args = ap.parse_args()

    cells = load_cells(args.mesh, args.variant)
    rows = [roofline_row(d) for d in cells]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))

    if args.md:
        print("| arch | shape | compute | memory | collective | dominant | MFU-bound | useful | mem GiB | fits |")
        print("|---|---|---|---|---|---|---|---|---|---|")
        for r in rows:
            print(f"| {r['arch']} | {r['shape']} | {fmt_s(r['t_compute_s'])} | {fmt_s(r['t_memory_s'])} "
                  f"| {fmt_s(r['t_collective_s'])} | **{r['dominant']}** | {r['mfu_bound']*100:.1f}% "
                  f"| {r['useful_flop_ratio']:.2f} | {r['mem_gib']:.1f} | {'y' if r['fits'] else 'N'} |")
    else:
        hdr = f"{'arch':24s} {'shape':14s} {'compute':9s} {'memory':9s} {'collect':9s} {'dominant':10s} {'MFU%':6s} {'useful':6s}"
        print(hdr)
        print("-" * len(hdr))
        for r in rows:
            print(f"{r['arch']:24s} {r['shape']:14s} {fmt_s(r['t_compute_s'])} {fmt_s(r['t_memory_s'])} "
                  f"{fmt_s(r['t_collective_s'])} {r['dominant']:10s} {r['mfu_bound']*100:5.1f}% "
                  f"{r['useful_flop_ratio']:5.2f}")
    return rows


if __name__ == "__main__":
    main()
