"""Quantized two-stage serving tier vs the exact f32 scan (ISSUEs 2 + 3).

Part 1 (ISSUE 2): serves the sift-like smoke workload through the distributed
engine twice — f32 fused scan vs PQ/ADC shortlist + exact rerank — on the
SAME LIRA store (η>0 replicas included), and reports QPS, recall@10 and
scan-store bytes.

Part 2 (ISSUE 3): residual vs non-residual PQ at EQUAL code size (same
pq_m/pq_ks, same partitions/probing model) on a clustered workload — the
regime where non-residual codes spend their budget encoding centroids. The
shortlist is deliberately shallow (rerank=4 vs the 32 the sift-like run
needs) so stage-1 code quality, not the exact rerank, decides recall.

Acceptance (enforced here; run.py turns a raise into a CI failure):
  * quantized recall@10 within 2% of the f32 path (sift-like, ISSUE 2),
  * scan store ≥ 8× smaller (sift-like, ISSUE 2),
  * residual recall@10 gap vs exact f32 ≤ the non-residual gap on the
    clustered workload (ISSUE 3).
QPS note: the CPU gather path understates the quantized tier — on TPU the
ADC scan is a fused one-hot MXU contraction (kernels.pq_adc_topk, incl. the
residual offset operands) and the bandwidth ratio below is the expected
speedup regime.

ISSUE 8 rides along: a dedicated ``adc_interpret`` row exercises the
scalar-prefetch kernel path (on CPU the default impl is "ref", so the rows
above never touch it), records the stage-1 staged-operand accounting —
compact ``lut_pad`` plane + qbuf indices vs the retired per-slot expansion —
and anchors CI's perf ratchet; the stream-tile autotune sweep for this store
shape is persisted under ``autotune``.
"""
from __future__ import annotations

import dataclasses
import time

import jax

from benchmarks import _harness as H
from repro.configs.base import LiraSystemConfig
from repro.core.metrics import recall_at_k
from repro.data import make_vector_dataset
from repro.launch.mesh import make_test_mesh
from repro.serving.engine import LiraEngine
from repro.serving.quantized import build_quantized_store, scan_store_bytes

DATASET = "sift-like"
B = 64
K = 10
N_QUERIES = 512
SIGMA = 0.3
STORE_K, STORE_ETA = 100, 0.03  # must mirror the get_stores cache key
# rerank=32 (rk=320 per partition): this synthetic mixture's NN distances sit
# close to the PQ reconstruction error, so the shortlist must run deeper than
# on real SIFT — the knob the quantized tier exposes for exactly this trade
PQ_M, PQ_KS, RERANK = 16, 256, 32


def _engine():
    ds = H.get_dataset(DATASET)
    params, _ = H.get_probing_model(DATASET, B)
    _, _, s_lira = H.get_stores(DATASET, B, k=STORE_K, eta=STORE_ETA)
    qs = H._cached(
        # codes derive from s_lira: key must cover its parameters too, or a
        # stores rebuild would silently pair stale codes with new vectors
        f"qstore_{DATASET}_B{B}_k{STORE_K}_eta{STORE_ETA}_m{PQ_M}_ks{PQ_KS}",
        lambda: build_quantized_store(jax.random.PRNGKey(0), s_lira.vectors,
                                      s_lira.ids, m=PQ_M, ks=PQ_KS))
    cfg = LiraSystemConfig(
        arch="lira", dim=ds.base.shape[1], n_partitions=B,
        capacity=s_lira.capacity, k=K, nprobe_max=16,
        tier="pq", pq_m=PQ_M, pq_ks=qs.ks, rerank=RERANK)
    store = {"centroids": s_lira.centroids, "vectors": s_lira.vectors,
             "ids": s_lira.ids, "codes": qs.codes, "codebooks": qs.codebooks}
    import jax.numpy as jnp
    params = jax.tree.map(jnp.asarray, params)
    return LiraEngine(cfg=cfg, params=params, store=store, mesh=make_test_mesh()), ds


def run(emit):
    eng, ds = _engine()
    q = ds.queries[:N_QUERIES]
    _, gti = H.get_gt(DATASET, 200)
    gti = gti[:N_QUERIES, :K]

    from benchmarks import roofline
    from benchmarks.scan_paths import _scan_cost
    from repro.kernels import autotune

    # tune the ADC stream tile for THIS store shape before any jit warm-up,
    # so the compiled steps bake the winning tile in; the sweep record lands
    # in the payload (auditable tile choice)
    cap = int(eng.cfg.capacity)
    rk = min(cap, RERANK * K)
    autotune.autotune_pq_adc_qbuf(cap, PQ_M, int(eng.cfg.pq_ks), rk,
                                  candidates=(64, 128))

    results = {}
    for label, tier in (("f32", "f32"), ("adc", "pq")):
        warm = eng.search(q, sigma=SIGMA, tier=tier)     # warm jit
        ids = warm.ids
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            eng.search(q, sigma=SIGMA, tier=tier)
        dt = (time.perf_counter() - t0) / reps
        results[label] = (dt, recall_at_k(ids, gti, K), warm)

    sb = scan_store_bytes(eng.store)
    (t_f, r_f, w_f), (t_q, r_q, w_q) = results["f32"], results["adc"]
    emit("quantized_scan/f32_scan", t_f * 1e6,
         f"qps={N_QUERIES/t_f:.0f};recall={r_f:.4f};store_mb={sb['f32']/2**20:.1f}")
    emit("quantized_scan/adc_scan", t_q * 1e6,
         f"qps={N_QUERIES/t_q:.0f};recall={r_q:.4f};store_mb={sb['quantized']/2**20:.1f};"
         f"m={PQ_M};ks={eng.cfg.pq_ks};rerank={RERANK}")
    emit("quantized_scan/summary", 0.0,
         f"bytes_ratio=x{sb['ratio']:.1f};recall_gap={r_f - r_q:.4f};"
         f"target_gap<=0.02;target_ratio>=8")

    if sb["ratio"] < 8.0:
        raise AssertionError(f"scan store only {sb['ratio']:.1f}x smaller (<8x)")
    if r_q < r_f - 0.02:
        raise AssertionError(
            f"quantized recall {r_q:.4f} more than 2% below f32 {r_f:.4f}")

    def _rates(tier_name, warm, dt):
        probes = float(warm.nprobe_eff.sum()) - warm.overflow
        flops, bytes_ = _scan_cost(eng.cfg, tier_name, probes, N_QUERIES)
        return roofline.ceiling_fracs(flops / dt, bytes_ / dt)

    # ---- kernel-path row: on CPU the default impl is "ref", so the rows
    # above never exercise the Pallas kernels — measure the interpret path
    # explicitly (query subset: the interpreter is slow, the point is the
    # staging accounting + a perf-ratchet anchor, not absolute QPS)
    nq_int = 128
    q_int = q[:nq_int]
    warm_int = eng.search(q_int, sigma=SIGMA, tier="pq", impl="interpret")
    t0 = time.perf_counter()
    eng.search(q_int, sigma=SIGMA, tier="pq", impl="interpret")
    t_int = time.perf_counter() - t0
    probes_int = float(warm_int.nprobe_eff.sum()) - warm_int.overflow
    flops_i, bytes_i = _scan_cost(eng.cfg, "pq", probes_int, nq_int)
    # stage-1 per-query operand staging: what the qbuf kernel actually
    # stages (compact LUT plane + indices) vs what the retired host-side
    # lut_pad[qbuf] gather materialized (one LUT copy per occupied slot)
    from repro.serving import scan as serving_scan

    q_row = nq_int                       # pow2 bucket: 128 is already a bucket
    q_cap = max(8, int(q_row * eng.cfg.nprobe_max / B * eng.cfg.q_cap_factor))
    staged = serving_scan.staged_operand_bytes(
        jax.ShapeDtypeStruct((B, q_cap), "int32"),
        jax.ShapeDtypeStruct((q_row + 1, PQ_M, int(eng.cfg.pq_ks)), "float32"))
    # the analytic model's LUT term is the compact plane — reality now
    # matches it; the expanded-model variant shows what the old staging
    # added on top (the ratchet metric is the compact one)
    extra = staged["expanded_bytes"] - staged["compact_bytes"]
    fr_compact = roofline.ceiling_fracs(flops_i / t_int, bytes_i / t_int)
    fr_expanded = roofline.ceiling_fracs(flops_i / t_int,
                                         (bytes_i + extra) / t_int)
    emit("quantized_scan/adc_interpret", t_int * 1e6,
         f"qps={nq_int/t_int:.0f};staged_compact_kb={staged['compact_bytes']/2**10:.0f};"
         f"staged_expanded_kb={staged['expanded_bytes']/2**10:.0f};"
         f"amplification_removed=x{staged['expanded_bytes']/staged['compact_bytes']:.1f}")

    payload = {
        "suite": "quantized_scan",
        "config": {"dataset": DATASET, "partitions": B, "k": K,
                   "n_queries": N_QUERIES, "sigma": SIGMA, "pq_m": PQ_M,
                   "pq_ks": int(eng.cfg.pq_ks), "rerank": RERANK},
        "roofline_ceilings": {"peak_flops": roofline.PEAK,
                              "hbm_bytes_per_s": roofline.HBM},
        "f32": {"seconds": t_f, "qps": N_QUERIES / t_f, "recall": r_f,
                "store_bytes": sb["f32"], **_rates("f32", w_f, t_f)},
        "adc": {"seconds": t_q, "qps": N_QUERIES / t_q, "recall": r_q,
                "store_bytes": sb["quantized"], **_rates("pq", w_q, t_q)},
        "adc_interpret": {
            "seconds": t_int, "qps": nq_int / t_int, "n_queries": nq_int,
            **fr_compact,
            "staged_operand_bytes": {
                **staged,
                "amplification_removed":
                    staged["expanded_bytes"] / staged["compact_bytes"]},
            "expanded_model": fr_expanded,
        },
        "autotune": autotune.records(),
        "bytes_ratio": sb["ratio"],
        "recall_gap": r_f - r_q,
    }
    payload["residual_compare"] = _run_residual_compare(emit)
    return payload


# ------------------------------------------- residual vs non-residual (ISSUE 3)

CL_N, CL_Q, CL_DIM, CL_B = 20_000, 256, 64, 16
CL_M, CL_KS, CL_RERANK = 8, 64, 4
CL_SEED, CL_ETA = 5, 0.03
# every derived artifact (engines, GT) must key on the full dataset identity,
# or a constant change silently pairs stale engines with rebuilt data
_CL_DS_KEY = f"clustered_n{CL_N}_d{CL_DIM}_B{CL_B}_s{CL_SEED}"


def _clustered_engines():
    """One clustered index, three serving forms. The non-residual engine is
    built end-to-end; the residual engine reuses its partitions, probing model
    and (m, ks) with only the code semantics changed — equal code size by
    construction."""
    ds = H._cached(
        f"ds_{_CL_DS_KEY}",
        lambda: make_vector_dataset("clustered", n=CL_N, n_queries=CL_Q,
                                    dim=CL_DIM, n_modes=CL_B, center_scale=8.0,
                                    spread=0.5, boundary_frac=0.05,
                                    noise_frac=0.0, seed=CL_SEED))

    def build():
        from repro.serving import BuildConfig

        eng = LiraEngine.build(
            make_test_mesh(), ds.base, BuildConfig(
                n_partitions=CL_B, k=K, eta=CL_ETA, train_frac=0.25, epochs=5,
                nprobe_max=CL_B, tier="pq", pq_m=CL_M, pq_ks=CL_KS,
                rerank=CL_RERANK))
        qs = build_quantized_store(
            jax.random.PRNGKey(1), eng.store["vectors"], eng.store["ids"],
            m=CL_M, ks=eng.cfg.pq_ks, residual=True,
            centroids=eng.store["centroids"])
        return eng.cfg, eng.params, eng.store, qs

    cfg, params, store, qs = H._cached(
        f"qres_{_CL_DS_KEY}_eta{CL_ETA}_k{K}_m{CL_M}_ks{CL_KS}", build)
    cfg = dataclasses.replace(cfg, rerank=CL_RERANK)  # rerank is not in the key
    eng_nr = LiraEngine(cfg=cfg, params=params, store=store,
                        mesh=make_test_mesh())
    store_r = {**store, "codes": qs.codes, "codebooks": qs.codebooks,
               "cterm": qs.cterm}
    eng_r = LiraEngine(cfg=dataclasses.replace(cfg, tier="residual_pq"),
                       params=params, store=store_r, mesh=eng_nr.mesh)
    return eng_nr, eng_r, ds


def _run_residual_compare(emit):
    import numpy as np

    from repro.core import ground_truth as gt

    eng_nr, eng_r, ds = _clustered_engines()
    _, gti = H._cached(f"gt_{_CL_DS_KEY}_k{K}",
                       lambda: gt.exact_knn(ds.queries, ds.base, K))
    q = ds.queries

    recalls, times = {}, {}
    # probe-all σ: f32 is then exact, so each tier's gap is pure quantization
    for name, eng, tier in (("f32", eng_r, "f32"),
                            ("nonres", eng_nr, "pq"),
                            ("res", eng_r, "residual_pq")):
        ids = eng.search(q, sigma=-1.0, tier=tier).ids  # warm jit
        t0 = time.perf_counter()
        eng.search(q, sigma=-1.0, tier=tier)
        times[name] = time.perf_counter() - t0
        recalls[name] = recall_at_k(np.asarray(ids), gti, K)

    gap_nr = recalls["f32"] - recalls["nonres"]
    gap_r = recalls["f32"] - recalls["res"]
    sb_r = scan_store_bytes(eng_r.store)
    for name in ("f32", "nonres", "res"):
        emit(f"quantized_scan/clustered_{name}", times[name] * 1e6,
             f"qps={CL_Q/times[name]:.0f};recall={recalls[name]:.4f}")
    emit("quantized_scan/residual_summary", 0.0,
         f"gap_res={gap_r:.4f};gap_nonres={gap_nr:.4f};m={CL_M};ks={CL_KS};"
         f"rerank={CL_RERANK};bytes_ratio=x{sb_r['ratio']:.1f};"
         f"target=gap_res<=gap_nonres")

    if gap_r > gap_nr:
        raise AssertionError(
            f"residual recall gap {gap_r:.4f} exceeds non-residual gap "
            f"{gap_nr:.4f} on the clustered workload at equal code size")
    return {
        "config": {"n": CL_N, "n_queries": CL_Q, "dim": CL_DIM,
                   "partitions": CL_B, "pq_m": CL_M, "pq_ks": CL_KS,
                   "rerank": CL_RERANK, "eta": CL_ETA},
        "recall": {n: recalls[n] for n in ("f32", "nonres", "res")},
        "seconds": {n: times[n] for n in ("f32", "nonres", "res")},
        "gap_res": gap_r, "gap_nonres": gap_nr,
        "bytes_ratio": sb_r["ratio"],
    }
