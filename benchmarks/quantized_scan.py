"""Quantized two-stage serving tier vs the exact f32 scan (ISSUE 2).

Serves the sift-like smoke workload through the distributed engine twice —
f32 fused scan vs PQ/ADC shortlist + exact rerank — on the SAME LIRA store
(η>0 replicas included), and reports QPS, recall@10 and scan-store bytes.

Acceptance (enforced here; run.py turns a raise into a CI failure):
  * quantized recall@10 within 2% of the f32 path,
  * scan store ≥ 8× smaller.
QPS note: the CPU gather path understates the quantized tier — on TPU the
ADC scan is a fused one-hot MXU contraction (kernels.pq_adc_topk) and the
bandwidth ratio below is the expected speedup regime.
"""
from __future__ import annotations

import time

import jax

from benchmarks import _harness as H
from repro.configs.base import LiraSystemConfig
from repro.core.metrics import recall_at_k
from repro.launch.mesh import make_test_mesh
from repro.serving.engine import LiraEngine
from repro.serving.quantized import build_quantized_store, scan_store_bytes

DATASET = "sift-like"
B = 64
K = 10
N_QUERIES = 512
SIGMA = 0.3
STORE_K, STORE_ETA = 100, 0.03  # must mirror the get_stores cache key
# rerank=32 (rk=320 per partition): this synthetic mixture's NN distances sit
# close to the PQ reconstruction error, so the shortlist must run deeper than
# on real SIFT — the knob the quantized tier exposes for exactly this trade
PQ_M, PQ_KS, RERANK = 16, 256, 32


def _engine():
    ds = H.get_dataset(DATASET)
    params, _ = H.get_probing_model(DATASET, B)
    _, _, s_lira = H.get_stores(DATASET, B, k=STORE_K, eta=STORE_ETA)
    qs = H._cached(
        # codes derive from s_lira: key must cover its parameters too, or a
        # stores rebuild would silently pair stale codes with new vectors
        f"qstore_{DATASET}_B{B}_k{STORE_K}_eta{STORE_ETA}_m{PQ_M}_ks{PQ_KS}",
        lambda: build_quantized_store(jax.random.PRNGKey(0), s_lira.vectors,
                                      s_lira.ids, m=PQ_M, ks=PQ_KS))
    cfg = LiraSystemConfig(
        arch="lira", dim=ds.base.shape[1], n_partitions=B,
        capacity=s_lira.capacity, k=K, nprobe_max=16,
        quantized=True, pq_m=PQ_M, pq_ks=qs.ks, rerank=RERANK)
    store = {"centroids": s_lira.centroids, "vectors": s_lira.vectors,
             "ids": s_lira.ids, "codes": qs.codes, "codebooks": qs.codebooks}
    import jax.numpy as jnp
    params = jax.tree.map(jnp.asarray, params)
    return LiraEngine(cfg=cfg, params=params, store=store, mesh=make_test_mesh()), ds


def run(emit):
    eng, ds = _engine()
    q = ds.queries[:N_QUERIES]
    _, gti = H.get_gt(DATASET, 200)
    gti = gti[:N_QUERIES, :K]

    results = {}
    for tier in ("f32", "adc"):
        quantized = tier == "adc"
        _, ids, _ = eng.search(q, sigma=SIGMA, quantized=quantized)  # warm jit
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            eng.search(q, sigma=SIGMA, quantized=quantized)
        dt = (time.perf_counter() - t0) / reps
        results[tier] = (dt, recall_at_k(ids, gti, K))

    sb = scan_store_bytes(eng.store)
    (t_f, r_f), (t_q, r_q) = results["f32"], results["adc"]
    emit("quantized_scan/f32_scan", t_f * 1e6,
         f"qps={N_QUERIES/t_f:.0f};recall={r_f:.4f};store_mb={sb['f32']/2**20:.1f}")
    emit("quantized_scan/adc_scan", t_q * 1e6,
         f"qps={N_QUERIES/t_q:.0f};recall={r_q:.4f};store_mb={sb['quantized']/2**20:.1f};"
         f"m={PQ_M};ks={eng.cfg.pq_ks};rerank={RERANK}")
    emit("quantized_scan/summary", 0.0,
         f"bytes_ratio=x{sb['ratio']:.1f};recall_gap={r_f - r_q:.4f};"
         f"target_gap<=0.02;target_ratio>=8")

    if sb["ratio"] < 8.0:
        raise AssertionError(f"scan store only {sb['ratio']:.1f}x smaller (<8x)")
    if r_q < r_f - 0.02:
        raise AssertionError(
            f"quantized recall {r_q:.4f} more than 2% below f32 {r_f:.4f}")
