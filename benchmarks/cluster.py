"""Cluster-serving gate (ISSUE 10): throughput scaling vs R, hedged tail,
and fault injection with zero lost queries.

Three sections over one set of built shard engines (control planes are
re-wrapped per section — engines hold no routing state):

  * **throughput vs R** — the same batch stream through R=1 and R=2 replica
    groups, REAL measured engine service charged to each winning replica's
    virtual busy-time; cluster makespan is the busiest replica (shards and
    replicas are parallel pods). Gate: R=2 throughput ≥ ``MIN_SCALING``× R=1
    (the ratio is dimensionless — box speed cancels — so it ratchets).
  * **hedged p99** — R=3 with one 25× straggler, ``fixed_service_s`` virtual
    latencies (the policy outcome is exactly deterministic, so the committed
    ratio never drifts with machine noise); the same seeded stream with
    hedging off then on. Gate: hedging cuts p99 below the straggler's
    latency; the p99 ratio is the ratcheted series.
  * **fault injection** — a replica killed mid-stream with a batch in
    flight: every batch must still be answered (zero lost queries), results
    bit-identical to an unkilled reference run, recall vs exact ground truth
    unchanged. Exactness conditions: σ=-1 + rerank·k ≥ capacity (see
    tests/test_cluster.py).

Returns the JSON payload persisted as ``BENCH_cluster.json``;
``benchmarks/perf_ratchet.py`` gates ``throughput.scaling_r2_over_r1`` and
``hedging.p99_ratio`` against the committed snapshot.
"""
from __future__ import annotations

import numpy as np

from repro.core import ground_truth as gt
from repro.core.metrics import recall_at_k
from repro.data import make_vector_dataset
from repro.launch.mesh import make_test_mesh
from repro.obs import MetricsRegistry
from repro.serving import BuildConfig, ClusterConfig, LiraCluster, SearchRequest
from repro.utils.clock import FakeClock

N, NQ, DIM, K = 2_000, 256, 16, 10
B = 4                       # partitions per shard
S = 2                       # LANNS level-1 shards
BS = 32                     # query rows per batch
RERANK = 64                 # rerank·k ≥ capacity → exact over scanned rows
SEED = 11
SERVICE_S = 1e-3            # virtual per-batch service (hedging section)
STRAGGLER = 25.0
N_THROUGHPUT, N_TAIL, N_FAULT, KILL_AT = 24, 200, 16, 5
MIN_SCALING = 1.3           # R=2 must beat R=1 by at least this factor


def _batches(queries, n_batches):
    for j in range(n_batches):
        yield queries[np.arange(j * BS, (j + 1) * BS) % len(queries)]


def _rewrap(cluster, ccfg, **kw):
    return LiraCluster([g.engine for g in cluster.groups],
                       [g.row_ids for g in cluster.groups], ccfg, **kw)


def _makespan(cluster) -> float:
    """Busiest replica's effective busy time — the parallel-pod completion
    time for the stream."""
    return max(m.busy_s for g in cluster.groups for m in g.members)


def run(emit):
    ds = make_vector_dataset(n=N, n_queries=NQ, dim=DIM, n_modes=8, seed=SEED)
    mesh = make_test_mesh()
    base = LiraCluster.build(
        mesh, ds.base, BuildConfig(
            n_partitions=B, k=K, eta=0.03, train_frac=0.4, epochs=2,
            nprobe_max=B, rerank=RERANK, seed=SEED),
        ClusterConfig(n_shards=S, n_replicas=1, seed=SEED),
        clock=FakeClock())
    base.search(SearchRequest(queries=ds.queries[:BS], sigma=-1.0))  # warm jit

    # ------------------------------------------------- throughput scaling vs R
    thr = {}
    for r in (1, 2):
        cl = _rewrap(base, ClusterConfig(n_shards=S, n_replicas=r,
                                         hedging=False, seed=SEED),
                     clock=FakeClock())
        rows = 0
        for q in _batches(ds.queries, N_THROUGHPUT):
            rows += cl.search(SearchRequest(queries=q, sigma=-1.0)).dists.shape[0]
        makespan = _makespan(cl)
        thr[f"r{r}"] = {"rows": rows, "makespan_s": round(makespan, 6),
                        "rows_per_s": round(rows / makespan, 1)}
    scaling = thr["r2"]["rows_per_s"] / thr["r1"]["rows_per_s"]
    assert scaling >= MIN_SCALING, (
        f"R=2 throughput scaled only {scaling:.2f}× over R=1 "
        f"(gate {MIN_SCALING}×): routing is not spreading load")
    emit("cluster/throughput_scaling_r2_over_r1", scaling * 1e6,
         f"r1={thr['r1']['rows_per_s']}rps r2={thr['r2']['rows_per_s']}rps")

    # ------------------------------------------------ p99 with/without hedging
    tails, regs = {}, {}
    for mode, hedging in (("unhedged", False), ("hedged", True)):
        regs[mode] = reg = MetricsRegistry()
        cl = _rewrap(base, ClusterConfig(n_shards=S, n_replicas=3,
                                         hedging=hedging, seed=SEED),
                     clock=FakeClock(), fixed_service_s=SERVICE_S, metrics=reg)
        lats = []
        for i, q in enumerate(_batches(ds.queries, N_TAIL)):
            if i == 20:  # healthy hedge-warmup history first
                for g in cl.groups:
                    g.router.replicas[0].latency_scale = STRAGGLER
            lats.append(cl.search(SearchRequest(queries=q, sigma=-1.0))
                        .stats.latency_ms)
        tails[mode] = float(np.quantile(lats[20:], 0.99))
    hedges = regs["hedged"].counter("lira_hedges_total").total()
    hedge_wins = regs["hedged"].counter("lira_hedge_wins_total").total()
    assert hedges > 0, "straggler never hedged: deadline policy is dead"
    assert tails["hedged"] < STRAGGLER * SERVICE_S * 1e3, (
        f"hedged p99 {tails['hedged']:.2f}ms still at the straggler's "
        f"{STRAGGLER * SERVICE_S * 1e3:.0f}ms")
    assert tails["hedged"] < tails["unhedged"], "hedging did not cut the tail"
    p99_ratio = tails["hedged"] / tails["unhedged"]
    emit("cluster/hedged_p99_ms", tails["hedged"] * 1e3,
         f"unhedged={tails['unhedged']:.2f}ms hedges={hedges:.0f}")

    # --------------------------------------- fault injection: zero lost queries
    _, gti = gt.exact_knn(ds.queries, ds.base, K)
    runs = {}
    for mode in ("reference", "killed"):
        reg = MetricsRegistry()
        cl = _rewrap(base, ClusterConfig(n_shards=S, n_replicas=2, seed=SEED),
                     clock=FakeClock(), fixed_service_s=SERVICE_S, metrics=reg)
        ids, rows = [], 0
        for i, q in enumerate(_batches(ds.queries, N_FAULT)):
            if mode == "killed" and i == KILL_AT:
                cl.fail_replica(0, 0, inflight=True)
            res = cl.search(SearchRequest(queries=q, sigma=-1.0))
            ids.append(np.asarray(res.ids))
            rows += res.ids.shape[0]
        runs[mode] = {
            "ids": np.concatenate(ids, 0), "rows": rows,
            "requeued": sum(g.router.requeued for g in cl.groups),
            "failovers": int(reg.counter("lira_failovers_total").total()),
        }
    expected_rows = N_FAULT * BS
    lost = expected_rows - runs["killed"]["rows"]
    assert lost == 0, f"{lost} query rows lost across the replica kill"
    assert runs["killed"]["requeued"] == 1, (
        f"expected exactly 1 replayed in-flight batch, "
        f"got {runs['killed']['requeued']}")
    assert np.array_equal(runs["killed"]["ids"], runs["reference"]["ids"]), \
        "replica kill changed answers (replay is not transparent)"
    gt_tile = np.concatenate(
        [gti[np.arange(j * BS, (j + 1) * BS) % NQ] for j in range(N_FAULT)], 0)
    rec = {m: float(recall_at_k(runs[m]["ids"], gt_tile, K)) for m in runs}
    assert rec["killed"] == rec["reference"], (
        f"recall moved across the kill: {rec}")
    emit("cluster/fault_requeued", runs["killed"]["requeued"],
         f"lost={lost} recall={rec['killed']:.4f}")

    return {
        "suite": "cluster",
        "config": {"n": N, "dim": DIM, "shards": S, "partitions_per_shard": B,
                   "k": K, "batch_rows": BS, "straggler_scale": STRAGGLER,
                   "service_s": SERVICE_S, "min_scaling": MIN_SCALING},
        "throughput": {**thr,
                       "scaling_r2_over_r1": round(scaling, 4)},
        "hedging": {"p99_ms_unhedged": round(tails["unhedged"], 4),
                    "p99_ms_hedged": round(tails["hedged"], 4),
                    "p99_ratio": round(p99_ratio, 4),
                    "hedges": int(hedges), "hedge_wins": int(hedge_wins)},
        "fault": {"batches": N_FAULT, "kill_at": KILL_AT,
                  "lost_queries": int(lost),
                  "requeued": runs["killed"]["requeued"],
                  "failovers": runs["killed"]["failovers"],
                  "recall_reference": round(rec["reference"], 4),
                  "recall_killed": round(rec["killed"], 4),
                  "ids_identical": True},
    }
