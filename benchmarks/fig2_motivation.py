"""Paper Fig 2 + Fig 10 + Fig 4(LEFT): motivation statistics on our data —
probing waste of distance ranking (nprobe*_dist − nprobe*), ubiquity of
long-tail kNN, and the boundary-point correlation used by redundancy."""
from __future__ import annotations

import time

import numpy as np

from benchmarks import _harness as H
from repro.core import ground_truth as gt

K = 100
DATASET = "sift-like"


def run(emit):
    ds = H.get_dataset(DATASET)
    _, gti = H.get_gt(DATASET, 200)
    gti = gti[:, :K]

    for b in (8, 16, 32, 64):
        t0 = time.time()
        assign, cents = H.get_partitions(DATASET, b)
        ncd = gt.knn_count_distribution(gti, assign, b)
        labels = (ncd > 0).astype(np.float32)
        nstar = gt.optimal_nprobe(labels)
        ndist = gt.nprobe_dist(gti, assign, ds.queries, cents)
        waste = ndist - nstar
        # long-tail: min nonzero count == 1 (paper def. 3)
        mins = np.where(ncd == 0, 10**9, ncd).min(-1)
        long_tail_frac = float((mins == 1).mean())
        dt = time.time() - t0
        emit(f"fig2/B{b}", dt * 1e6,
             f"nprobe*={nstar.mean():.2f};nprobe*_dist={ndist.mean():.2f};"
             f"waste_mean={waste.mean():.2f};waste_p95={np.quantile(waste,0.95):.0f};"
             f"long_tail_frac={long_tail_frac:.3f}")

    # Fig 4 LEFT: large predicted-nprobe points are more often long-tail points
    b = 64
    assign, cents = H.get_partitions(DATASET, b)
    sub, lab = H.get_train_labels(DATASET, b, K)
    nstar_pts = lab.sum(-1)
    # a point is long-tail if it appears as a count-1 kNN of some other point
    ncd_pts = None  # reuse labels: count dist of training points among themselves
    emit("fig4/corr", 0,
         f"mean_nprobe*_of_points={nstar_pts.mean():.2f};p90={np.quantile(nstar_pts,0.9):.0f}")
