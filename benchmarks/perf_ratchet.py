"""Perf ratchet: fail CI when a tracked perf ratio regresses.

Compares fresh ``BENCH_*.json`` payloads against the snapshots committed
under ``benchmarks/results/``. Absolute times are machine noise (CI boxes
differ run to run), so every gate is a RATIO OF RATIOS: each tracked metric
is first normalized WITHIN its own payload by a second measurement from the
same box and process (machine speed cancels), and only then compared
fresh-vs-committed against a ``max_regression`` band.

    PYTHONPATH=src python -m benchmarks.perf_ratchet \
        --fresh bench-json --committed benchmarks/results [--max-regression 0.2]

Metrics tracked:
  * scan_paths (higher is better):
    tiers.<t>.interpret.frac_of_hbm_bw / tiers.<t>.ref.frac_of_hbm_bw
    for t in {f32, quantized, residual} — the kernel path's roofline
    fraction normalized by the jnp ref path;
  * quantized_scan (higher is better):
    adc_interpret.frac_of_hbm_bw / adc.frac_of_hbm_bw
    (the scalar-prefetch kernel path vs the jnp default);
  * serving (LOWER is better): near-saturation tail latency — the 0.8×
    load point's p99_ms normalized by the same payload's measured
    batch_service_ms, i.e. "p99 in units of one batch's serve time". Box
    speed cancels (both numbers time the same engine on the same box);
    what's left is queueing + scheduling overhead, which is exactly what
    front-end/engine changes can regress. Fails when the fresh ratio rises
    more than ``max_regression`` above the committed one.
  * cluster (higher is better): throughput.scaling_r2_over_r1 — R=2 vs R=1
    replica-group throughput over the same batch stream, a dimensionless
    load-spreading ratio (box speed cancels between the two wraps);
  * cluster (LOWER is better): hedging.p99_ratio — hedged p99 normalized by
    the unhedged p99 of the same seeded straggler stream; the policy runs on
    ``fixed_service_s`` virtual latencies, so the ratio is exactly
    deterministic.

A missing committed snapshot skips that metric with a warning (first run of
a new suite must be able to land its own baseline); a missing FRESH payload
is an error — the bench that was supposed to produce it broke.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys


def _get(d: dict, path: str):
    cur = d
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            raise KeyError(path)
        cur = cur[part]
    return cur


def _path_ratio(kernel_path: str, ref_path: str):
    """Extractor for the kernel-vs-ref roofline gates: two dotted paths into
    one payload, divided (machine speed cancels)."""

    def extract(payload: dict) -> float:
        kernel = float(_get(payload, kernel_path))
        ref = float(_get(payload, ref_path))
        if ref <= 0:
            raise ValueError(f"ref-path fraction {ref_path} is {ref}; "
                             "cannot normalize")
        return kernel / ref

    return extract


def _serving_p99_batches(payload: dict) -> float:
    """Near-saturation p99 in units of one measured batch service time: the
    machine-robust serving tail gate (both numbers ran on the same box)."""
    pt = next((p for p in payload.get("points", ())
               if abs(float(p.get("offered_x_drain", -1)) - 0.8) < 1e-6),
              None)
    if pt is None:
        raise KeyError("points[offered_x_drain=0.8]")
    batch_ms = float(_get(payload, "batch_service_ms"))
    if batch_ms <= 0:
        raise ValueError(f"batch_service_ms is {batch_ms}; cannot normalize")
    return float(pt["p99_ms"]) / batch_ms


# (suite, metric name, extractor(payload) -> normalized ratio, higher_is_better)
METRICS = [
    ("scan_paths", f"scan_paths/{t}_hbm_frac",
     _path_ratio(f"tiers.{t}.interpret.frac_of_hbm_bw",
                 f"tiers.{t}.ref.frac_of_hbm_bw"), True)
    for t in ("f32", "quantized", "residual")
] + [
    ("quantized_scan", "quantized_scan/adc_interpret_hbm_frac",
     _path_ratio("adc_interpret.frac_of_hbm_bw", "adc.frac_of_hbm_bw"),
     True),
    ("serving", "serving/p99_batches_at_0.8x", _serving_p99_batches, False),
    ("cluster", "cluster/throughput_scaling_r2_over_r1",
     lambda p: float(_get(p, "throughput.scaling_r2_over_r1")), True),
    ("cluster", "cluster/hedged_p99_ratio",
     lambda p: float(_get(p, "hedging.p99_ratio")), False),
]


def check(fresh_dir: pathlib.Path, committed_dir: pathlib.Path,
          max_regression: float) -> list[str]:
    """Returns a list of failure messages (empty = ratchet holds)."""
    failures: list[str] = []
    for suite, name, extract, higher_is_better in METRICS:
        fresh_file = fresh_dir / f"BENCH_{suite}.json"
        committed_file = committed_dir / f"BENCH_{suite}.json"
        if not fresh_file.exists():
            failures.append(f"{name}: fresh payload {fresh_file} missing — "
                            "did the bench run?")
            continue
        fresh = json.loads(fresh_file.read_text())
        if not committed_file.exists():
            print(f"[ratchet] {name}: no committed snapshot "
                  f"({committed_file}) — skipping (baseline run)")
            continue
        committed = json.loads(committed_file.read_text())
        try:
            r_fresh = extract(fresh)
            r_committed = extract(committed)
        except KeyError as e:
            print(f"[ratchet] {name}: metric {e} absent (older schema) — "
                  "skipping")
            continue
        if higher_is_better:
            bound = r_committed * (1.0 - max_regression)
            ok = r_fresh >= bound
            word = "floor"
        else:
            bound = r_committed * (1.0 + max_regression)
            ok = r_fresh <= bound
            word = "ceiling"
        print(f"[ratchet] {name}: fresh={r_fresh:.4f} committed="
              f"{r_committed:.4f} {word}={bound:.4f} "
              f"{'OK' if ok else 'REGRESSED'}")
        if not ok:
            failures.append(
                f"{name}: normalized ratio {r_fresh:.4f} regressed more "
                f"than {max_regression:.0%} past committed "
                f"{r_committed:.4f} ({word} {bound:.4f})")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh", required=True,
                    help="directory with the just-produced BENCH_*.json")
    ap.add_argument("--committed", default="benchmarks/results",
                    help="directory with the committed snapshots")
    ap.add_argument("--max-regression", type=float, default=0.2,
                    help="tolerated fractional drop of the normalized ratio")
    args = ap.parse_args(argv)
    failures = check(pathlib.Path(args.fresh), pathlib.Path(args.committed),
                     args.max_regression)
    if failures:
        for f in failures:
            print(f"[ratchet] FAIL: {f}", file=sys.stderr)
        return 1
    print("[ratchet] all tracked kernel-path ratios within bounds")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
