"""Perf ratchet: fail CI when the kernel path's roofline fraction regresses.

Compares fresh ``BENCH_scan_paths.json`` / ``BENCH_quantized_scan.json``
payloads against the snapshots committed under ``benchmarks/results/``.
Absolute times are machine noise (CI boxes differ run to run), so the gate is
a RATIO OF RATIOS: for each tracked metric the kernel path's
``ceiling_fracs.frac_of_hbm_bw`` is first normalized by the same payload's
ref-path fraction (machine speed cancels — both rows ran on the same box,
same process), and only then compared fresh-vs-committed. A normalized ratio
below ``1 - max_regression`` of the committed one fails.

    PYTHONPATH=src python -m benchmarks.perf_ratchet \
        --fresh bench-json --committed benchmarks/results [--max-regression 0.2]

Metrics tracked (kernel row / ref row, both from one payload):
  * scan_paths:      tiers.<t>.interpret.frac_of_hbm_bw / tiers.<t>.ref...
                     for t in {f32, quantized, residual}
  * quantized_scan:  adc_interpret.frac_of_hbm_bw / adc.frac_of_hbm_bw
                     (the scalar-prefetch kernel path vs the jnp default)

A missing committed snapshot skips that metric with a warning (first run of
a new suite must be able to land its own baseline); a missing FRESH payload
is an error — the bench that was supposed to produce it broke.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys


def _get(d: dict, path: str):
    cur = d
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            raise KeyError(path)
        cur = cur[part]
    return cur


# (suite, metric name, kernel-row path, ref-row path)
METRICS = [
    ("scan_paths", f"scan_paths/{t}_hbm_frac",
     f"tiers.{t}.interpret.frac_of_hbm_bw", f"tiers.{t}.ref.frac_of_hbm_bw")
    for t in ("f32", "quantized", "residual")
] + [
    ("quantized_scan", "quantized_scan/adc_interpret_hbm_frac",
     "adc_interpret.frac_of_hbm_bw", "adc.frac_of_hbm_bw"),
]


def _normalized(payload: dict, kernel_path: str, ref_path: str) -> float:
    kernel = float(_get(payload, kernel_path))
    ref = float(_get(payload, ref_path))
    if ref <= 0:
        raise ValueError(f"ref-path fraction {ref_path} is {ref}; cannot "
                         "normalize")
    return kernel / ref


def check(fresh_dir: pathlib.Path, committed_dir: pathlib.Path,
          max_regression: float) -> list[str]:
    """Returns a list of failure messages (empty = ratchet holds)."""
    failures: list[str] = []
    for suite, name, kernel_path, ref_path in METRICS:
        fresh_file = fresh_dir / f"BENCH_{suite}.json"
        committed_file = committed_dir / f"BENCH_{suite}.json"
        if not fresh_file.exists():
            failures.append(f"{name}: fresh payload {fresh_file} missing — "
                            "did the bench run?")
            continue
        fresh = json.loads(fresh_file.read_text())
        if not committed_file.exists():
            print(f"[ratchet] {name}: no committed snapshot "
                  f"({committed_file}) — skipping (baseline run)")
            continue
        committed = json.loads(committed_file.read_text())
        try:
            r_fresh = _normalized(fresh, kernel_path, ref_path)
            r_committed = _normalized(committed, kernel_path, ref_path)
        except KeyError as e:
            print(f"[ratchet] {name}: metric {e} absent (older schema) — "
                  "skipping")
            continue
        floor = r_committed * (1.0 - max_regression)
        verdict = "OK" if r_fresh >= floor else "REGRESSED"
        print(f"[ratchet] {name}: fresh={r_fresh:.4f} committed="
              f"{r_committed:.4f} floor={floor:.4f} {verdict}")
        if r_fresh < floor:
            failures.append(
                f"{name}: kernel/ref HBM-bw ratio {r_fresh:.4f} fell more "
                f"than {max_regression:.0%} below committed {r_committed:.4f}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh", required=True,
                    help="directory with the just-produced BENCH_*.json")
    ap.add_argument("--committed", default="benchmarks/results",
                    help="directory with the committed snapshots")
    ap.add_argument("--max-regression", type=float, default=0.2,
                    help="tolerated fractional drop of the normalized ratio")
    args = ap.parse_args(argv)
    failures = check(pathlib.Path(args.fresh), pathlib.Path(args.committed),
                     args.max_regression)
    if failures:
        for f in failures:
            print(f"[ratchet] FAIL: {f}", file=sys.stderr)
        return 1
    print("[ratchet] all tracked kernel-path ratios within bounds")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
