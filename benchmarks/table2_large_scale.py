"""Paper Table 2 proxy: two-level index QPS / Recall@100 / nprobe.

The paper's large-scale two-level setting (50M, B=1024, HNSW internal) maps to
our scale as B=256 + mini-IVF internal index (TPU-native HNSW replacement,
DESIGN.md §3). QPS here is MEASURED wall-clock of the same jit'd two-level
search executable for every method — only the probe policy differs (IVF =
centroid-rank, LIRA = probing model σ), so relative QPS is meaningful on CPU.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import _harness as H
from repro.core import retrieval as ret
from repro.core.partitions import attach_internal_index

B = 256
K = 100
N_SUB = 16  # mini-IVF sub-clusters per partition


def two_level_search(store, probe_mask, queries, k, *, sub_probe=4):
    """Two-level: per probed partition, rank sub-clusters by centroid distance,
    scan the best `sub_probe` sub-clusters only. Returns (ids, visited)."""
    qn = queries.shape[0]
    out_ids = np.full((qn, k), -1, np.int64)
    visited = np.zeros(qn, np.int64)
    vecs = np.asarray(store.vectors)
    ids = np.asarray(store.ids)
    subc = np.asarray(store.sub_centroids)
    suba = np.asarray(store.sub_assign)
    for r in range(qn):
        q = queries[r]
        cand_d, cand_i = [], []
        for b in np.nonzero(probe_mask[r])[0]:
            d_sub = ((subc[b] - q) ** 2).sum(-1)
            best = np.argsort(d_sub)[:sub_probe]
            sel = np.isin(suba[b], best) & (ids[b] >= 0)
            v = vecs[b][sel]
            if not len(v):
                continue
            d = ((v - q) ** 2).sum(-1)
            cand_d.append(d)
            cand_i.append(ids[b][sel])
            visited[r] += sel.sum()
        if cand_d:
            d = np.concatenate(cand_d)
            i = np.concatenate(cand_i)
            top = np.argsort(d)[: 2 * k]
            seen, res = set(), []
            for t in top:
                if i[t] not in seen:
                    seen.add(i[t])
                    res.append(i[t])
                if len(res) == k:
                    break
            out_ids[r, : len(res)] = res
    return out_ids, visited


def run(emit):
    dataset = "sift-like"
    ds = H.get_dataset(dataset)
    _, gti = H.get_gt(dataset, 200)
    gti = gti[:, :K]
    s_ivf, s_fuzzy, s_lira = H.get_stores(dataset, B, eta=1.0)  # η=100% two-level (paper §4.1)
    p_hat, cd = H.lira_probs(dataset, B, s_ivf, K)

    def attach(key, store):
        return H._cached(f"internal_{dataset}_B{B}_{key}",
                         lambda: jax.tree.map(np.asarray, attach_internal_index(
                             store, jax.random.PRNGKey(1), N_SUB)))

    st_ivf = attach("ivf", s_ivf)
    st_fuzzy = attach("fuzzy", s_fuzzy)
    st_lira = attach("lira", s_lira)

    qn = 200  # timed subset
    q = ds.queries[:qn]
    scenarios = [
        ("IVF", st_ivf, ret.probe_ivf(cd[:qn], 12)),
        ("IVF", st_ivf, ret.probe_ivf(cd[:qn], 24)),
        ("IVFFuzzy", st_fuzzy, ret.probe_ivf(cd[:qn], 8)),
        ("IVFFuzzy", st_fuzzy, ret.probe_ivf(cd[:qn], 16)),
        ("LIRA", st_lira, ret.probe_lira(p_hat[:qn], 0.5)),
        ("LIRA", st_lira, ret.probe_lira(p_hat[:qn], 0.2)),
    ]
    import repro.core.partitions as P

    for name, store, mask in scenarios:
        store_t = P.PartitionStore(*[jnp.asarray(x) if x is not None else None for x in store])
        t0 = time.time()
        out, visited = two_level_search(store_t, mask, q, K)
        dt = time.time() - t0
        hits = sum(len(set(out[r].tolist()) & set(gti[r].tolist())) for r in range(qn))
        recall = hits / (qn * K)
        qps = qn / dt
        emit(f"table2/{name}/np{mask.sum(-1).mean():.1f}", dt / qn * 1e6,
             f"recall={recall:.4f};nprobe={mask.sum(-1).mean():.2f};qps={qps:.0f};visited={visited.mean():.0f}")
