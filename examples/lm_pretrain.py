"""Train a small LM for a few hundred steps with the full substrate: the same
transformer/config/trainer/checkpoint/pipeline stack the dry-run lowers at
235B scale, here at ~3M params on CPU.

    PYTHONPATH=src python examples/lm_pretrain.py [--steps 200]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager
from repro.configs.base import LMConfig, MoEConfig
from repro.data.pipeline import PipelineSpec, TokenPipeline
from repro.launch.mesh import make_test_mesh
from repro.models import build_bundle
from repro.models.api import ShapeSpec
from repro.train import optimizer as opt
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--moe", action="store_true", help="use a tiny MoE variant")
    args = ap.parse_args()

    cfg = LMConfig(
        arch="lm-3m", n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=384, vocab=2048, attn_block=64,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=128) if args.moe else None,
    )
    mesh = make_test_mesh(data=1, model=1)
    bundle = build_bundle(cfg, mesh)
    shape = ShapeSpec("train_sm", "train", {"seq_len": 128, "global_batch": 16})
    sd = bundle.step(shape)
    params = bundle.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.2f}M  (moe={bool(cfg.moe)})")

    tx = opt.adamw(opt.cosine_schedule(3e-3, 20, args.steps))
    pipeline = TokenPipeline(PipelineSpec(global_batch=16, seed=0), seq_len=128, vocab=2048)

    with mesh:
        trainer = Trainer(sd.fn, (params, tx.init(params)), pipeline,
                          ckpt_manager=CheckpointManager("/tmp/lm_pretrain_ckpt", keep=2),
                          ckpt_every=100, log_every=20)
        state, history = trainer.run(args.steps)
    first, last = history[0], history[-1]
    print(f"loss {first['loss']:.3f} (step {first['step']}) → {last['loss']:.3f} (step {last['step']})")
    assert last["loss"] < first["loss"], "LM did not learn"
    print("ok")


if __name__ == "__main__":
    main()
