"""Quickstart: build a LIRA index on synthetic vectors and search it.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's full pipeline on a small dataset in ~1 minute:
K-Means partitions → probing-model training → learning-based redundancy →
query-aware retrieval, then compares against plain IVF.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_store, kmeans_fit, probing
from repro.core import ground_truth as gt
from repro.core import retrieval as ret
from repro.core.redundancy import plan_redundancy, replica_rows
from repro.core.train_probing import train_probing_model
from repro.data import make_vector_dataset


def main():
    k, b = 10, 32
    print("1) dataset: 20k synthetic 64-d vectors (SIFT-like hardness)")
    ds = make_vector_dataset(n=20_000, n_queries=300, dim=64, n_modes=64, seed=1)

    print("2) K-Means partitions (B=32)")
    st = kmeans_fit(jax.random.PRNGKey(0), jnp.asarray(ds.base), n_clusters=b, n_iters=15)
    assign, cents = np.asarray(st.assign), np.asarray(st.centroids)

    print("3) probing-model labels from a 8k training subset (paper A.3)")
    sub = np.random.default_rng(0).choice(len(ds.base), 8_000, replace=False)
    xs = ds.base[sub]
    _, sti = gt.exact_knn(xs, xs, k, exclude_self=True)
    lab = np.zeros((len(sub), b), np.float32)
    rows = np.repeat(np.arange(len(sub)), sti.shape[1])
    np.add.at(lab, (rows, assign[sub][sti].reshape(-1)), 1.0)
    lab = (lab > 0).astype(np.float32)

    print("4) train probing model f(q, I) = p̂  (BCE, paper §3.2)")
    params, tlog = train_probing_model(jax.random.PRNGKey(1), xs, lab, cents,
                                       epochs=6, batch=256, lr=2e-3)
    print(f"   loss {tlog.losses[0]:.2f} → {tlog.losses[-1]:.3f}; "
          f"kNN-partition recall {tlog.recalls[-1]:.3f}")

    print("5) learning-based redundancy (η=10%, paper §3.3)")
    ids = np.arange(len(ds.base), dtype=np.int32)
    plan = plan_redundancy(params, ds.base, assign, cents, eta=0.10)
    store = build_store(ds.base, ids, assign, cents,
                        extra=replica_rows(plan, ds.base, ids))

    print("6) query-aware retrieval vs IVF at matched recall")
    _, gti = gt.exact_knn(ds.queries, ds.base, k)
    ptk = ret.partition_topk(store, ds.queries, k)
    cd = ret.lira_inputs(store, ds.queries)
    p_hat = np.asarray(probing.probs(params, jnp.asarray(ds.queries), jnp.asarray(cd)))

    lira = ret.evaluate_probe(ptk, ret.probe_lira(p_hat, 0.15), gti, k)
    ivf = None
    for n in range(1, b + 1):
        ivf = ret.evaluate_probe(ptk, ret.probe_ivf(cd, n), gti, k)
        if ivf.recall >= lira.recall:
            break
    print(f"   LIRA: recall={lira.recall:.3f} cmp={lira.cmp_mean:.0f} nprobe={lira.nprobe_mean:.2f}")
    print(f"   IVF : recall={ivf.recall:.3f} cmp={ivf.cmp_mean:.0f} nprobe={ivf.nprobe_mean:.2f}")
    print(f"   → LIRA saves {1 - lira.cmp_mean / ivf.cmp_mean:.0%} distance computations")


if __name__ == "__main__":
    main()
