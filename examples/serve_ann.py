"""End-to-end serving driver (the paper's kind of system): build a LIRA index
and serve batched queries through the DISTRIBUTED engine (shard_map dispatch,
partition shards on the 'model' axis) — the same serve_step the multi-pod
dry-run lowers at 256/512 chips, here on a small local mesh.

    PYTHONPATH=src python examples/serve_ann.py
"""
import time

import numpy as np

from repro.data import make_vector_dataset
from repro.launch.mesh import make_test_mesh
from repro.serving import BuildConfig, LiraEngine, SearchRequest


def main():
    ds = make_vector_dataset(n=20_000, n_queries=512, dim=64, n_modes=64, seed=2)
    mesh = make_test_mesh(data=1, model=1)  # production: make_production_mesh()

    print("building LIRA engine (kmeans → probe training → redundancy → store → PQ)…")
    t0 = time.time()
    engine = LiraEngine.build(mesh, ds.base, BuildConfig(
        n_partitions=32, k=10, eta=0.05, train_frac=0.4, epochs=5,
        nprobe_max=8, tier="residual_pq", pq_m=16, rerank=16))
    from repro.serving import scan_store_bytes

    sb = scan_store_bytes(engine.store)
    print(f"  built in {time.time()-t0:.0f}s; capacity={engine.cfg.capacity}; "
          f"residual-PQ scan store x{sb['ratio']:.1f} smaller")

    from repro.core import ground_truth as gt
    from repro.core.metrics import recall_at_k

    _, gti = gt.exact_knn(ds.queries, ds.base, 10)

    # both tiers serve from the same engine: codes ride next to the f32 store,
    # and a SearchRequest picks which declared tier scans it
    for label, tier in (("f32 exact scan", "f32"),
                        ("residual PQ/ADC + rerank", "residual_pq")):
        req = SearchRequest(queries=ds.queries, sigma=0.3, tier=tier)
        engine.search(req)  # warm the jit cache
        t0 = time.time()
        res = engine.search(req)
        dt = time.time() - t0
        print(f"  [{label}] {len(ds.queries)/dt:.0f} QPS (1-CPU container); "
              f"mean nprobe={res.nprobe_eff.mean():.2f}; dropped probes="
              f"{res.overflow}; recall@10={recall_at_k(res.ids, gti, 10):.3f}")

    # online path: single-query requests through the dynamic-batching
    # front-end — search_one routes through the attached queue, requests
    # coalesce into pow2-bucketed batches, telemetry comes back per request
    from repro.configs.base import FrontendConfig
    from repro.serving.frontend import FakeClock, simulate_open_loop

    fe = engine.attach_frontend(
        FrontendConfig(max_batch=32, max_wait_ms=5.0),
        clock=FakeClock(), charge_service=True)
    for s in (8, 16, 32):   # warm the flushable jit buckets: steady-state
        engine.search(SearchRequest(queries=ds.queries[:s], sigma=0.3,
                                    tier="residual_pq"))
    stats, pendings = simulate_open_loop(
        fe, ds.queries, rate_qps=1500.0, n_requests=128, sigma=0.3,
        tier="residual_pq")
    one = pendings[0].result()
    print(f"  [front-end @1500qps offered] p50={stats.p50_ms:.2f}ms "
          f"p99={stats.p99_ms:.2f}ms qps={stats.qps:.0f} "
          f"mean_batch={stats.mean_batch:.1f} shed={stats.shed}; first "
          f"request waited {one.stats.queue_ms:.2f}ms in a "
          f"{one.stats.batch_size}-row batch")


if __name__ == "__main__":
    main()
