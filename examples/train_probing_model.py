"""Fault-tolerant distributed-style training driver for the probing model:
Trainer + atomic checkpoints + deterministic resumable pipeline. Kill it
mid-run (Ctrl-C) and re-run — it resumes from the last checkpoint and ends in
the same state.

    PYTHONPATH=src python examples/train_probing_model.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager
from repro.core import ground_truth as gt, kmeans_fit, probing
from repro.core.kmeans import centroid_distances
from repro.data import make_vector_dataset
from repro.data.pipeline import PipelineSpec, ProbingPipeline
from repro.train import optimizer as opt
from repro.train.trainer import Trainer


def main():
    b, k = 32, 10
    ds = make_vector_dataset(n=20_000, n_queries=100, dim=64, n_modes=64, seed=3)
    st = kmeans_fit(jax.random.PRNGKey(0), jnp.asarray(ds.base), n_clusters=b, n_iters=12)
    assign, cents = np.asarray(st.assign), np.asarray(st.centroids)

    sub = np.random.default_rng(0).choice(len(ds.base), 6000, replace=False)
    xs = ds.base[sub]
    _, sti = gt.exact_knn(xs, xs, k, exclude_self=True)
    lab = np.zeros((len(sub), b), np.float32)
    np.add.at(lab, (np.repeat(np.arange(len(sub)), k), assign[sub][sti].reshape(-1)), 1.0)
    lab = (lab > 0).astype(np.float32)
    cd = np.asarray(centroid_distances(jnp.asarray(xs), jnp.asarray(cents)))

    pc = probing.ProbingConfig(dim=xs.shape[1], n_partitions=b)
    params = probing.init(jax.random.PRNGKey(1), pc)
    tx = opt.adamw(opt.cosine_schedule(2e-3, 50, 2000))

    def step_fn(state, batch):
        p, s = state
        loss, grads = jax.value_and_grad(probing.bce_loss)(
            p, batch["q"], batch["cent_dist"], batch["labels"])
        grads, gnorm = opt.clip_by_global_norm(grads, 1.0)
        updates, s = tx.update(grads, s, p)
        return (opt.apply_updates(p, updates), s), {"loss": loss, "grad_norm": gnorm}

    pipeline = ProbingPipeline(PipelineSpec(global_batch=256, seed=0), xs, cd, lab)
    trainer = Trainer(step_fn, (params, tx.init(params)), pipeline,
                      ckpt_manager=CheckpointManager("/tmp/lira_probe_ckpt", keep=3),
                      ckpt_every=100, log_every=50)
    print(f"starting at step {trainer.start_step} (0 = fresh, >0 = resumed)")
    state, history = trainer.run(600)
    for h in history[-4:]:
        print(h)


if __name__ == "__main__":
    main()
