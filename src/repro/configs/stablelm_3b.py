"""stablelm-3b [hf:stabilityai/stablelm-2-1_6b family; unverified] — dense: 32L
d_model=2560 32H (GQA kv=32 = MHA, head_dim=80) d_ff=6912 vocab=50304."""
from repro.configs.base import LMConfig, LM_SHAPES
from repro.models.api import ShapeSpec

CONFIG = LMConfig(
    arch="stablelm-3b",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=6912, vocab=50304,
)
SHAPES = LM_SHAPES

SMOKE = LMConfig(
    arch="stablelm-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=192, vocab=512,
)
SMOKE_SHAPES = (ShapeSpec("train_sm", "train", {"seq_len": 64, "global_batch": 4}),
                ShapeSpec("decode_sm", "decode", {"seq_len": 64, "global_batch": 4}))
