"""Architecture registry: ``get_config(arch_id)`` -> (config, shapes).

10 assigned architectures + the paper's own system (lira-ann). Each module
defines CONFIG, SHAPES and SMOKE (a reduced same-family config for CPU tests).
"""
from __future__ import annotations

import importlib

ARCH_IDS = (
    "qwen3_moe_235b_a22b",
    "moonshot_v1_16b_a3b",
    "deepseek_coder_33b",
    "mistral_large_123b",
    "stablelm_3b",
    "dimenet",
    "deepfm",
    "autoint",
    "mind",
    "dlrm_rm2",
    "lira_ann",
    "lira_ann_q",
)

# CLI ids use dashes
def canon(arch: str) -> str:
    return arch.replace("-", "_")


def get_config(arch: str):
    mod = importlib.import_module(f"repro.configs.{canon(arch)}")
    return mod.CONFIG, mod.SHAPES


def get_smoke(arch: str):
    mod = importlib.import_module(f"repro.configs.{canon(arch)}")
    return mod.SMOKE, getattr(mod, "SMOKE_SHAPES", None)


def all_cells():
    """Every (arch, shape) dry-run cell."""
    for arch in ARCH_IDS:
        cfg, shapes = get_config(arch)
        for shape in shapes:
            yield arch, cfg, shape
