"""deepfm [arXiv:1703.04247; paper] — n_sparse=39 embed_dim=10
mlp=400-400-400, FM interaction."""
from repro.configs.base import RecsysConfig, RECSYS_SHAPES
from repro.models.api import ShapeSpec

CONFIG = RecsysConfig(
    arch="deepfm", n_dense=0, n_sparse=39, embed_dim=10,
    vocab_per_field=1_000_000, interaction="fm", mlp=(400, 400, 400),
)
SHAPES = RECSYS_SHAPES

SMOKE = RecsysConfig(
    arch="deepfm-smoke", n_dense=0, n_sparse=6, embed_dim=8,
    vocab_per_field=128, interaction="fm", mlp=(32, 32),
)
SMOKE_SHAPES = (ShapeSpec("train_sm", "rec_train", {"batch": 64}),
                ShapeSpec("serve_sm", "rec_serve", {"batch": 32}))
