"""lira-ann-q — the quantized two-stage serving tier of lira-ann: residual-PQ
ADC shortlist over uint8 codes (+ per-partition LUT offsets, core/pq.py) +
exact f32 rerank (serving/quantized.py). Registered as its own arch id so
registry-driven tooling (arch smoke tests, dry-run cells) exercises the
quantized bundle path including the residual cterm store plane."""
from repro.configs.lira_ann import (  # noqa: F401
    CONFIG_QUANTIZED as CONFIG,
    SHAPES,
    SMOKE_QUANTIZED as SMOKE,
    SMOKE_SHAPES,
)
