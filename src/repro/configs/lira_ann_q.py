"""lira-ann-q — the quantized two-stage serving tier of lira-ann: PQ/ADC
shortlist over uint8 codes + exact f32 rerank (serving/quantized.py).
Registered as its own arch id so registry-driven tooling (arch smoke tests,
dry-run cells) exercises the quantized bundle path."""
from repro.configs.lira_ann import (  # noqa: F401
    CONFIG_QUANTIZED as CONFIG,
    SHAPES,
    SMOKE_QUANTIZED as SMOKE,
    SMOKE_SHAPES,
)
