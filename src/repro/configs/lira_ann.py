"""lira-ann — the paper's own system (WWW'25): B=1024 partitions over a 67M-
point store (large-scale setting, paper §4.1), probing-model meta index,
distributed serve + probe-train steps."""
from repro.configs.base import LiraSystemConfig, LIRA_SHAPES
from repro.models.api import ShapeSpec

CONFIG = LiraSystemConfig(
    arch="lira-ann", dim=128, n_partitions=1024, capacity=65536, k=100,
    nprobe_max=64,
)
SHAPES = LIRA_SHAPES

# residual_pq tier: uint8 PQ codes (m=16, ks=256 → 16 B/slot vs 512 B f32 =
# 32× smaller scan store), exact f32 rerank of the r·k shortlist; the codes
# encode x − centroid (the full budget goes to the within-partition residual —
# the win on clustered stores), at the cost of a per-slot f32 cterm plane
# (+4 B/slot) and a per-(query, partition) offset in the scan.
CONFIG_QUANTIZED = LiraSystemConfig(
    arch="lira-ann-q", dim=128, n_partitions=1024, capacity=65536, k=100,
    nprobe_max=64, tier="residual_pq", pq_m=16, pq_ks=256, rerank=4,
)

SMOKE = LiraSystemConfig(
    arch="lira-smoke", dim=16, n_partitions=16, capacity=64, k=10,
    nprobe_max=4,
)

SMOKE_QUANTIZED = LiraSystemConfig(
    arch="lira-smoke-q", dim=16, n_partitions=16, capacity=64, k=10,
    nprobe_max=4, tier="residual_pq", pq_m=2, pq_ks=16, rerank=4,
)
SMOKE_SHAPES = (ShapeSpec("serve_sm", "lira_serve", {"n_queries": 64}),
                ShapeSpec("train_sm", "lira_train", {"batch": 64}))
