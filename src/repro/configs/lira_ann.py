"""lira-ann — the paper's own system (WWW'25): B=1024 partitions over a 67M-
point store (large-scale setting, paper §4.1), probing-model meta index,
distributed serve + probe-train steps."""
from repro.configs.base import LiraSystemConfig, LIRA_SHAPES
from repro.models.api import ShapeSpec

CONFIG = LiraSystemConfig(
    arch="lira-ann", dim=128, n_partitions=1024, capacity=65536, k=100,
    nprobe_max=64,
)
SHAPES = LIRA_SHAPES

SMOKE = LiraSystemConfig(
    arch="lira-smoke", dim=16, n_partitions=16, capacity=64, k=10,
    nprobe_max=4,
)
SMOKE_SHAPES = (ShapeSpec("serve_sm", "lira_serve", {"n_queries": 64}),
                ShapeSpec("train_sm", "lira_train", {"batch": 64}))
