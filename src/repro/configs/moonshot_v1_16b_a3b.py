"""moonshot-v1-16b-a3b [hf:moonshotai/Moonlight-16B-A3B; hf] — 48L d_model=2048
16H (GQA kv=16, head_dim=128) MoE 64 experts top-6 (+2 shared), expert
d_ff=1408, vocab=163840."""
from repro.configs.base import LMConfig, LM_SHAPES, MoEConfig
from repro.models.api import ShapeSpec

CONFIG = LMConfig(
    arch="moonshot-v1-16b-a3b",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab=163840,
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2),
    logits_chunk=8,
)
SHAPES = LM_SHAPES

SMOKE = LMConfig(
    arch="moonshot-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=96, vocab=512,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=96, n_shared=1),
)
SMOKE_SHAPES = (ShapeSpec("train_sm", "train", {"seq_len": 64, "global_batch": 4}),
                ShapeSpec("decode_sm", "decode", {"seq_len": 64, "global_batch": 4}))
