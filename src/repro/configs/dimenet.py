"""dimenet [arXiv:2003.03123; unverified] — n_blocks=6 d_hidden=128
n_bilinear=8 n_spherical=7 n_radial=6. Triplet-gather kernel regime."""
from repro.configs.base import GNNConfig, GNN_SHAPES
from repro.models.api import ShapeSpec

CONFIG = GNNConfig(
    arch="dimenet",
    n_blocks=6, d_hidden=128, n_bilinear=8, n_spherical=7, n_radial=6,
)
SHAPES = GNN_SHAPES

SMOKE = GNNConfig(
    arch="dimenet-smoke",
    n_blocks=2, d_hidden=32, n_bilinear=4, n_spherical=3, n_radial=4,
)
SMOKE_SHAPES = (
    ShapeSpec("molecule_sm", "graph_train",
              {"n_nodes": 12, "n_edges": 32, "batch": 4, "d_feat": 0, "triplet_mult": 4}),
    ShapeSpec("graph_sm", "graph_train",
              {"n_nodes": 64, "n_edges": 256, "d_feat": 16, "triplet_mult": 4}),
)
