"""mistral-large-123b [hf:mistralai/Mistral-Large-Instruct-2407; unverified] —
dense: 88L d_model=12288 96H (GQA kv=8, head_dim=128) d_ff=28672 vocab=32768."""
from repro.configs.base import LMConfig, LM_SHAPES
from repro.models.api import ShapeSpec

CONFIG = LMConfig(
    arch="mistral-large-123b",
    n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab=32768,
    grad_accum=4,
    # §Perf H2: enable ffn_impl="sp" in production (collective −51%);
    # default stays "gatherw" so the recorded baseline table reproduces.
)
SHAPES = LM_SHAPES

SMOKE = LMConfig(
    arch="mistral-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=192, vocab=512,
)
SMOKE_SHAPES = (ShapeSpec("train_sm", "train", {"seq_len": 64, "global_batch": 4}),
                ShapeSpec("decode_sm", "decode", {"seq_len": 64, "global_batch": 4}))
