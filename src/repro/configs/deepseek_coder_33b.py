"""deepseek-coder-33b [arXiv:2401.14196; hf] — dense llama-arch: 62L
d_model=7168 56H (GQA kv=8, head_dim=128) d_ff=19200 vocab=32256."""
from repro.configs.base import LMConfig, LM_SHAPES
from repro.models.api import ShapeSpec

CONFIG = LMConfig(
    arch="deepseek-coder-33b",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=19200, vocab=32256,
)
SHAPES = LM_SHAPES

SMOKE = LMConfig(
    arch="deepseek-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=192, vocab=512,
)
SMOKE_SHAPES = (ShapeSpec("train_sm", "train", {"seq_len": 64, "global_batch": 4}),
                ShapeSpec("decode_sm", "decode", {"seq_len": 64, "global_batch": 4}))
