"""dlrm-rm2 [arXiv:1906.00091; paper] — n_dense=13 n_sparse=26 embed_dim=64
bot_mlp=13-512-256-64 top_mlp=512-512-256-1, dot interaction, multi-hot bags."""
from repro.configs.base import RecsysConfig, RECSYS_SHAPES
from repro.models.api import ShapeSpec

CONFIG = RecsysConfig(
    arch="dlrm-rm2", n_dense=13, n_sparse=26, embed_dim=64,
    vocab_per_field=1_000_000, interaction="dot",
    bot_mlp=(13, 512, 256, 64), top_mlp=(512, 512, 256, 1), nnz=4,
)
SHAPES = RECSYS_SHAPES

SMOKE = RecsysConfig(
    arch="dlrm-smoke", n_dense=4, n_sparse=6, embed_dim=8,
    vocab_per_field=128, interaction="dot",
    bot_mlp=(4, 16, 8), top_mlp=(32, 16, 1), nnz=2,
)
SMOKE_SHAPES = (ShapeSpec("train_sm", "rec_train", {"batch": 64}),
                ShapeSpec("serve_sm", "rec_serve", {"batch": 32}))
