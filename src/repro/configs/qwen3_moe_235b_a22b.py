"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-30B-A3B family; hf] — 94L d_model=4096
64H (GQA kv=4, head_dim=128) MoE 128 experts top-8, expert d_ff=1536,
vocab=151936."""
from repro.configs.base import LMConfig, LM_SHAPES, MoEConfig
from repro.models.api import ShapeSpec

CONFIG = LMConfig(
    arch="qwen3-moe-235b-a22b",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab=151936,
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536),
    logits_chunk=8, grad_accum=4,
)
SHAPES = LM_SHAPES

SMOKE = LMConfig(
    arch="qwen3-moe-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=96, vocab=512,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=96),
)
SMOKE_SHAPES = (ShapeSpec("train_sm", "train", {"seq_len": 64, "global_batch": 4}),
                ShapeSpec("decode_sm", "decode", {"seq_len": 64, "global_batch": 4}))
