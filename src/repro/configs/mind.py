"""mind [arXiv:1904.08030; unverified] — embed_dim=64 n_interests=4
capsule_iters=3, multi-interest retrieval."""
from repro.configs.base import RecsysConfig, RECSYS_SHAPES
from repro.models.api import ShapeSpec

CONFIG = RecsysConfig(
    arch="mind", n_dense=0, n_sparse=1, embed_dim=64,
    vocab_per_field=1_000_000, interaction="multi-interest",
    n_interests=4, capsule_iters=3, hist_len=50,
)
SHAPES = RECSYS_SHAPES

SMOKE = RecsysConfig(
    arch="mind-smoke", n_dense=0, n_sparse=1, embed_dim=16,
    vocab_per_field=128, interaction="multi-interest",
    n_interests=2, capsule_iters=2, hist_len=10,
)
SMOKE_SHAPES = (ShapeSpec("train_sm", "rec_train", {"batch": 64}),
                ShapeSpec("serve_sm", "rec_serve", {"batch": 32}))
