"""autoint [arXiv:1810.11921; paper] — n_sparse=39 embed_dim=16
3 self-attn layers, 2 heads, d_attn=32."""
from repro.configs.base import RecsysConfig, RECSYS_SHAPES
from repro.models.api import ShapeSpec

CONFIG = RecsysConfig(
    arch="autoint", n_dense=0, n_sparse=39, embed_dim=16,
    vocab_per_field=1_000_000, interaction="self-attn",
    n_attn_layers=3, n_heads=2, d_attn=32,
)
SHAPES = RECSYS_SHAPES

SMOKE = RecsysConfig(
    arch="autoint-smoke", n_dense=0, n_sparse=6, embed_dim=8,
    vocab_per_field=128, interaction="self-attn",
    n_attn_layers=2, n_heads=2, d_attn=8,
)
SMOKE_SHAPES = (ShapeSpec("train_sm", "rec_train", {"batch": 64}),
                ShapeSpec("serve_sm", "rec_serve", {"batch": 32}))
