"""Config schema for all assigned architectures + the paper's own system."""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.models.api import ShapeSpec


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    n_shared: int = 0               # shared (always-on) experts


@dataclasses.dataclass(frozen=True)
class LMConfig:
    arch: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    moe: Optional[MoEConfig] = None
    rope_theta: float = 10_000.0
    dtype: str = "bfloat16"
    # execution knobs (beyond-paper perf levers; see EXPERIMENTS.md §Perf)
    remat: str = "full"             # full | dots | none
    attn_block: int = 1024          # flash-scan KV block
    moe_impl: str = "gather"        # gather (psum-combine) | a2a (EP all-to-all)
    logits_chunk: int = 0           # 0 = unchunked loss
    grad_accum: int = 1             # microbatches per step (memory lever)
    ffn_impl: str = "gatherw"       # gatherw (replicate weights per use) |
                                    # sp (Megatron-SP: gather ACTIVATIONS over
                                    # seq, keep F model-sharded, reduce-scatter
                                    # back — §Perf H2)
    attn_score_dtype: str = "float32"  # float32 | bfloat16 (materialized scores)

    @property
    def param_count(self) -> int:
        d, f, v, l = self.d_model, self.d_ff, self.vocab, self.n_layers
        attn = d * self.n_heads * self.head_dim * 2 + d * self.n_kv_heads * self.head_dim * 2
        if self.moe is not None:
            ff = 3 * d * self.moe.d_ff_expert * (self.moe.n_experts + self.moe.n_shared) + d * self.moe.n_experts
        else:
            ff = 3 * d * f
        return l * (attn + ff + 2 * d) + 2 * v * d + d

    @property
    def active_param_count(self) -> int:
        """Per-token active params (MoE counts top_k + shared experts only)."""
        d, f, v, l = self.d_model, self.d_ff, self.vocab, self.n_layers
        attn = d * self.n_heads * self.head_dim * 2 + d * self.n_kv_heads * self.head_dim * 2
        if self.moe is not None:
            ff = 3 * d * self.moe.d_ff_expert * (self.moe.top_k + self.moe.n_shared) + d * self.moe.n_experts
        else:
            ff = 3 * d * f
        return l * (attn + ff + 2 * d) + 2 * v * d + d


LM_SHAPES: Sequence[ShapeSpec] = (
    ShapeSpec("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
    ShapeSpec("prefill_32k", "prefill", {"seq_len": 32768, "global_batch": 32}),
    ShapeSpec("decode_32k", "decode", {"seq_len": 32768, "global_batch": 128}),
    ShapeSpec("long_500k", "decode", {"seq_len": 524288, "global_batch": 1}),
)


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    arch: str
    n_blocks: int
    d_hidden: int
    n_bilinear: int
    n_spherical: int
    n_radial: int
    d_feat: int = 0                 # 0 = atom-type embedding input
    dtype: str = "float32"
    remat: str = "full"


GNN_SHAPES: Sequence[ShapeSpec] = (
    ShapeSpec("full_graph_sm", "graph_train",
              {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433, "triplet_mult": 4}),
    ShapeSpec("minibatch_lg", "graph_train",
              {"n_nodes": 169984, "n_edges": 168960, "d_feat": 602, "triplet_mult": 4,
               "total_nodes": 232965, "total_edges": 114615892, "batch_nodes": 1024, "fanout": (15, 10)}),
    ShapeSpec("ogb_products", "graph_train",
              {"n_nodes": 2449029, "n_edges": 61859140, "d_feat": 100, "triplet_mult": 2}),
    ShapeSpec("molecule", "graph_train",
              {"n_nodes": 30, "n_edges": 64, "batch": 128, "d_feat": 0, "triplet_mult": 8}),
)


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    arch: str
    n_dense: int
    n_sparse: int
    embed_dim: int
    vocab_per_field: int
    interaction: str                      # fm | self-attn | multi-interest | dot
    bot_mlp: Sequence[int] = ()
    top_mlp: Sequence[int] = ()
    mlp: Sequence[int] = ()
    n_attn_layers: int = 0
    n_heads: int = 0
    d_attn: int = 0
    n_interests: int = 0
    capsule_iters: int = 0
    hist_len: int = 50                    # MIND behaviour-sequence length
    nnz: int = 1                          # multi-hot bag size (EmbeddingBag)
    dtype: str = "float32"


RECSYS_SHAPES: Sequence[ShapeSpec] = (
    ShapeSpec("train_batch", "rec_train", {"batch": 65536}),
    ShapeSpec("serve_p99", "rec_serve", {"batch": 512}),
    ShapeSpec("serve_bulk", "rec_serve", {"batch": 262144}),
    ShapeSpec("retrieval_cand", "retrieval", {"batch": 1, "n_candidates": 1_000_000}),
)


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Dynamic-batching front-end knobs (serving/frontend.py). The front-end
    accumulates single-query ``SearchRequest``s into coalesced batches flushed
    on whichever trigger fires first — size (``max_batch`` rows, rounded up to
    the engine's pow2 jit-cache bucket so flushes land on compiled steps) or
    deadline (``max_wait_ms`` since enqueue, overridable per request via
    ``SearchRequest.deadline_ms``) — and sheds load once ``max_queue``
    requests are waiting (admission control; shed requests resolve immediately
    with ``SearchStats.shed=True`` instead of stalling the queue)."""

    max_batch: int = 64             # size trigger, in coalesced query rows
    max_wait_ms: float = 2.0        # deadline trigger for queued requests
    max_queue: int = 256            # admission-control bound, in requests
    # retired knob, accepted for config compatibility: latency quantiles now
    # come from fixed-bucket histograms in the metrics registry
    # (repro.obs.metrics — O(buckets) memory for any service lifetime), so
    # there is no per-observation reservoir left to size
    latency_window: int = 1024


# builtin serving-tier aliases → canonical names. Must mirror the `aliases`
# declared by the builtin Tier classes in serving/tiers.py (which cannot be
# imported here without a cycle); tests/test_tiers.py asserts the two agree.
_TIER_ALIASES = {"quantized": "pq", "residual": "residual_pq",
                 "exact": "f32", "float32": "f32"}


@dataclasses.dataclass(frozen=True)
class LiraSystemConfig:
    """The paper's own system as a lowerable architecture."""
    arch: str
    dim: int
    n_partitions: int
    capacity: int
    k: int
    nprobe_max: int
    q_hidden: Sequence[int] = (256, 128)
    i_hidden: Sequence[int] = (128,)
    p_hidden: Sequence[int] = (256,)
    dtype: str = "float32"
    store_dtype: str = "float32"    # vector storage (bfloat16 halves scan reads)
    q_cap_factor: float = 2.0       # query-dispatch slack (compute ∝ this)
    auto_q_cap: bool = False        # engine doubles q_cap_factor (and recompiles
                                    # on the next bucket) after persistent
                                    # q_cap overflow
    impl: str = "auto"              # partition-scan backend (serving/scan.py):
                                    # auto (pallas on TPU, ref elsewhere) | ref
                                    # (portable jnp) | pallas (fused kernels) |
                                    # interpret (kernels via the interpreter)
    # serving tier (serving/tiers.py registry): "f32" exact scan | "pq"
    # ADC shortlist + exact rerank | "residual_pq" PQ over x − centroid |
    # any registered custom tier. "" (legacy) derives the tier from the
    # deprecated booleans below.
    tier: str = ""
    pq_m: int = 16                  # PQ subspaces (dim % pq_m == 0)
    pq_ks: int = 256                # codewords/subspace (≤ 256 → uint8 codes)
    rerank: int = 4                 # shortlist depth r: rerank r·k per partition
    # mutable-index knobs (serving/engine.py insert/delete/maybe_repartition):
    eta: float = 0.0                # replica fraction refreshed on repartition
                                    # (set from BuildConfig.eta at build time)
    repartition_threshold: float = 0.25  # staleness ((misassigned inserts +
                                    # tombstones) / live rows) at which
                                    # maybe_repartition() fires
    # DEPRECATED read-only aliases of `tier`, kept one release for legacy
    # callers. When `tier` is set they are (re)derived from it in
    # __post_init__, so dataclasses.replace(cfg, quantized=...) on a cfg whose
    # tier is already resolved is a no-op — replace `tier` instead.
    quantized: bool = False         # alias: tier in ("pq", "residual_pq")
    residual_pq: bool = False       # alias: tier == "residual_pq"

    def __post_init__(self):
        if not self.tier:
            # legacy semantics preserved exactly: residual was a mode OF the
            # quantized tier (residual_pq alone used to serve the plain f32
            # scan), so it only selects residual_pq when quantized is set too
            object.__setattr__(
                self, "tier",
                "residual_pq" if (self.quantized and self.residual_pq)
                else ("pq" if self.quantized else "f32"))
        else:
            # canonicalize builtin aliases so the derived booleans (and any
            # tier-name comparison downstream) can't be fooled by e.g.
            # tier="residual" — serving/tiers.py registers these same aliases
            # and tests/test_tiers.py pins the two maps together
            object.__setattr__(self, "tier",
                               _TIER_ALIASES.get(self.tier, self.tier))
        # both aliases re-derive from the resolved tier in every case, so
        # they are always self-consistent with it
        object.__setattr__(self, "quantized",
                           self.tier in ("pq", "residual_pq"))
        object.__setattr__(self, "residual_pq", self.tier == "residual_pq")


LIRA_SHAPES: Sequence[ShapeSpec] = (
    ShapeSpec("serve_10k", "lira_serve", {"n_queries": 8192}),
    ShapeSpec("train_probe", "lira_train", {"batch": 4096}),
)
