"""repro: LIRA (WWW'25) — learning-based query-aware partitioned ANN search on TPU pods.

Layers:
  repro.core         — the paper's contribution (probing model, redundancy, retrieval)
  repro.kernels      — Pallas TPU kernels for the scoring hot path
  repro.models       — assigned architectures (LM / GNN / recsys)
  repro.data         — synthetic datasets + resumable pipeline + graph sampler
  repro.train        — optimizer, trainer, gradient compression
  repro.ckpt         — atomic sharded checkpointing
  repro.serving      — distributed LIRA serving engine
  repro.distributed  — sharding rules + collective helpers + fault sim
  repro.launch       — production mesh, multi-pod dry-run, drivers
"""

__version__ = "1.0.0"
