"""Version-compat shims so the tree imports and runs on every jax we support.

`shard_map` graduated from `jax.experimental.shard_map` to a top-level
`jax.shard_map` (and its `check_rep` kwarg was renamed `check_vma`) in newer
releases; the CI/container image pins an 0.4.x jax where only the experimental
spelling exists. All repo code imports `shard_map` from here and uses the new
`check_vma` name — the shim translates for old jax.
"""
from __future__ import annotations

import functools
import inspect

import jax

try:
    _shard_map = jax.shard_map  # jax >= 0.5: top-level API
except AttributeError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

def make_mesh(shape, axis_names):
    """jax.make_mesh with explicit Auto axis types where the API has them
    (jax >= 0.5); plain make_mesh on 0.4.x, where Auto is the only behavior."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(shape, axis_names,
                             axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(shape, axis_names)


if "check_vma" in inspect.signature(_shard_map).parameters:
    shard_map = _shard_map
else:

    @functools.wraps(_shard_map)
    def shard_map(*args, **kwargs):
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _shard_map(*args, **kwargs)
