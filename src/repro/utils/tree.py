"""Small pytree helpers used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_count(tree) -> int:
    """Total number of scalar parameters in a pytree."""
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree)))


def tree_bytes(tree) -> int:
    """Total bytes of a pytree of arrays or ShapeDtypeStructs."""
    return int(
        sum(np.prod(x.shape) * jnp.dtype(x.dtype).itemsize for x in jax.tree.leaves(tree))
    )


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def global_norm(tree) -> jax.Array:
    """L2 norm over all leaves (computed in f32 for stability)."""
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))
