from repro.utils.tree import tree_bytes, tree_count, tree_zeros_like, global_norm  # noqa: F401
