"""Canonical injectable clocks for the serving stack.

Every time-dependent layer (serving/frontend.py batching deadlines,
obs/trace.py span durations, distributed/fault.py heartbeats and hedge
deadlines, serving/cluster.py failover) takes a zero-arg ``clock`` callable
returning seconds instead of reading wall time directly. ``FakeClock`` is
the one deterministic implementation they all share: time moves only via
``advance``, so scheduler/failover tests never sleep and latency assertions
are exact. Production callers pass ``time.monotonic`` (scheduling) or
``time.perf_counter`` (durations).

Historically ``FakeClock`` lived in serving/frontend.py; it is re-exported
from there (and ``repro.serving``) for back-compat.
"""
from __future__ import annotations

__all__ = ["FakeClock"]


class FakeClock:
    """Deterministic injectable clock: time moves only via ``advance``. Used
    by the scheduler tests (no wall-clock sleeps in tier-1) and the open-loop
    load simulation, where measured service time is charged explicitly."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def __call__(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"clock cannot go backwards (dt={dt})")
        self._t += float(dt)
        return self._t
