"""Training launcher: any LM/recsys arch at a REDUCED scale on the local mesh,
with the production substrate (trainer, atomic checkpoints, resumable
pipeline). On a real pod the same code runs under `jax.distributed.initialize`
with make_production_mesh().

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b --steps 100
    PYTHONPATH=src python -m repro.launch.train --arch deepfm --steps 200
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.ckpt import CheckpointManager
from repro.configs import get_smoke
from repro.data.pipeline import PipelineSpec, RecsysPipeline, TokenPipeline
from repro.launch.mesh import make_test_mesh
from repro.models import build_bundle
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="simulate a crash at this step (restart resumes)")
    args = ap.parse_args()

    smoke, shapes = get_smoke(args.arch)
    shape = next(s for s in shapes if "train" in s.kind)
    mesh = make_test_mesh()
    bundle = build_bundle(smoke, mesh)
    sd = bundle.step(shape)
    params = bundle.init(jax.random.PRNGKey(0), shape)

    from repro.train import optimizer as opt

    tx = opt.adamw(1e-3)
    state = (params, tx.init(params))

    from repro.configs.base import LMConfig, RecsysConfig

    if isinstance(smoke, LMConfig):
        pipeline = TokenPipeline(PipelineSpec(global_batch=shape["global_batch"]),
                                 seq_len=shape["seq_len"], vocab=smoke.vocab)
    elif isinstance(smoke, RecsysConfig):
        pipeline = RecsysPipeline(PipelineSpec(global_batch=shape["batch"]), smoke)
    else:
        raise SystemExit(f"use examples/ or benchmarks for arch {args.arch}")

    with mesh:
        trainer = Trainer(sd.fn, state, pipeline,
                          ckpt_manager=CheckpointManager(args.ckpt_dir, keep=2),
                          ckpt_every=50, log_every=10)
        print(f"{args.arch}: starting at step {trainer.start_step}")
        _, history = trainer.run(args.steps, fail_at=args.fail_at)
    for h in history[-3:]:
        print(h)


if __name__ == "__main__":
    main()
