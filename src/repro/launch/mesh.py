"""Production mesh (dry-run spec): 16×16 = 256 chips/pod; 2 pods = 512 chips.

Defined as functions so importing this module never touches jax device state.
"""
from __future__ import annotations

from repro.utils.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh for CPU tests (same axis names as production)."""
    if pod:
        return make_mesh((pod, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))


# TPU v5e roofline constants (per chip) — EXPERIMENTS.md §Roofline
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link (conservative single-link figure)
HBM_PER_CHIP = 16 * 2**30     # 16 GiB
