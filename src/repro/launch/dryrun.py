import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile EVERY (arch × shape)
cell on the production meshes — 16×16 single-pod and 2×16×16 multi-pod —
recording memory analysis, HLO/analytic cost terms, and the collective
schedule for the roofline (EXPERIMENTS.md §Dry-run / §Roofline).

The XLA_FLAGS line above MUST precede every other import (jax locks the device
count at first init). Run one cell:

    PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-coder-33b \
        --shape train_4k --mesh single

or everything (subprocess per cell, failures isolated):

    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import pathlib
import subprocess
import sys
import time

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def model_flops(config, shape) -> float:
    """Analytic MODEL_FLOPS: 6·N·D train / 2·N·D inference (+ attention terms);
    MoE counts active params only (EXPERIMENTS.md §Roofline)."""
    from repro.configs.base import GNNConfig, LiraSystemConfig, LMConfig, RecsysConfig

    if isinstance(config, LMConfig):
        n_act = config.active_param_count
        l, h, dh = config.n_layers, config.n_heads, config.head_dim
        if shape.kind == "train":
            t = shape["global_batch"] * shape["seq_len"]
            attn = 6 * l * shape["global_batch"] * shape["seq_len"] ** 2 * h * dh  # causal-adjusted (×0.5 of full)
            return 6.0 * n_act * t + attn
        if shape.kind == "prefill":
            t = shape["global_batch"] * shape["seq_len"]
            attn = 2 * l * shape["global_batch"] * shape["seq_len"] ** 2 * h * dh
            return 2.0 * n_act * t + attn
        if shape.kind == "decode":
            b, s = shape["global_batch"], shape["seq_len"]
            attn = 4 * l * b * s * h * dh
            return 2.0 * n_act * b + attn
    if isinstance(config, GNNConfig):
        e = shape["n_edges"] * shape.dims.get("batch", 1)
        t = e * shape["triplet_mult"]
        hdim = config.d_hidden
        per_block = 2 * t * hdim * hdim * (config.n_bilinear + 1) + 6 * e * hdim * hdim
        fwd = config.n_blocks * per_block + 2 * e * (2 * hdim) * hdim
        return 3.0 * fwd  # train
    if isinstance(config, RecsysConfig):
        b = shape["batch"] if shape.kind != "retrieval" else shape["n_candidates"]
        d = config.embed_dim
        f = config.n_sparse
        per = 0.0
        if config.interaction == "fm":
            sizes = (f * d, *config.mlp, 1)
            per = sum(2 * a * bb for a, bb in zip(sizes[:-1], sizes[1:]))
        elif config.interaction == "self-attn":
            da = config.d_attn * config.n_heads
            d_in = d
            for _ in range(config.n_attn_layers):
                per += 2 * f * d_in * da * 4 + 4 * f * f * da
                d_in = da
            per += 2 * f * da
        elif config.interaction == "multi-interest":
            per = config.capsule_iters * (4 * config.hist_len * config.n_interests * d) + 2 * config.hist_len * d * d
        elif config.interaction == "dot":
            sizes = tuple(config.bot_mlp)
            per += sum(2 * a * bb for a, bb in zip(sizes[:-1], sizes[1:]))
            nf = config.n_sparse + 1
            per += 2 * nf * nf * d
            d_int = nf * (nf - 1) // 2 + config.bot_mlp[-1]
            sizes = (d_int, *config.top_mlp)
            per += sum(2 * a * bb for a, bb in zip(sizes[:-1], sizes[1:]))
        mult = 3.0 if shape.kind == "rec_train" else 1.0
        return mult * b * per
    if isinstance(config, LiraSystemConfig):
        if shape.kind == "lira_serve":
            q = shape["n_queries"]
            return q * config.nprobe_max * config.capacity * 2.0 * config.dim
        if shape.kind == "lira_train":
            import jax

            from repro.serving.engine import probing_param_specs_cache

            import numpy as np
            n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(probing_param_specs_cache(config)))
            return 6.0 * n_params * shape["batch"]
    return 0.0


def top_buffers(text: str, n: int = 15):
    """Largest HLO result buffers with op names — the memory 'profile'."""
    import re

    from repro.launch.hlo_cost import _DTYPE_BYTES, _SHAPE_RE

    best = []
    for line in text.splitlines():
        m = re.match(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(", line)
        if not m or m.group(3) in ("parameter", "tuple", "get-tuple-element"):
            continue
        b = 0
        for dt, dims in _SHAPE_RE.findall(m.group(2)):
            if dt in _DTYPE_BYTES:
                cnt = 1
                for d in (dims.split(",") if dims else []):
                    cnt *= int(d)
                b += cnt * _DTYPE_BYTES[dt]
        meta = re.search(r'op_name="([^"]*)"', line)
        best.append((b, m.group(3), (meta.group(1) if meta else m.group(1))[:110]))
    best.sort(reverse=True)
    return best[:n]


def _lower_cell(config, shape, mesh):
    """Build + lower + compile one cell. Returns (compiled, t_lower, t_compile)."""
    import jax

    from repro.models import build_bundle
    from repro.models.api import named_shardings

    bundle = build_bundle(config, mesh)
    sd = bundle.step(shape)
    pspecs = bundle.param_specs(shape)
    pshard = named_shardings(mesh, bundle.param_pspecs(shape))
    in_shard_named = {k: named_shardings(mesh, v) for k, v in sd.input_pspecs.items()}
    ispecs = sd.input_specs

    train_kinds = ("train", "graph_train", "rec_train", "lira_train")
    t0 = time.time()
    with mesh:
        if shape.kind in train_kinds:
            oshard = named_shardings(mesh, bundle.opt_pspecs(shape))
            ospecs = bundle.opt_specs(shape)
            args = ((pspecs, ospecs), ispecs)
            shardings = ((pshard, oshard), in_shard_named)
            fn = jax.jit(sd.fn, in_shardings=shardings, donate_argnums=(0,))
            lowered = fn.lower(*args)
        elif shape.kind == "prefill":
            okw = {}
            if sd.out_pspecs is not None:
                okw["out_shardings"] = named_shardings(mesh, sd.out_pspecs)
            lowered = jax.jit(sd.fn, in_shardings=(pshard, in_shard_named["tokens"]), **okw).lower(
                pspecs, ispecs["tokens"])
        elif shape.kind == "decode":
            okw = {}
            if sd.out_pspecs is not None:
                okw["out_shardings"] = named_shardings(mesh, sd.out_pspecs)
            fn = jax.jit(sd.fn,
                         in_shardings=(pshard, in_shard_named["cache"],
                                       in_shard_named["tokens"], in_shard_named["pos"]),
                         donate_argnums=(1,), **okw)
            lowered = fn.lower(pspecs, ispecs["cache"], ispecs["tokens"], ispecs["pos"])
        elif shape.kind == "rec_serve" or shape.kind == "retrieval":
            lowered = jax.jit(sd.fn, in_shardings=(pshard, in_shard_named)).lower(pspecs, ispecs)
        elif shape.kind == "lira_serve":
            lowered = jax.jit(sd.fn,
                              in_shardings=(pshard, in_shard_named["store"], in_shard_named["queries"])
                              ).lower(pspecs, ispecs["store"], ispecs["queries"])
        else:
            raise ValueError(shape.kind)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    return compiled, t_lower, t_compile


def run_cell(arch: str, shape_name: str, mesh_kind: str, variant: str = "baseline",
             out_path: str | None = None, verbose: bool = True, show_buffers: bool = False) -> dict:
    import dataclasses

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.launch import hlo_cost
    from repro.launch.mesh import HBM_PER_CHIP, make_production_mesh
    from repro.models import build_bundle  # noqa: F401 (re-exported for callers)

    config, shapes = get_config(arch)
    if variant != "baseline":
        config = apply_variant(config, variant)
    shape = next(s for s in shapes if s.name == shape_name)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(np.prod(list(mesh.shape.values())))

    compiled, t_lower, t_compile = _lower_cell(config, shape, mesh)

    # Temp-memory probe: XLA:CPU FloatNormalization shadows bf16 buffers with
    # f32 copies (don't exist on TPU). Recompile with dtype=f32 — artifact-free
    # buffer accounting — and estimate the TPU bf16 temp as half of it
    # (activations halve; minority f32 accumulators make this conservative-ish).
    temp_probe = None
    if getattr(config, "dtype", "float32") == "bfloat16":
        cfg_f32 = dataclasses.replace(config, dtype="float32")
        probe_compiled, _, _ = _lower_cell(cfg_f32, shape, mesh)
        temp_probe = probe_compiled.memory_analysis()
        del probe_compiled

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    text = compiled.as_text()
    hc = hlo_cost.analyze(text)
    shadows = hlo_cost.f32_shadow_bytes(text)
    mf = model_flops(config, shape)

    per_dev_hbm = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                   + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    # State (args/out/alias) keeps declared dtypes — exact. Temp from the f32
    # probe (artifact-free) halved for bf16 on TPU; f32-native archs unchanged.
    if temp_probe is not None:
        adj_temp = temp_probe.temp_size_in_bytes // 2
    else:
        adj_temp = mem.temp_size_in_bytes
    per_dev_tpu = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                   + adj_temp - mem.alias_size_in_bytes)
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "variant": variant,
        "kind": shape.kind, "n_chips": n_chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument": mem.argument_size_in_bytes,
            "output": mem.output_size_in_bytes,
            "temp": mem.temp_size_in_bytes,
            "alias": mem.alias_size_in_bytes,
            "per_device_total": int(per_dev_hbm),
            "f32_shadow_bytes": shadows["bytes_total"],
            "f32_shadow_count": shadows["count"],
            "temp_f32_probe": (temp_probe.temp_size_in_bytes if temp_probe else None),
            "temp_tpu_estimate": int(adj_temp),
            "per_device_tpu_adjusted": int(per_dev_tpu),
            "fits_16g": bool(per_dev_tpu <= HBM_PER_CHIP),
            "fits_16g_cpu_raw": bool(per_dev_hbm <= HBM_PER_CHIP),
        },
        "xla_cost_analysis": {"flops": ca.get("flops", 0.0), "bytes": ca.get("bytes accessed", 0.0)},
        "hlo": {
            "flops_per_device": hc["flops"],
            "bytes_per_device": hc["bytes"],
            "collective_bytes_per_device": hc["collective_bytes"],
            "collectives": hc["collectives"],
            "top_flops": hc["top_flops"][:8],
        },
        "model_flops_global": mf,
        "model_flops_per_device": mf / n_chips,
    }
    if verbose:
        print(json.dumps({k: result[k] for k in
                          ("arch", "shape", "mesh", "variant", "n_chips", "compile_s")}))
        print(f"  memory/device: {per_dev_hbm/2**30:.2f} GiB raw | "
              f"{per_dev_tpu/2**30:.2f} GiB tpu-adj (shadows {shadows['bytes_total']/2**30:.2f} GiB) "
              f"fits16G={result['memory']['fits_16g']}")
        print(f"  hlo flops/dev: {hc['flops']:.3e}  bytes/dev: {hc['bytes']:.3e}  "
              f"coll/dev: {hc['collective_bytes']:.3e}")
        print(f"  model flops/dev: {mf/n_chips:.3e}  useful-ratio: "
              f"{(mf/n_chips)/max(hc['flops'],1):.3f}")
    if show_buffers:
        for b, op, name in top_buffers(text):
            print(f"  {b/2**30:7.2f} GiB {op:22s} {name}")
    if out_path:
        pathlib.Path(out_path).parent.mkdir(parents=True, exist_ok=True)
        pathlib.Path(out_path).write_text(json.dumps(result, indent=1))
    return result


def apply_variant(config, variant: str):
    """Named perf variants for §Perf hillclimbing. Supports one level of
    nesting for sub-configs (e.g. moe.capacity_factor=1.0)."""
    import dataclasses
    if variant == "baseline":
        return config
    overrides = {}
    for kv in variant.split(","):
        k, v = kv.split("=")
        if "." in k:
            outer, inner = k.split(".", 1)
            sub = overrides.get(outer, getattr(config, outer))
            cur = getattr(sub, inner)
            overrides[outer] = dataclasses.replace(
                sub, **{inner: type(cur)(v) if not isinstance(cur, bool) else v == "True"})
        else:
            cur = getattr(config, k)
            overrides[k] = type(cur)(v) if not isinstance(cur, bool) else v == "True"
    return dataclasses.replace(config, **overrides)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--timeout", type=int, default=1200)
    ap.add_argument("--out")
    ap.add_argument("--buffers", action="store_true", help="print largest HLO buffers")
    args = ap.parse_args()

    if args.all:
        from repro.configs import ARCH_IDS, get_config

        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        failures, done = [], 0
        cells = []
        for arch in ARCH_IDS:
            _, shapes = get_config(arch)
            for shape in shapes:
                for mk in meshes:
                    cells.append((arch, shape.name, mk))
        print(f"dry-run: {len(cells)} cells")
        for arch, shape_name, mk in cells:
            out = RESULTS_DIR / f"{arch}__{shape_name}__{mk}__{args.variant}.json"
            if out.exists():
                done += 1
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
                   "--shape", shape_name, "--mesh", mk, "--variant", args.variant,
                   "--out", str(out)]
            t0 = time.time()
            try:
                r = subprocess.run(cmd, capture_output=True, text=True, timeout=args.timeout,
                                   env={**os.environ, "PYTHONPATH": "src"})
                if r.returncode != 0:
                    failures.append((arch, shape_name, mk, r.stderr[-2000:]))
                    print(f"FAIL {arch}/{shape_name}/{mk} ({time.time()-t0:.0f}s)")
                else:
                    done += 1
                    print(f"ok   {arch}/{shape_name}/{mk} ({time.time()-t0:.0f}s)")
            except subprocess.TimeoutExpired:
                failures.append((arch, shape_name, mk, "timeout"))
                print(f"TIMEOUT {arch}/{shape_name}/{mk}")
        print(f"\n{done}/{len(cells)} cells passed, {len(failures)} failures")
        for f in failures:
            print("-" * 60)
            print(f[0], f[1], f[2])
            print(f[3][:1500])
        sys.exit(1 if failures else 0)

    out = args.out or str(RESULTS_DIR / f"{args.arch}__{args.shape}__{args.mesh}__{args.variant}.json")
    run_cell(args.arch, args.shape, args.mesh, args.variant, out, show_buffers=args.buffers)


if __name__ == "__main__":
    main()
