"""HLO-text cost model with while-loop trip-count multiplication.

XLA's ``compiled.cost_analysis()`` counts a while body ONCE (verified in this
container), which under-reports every scanned model by ~n_layers×. This parser
walks the optimized SPMD module text instead:

  * FLOPs: every ``dot`` op — 2 · |result| · Π(contracting dims) — multiplied
    by the product of enclosing while trip counts (read from
    ``backend_config={"known_trip_count":...}``);
  * HBM bytes: Σ over materializing ops of (result + operand bytes). Post-
    fusion, each op ≈ one HBM round trip, so this is a faithful traffic model;
  * collective bytes: result bytes of all-reduce / all-gather / reduce-scatter
    / all-to-all / collective-permute (per-device, since the module is SPMD).

Numbers are PER DEVICE. Also returns the top FLOP contributors with their JAX
op names — this is the "profile" the §Perf loop iterates on.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w\.\-]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast", "while",
    "after-all", "partition-id", "replica-id", "iota", "conditional", "call",
    "custom-call", "rng-bit-generator", "get-dimension-size", "domain", "opt-barrier",
    "reshape",
    # while-carry copies: XLA:CPU materializes full copies of loop-carried
    # buffers (e.g. the KV cache) that the TPU backend updates in place
    "copy",
}
# ops that touch only their RESULT-sized region of memory (plus an equal-sized
# read): counting full operands would charge a dynamic-slice of a 5 GB KV
# cache 5 GB instead of the slice it actually reads.
RESULT_SIZED_OPS = {"dynamic-slice", "slice", "gather", "broadcast", "pad", "reverse"}
# in-place update: reads+writes the update region only
UPDATE_SIZED_OPS = {"dynamic-update-slice", "scatter", "select-and-scatter"}
COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute",
    "all-reduce-start", "all-gather-start", "collective-permute-start",
    "ragged-all-to-all",
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class _Instr:
    name: str
    result_type: str
    op: str
    args: str
    line: str


@dataclass
class _Computation:
    name: str
    params: dict = field(default_factory=dict)   # param name -> type str
    instrs: list = field(default_factory=list)


def _parse_computations(text: str) -> dict:
    comps: dict[str, _Computation] = {}
    cur = None
    for line in text.splitlines():
        m = _COMP_RE.match(line)
        if m and ("->" in line):
            cur = _Computation(name=m.group(1))
            # parameter declarations: "name: type, name: type"
            for pdecl in re.findall(r"([\w\.\-]+):\s*([^,)]+(?:\([^)]*\))?)", m.group(2)):
                cur.params[pdecl[0]] = pdecl[1]
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        im = _INSTR_RE.match(line)
        if im:
            cur.instrs.append(_Instr(im.group(1), im.group(2), im.group(3), im.group(4), line))
    return comps


def _dot_flops(instr: _Instr, shapes: dict) -> float:
    # contracting dim sizes from the lhs operand shape
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.line)
    ops = _OPERAND_RE.findall(instr.args)
    if not ops:
        return 0.0
    lhs_type = shapes.get(ops[0], "")
    sm = _SHAPE_RE.search(lhs_type)
    if sm is None:
        return 0.0
    lhs_dims = [int(d) for d in sm.group(2).split(",")] if sm.group(2) else []
    contract = 1
    if mc and mc.group(1):
        for c in mc.group(1).split(","):
            ci = int(c)
            if ci < len(lhs_dims):
                contract *= lhs_dims[ci]
    result_elems = 0
    rm = _SHAPE_RE.search(instr.result_type)
    if rm:
        result_elems = 1
        if rm.group(2):
            for d in rm.group(2).split(","):
                result_elems *= int(d)
    return 2.0 * result_elems * contract


_ALIAS_OPS = ("bitcast", "reshape", "copy", "convert", "transpose")


def _dims_of(type_str: str):
    m = _SHAPE_RE.search(type_str)
    return m.group(2) if m else None


def _min_dtype_bytes(type_a: str, type_b: str) -> int:
    """Bytes of the cheaper-dtype view of the same dims (bf16 original vs its
    f32 CPU-normalization shadow)."""
    return min(_shape_bytes(type_a) or 1 << 62, _shape_bytes(type_b) or 1 << 62)


def _instr_bytes(ins: _Instr, shapes: dict, comps: dict, aliases: dict) -> float:
    """HBM traffic model for one (post-fusion) instruction.

    Conventions (documented in EXPERIMENTS.md §Roofline):
      * slice-like ops: read charged at the ORIGINAL dtype of the sliced buffer
        (resolved through convert/bitcast chains — f32 shadows of bf16 buffers
        are XLA:CPU artifacts); no write charge (fuses into the consumer on TPU);
      * DUS (standalone or fused): read+write of the update region only;
      * bf16<->f32 converts: 0 (fused on TPU / don't exist);
      * fusion: params consumed only via slices inside charge slice bytes
        (alias-chased); DUS targets are not reads.
    """
    rb = _shape_bytes(ins.result_type)

    def resolved_bytes(name: str) -> int:
        t = aliases.get(name, shapes.get(name, ""))
        return _shape_bytes(t)

    if ins.op in RESULT_SIZED_OPS:
        # read-only charge, at the min of result vs source dtype width
        ops_n = _OPERAND_RE.findall(ins.args)
        if ops_n and ops_n[0] in shapes:
            src_b = resolved_bytes(ops_n[0])
            full_b = _shape_bytes(shapes[ops_n[0]])
            scale = src_b / full_b if full_b else 1.0
            return float(min(rb, rb * scale) if scale < 1.0 else rb)
        return float(rb)
    if ins.op in UPDATE_SIZED_OPS:
        ops_n = _OPERAND_RE.findall(ins.args)
        upd = _shape_bytes(shapes.get(ops_n[1], "")) if len(ops_n) > 1 else 0
        return 2.0 * upd
    if ins.op == "convert":
        ops_n = _OPERAND_RE.findall(ins.args)
        src = shapes.get(ops_n[0], "") if ops_n else ""
        sm, rm = _SHAPE_RE.search(src), _SHAPE_RE.search(ins.result_type)
        pair = {sm.group(1), rm.group(1)} if (sm and rm) else set()
        return 0.0 if pair <= {"bf16", "f32"} else 2.0 * rb

    if ins.op == "fusion":
        tgts = _CALLS_RE.findall(ins.line)
        inner = comps.get(tgts[0]) if tgts else None
        if inner is not None:
            ishapes = dict(inner.params)
            ialias: dict = {}
            dus_updates = 0.0
            dus_targets: set = set()
            for ii in inner.instrs:
                ishapes[ii.name] = ii.result_type
                ops_i = _OPERAND_RE.findall(ii.args)
                if ii.op in _ALIAS_OPS and ops_i:
                    base = ialias.get(ops_i[0], ops_i[0])
                    if _dims_of(ishapes.get(ops_i[0], "")) == _dims_of(ii.result_type):
                        ialias[ii.name] = base
                if ii.op in UPDATE_SIZED_OPS and len(ops_i) > 1:
                    dus_updates += _shape_bytes(ishapes.get(ops_i[1], ""))
                    dus_targets.add(ialias.get(ops_i[0], ops_i[0]))
            # write: in-place if the fusion result dims match a DUS target
            root_dims = _dims_of(ins.result_type)
            in_place = any(
                _dims_of(ishapes.get(t, inner.params.get(t, ""))) == root_dims for t in dus_targets
            )
            wb = 2.0 * dus_updates if (in_place and dus_updates) else float(rb)
            # reads per parameter (alias-chased; DUS targets excluded)
            pnames = list(inner.params.keys())
            onames = _OPERAND_RE.findall(ins.args)
            total_r = 0.0
            for pi, pname in enumerate(pnames):
                outer = onames[pi] if pi < len(onames) else None
                full = resolved_bytes(outer) if outer and outer in shapes else _shape_bytes(
                    inner.params.get(pname, ""))
                names = {pname} | {a for a, b in ialias.items() if b == pname}
                if pname in dus_targets or (names & dus_targets):
                    continue  # in-place target, not a read
                sliced, nonslice = 0.0, False
                for ii in inner.instrs:
                    ops_i = set(_OPERAND_RE.findall(ii.args))
                    if ops_i & names:
                        if ii.op in ("dynamic-slice", "slice", "gather"):
                            sliced += _shape_bytes(ii.result_type)
                        elif ii.op not in _ALIAS_OPS and ii.op != "parameter":
                            nonslice = True
                total_r += full if (nonslice or sliced == 0.0) else min(full, sliced)
            return wb + total_r
        return 2.0 * rb

    b = float(rb)
    for opn in _OPERAND_RE.findall(ins.args):
        if opn in shapes:
            b += resolved_bytes(opn)
    return b


def analyze(text: str, top_n: int = 15) -> dict:
    comps = _parse_computations(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line)
            if m:
                entry = m.group(1)
    if entry is None:  # fallback: computation named main*
        entry = next((n for n in comps if n.startswith("main")), next(iter(comps)))

    # per-computation local costs + call edges
    flops_c: dict[str, float] = {}
    bytes_c: dict[str, float] = {}
    coll_c: dict[str, dict] = {}
    edges: dict[str, list] = defaultdict(list)   # comp -> [(child, mult)]
    contribs: dict[str, list] = defaultdict(list)

    for cname, comp in comps.items():
        shapes = dict(comp.params)
        aliases: dict = {}
        fl = by = 0.0
        coll = defaultdict(float)
        for ins in comp.instrs:
            shapes[ins.name] = ins.result_type
            ops_a = _OPERAND_RE.findall(ins.args)
            if ins.op in _ALIAS_OPS and ops_a and ops_a[0] in shapes:
                if _dims_of(shapes[ops_a[0]]) == _dims_of(ins.result_type):
                    aliases[ins.name] = aliases.get(ops_a[0], shapes[ops_a[0]])
            if ins.op == "dot":
                f = _dot_flops(ins, shapes)
                fl += f
                meta = re.search(r'op_name="([^"]*)"', ins.line)
                contribs[cname].append((f, meta.group(1) if meta else ins.name))
            if ins.op in COLLECTIVES and not ins.op.endswith("-done"):
                # charge at the ORIGINAL dtype: XLA:CPU hoists bf16->f32
                # converts before gathers (FloatNormalization), doubling
                # apparent bytes vs the TPU target where operands stay bf16
                cb = _shape_bytes(ins.result_type)
                ops_c = _OPERAND_RE.findall(ins.args)
                if ops_c and ops_c[0] in aliases:
                    om = _SHAPE_RE.search(aliases[ops_c[0]])
                    rm = _SHAPE_RE.search(ins.result_type)
                    if om and rm and om.group(1) != rm.group(1):
                        scale = _DTYPE_BYTES.get(om.group(1), 4) / max(
                            _DTYPE_BYTES.get(rm.group(1), 4), 1)
                        if scale < 1.0:
                            cb *= scale
                coll[ins.op.replace("-start", "")] += cb
            if ins.op not in SKIP_BYTES_OPS and not ins.op.endswith("-done"):
                by += _instr_bytes(ins, shapes, comps, aliases)
            # call edges: while/call propagate BOTH flops and bytes; fusion-like
            # ops count bytes at the CALL SITE only (inner instrs are register-
            # resident on TPU), so bytes do not flow into their computations
            if ins.op == "while":
                trip = 1
                tm = _TRIP_RE.search(ins.line)
                if tm:
                    trip = int(tm.group(1))
                for tgt in _CALLS_RE.findall(ins.line):
                    edges[cname].append((tgt, trip, True))
            elif ins.op in ("call", "conditional"):
                for tgt in _CALLS_RE.findall(ins.line):
                    edges[cname].append((tgt, 1, True))
            elif ins.op in ("fusion", "reduce", "map", "scatter", "sort", "reduce-window", "select-and-scatter", "custom-call"):
                for tgt in _CALLS_RE.findall(ins.line):
                    edges[cname].append((tgt, 1, False))
        flops_c[cname] = fl
        bytes_c[cname] = by
        coll_c[cname] = dict(coll)

    # accumulate multipliers via DFS from entry (DAG; cycles impossible in HLO)
    mult: dict[str, float] = defaultdict(float)        # flops multiplier
    mult_b: dict[str, float] = defaultdict(float)      # bytes multiplier

    def visit(name, m, mb):
        mult[name] += m
        mult_b[name] += mb
        for child, cm, bytes_flow in edges.get(name, ()):
            if child in comps:
                visit(child, m * cm, mb * cm if bytes_flow else 0.0)

    visit(entry, 1.0, 1.0)

    total_flops = sum(flops_c[c] * mult.get(c, 0.0) for c in comps)
    total_bytes = sum(bytes_c[c] * mult_b.get(c, 0.0) for c in comps)
    coll_total: dict[str, float] = defaultdict(float)
    for c in comps:
        for op, b in coll_c[c].items():
            coll_total[op] += b * mult.get(c, 0.0)

    # top contributors (weighted)
    top = []
    for c in comps:
        for f, opname in contribs[c]:
            top.append((f * mult.get(c, 0.0), opname))
    top.sort(reverse=True)
    agg = defaultdict(float)
    for f, opname in top:
        agg[opname] += f
    top_named = sorted(agg.items(), key=lambda kv: -kv[1])[:top_n]

    return {
        "flops": total_flops,
        "bytes": total_bytes,
        "collective_bytes": float(sum(coll_total.values())),
        "collectives": {k: float(v) for k, v in sorted(coll_total.items())},
        "top_flops": [(n, f) for n, f in top_named],
    }


def f32_shadow_bytes(text: str, min_bytes: int = 1 << 26) -> dict:
    """XLA:CPU float-normalization artifact inventory.

    The CPU backend has no native bf16 compute, so FloatNormalization inserts
    f32 CONVERT copies of bf16 buffers (verified: whole KV caches get f32
    shadows hoisted out of the decode loop). These buffers DO NOT EXIST on the
    TPU target (native bf16). We enumerate large f32 converts whose operand is
    a bf16 tensor of identical dims so the dry-run can report a TPU-adjusted
    temp estimate alongside the raw CPU measurement (EXPERIMENTS.md §Dry-run
    documents the methodology)."""
    comps = _parse_computations(text)
    total = 0
    count = 0
    largest = []
    for comp in comps.values():
        shapes = dict(comp.params)
        for ins in comp.instrs:
            shapes[ins.name] = ins.result_type
            if ins.op != "convert":
                continue
            rm = _SHAPE_RE.search(ins.result_type)
            if rm is None or rm.group(1) != "f32":
                continue
            ops = _OPERAND_RE.findall(ins.args)
            if not ops or ops[0] not in shapes:
                continue
            om = _SHAPE_RE.search(shapes[ops[0]])
            if om is None or om.group(1) != "bf16" or om.group(2) != rm.group(2):
                continue
            b = _shape_bytes(ins.result_type)
            if b >= min_bytes:
                total += b
                count += 1
                largest.append(b)
    largest.sort(reverse=True)
    return {"bytes_total": total, "count": count, "largest": largest[:8]}
