"""Serving launcher: build a LIRA index and serve query batches through the
distributed engine, then through a real multi-pod ``LiraCluster`` — LANNS
shards × replica groups with routed/hedged dispatch and a mid-stream replica
kill (DESIGN.md §5).

    PYTHONPATH=src python -m repro.launch.serve --n 20000 --queries 1024
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.data import make_vector_dataset
from repro.launch.mesh import make_test_mesh
from repro.serving import (
    BuildConfig,
    ClusterConfig,
    LiraCluster,
    LiraEngine,
    SearchRequest,
    tiers,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--queries", type=int, default=1024)
    ap.add_argument("--partitions", type=int, default=32)
    ap.add_argument("--sigma", type=float, default=0.3)
    ap.add_argument("--pods", type=int, default=2,
                    help="replicas per shard in the serving cluster")
    ap.add_argument("--shards", type=int, default=2,
                    help="LANNS level-1 shards in the serving cluster")
    ap.add_argument("--tier", default="f32", choices=tiers.names(),
                    help="serving tier (serving/tiers.py registry): f32 exact "
                         "scan | pq ADC shortlist + exact rerank | residual_pq "
                         "PQ over x − centroid with per-partition LUT offsets")
    ap.add_argument("--quantized", action="store_true",
                    help="DEPRECATED: use --tier pq")
    ap.add_argument("--residual", action="store_true",
                    help="DEPRECATED: use --tier residual_pq")
    ap.add_argument("--rerank", type=int, default=8,
                    help="quantized shortlist depth r (rerank r·k per partition)")
    ap.add_argument("--auto-q-cap", action="store_true",
                    help="double q_cap_factor (and recompile) after persistent "
                         "dispatch-bucket overflow")
    ap.add_argument("--impl", default="auto",
                    choices=("auto", "ref", "pallas", "interpret"),
                    help="partition-scan backend (serving/scan.py): auto picks "
                         "the fused kernels on TPU, the portable jnp path "
                         "elsewhere; interpret forces the kernels through the "
                         "Pallas interpreter for parity checks")
    ap.add_argument("--profile-dir", default="",
                    help="capture a jax.profiler trace of the serving section "
                         "into this directory; open with TensorBoard's "
                         "profile plugin (op_profile groups device time under "
                         "the lira.probing/dispatch/scan/merge named scopes)")
    ap.add_argument("--trace-out", default="",
                    help="stream host-side serving spans (repro.obs.trace) to "
                         "this JSON-lines file")
    args = ap.parse_args()
    tier = args.tier
    if args.quantized or args.residual:
        tier = tiers.legacy_tier_name(args.quantized, args.residual)
        print(f"--quantized/--residual are deprecated; use --tier {tier}")

    ds = make_vector_dataset(n=args.n, n_queries=args.queries, dim=64, n_modes=64, seed=4)
    mesh = make_test_mesh()
    print("building index…")
    engine = LiraEngine.build(mesh, ds.base, BuildConfig(
        n_partitions=args.partitions, k=10, eta=0.05, train_frac=0.4, epochs=5,
        tier=tier, rerank=args.rerank, impl=args.impl,
        auto_q_cap=args.auto_q_cap))
    if tier != "f32":
        from repro.serving import scan_store_bytes

        sb = scan_store_bytes(engine.store)
        print(f"  {tier} tier: m={engine.cfg.pq_m} ks={engine.cfg.pq_ks} "
              f"rerank={engine.cfg.rerank}; scan store x{sb['ratio']:.1f} smaller")

    from repro.obs import Tracer, default_registry, profile_capture

    if args.trace_out:
        engine.tracer = Tracer(sink=args.trace_out)

    print(f"serving {args.queries} queries…")
    with profile_capture(args.profile_dir):
        t0 = time.time()
        res = engine.search(SearchRequest(queries=ds.queries, sigma=args.sigma))
        dt = time.time() - t0
    print(f"  {args.queries/dt:.0f} QPS local; adaptive nprobe "
          f"mean={res.nprobe_eff.mean():.2f}; dropped probes (q_cap overflow)="
          f"{res.overflow}; dedup_hits={res.stats.dedup_hits}; "
          f"bucket={res.stats.bucket} cache_hit={res.stats.cache_hit}")
    if res.stats.stages is not None:
        breakdown = " ".join(f"{name}={ms:.2f}ms"
                             for name, ms in res.stats.stages.items())
        print(f"  stages: {breakdown} (e2e {res.stats.latency_ms:.2f}ms)")
    if args.profile_dir:
        print(f"  profiler trace in {args.profile_dir} — "
              "tensorboard --logdir there, Profile > op_profile")

    # online front-end: single-query stream through the dynamic batcher
    # (virtual clock, real serve cost charged onto it — serving/frontend.py)
    from repro.configs.base import FrontendConfig
    from repro.serving.frontend import FakeClock, simulate_open_loop

    one = engine.search_one(SearchRequest(queries=ds.queries[0],
                                          sigma=args.sigma))
    print(f"  search_one: k={one.ids.shape[-1]} "
          f"nprobe_eff={float(one.nprobe_eff[0]):.2f}")
    fe = engine.attach_frontend(
        FrontendConfig(max_batch=32, max_wait_ms=5.0, max_queue=256),
        clock=FakeClock(), charge_service=True)
    for s in (8, 16, 32):   # warm the flushable jit buckets: steady-state
        engine.search(SearchRequest(queries=ds.queries[:s], sigma=args.sigma))
    try:
        stats, _ = simulate_open_loop(
            fe, ds.queries, rate_qps=2000.0, n_requests=256,
            sigma=args.sigma)
        print(f"  front-end @2000qps offered: p50={stats.p50_ms:.2f}ms "
              f"p99={stats.p99_ms:.2f}ms qps={stats.qps:.0f} "
              f"mean_batch={stats.mean_batch:.1f} shed={stats.shed}")
    finally:
        engine.frontend = None

    # multi-pod control plane: a REAL LiraCluster — LANNS shards × replica
    # groups serving the same corpus, with routed/hedged dispatch and one
    # replica killed mid-stream (its in-flight batch replays; nothing is lost)
    print(f"building {args.shards}-shard × {args.pods}-replica cluster…")
    cluster = LiraCluster.build(mesh, ds.base, BuildConfig(
        n_partitions=max(8, args.partitions // args.shards), k=10, eta=0.05,
        train_frac=0.4, epochs=5, tier=tier, rerank=args.rerank,
        impl=args.impl),
        ClusterConfig(n_shards=args.shards, n_replicas=args.pods,
                      hedge_warmup=8))
    n_batches, kill_at, bs = 32, 10, 32
    rows = 0
    for j in range(n_batches):
        if j == kill_at and args.pods > 1:
            cluster.fail_replica(0, 0, inflight=True)
        sel = np.arange(j * bs, (j + 1) * bs) % len(ds.queries)
        cres = cluster.search(SearchRequest(queries=ds.queries[sel],
                                            sigma=args.sigma))
        rows += cres.dists.shape[0]
    requeued = sum(g.router.requeued for g in cluster.groups)
    hedges = sum(g.mitigator.hedges for g in cluster.groups)
    served = {f"s{r['shard']}r{r['replica']}": r["served"]
              for r in cluster.replica_table()}
    print(f"  cluster: {rows} rows over {n_batches} batches, served={served} "
          f"(replica (0,0) killed at batch {kill_at}: {requeued} re-queued, "
          f"{hedges} hedges, 0 lost); last merge: nprobe "
          f"mean={cres.nprobe_eff.mean():.2f} routes={cres.stats.routes}")

    # registry snapshot: the cumulative counters this process accumulated
    reg = default_registry()
    print(f"  metrics: overflow_rate={engine.overflow_rate():.4f} "
          f"searches={reg.counter('lira_engine_searches_total').total():.0f} "
          f"jit_misses="
          f"{reg.counter('lira_engine_jit_cache_misses_total').total():.0f} "
          f"dedup_hits="
          f"{reg.counter('lira_engine_dedup_hits_total').total():.0f}")
    if args.trace_out:
        engine.tracer.close()
        print(f"  spans streamed to {args.trace_out}")


if __name__ == "__main__":
    main()
