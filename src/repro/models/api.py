"""Uniform model API consumed by the launcher / dry-run / smoke tests.

Every architecture module exposes ``make_bundle(config) -> ModelBundle``:

  init(rng)            — real parameters (REDUCED configs only; smoke tests)
  param_specs()        — ShapeDtypeStruct pytree (full configs; no allocation)
  param_pspecs()       — PartitionSpec pytree (logical axes resolved via rules)
  step(shape)          — StepDef for a ShapeSpec: fn + input specs/shardings

Steps take and return explicit pytrees; training steps have signature
``fn(state, batch) -> (state, metrics)`` where state = (params, opt_state),
serving steps ``fn(params, *inputs) -> outputs``. Everything is jit-able and
shardable with in_shardings/out_shardings derived from the pspecs here.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell from the assignment."""

    name: str                     # e.g. "train_4k"
    kind: str                     # train | prefill | decode | graph_train | rec_train | rec_serve | retrieval
    dims: Mapping[str, int]       # shape parameters (seq_len, global_batch, ...)

    def __getitem__(self, k):
        return self.dims[k]


@dataclasses.dataclass
class StepDef:
    """A lowerable step: callable + input/output shapes and shardings."""

    fn: Callable
    input_specs: dict            # name -> ShapeDtypeStruct (data inputs only)
    input_pspecs: dict           # name -> PartitionSpec
    out_pspecs: Any              # pytree of PartitionSpec (or None = auto)
    donate: Sequence[int] = ()


@dataclasses.dataclass
class ModelBundle:
    name: str
    config: Any
    init: Callable               # rng -> params
    param_specs: Callable        # () -> pytree of ShapeDtypeStruct
    param_pspecs: Callable       # () -> pytree of PartitionSpec
    step: Callable               # (ShapeSpec, **opts) -> StepDef
    # optimizer-state spec builders (for train steps); default = AdamW shapes
    opt_specs: Optional[Callable] = None
    opt_pspecs: Optional[Callable] = None


def sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def named_shardings(mesh, pspec_tree):
    return jax.tree.map(
        lambda spec: jax.sharding.NamedSharding(mesh, spec),
        pspec_tree,
        is_leaf=lambda x: isinstance(x, P) or x is None,
    )


def adamw_state_specs(param_specs_tree):
    """ShapeDtypeStructs of repro.train.optimizer.adamw state for given params."""
    from repro.train.optimizer import OptState

    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return OptState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=jax.tree.map(f32, param_specs_tree),
        nu=jax.tree.map(f32, param_specs_tree),
    )


def adamw_state_pspecs(param_pspecs_tree):
    from repro.train.optimizer import OptState

    return OptState(
        step=P(),
        mu=jax.tree.map(lambda p: p, param_pspecs_tree, is_leaf=lambda x: isinstance(x, P) or x is None),
        nu=jax.tree.map(lambda p: p, param_pspecs_tree, is_leaf=lambda x: isinstance(x, P) or x is None),
    )
