"""Transformer building blocks: RMSNorm, RoPE, flash attention (scan over KV
blocks — no [S, S] materialization), GQA, dense/MoE FFN.

Sharding contract (DESIGN.md §5): activations [B, S, D] carry
P(batch=("pod","data"), seq="model") everywhere; weights use flat head layouts
[D, H·Dh] so the "model" axis never has to divide the head count.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    x32 = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * w.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, Dh]; positions: [B, S] or [S]."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                        # [Dh/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [.., S, Dh/2]
    if ang.ndim == 2:  # [S, Dh/2] -> broadcast batch
        ang = ang[None]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """[B, S, KV, Dh] -> [B, S, H, Dh] by group repetition."""
    b, s, kv, dh = k.shape
    rep = n_heads // kv
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, rep, dh)).reshape(b, s, n_heads, dh)


def flash_attention(
    q: jax.Array,          # [B, Sq, H, Dh]
    k: jax.Array,          # [B, Skv, KV, Dh]
    v: jax.Array,          # [B, Skv, KV, Dh]
    *,
    causal: bool,
    block: int = 1024,
    q_offset: int = 0,     # global position of q[0] (chunked prefill)
    score_dtype=jnp.float32,  # bf16 halves materialized score traffic (§Perf)
) -> jax.Array:
    """Online-softmax attention, lax.scan over KV blocks: the [Sq, Skv] score
    matrix never exists in HBM; per-step tile is [B, H, Sq, block]."""
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    kv = k.shape[2]
    g = h // kv
    assert skv % block == 0, (skv, block)
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    # GQA via grouped einsum — NEVER materialize K/V expanded to H heads
    # (a broadcast [B, S, H, Dh] copy costs 13 GB at mistral-123b scale).
    # K/V stay in storage dtype; f32 only via accumulation (an explicit
    # astype(f32) gets hoisted by XLA into a full-KV f32 copy).
    qg = q.astype(k.dtype).reshape(b, sq, kv, g, dh)

    rows = q_offset + jnp.arange(sq)

    def body(carry, blk):
        acc, m, l = carry
        ks = jax.lax.dynamic_slice_in_dim(k, blk * block, block, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(v, blk * block, block, axis=1)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, ks,
                       preferred_element_type=score_dtype) * scale
        s = s.astype(jnp.float32)
        if causal:
            cols = blk * block + jnp.arange(block)
            s = jnp.where(cols[None, None, None, None, :] <= rows[None, None, None, :, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(k.dtype), vs,
                        preferred_element_type=jnp.float32)
        acc_new = acc * alpha[..., None] + pv
        return (acc_new, m_new, l_new), None

    # remat per block: backward recomputes scores instead of saving
    # [B, KV, G, Sq, block] f32 residuals for every block step
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)

    acc0 = jnp.zeros((b, kv, g, sq, dh), jnp.float32)
    m0 = jnp.full((b, kv, g, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, kv, g, sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), jnp.arange(skv // block))
    out = acc / jnp.maximum(l, 1e-30)[..., None]               # [B, KV, G, Sq, Dh]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dh).astype(q.dtype)


def swiglu_mlp(x: jax.Array, wi: jax.Array, wg: jax.Array, wo: jax.Array) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, wi)
    g = jnp.einsum("bsd,df->bsf", x, wg)
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * h, wo)


# --------------------------------------------------------------------- MoE

def moe_dispatch_local(x_all, router_w, e0: int, e_loc: int, top_k: int, capacity: int):
    """Per-device sort-based token-choice dispatch for the LOCAL expert range.

    x_all: [T, D] tokens (already gathered over the model axis).
    Returns (buf [E_loc, C, D], gate_buf [E_loc, C], tok_buf [E_loc, C] with
    T as the drop sentinel).
    """
    t, d = x_all.shape
    logits = jnp.einsum("td,de->te", x_all, router_w).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    g, eidx = jax.lax.top_k(probs, top_k)                       # [T, k]
    g = g / jnp.maximum(g.sum(-1, keepdims=True), 1e-9)         # renormalize top-k

    flat_e = eidx.reshape(-1)
    flat_t = jnp.broadcast_to(jnp.arange(t)[:, None], eidx.shape).reshape(-1)
    flat_g = g.reshape(-1)

    local = (flat_e >= e0) & (flat_e < e0 + e_loc)
    key = jnp.where(local, flat_e - e0, e_loc)                  # e_loc = trash bucket
    order = jnp.argsort(key, stable=True)
    skey = key[order]
    start = jnp.searchsorted(skey, jnp.arange(e_loc + 1))
    pos = jnp.arange(t * top_k) - start[jnp.clip(skey, 0, e_loc)]
    keep = (skey < e_loc) & (pos < capacity)
    # out-of-range rows are dropped by scatter mode="drop"
    row = jnp.where(keep, skey, e_loc)
    col = jnp.where(keep, pos, 0)
    # Scatter token INDICES (not rows) first, gather once afterwards — avoids
    # materializing a [T·k, D] intermediate (4.3 GB/device at qwen3 scale).
    gate_buf = jnp.zeros((e_loc, capacity), jnp.float32).at[row, col].set(
        flat_g[order], mode="drop")
    tok_buf = jnp.full((e_loc, capacity), t, jnp.int32).at[row, col].set(
        flat_t[order], mode="drop")
    x_pad = jnp.concatenate([x_all, jnp.zeros((1, d), x_all.dtype)], axis=0)
    buf = x_pad[tok_buf]                                        # [E_loc, C, D]
    return buf, gate_buf, tok_buf


def moe_expert_ffn(buf, wi, wg, wo):
    """buf: [E, C, D]; wi/wg: [E, D, F]; wo: [E, F, D]."""
    h = jnp.einsum("ecd,edf->ecf", buf, wi)
    g = jnp.einsum("ecd,edf->ecf", buf, wg)
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, wo)


def moe_combine_local(expert_out, gate_buf, tok_buf, n_tokens: int):
    """Scatter-add weighted expert outputs back to the token axis."""
    weighted = expert_out.astype(jnp.float32) * gate_buf[..., None]
    out = jnp.zeros((n_tokens + 1, expert_out.shape[-1]), jnp.float32)
    out = out.at[tok_buf.reshape(-1)].add(weighted.reshape(-1, weighted.shape[-1]), mode="drop")
    return out[:n_tokens]


def _sort_pack(key, n_buckets: int, capacity: int):
    """Sort-based bucketing: key [N] in [0, n_buckets) (or >= n_buckets =
    drop). Returns slot [n_buckets, capacity] of indices into the ORIGINAL
    array, sentinel = N."""
    n = key.shape[0]
    key_c = jnp.where((key >= 0) & (key < n_buckets), key, n_buckets)
    order = jnp.argsort(key_c, stable=True)
    skey = key_c[order]
    start = jnp.searchsorted(skey, jnp.arange(n_buckets + 1))
    pos = jnp.arange(n) - start[jnp.clip(skey, 0, n_buckets)]
    keep = (skey < n_buckets) & (pos < capacity)
    row = jnp.where(keep, skey, n_buckets)
    col = jnp.where(keep, pos, 0)
    return jnp.full((n_buckets, capacity), n, jnp.int32).at[row, col].set(
        order.astype(jnp.int32), mode="drop")


def moe_a2a_local(x_flat, router_w, e0, e_loc, model_n: int, top_k: int,
                  c_send: int, c_exp: int, wi, wg, wo, axis_name: str = "model"):
    """True expert-parallel MoE: route LOCAL tokens, all_to_all only the routed
    (token, expert-copy) pairs to their expert shard, compute, a2a back,
    gate-combine at the source. Collective volume ≈ 2·T_loc·top_k·D/model_n
    per direction vs the gather path's (model_n−1)/model_n·T_row·D all-gather
    + reduce-scatter (§Perf H3 napkin: 63 MB vs 503 MB per layer·microbatch at
    qwen3 scale). No psum needed — output stays seq-sharded."""
    t, d = x_flat.shape
    logits = jnp.einsum("td,de->te", x_flat, router_w).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    g, eidx = jax.lax.top_k(probs, top_k)                      # [T, k]
    g = g / jnp.maximum(g.sum(-1, keepdims=True), 1e-9)

    flat_e = eidx.reshape(-1)                                  # [T·k]
    flat_t = jnp.broadcast_to(jnp.arange(t)[:, None], eidx.shape).reshape(-1)
    flat_g = g.reshape(-1)
    dest = flat_e // e_loc

    slot = _sort_pack(dest, model_n, c_send)                   # [model_n, c_send] pair idx
    pad = flat_e.shape[0]
    e_pad = jnp.concatenate([flat_e, jnp.full((1,), -1, flat_e.dtype)])
    t_pad = jnp.concatenate([flat_t, jnp.full((1,), t, flat_t.dtype)])
    g_pad = jnp.concatenate([flat_g, jnp.zeros((1,), flat_g.dtype)])
    x_pad = jnp.concatenate([x_flat, jnp.zeros((1, d), x_flat.dtype)])

    send_x = x_pad[jnp.minimum(t_pad[slot], t)]                # [model_n, c_send, d]
    send_e = e_pad[jnp.minimum(slot, pad)]                     # [model_n, c_send]

    recv_x = jax.lax.all_to_all(send_x, axis_name, split_axis=0, concat_axis=0, tiled=True)
    recv_e = jax.lax.all_to_all(send_e, axis_name, split_axis=0, concat_axis=0, tiled=True)

    rt = model_n * c_send
    rx = recv_x.reshape(rt, d)
    re = recv_e.reshape(rt) - e0                               # local expert offset, -neg = pad
    slot2 = _sort_pack(re, e_loc, c_exp)                       # [e_loc, c_exp] recv idx
    rx_pad = jnp.concatenate([rx, jnp.zeros((1, d), rx.dtype)])
    buf = rx_pad[jnp.minimum(slot2, rt)]                       # [e_loc, c_exp, d]
    eout = moe_expert_ffn(buf, wi, wg, wo)
    back_flat = moe_combine_local(
        eout, jnp.ones(slot2.shape, jnp.float32), jnp.minimum(slot2, rt), rt)
    back = jax.lax.all_to_all(back_flat.reshape(model_n, c_send, d).astype(x_flat.dtype),
                              axis_name, split_axis=0, concat_axis=0, tiled=True)

    # gate-combine at the source: slot layout is preserved round-trip
    src_tok = t_pad[jnp.minimum(slot, pad)].reshape(-1)        # [model_n·c_send]
    src_gate = g_pad[jnp.minimum(slot, pad)].reshape(-1)
    out = jnp.zeros((t + 1, d), jnp.float32)
    out = out.at[src_tok].add(back.reshape(-1, d).astype(jnp.float32) * src_gate[:, None],
                              mode="drop")
    return out[:t]
