"""Model registry: config dataclass type -> bundle factory."""
from __future__ import annotations


def build_bundle(config, mesh):
    from repro.configs.base import GNNConfig, LiraSystemConfig, LMConfig, RecsysConfig
    from repro.models import dimenet, recsys, transformer
    from repro.serving import engine

    if isinstance(config, LMConfig):
        return transformer.make_bundle(config, mesh)
    if isinstance(config, GNNConfig):
        return dimenet.make_bundle(config, mesh)
    if isinstance(config, RecsysConfig):
        return recsys.make_bundle(config, mesh)
    if isinstance(config, LiraSystemConfig):
        return engine.make_bundle(config, mesh)
    raise TypeError(f"unknown config type {type(config)}")
