"""Dense + MoE GQA transformer LM (the 5 assigned LM architectures).

Execution design (DESIGN.md §5):
  * scan-over-layers with configurable remat — HLO size and live memory are
    O(1) in depth;
  * activations sharded [batch→("pod","data"), seq→"model"] uniformly;
  * weights: flat head layouts [D, H·Dh] (model-axis never divides head
    counts), fsdp("data") × tensor("model") 2D sharding;
  * attention: online-softmax scan over KV blocks (no [S,S] matrix);
  * MoE: shard_map expert parallelism — tokens all-gathered over "model",
    sort-based token-choice dispatch to the local expert shard, psum_scatter
    combine (baseline; `moe_impl="a2a"` is the hillclimbed variant);
  * decode: shard_map flash-decode over a sequence-sharded KV cache with
    logsumexp psum merge (supports 500k-token caches; long_500k shards the
    cache over every mesh axis).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.utils.compat import shard_map

from repro.configs.base import LMConfig
from repro.distributed.sharding import logical_to_pspec
from repro.models import layers as L
from repro.models.api import ModelBundle, ShapeSpec, StepDef, adamw_state_pspecs, adamw_state_specs, sds
from repro.train import optimizer as opt


# --------------------------------------------------------------- param layout

def _param_defs(cfg: LMConfig) -> dict:
    """path -> (shape, logical_axes). Layer params carry a leading 'stack' axis."""
    d, v = cfg.d_model, cfg.vocab
    h_flat = cfg.n_heads * cfg.head_dim
    kv_flat = cfg.n_kv_heads * cfg.head_dim
    l = cfg.n_layers
    defs = {
        "embed": ((v, d), (None, "fsdp")),
        "unembed": ((d, v), ("fsdp", "vocab")),
        "ln_f": ((d,), (None,)),
        "layers.ln1": ((l, d), ("stack", None)),
        "layers.ln2": ((l, d), ("stack", None)),
        "layers.wq": ((l, d, h_flat), ("stack", "fsdp", "heads_flat")),
        "layers.wk": ((l, d, kv_flat), ("stack", "fsdp", "heads_flat")),
        "layers.wv": ((l, d, kv_flat), ("stack", "fsdp", "heads_flat")),
        "layers.wo": ((l, h_flat, d), ("stack", "heads_flat", "fsdp")),
    }
    if cfg.moe is None:
        f = cfg.d_ff
        defs.update({
            "layers.wi": ((l, d, f), ("stack", "fsdp", "mlp")),
            "layers.wg": ((l, d, f), ("stack", "fsdp", "mlp")),
            "layers.wo_ff": ((l, f, d), ("stack", "mlp", "fsdp")),
        })
    else:
        e, fe = cfg.moe.n_experts, cfg.moe.d_ff_expert
        defs.update({
            "layers.router": ((l, d, e), ("stack", None, None)),
            "layers.wi_e": ((l, e, d, fe), ("stack", "expert", "fsdp", None)),
            "layers.wg_e": ((l, e, d, fe), ("stack", "expert", "fsdp", None)),
            "layers.wo_e": ((l, e, fe, d), ("stack", "expert", None, "fsdp")),
        })
        if cfg.moe.n_shared:
            fs = cfg.moe.n_shared * fe
            defs.update({
                "layers.ws_i": ((l, d, fs), ("stack", "fsdp", "mlp")),
                "layers.ws_g": ((l, d, fs), ("stack", "fsdp", "mlp")),
                "layers.ws_o": ((l, fs, d), ("stack", "mlp", "fsdp")),
            })
    return defs


def _nest(flat: dict) -> dict:
    out: dict = {}
    for path, val in flat.items():
        node = out
        parts = path.split(".")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return out


def _dtype(cfg: LMConfig):
    return jnp.dtype(cfg.dtype)


def param_specs(cfg: LMConfig):
    return _nest({k: sds(s, _dtype(cfg)) for k, (s, _) in _param_defs(cfg).items()})


def param_pspecs(cfg: LMConfig, mesh):
    return _nest({k: logical_to_pspec(ax, mesh) for k, (_, ax) in _param_defs(cfg).items()})


def init_params(rng: jax.Array, cfg: LMConfig):
    defs = _param_defs(cfg)
    keys = jax.random.split(rng, len(defs))
    flat = {}
    for key, (path, (shape, _)) in zip(keys, defs.items()):
        if path.endswith(("ln1", "ln2", "ln_f")):
            flat[path] = jnp.ones(shape, _dtype(cfg))
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            flat[path] = (jax.random.normal(key, shape, jnp.float32) / math.sqrt(fan_in)).astype(_dtype(cfg))
    return _nest(flat)


# --------------------------------------------------------------- MoE block

def _moe_block(h, lp, cfg: LMConfig, mesh, batch_axes, *, seq_sharded: bool):
    """shard_map expert parallelism. h: [B, S, D] (S sharded over 'model' when
    seq_sharded). Returns (out, aux_loss)."""
    moe = cfg.moe
    model_n = mesh.shape["model"]
    data_n = mesh.shape.get("data", 1)
    e_loc = moe.n_experts // model_n
    b, s, d = h.shape
    b_loc = b // int(np.prod([mesh.shape[a] for a in batch_axes])) if batch_axes else b
    s_loc = s // model_n if seq_sharded else s
    t_gathered = b_loc * (s if seq_sharded else s_loc)
    capacity = max(1, int(math.ceil(t_gathered * moe.top_k / moe.n_experts * moe.capacity_factor)))

    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)
    h_spec = P(bspec, "model" if seq_sharded else None, None)
    has_data = "data" in mesh.axis_names
    w_in_spec = P("model", "data" if has_data else None, None)    # per-layer [E, D, F]
    w_out_spec = P("model", None, "data" if has_data else None)   # per-layer [E, F, D]

    use_a2a = cfg.moe_impl == "a2a" and seq_sharded and model_n > 1
    t_loc = b_loc * s_loc
    c_send = max(1, int(math.ceil(t_loc * moe.top_k / model_n * moe.capacity_factor)))
    c_exp = max(1, int(math.ceil(model_n * c_send / e_loc * moe.capacity_factor)))

    def f(h_loc, router_w, wi, wg, wo):
        # h_loc: [B_loc, S_loc, D]; wi/wg: [E_loc, D/data, F]; wo: [E_loc, F, D/data]
        e0_ = jax.lax.axis_index("model") * e_loc
        if data_n > 1:
            wi_f = jax.lax.all_gather(wi, "data", axis=1, tiled=True)
            wg_f = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
            wo_f = jax.lax.all_gather(wo, "data", axis=2, tiled=True)
        else:
            wi_f, wg_f, wo_f = wi, wg, wo
        if use_a2a:
            x_flat = h_loc.reshape(-1, d)
            out = L.moe_a2a_local(x_flat, router_w, e0_, e_loc, model_n, moe.top_k,
                                  c_send, c_exp, wi_f, wg_f, wo_f)
            # aux from local routing stats (approximate under a2a: per-shard)
            probs = jax.nn.softmax(
                jnp.einsum("td,de->te", x_flat, router_w).astype(jnp.float32), -1)
            aux = moe.n_experts * jnp.sum(
                probs.mean(0) * jax.nn.one_hot(jnp.argmax(probs, -1), moe.n_experts).mean(0))
            aux = jax.lax.pmean(aux, "model")
            return out.reshape(h_loc.shape).astype(h_loc.dtype), aux
        if seq_sharded:
            x_all = jax.lax.all_gather(h_loc, "model", axis=1, tiled=True)  # [B_loc, S, D]
        else:
            x_all = h_loc
        tt = x_all.shape[0] * x_all.shape[1]
        x_flat = x_all.reshape(tt, d)
        buf, gbuf, tbuf = L.moe_dispatch_local(x_flat, router_w, e0_, e_loc, moe.top_k, capacity)
        eout = L.moe_expert_ffn(buf, wi_f, wg_f, wo_f)
        out = L.moe_combine_local(eout, gbuf, tbuf, tt).reshape(x_all.shape)
        # load-balance aux (Switch): E * sum_e f_e * p_e over local experts
        probs = jax.nn.softmax(
            jnp.einsum("td,de->te", x_flat, router_w).astype(jnp.float32), -1)
        p_e = probs.mean(0)  # [E] (full E — fine, router replicated)
        assigned = (tbuf < tt).sum(-1).astype(jnp.float32)  # [E_loc]
        f_loc = assigned / jnp.maximum(tt * moe.top_k, 1)
        p_loc = jax.lax.dynamic_slice_in_dim(p_e, e0_, e_loc)
        aux = moe.n_experts * jnp.sum(f_loc * p_loc)
        aux = jax.lax.psum(aux, "model")
        if seq_sharded:
            out = jax.lax.psum_scatter(out, "model", scatter_dimension=1, tiled=True)
        else:
            out = jax.lax.psum(out, "model")
        return out.astype(h_loc.dtype), aux

    out, aux = shard_map(
        f, mesh=mesh,
        in_specs=(h_spec, P(None, None), w_in_spec, w_in_spec, w_out_spec),
        out_specs=(h_spec, P()),
        check_vma=False,
    )(h, lp["router"], lp["wi_e"], lp["wg_e"], lp["wo_e"])

    if moe.n_shared:
        out = out + L.swiglu_mlp(h, lp["ws_i"], lp["ws_g"], lp["ws_o"])
    return out, aux


# --------------------------------------------------------------- forward

def _constrain(x, mesh, spec):
    return jax.lax.with_sharding_constraint(x, jax.sharding.NamedSharding(mesh, spec))


def _tree_constrain(tree, pspec_tree, mesh):
    """with_sharding_constraint a pytree against a matching PartitionSpec tree
    (P is a tuple, so flatten each side with its own is_leaf)."""
    leaves, tdef = jax.tree.flatten(tree)
    specs = jax.tree.flatten(pspec_tree, is_leaf=lambda x: isinstance(x, P))[0]
    return tdef.unflatten(_constrain(l, mesh, s) for l, s in zip(leaves, specs))


def _sp_ffn(h2, lp, cfg: LMConfig, mesh, bspec, act):
    """Megatron-SP FFN: all-gather ACTIVATIONS over the seq('model') axis,
    compute with F model-sharded (weights gathered over 'data' only — 16×
    less than full replication), reduce-scatter the output back to
    seq-sharded. Activation AG+RS ≪ full weight gathers at ≥33B scale."""
    h2g = _constrain(h2, mesh, P(bspec, None, None))          # AG over model (seq)
    gate = jnp.einsum("bsd,df->bsf", h2g, lp["wg"])
    up = jnp.einsum("bsd,df->bsf", h2g, lp["wi"])
    gate = _constrain(gate, mesh, P(bspec, None, "model"))    # F stays sharded
    up = _constrain(up, mesh, P(bspec, None, "model"))
    ff = jnp.einsum("bsf,fd->bsd", jax.nn.silu(gate) * up, lp["wo_ff"])
    return _constrain(ff, mesh, act)                          # RS over model (seq)


def _layer_pspecs(cfg: LMConfig, mesh):
    """Per-layer weight PartitionSpecs (stack axis stripped) — applied INSIDE
    the scan body so gradient cotangents are constrained to the param sharding
    at production (reduce-scatter instead of full-tensor all-reduce)."""
    full = param_pspecs(cfg, mesh)["layers"]
    return {k: P(*v[1:]) for k, v in full.items()}


def forward(params, tokens, cfg: LMConfig, mesh, *, q_offset: int = 0):
    """Causal forward: tokens [B, S] -> final hidden [B, S, D] (pre-unembed)."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)
    act = P(bspec, "model", None)
    b, s = tokens.shape
    lspecs = _layer_pspecs(cfg, mesh)

    x = params["embed"][tokens].astype(_dtype(cfg))
    x = _constrain(x, mesh, act)
    positions = q_offset + jnp.arange(s)

    def layer(carry, lp):
        x, aux = carry
        lp = {k: _constrain(v, mesh, lspecs[k]) for k, v in lp.items()}
        h = L.rmsnorm(x, lp["ln1"])
        q = jnp.einsum("bsd,dq->bsq", h, lp["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
        k = jnp.einsum("bsd,dq->bsq", h, lp["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        v = jnp.einsum("bsd,dq->bsq", h, lp["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        # replicate K/V over the seq ("model") axis once per layer (explicit
        # all-gather; the flash scan then slices locally)
        k = _constrain(k, mesh, P(bspec, None, None, None))
        v = _constrain(v, mesh, P(bspec, None, None, None))
        o = L.flash_attention(q, k, v, causal=True, block=min(cfg.attn_block, s), q_offset=q_offset,
                              score_dtype=jnp.dtype(cfg.attn_score_dtype))
        o = jnp.einsum("bsq,qd->bsd", o.reshape(b, s, -1), lp["wo"])
        x = _constrain(x + o, mesh, act)
        h2 = L.rmsnorm(x, lp["ln2"])
        if cfg.moe is None:
            if cfg.ffn_impl == "sp":
                ff = _sp_ffn(h2, lp, cfg, mesh, bspec, act)
            else:
                ff = L.swiglu_mlp(h2, lp["wi"], lp["wg"], lp["wo_ff"])
        else:
            ff, aux_l = _moe_block(h2, lp, cfg, mesh, batch_axes, seq_sharded=s > 1)
            aux = aux + aux_l
        x = _constrain(x + ff, mesh, act)
        return (x, aux), None

    if cfg.remat == "full":
        layer = jax.checkpoint(layer, policy=jax.checkpoint_policies.nothing_saveable)
    elif cfg.remat == "dots":
        layer = jax.checkpoint(layer, policy=jax.checkpoint_policies.checkpoint_dots)

    (x, aux), _ = jax.lax.scan(layer, (x, jnp.zeros((), jnp.float32)), params["layers"])
    return L.rmsnorm(x, params["ln_f"]), aux


def _softmax_ce(hidden, unembed, labels, chunks: int):
    """Next-token CE; optionally chunked over seq with rematerialized logits."""
    b, s, d = hidden.shape

    def chunk_loss(h_c, y_c):
        logits = jnp.einsum("bsd,dv->bsv", h_c, unembed).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, y_c[..., None].astype(jnp.int32), -1)[..., 0]
        return (lse - gold).sum()

    if chunks <= 1:
        return chunk_loss(hidden, labels) / (b * s)
    assert s % chunks == 0
    hc = hidden.reshape(b, chunks, s // chunks, d).swapaxes(0, 1)
    yc = labels.reshape(b, chunks, s // chunks).swapaxes(0, 1)
    loss, _ = jax.lax.scan(
        lambda acc, xs: (acc + jax.checkpoint(chunk_loss)(*xs), None),
        jnp.zeros((), jnp.float32), (hc, yc))
    return loss / (b * s)


# --------------------------------------------------------------- train step

def make_train_step(cfg: LMConfig, mesh, tx):
    pspecs = param_pspecs(cfg, mesh)

    def loss_fn(p, tokens, labels):
        hidden, aux = forward(p, tokens, cfg, mesh)
        ce = _softmax_ce(hidden, p["unembed"], labels, cfg.logits_chunk)
        return ce + 0.01 * aux, (ce, aux)

    def train_step(state, batch):
        params, opt_state = state
        accum = max(1, cfg.grad_accum)
        if accum == 1:
            (loss, (ce, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch["tokens"], batch["labels"])
        else:
            # microbatched gradient accumulation: live activation footprint
            # shrinks by `accum` at the cost of an f32 grad accumulator
            b = batch["tokens"].shape[0]
            assert b % accum == 0
            toks = batch["tokens"].reshape(accum, b // accum, -1)
            labs = batch["labels"].reshape(accum, b // accum, -1)

            def micro(carry, mb):
                gacc, lacc, ceacc, auxacc = carry
                (l, (ce_i, aux_i)), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb[0], mb[1])
                # keep microbatch grads in the PARAM sharding — otherwise XLA
                # replicates the accumulator and all-reduces full grads every
                # microbatch (4 TB/step at mistral-123b scale)
                g = _tree_constrain(g, pspecs, mesh)
                gacc = jax.tree.map(lambda a, gi: a + gi.astype(jnp.float32), gacc, g)
                return (gacc, lacc + l, ceacc + ce_i, auxacc + aux_i), None

            gacc0 = _tree_constrain(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params), pspecs, mesh)
            zero = jnp.zeros((), jnp.float32)
            (gacc, loss, ce, aux), _ = jax.lax.scan(micro, (gacc0, zero, zero, zero), (toks, labs))
            grads = jax.tree.map(lambda g, p: (g / accum).astype(p.dtype), gacc, params)
            loss, ce, aux = loss / accum, ce / accum, aux / accum
        grads, gnorm = opt.clip_by_global_norm(grads, 1.0)
        updates, opt_state = tx.update(grads, opt_state, params)
        params = opt.apply_updates(params, updates)
        return (params, opt_state), {"loss": loss, "ce": ce, "moe_aux": aux, "grad_norm": gnorm}

    return train_step


# --------------------------------------------------------------- prefill

def make_prefill_step(cfg: LMConfig, mesh):
    """Forward + emit KV cache and last-position logits (inference prefill)."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)

    def prefill_step(params, tokens):
        b, s = tokens.shape
        x = params["embed"][tokens].astype(_dtype(cfg))
        x = _constrain(x, mesh, P(bspec, "model", None))
        positions = jnp.arange(s)

        def layer(x, lp):
            h = L.rmsnorm(x, lp["ln1"])
            q = jnp.einsum("bsd,dq->bsq", h, lp["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
            k = jnp.einsum("bsd,dq->bsq", h, lp["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
            v = jnp.einsum("bsd,dq->bsq", h, lp["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
            # pin the cache ys to seq-sharded BEFORE the replicated copy exists,
            # or sharding propagation merges them and the ys buffer replicates
            # the full sequence per device (20 GiB at 32k for MHA archs)
            k = _constrain(k, mesh, P(bspec, "model", None, None))
            v = _constrain(v, mesh, P(bspec, "model", None, None))
            kg = _constrain(k, mesh, P(bspec, None, None, None))
            vg = _constrain(v, mesh, P(bspec, None, None, None))
            o = L.flash_attention(q, kg, vg, causal=True, block=min(cfg.attn_block, s),
                                  score_dtype=jnp.dtype(cfg.attn_score_dtype))
            o = jnp.einsum("bsq,qd->bsd", o.reshape(b, s, -1), lp["wo"])
            x = _constrain(x + o, mesh, P(bspec, "model", None))
            h2 = L.rmsnorm(x, lp["ln2"])
            if cfg.moe is None:
                if cfg.ffn_impl == "sp":
                    ff = _sp_ffn(h2, lp, cfg, mesh, bspec, P(bspec, "model", None))
                else:
                    ff = L.swiglu_mlp(h2, lp["wi"], lp["wg"], lp["wo_ff"])
            else:
                ff, _ = _moe_block(h2, lp, cfg, mesh, batch_axes, seq_sharded=True)
            x = _constrain(x + ff, mesh, P(bspec, "model", None))
            return x, (k, v)

        if cfg.remat == "full":
            layer = jax.checkpoint(layer, policy=jax.checkpoint_policies.nothing_saveable)
        x, (kc, vc) = jax.lax.scan(layer, x, params["layers"])
        x = L.rmsnorm(x, params["ln_f"])
        last = x[:, -1]
        logits = jnp.einsum("bd,dv->bv", last, params["unembed"]).astype(jnp.float32)
        return logits, {"k": kc, "v": vc}

    return prefill_step


# --------------------------------------------------------------- decode

def _decode_seq_axes(mesh, global_batch: int):
    """Which mesh axes shard the KV-cache sequence dim (DESIGN.md §5)."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bprod = int(np.prod([mesh.shape[a] for a in batch_axes])) if batch_axes else 1
    if global_batch % max(bprod, 1) == 0 and global_batch >= bprod:
        return batch_axes, ("model",)
    # tiny batch (long-context): replicate batch, shard seq over everything
    return (), tuple(a for a in (*batch_axes, "model") if a in mesh.axis_names)


def _flash_decode(q, k_cache, v_cache, layer, cache_len, mesh, bspec, seq_axes, n_heads):
    """q: [B, 1, H, Dh]; caches: STACKED [L, B, S, KV, Dh] seq-sharded over
    seq_axes. Reads layer `layer` — the cache stays in the scan carry so the
    donated input buffer is updated in place (no xs/ys double buffering)."""

    def f(q_l, k_c, v_c):
        k_l = jax.lax.dynamic_index_in_dim(k_c, layer, 0, keepdims=False)
        v_l = jax.lax.dynamic_index_in_dim(v_c, layer, 0, keepdims=False)
        b, s_loc, kv, dh = k_l.shape
        g = n_heads // kv
        idx = jnp.zeros((), jnp.int32)
        for ax in seq_axes:
            idx = idx * mesh.shape[ax] + jax.lax.axis_index(ax)
        off = idx * s_loc
        # grouped-GQA einsum; cache stays in storage dtype (an astype(f32)
        # here becomes a hoisted full-cache f32 copy)
        qg = q_l.astype(k_l.dtype).reshape(b, 1, kv, g, dh)
        scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_l,
                       preferred_element_type=jnp.float32) * scale
        valid = (off + jnp.arange(s_loc)) < cache_len
        s = jnp.where(valid[None, None, None, None, :], s, -1e30)
        m_l = s.max(-1)
        p = jnp.exp(s - m_l[..., None])
        l_l = p.sum(-1)
        o_l = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(k_l.dtype), v_l,
                         preferred_element_type=jnp.float32)
        m_g = jax.lax.pmax(m_l, seq_axes)
        corr = jnp.exp(m_l - m_g)
        l_g = jax.lax.psum(l_l * corr, seq_axes)
        o_g = jax.lax.psum(o_l * corr[..., None], seq_axes)
        o = o_g / jnp.maximum(l_g, 1e-30)[..., None]           # [B, KV, G, 1, Dh]
        return o.transpose(0, 3, 1, 2, 4).reshape(b, 1, n_heads, dh)

    cache_spec = P(None, bspec, seq_axes if len(seq_axes) > 1 else seq_axes[0], None, None)
    return shard_map(
        f, mesh=mesh,
        in_specs=(P(bspec, None, None, None), cache_spec, cache_spec),
        out_specs=P(bspec, None, None, None),
        check_vma=False,
    )(q, k_cache, v_cache)


def _cache_insert(cache, new, layer, pos, mesh, bspec, seq_axes):
    """Write new [B, 1, KV, Dh] at (layer, pos) of the STACKED sharded cache."""
    def f(c_l, n_l):
        s_loc = c_l.shape[2]
        idx = jnp.zeros((), jnp.int32)
        for ax in seq_axes:
            idx = idx * mesh.shape[ax] + jax.lax.axis_index(ax)
        off = idx * s_loc
        owner = (pos >= off) & (pos < off + s_loc)
        li = jnp.clip(pos - off, 0, s_loc - 1)
        # DUS writes garbage on non-owners, second where-DUS restores: express
        # as select on the inserted row only to keep the update in place
        cur = jax.lax.dynamic_slice(c_l, (layer, 0, li, 0, 0),
                                    (1, *n_l.shape))[0]
        row = jnp.where(owner, n_l.astype(c_l.dtype), cur)
        return jax.lax.dynamic_update_slice(c_l, row[None], (layer, 0, li, 0, 0))

    cache_spec = P(None, bspec, seq_axes if len(seq_axes) > 1 else seq_axes[0], None, None)
    return shard_map(
        f, mesh=mesh,
        in_specs=(cache_spec, P(bspec, None, None, None)),
        out_specs=cache_spec,
        check_vma=False,
    )(cache, new)


def make_decode_step(cfg: LMConfig, mesh, global_batch: int, seq_len: int):
    batch_axes, seq_axes = _decode_seq_axes(mesh, global_batch)
    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)

    def decode_step(params, cache, tokens, pos):
        """tokens: [B, 1] int32; pos: [] int32 (current length). Returns
        (next_token [B], new cache). The cache rides in the scan CARRY so the
        donated buffer is updated in place (no xs/ys double buffering)."""
        b = tokens.shape[0]
        x = params["embed"][tokens].astype(_dtype(cfg))
        x = _constrain(x, mesh, P(bspec, None, None))

        def layer(carry, xs):
            x, kcache, vcache = carry
            lp, li = xs
            h = L.rmsnorm(x, lp["ln1"])
            q = jnp.einsum("bsd,dq->bsq", h, lp["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim)
            k = jnp.einsum("bsd,dq->bsq", h, lp["wk"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
            v = jnp.einsum("bsd,dq->bsq", h, lp["wv"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
            posv = jnp.full((b, 1), pos, jnp.int32)
            q = L.apply_rope(q, posv, cfg.rope_theta)
            k = L.apply_rope(k, posv, cfg.rope_theta)
            kcache = _cache_insert(kcache, k, li, pos, mesh, bspec, seq_axes)
            vcache = _cache_insert(vcache, v, li, pos, mesh, bspec, seq_axes)
            o = _flash_decode(q, kcache, vcache, li, pos + 1, mesh, bspec, seq_axes, cfg.n_heads)
            o = jnp.einsum("bsq,qd->bsd", o.astype(_dtype(cfg)).reshape(b, 1, -1), lp["wo"])
            x = (x + o).astype(_dtype(cfg))
            h2 = L.rmsnorm(x, lp["ln2"])
            if cfg.moe is None:
                ff = L.swiglu_mlp(h2, lp["wi"], lp["wg"], lp["wo_ff"])
            else:
                ff, _ = _moe_block(h2, lp, cfg, mesh, batch_axes, seq_sharded=False)
            return ((x + ff).astype(_dtype(cfg)), kcache, vcache), None

        (x, k_new, v_new), _ = jax.lax.scan(
            layer, (x, cache["k"], cache["v"]),
            (params["layers"], jnp.arange(cfg.n_layers)))
        x = L.rmsnorm(x[:, 0], params["ln_f"])
        logits = jnp.einsum("bd,dv->bv", x, params["unembed"]).astype(jnp.float32)
        next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return next_tok, {"k": k_new, "v": v_new}

    return decode_step, batch_axes, seq_axes


# --------------------------------------------------------------- bundle

def cache_specs(cfg: LMConfig, global_batch: int, seq_len: int):
    shape = (cfg.n_layers, global_batch, seq_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": sds(shape, _dtype(cfg)), "v": sds(shape, _dtype(cfg))}


def cache_pspecs(cfg: LMConfig, mesh, global_batch: int):
    batch_axes, seq_axes = _decode_seq_axes(mesh, global_batch)
    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)
    spec = P(None, bspec, seq_axes if len(seq_axes) > 1 else seq_axes[0], None, None)
    return {"k": spec, "v": spec}


def make_bundle(cfg: LMConfig, mesh) -> ModelBundle:
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)
    tx = opt.adamw(opt.cosine_schedule(3e-4, warmup=100, total=10_000), weight_decay=0.1)

    def step(shape: ShapeSpec) -> StepDef:
        if shape.kind == "train":
            s, gb = shape["seq_len"], shape["global_batch"]
            fn = make_train_step(cfg, mesh, tx)
            return StepDef(
                fn=fn,
                input_specs={"tokens": sds((gb, s), jnp.int32), "labels": sds((gb, s), jnp.int32)},
                input_pspecs={"tokens": P(bspec, None), "labels": P(bspec, None)},
                out_pspecs=None,
            )
        if shape.kind == "prefill":
            s, gb = shape["seq_len"], shape["global_batch"]
            fn = make_prefill_step(cfg, mesh)
            cache_spec = P(None, bspec, "model", None, None)
            return StepDef(
                fn=fn,
                input_specs={"tokens": sds((gb, s), jnp.int32)},
                input_pspecs={"tokens": P(bspec, None)},
                out_pspecs=(P(bspec, None), {"k": cache_spec, "v": cache_spec}),
            )
        if shape.kind == "decode":
            s, gb = shape["seq_len"], shape["global_batch"]
            fn, b_axes, seq_axes = make_decode_step(cfg, mesh, gb, s)
            dbspec = b_axes if len(b_axes) > 1 else (b_axes[0] if b_axes else None)
            return StepDef(
                fn=fn,
                input_specs={
                    "cache": cache_specs(cfg, gb, s),
                    "tokens": sds((gb, 1), jnp.int32),
                    "pos": sds((), jnp.int32),
                },
                input_pspecs={
                    "cache": cache_pspecs(cfg, mesh, gb),
                    "tokens": P(dbspec, None),
                    "pos": P(),
                },
                # cache out == cache in so donation aliases the 2×TB buffers
                out_pspecs=(P(dbspec), cache_pspecs(cfg, mesh, gb)),
                donate=(1,),
            )
        raise ValueError(f"unknown shape kind {shape.kind} for LM arch")

    return ModelBundle(
        name=cfg.arch,
        config=cfg,
        init=lambda rng, shape=None: init_params(rng, cfg),
        param_specs=lambda shape=None: param_specs(cfg),
        param_pspecs=lambda shape=None: param_pspecs(cfg, mesh),
        step=step,
        opt_specs=lambda shape=None: adamw_state_specs(param_specs(cfg)),
        opt_pspecs=lambda shape=None: adamw_state_pspecs(param_pspecs(cfg, mesh)),
    )
