"""DimeNet (Klicpera et al., arXiv:2003.03123) — directional message passing
with triplet interactions, adapted to TPU pods.

Kernel regime: triplet gather (kernel_taxonomy §GNN) — NOT expressible as SpMM.
Message passing is implemented with jax.ops.segment_sum over edge/triplet index
lists (this IS part of the system: JAX sparse is BCOO-only).

Distribution (DESIGN.md §5):
  * node arrays REPLICATED (≤2.4M·128 f32 ≈ 1.2 GB — fits every assigned shape);
  * edge arrays sharded over the flattened mesh (all axes);
  * triplets sharded ALIGNED WITH THEIR ji EDGE (data layer sorts triplets by
    ji), so the triplet→edge segment_sum is collective-free;
  * the edge→triplet gather m[kj] crosses shards: shard_map partial-gather
    (local-range rows, zeros elsewhere) + psum — memory O(E/shards), collective
    O(T·H) per block (the dominant roofline term for big graphs; §Perf
    hillclimbs it with locality-aware edge ordering);
  * edge→node segment_sum: local partial [N, H] + psum.

Simplifications vs the paper (noted per DESIGN.md §7): the spherical basis uses
a Chebyshev angular × sinc radial product instead of spherical Bessel roots —
identical shapes/compute pattern, same n_spherical × n_radial feature count.
Non-molecular graph shapes synthesize 3D positions (DimeNet needs geometry;
the assignment pairs it with citation/product graphs).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import GNNConfig
from repro.models.api import ModelBundle, ShapeSpec, StepDef, adamw_state_pspecs, adamw_state_specs, sds
from repro.train import optimizer as opt

from repro.utils.compat import shard_map


# ----------------------------------------------------------------- bases

def envelope(d, cutoff, p: int = 6):
    x = d / cutoff
    return (1.0 - (p + 1) * (p + 2) / 2 * x**p + p * (p + 2) * x ** (p + 1)
            - p * (p + 1) / 2 * x ** (p + 2)) * (x < 1.0)


def radial_basis(d, n_radial: int, cutoff: float = 5.0):
    """sin(nπ d/c)/d with smooth envelope. [E] -> [E, n_radial]."""
    d = jnp.maximum(d, 1e-6)[:, None]
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    return envelope(d, cutoff) * jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * d / cutoff) / d


def spherical_basis(angle, d, n_spherical: int, n_radial: int, cutoff: float = 5.0):
    """Chebyshev(cos θ) × radial product basis. [T] -> [T, n_spherical*n_radial]."""
    cosang = jnp.clip(jnp.cos(angle), -1.0, 1.0)[:, None]
    ls = jnp.arange(n_spherical, dtype=jnp.float32)
    ang = jnp.cos(ls * jnp.arccos(cosang))                       # [T, S]
    rad = radial_basis(d, n_radial, cutoff)                      # [T, R]
    return (ang[:, :, None] * rad[:, None, :]).reshape(d.shape[0], -1)


# ----------------------------------------------------------------- sharded ops

def _flat_axes(mesh):
    return tuple(mesh.axis_names)


def sharded_edge_gather(edge_feat, idx, mesh):
    """m[idx] where edge_feat [E, H] and idx [T] are both sharded over the
    flattened mesh: partial local gather + psum (no replication of edge_feat)."""
    axes = _flat_axes(mesh)

    def f(m_loc, idx_loc):
        e_loc = m_loc.shape[0]
        fi = jnp.zeros((), jnp.int32)
        for ax in axes:
            fi = fi * mesh.shape[ax] + jax.lax.axis_index(ax)
        e0 = fi * e_loc
        rel = idx_loc - e0
        ok = (rel >= 0) & (rel < e_loc)
        part = jnp.where(ok[:, None], m_loc[jnp.clip(rel, 0, e_loc - 1)], 0.0)
        return jax.lax.psum(part, axes)

    spec = P(axes if len(axes) > 1 else axes[0])
    return shard_map(f, mesh=mesh, in_specs=(P(spec[0], None), spec), out_specs=P(spec[0], None),
                     check_vma=False)(edge_feat, idx)


def sharded_segment_to_nodes(edge_feat, dst, n_nodes: int, mesh):
    """segment_sum sharded-edges -> replicated nodes: local partial + psum."""
    axes = _flat_axes(mesh)

    def f(m_loc, dst_loc):
        part = jax.ops.segment_sum(m_loc, dst_loc, num_segments=n_nodes)
        return jax.lax.psum(part, axes)

    spec = axes if len(axes) > 1 else axes[0]
    return shard_map(f, mesh=mesh, in_specs=(P(spec, None), P(spec)), out_specs=P(None, None),
                     check_vma=False)(edge_feat, dst)


def local_segment_to_edges(trip_feat, ji_local, n_edges_local_total: int, mesh):
    """Triplet->edge segment_sum; triplets are pre-aligned to their ji shard so
    this is collective-free (ids are LOCAL edge offsets)."""
    axes = _flat_axes(mesh)
    nshard = int(np.prod([mesh.shape[a] for a in axes]))
    e_loc = n_edges_local_total // nshard

    def f(t_loc, ji_loc):
        return jax.ops.segment_sum(t_loc, ji_loc, num_segments=e_loc)

    spec = axes if len(axes) > 1 else axes[0]
    return shard_map(f, mesh=mesh, in_specs=(P(spec, None), P(spec)), out_specs=P(spec, None),
                     check_vma=False)(trip_feat, ji_local)


# ----------------------------------------------------------------- params

def _param_defs(cfg: GNNConfig, d_feat: int) -> dict:
    h, nb, ns, nr = cfg.d_hidden, cfg.n_blocks, cfg.n_spherical, cfg.n_radial
    nbl = cfg.n_bilinear
    d_in = d_feat if d_feat > 0 else 16  # atom-type embedding width
    return {
        "node_proj": ((d_in, h), None),
        "atom_embed": ((100, 16), None),          # used when d_feat == 0
        "rbf_proj": ((nr, h), None),
        "edge_w": ((3 * h, h), None),
        "blocks.w_sbf": ((nb, ns * nr, nbl), None),
        "blocks.w_kj": ((nb, h, h), None),
        "blocks.w_bil": ((nb, nbl, h, h), None),
        "blocks.w_e1": ((nb, h, h), None),
        "blocks.w_e2": ((nb, h, h), None),
        "blocks.out_rbf": ((nb, nr, h), None),
        "blocks.out_w": ((nb, h, h), None),
        "readout1": ((h, h), None),
        "readout2": ((h, 1), None),
    }


def _nest(flat):
    out = {}
    for k, v in flat.items():
        node = out
        parts = k.split(".")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return out


def param_specs(cfg: GNNConfig, d_feat: int):
    return _nest({k: sds(s, jnp.float32) for k, (s, _) in _param_defs(cfg, d_feat).items()})


def param_pspecs(cfg: GNNConfig, d_feat: int, mesh):
    return _nest({k: P() for k in _param_defs(cfg, d_feat)})  # params replicated (tiny)


def init_params(rng, cfg: GNNConfig, d_feat: int):
    defs = _param_defs(cfg, d_feat)
    keys = jax.random.split(rng, len(defs))
    flat = {}
    for key, (path, (shape, _)) in zip(keys, defs.items()):
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        flat[path] = jax.random.normal(key, shape, jnp.float32) / math.sqrt(fan_in)
    return _nest(flat)


# ----------------------------------------------------------------- forward

def forward(params, batch, cfg: GNNConfig, mesh, *, n_nodes: int, d_feat: int):
    """batch: pos [N,3], feat [N,d_feat] or z [N], edge src/dst [E], triplet
    kj [T] (global edge ids), ji_local [T] (edge offset within owning shard),
    edge_mask [E], trip_mask [T]. Returns per-node scalar predictions [N]."""
    pos = batch["pos"]
    src, dst = batch["src"], batch["dst"]
    emask = batch["edge_mask"].astype(jnp.float32)[:, None]
    tmask = batch["trip_mask"].astype(jnp.float32)[:, None]

    if d_feat > 0:
        hx = batch["feat"] @ params["node_proj"]
    else:
        hx = params["atom_embed"][batch["z"]] @ params["node_proj"]
    hx = jax.nn.silu(hx)                                        # [N, H] replicated

    vec = pos[dst] - pos[src]                                   # [E, 3] sharded
    dist = jnp.linalg.norm(vec + 1e-9, axis=-1)
    rbf = radial_basis(dist, cfg.n_radial)                      # [E, R]

    m = jax.nn.silu(
        jnp.concatenate([hx[src], hx[dst], rbf @ params["rbf_proj"]], -1) @ params["edge_w"]
    ) * emask                                                   # [E, H]

    # triplet geometry: angle between edge ji and edge kj at vertex j
    kj = batch["trip_kj"]
    ji_glob = batch["trip_ji"]
    v_ji = sharded_edge_gather(vec, ji_glob, mesh)              # [T, 3]
    v_kj = sharded_edge_gather(vec, kj, mesh)
    cos_t = jnp.sum(-v_ji * v_kj, -1) / (
        jnp.linalg.norm(v_ji, axis=-1) * jnp.linalg.norm(v_kj, axis=-1) + 1e-9)
    angle = jnp.arccos(jnp.clip(cos_t, -1 + 1e-6, 1 - 1e-6))
    d_kj = sharded_edge_gather(dist[:, None], kj, mesh)[:, 0]
    sbf = spherical_basis(angle, d_kj, cfg.n_spherical, cfg.n_radial)  # [T, S*R]

    node_out = jnp.zeros((n_nodes, cfg.d_hidden), jnp.float32)
    n_edges = m.shape[0]

    def block(carry, bp):
        m, node_out = carry
        a = sbf @ bp["w_sbf"]                                   # [T, nbl]
        u = sharded_edge_gather(m, kj, mesh) @ bp["w_kj"]       # [T, H]
        msg = jnp.zeros_like(u)
        for b in range(cfg.n_bilinear):                         # unrolled bilinear
            msg = msg + a[:, b:b + 1] * (u @ bp["w_bil"][b])
        msg = msg * tmask
        agg = local_segment_to_edges(msg, batch["trip_ji_local"], n_edges, mesh)
        m = (m + jax.nn.silu(jax.nn.silu((m + agg) @ bp["w_e1"]) @ bp["w_e2"])) * emask
        contrib = sharded_segment_to_nodes((rbf @ bp["out_rbf"]) * m, dst, n_nodes, mesh)
        node_out = node_out + contrib @ bp["out_w"]
        return (m, node_out), None

    blk = block
    if cfg.remat == "full":
        blk = jax.checkpoint(block, policy=jax.checkpoint_policies.nothing_saveable)
    (m, node_out), _ = jax.lax.scan(blk, (m, node_out), params["blocks"])
    return (jax.nn.silu(node_out @ params["readout1"]) @ params["readout2"])[:, 0]  # [N]


# ----------------------------------------------------------------- steps

def make_train_step(cfg: GNNConfig, mesh, tx, *, n_nodes: int, d_feat: int):
    def train_step(state, batch):
        params, opt_state = state

        def loss_fn(p):
            pred = forward(p, batch, cfg, mesh, n_nodes=n_nodes, d_feat=d_feat)
            mask = batch["node_mask"].astype(jnp.float32)
            return jnp.sum(((pred - batch["target"]) ** 2) * mask) / jnp.maximum(mask.sum(), 1.0)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads, gnorm = opt.clip_by_global_norm(grads, 1.0)
        updates, opt_state = tx.update(grads, opt_state, params)
        params = opt.apply_updates(params, updates)
        return (params, opt_state), {"loss": loss, "grad_norm": gnorm}

    return train_step


def _pad_to(n, mult):
    return int(-(-n // mult) * mult)


def make_bundle(cfg: GNNConfig, mesh) -> ModelBundle:
    axes = tuple(mesh.axis_names)
    nshard = int(np.prod([mesh.shape[a] for a in axes]))
    espec = P(axes if len(axes) > 1 else axes[0])
    tx = opt.adamw(opt.cosine_schedule(1e-3, 100, 10_000))

    def step(shape: ShapeSpec) -> StepDef:
        assert shape.kind == "graph_train"
        n_graphs = shape.dims.get("batch", 1)
        n_nodes = shape["n_nodes"] * n_graphs
        n_edges = _pad_to(shape["n_edges"] * n_graphs, max(nshard, 256))
        n_trip = _pad_to(shape["n_edges"] * n_graphs * shape["triplet_mult"], max(nshard, 256))
        d_feat = shape["d_feat"]
        fn = make_train_step(cfg, mesh, tx, n_nodes=n_nodes, d_feat=d_feat)
        specs = {
            "pos": sds((n_nodes, 3)),
            "src": sds((n_edges,), jnp.int32),
            "dst": sds((n_edges,), jnp.int32),
            "trip_kj": sds((n_trip,), jnp.int32),
            "trip_ji": sds((n_trip,), jnp.int32),
            "trip_ji_local": sds((n_trip,), jnp.int32),
            "edge_mask": sds((n_edges,), jnp.int32),
            "trip_mask": sds((n_trip,), jnp.int32),
            "node_mask": sds((n_nodes,), jnp.int32),
            "target": sds((n_nodes,)),
        }
        if d_feat > 0:
            specs["feat"] = sds((n_nodes, d_feat))
        else:
            specs["z"] = sds((n_nodes,), jnp.int32)
        pspecs = {
            "pos": P(None, None), "node_mask": P(None), "target": P(None),
            "src": espec, "dst": espec, "edge_mask": espec,
            "trip_kj": espec, "trip_ji": espec, "trip_ji_local": espec, "trip_mask": espec,
        }
        pspecs["feat" if d_feat > 0 else "z"] = P(None, None) if d_feat > 0 else P(None)
        return StepDef(fn=fn, input_specs=specs, input_pspecs=pspecs, out_pspecs=None)

    # node_proj input width follows the shape's d_feat (non-molecular graphs
    # project raw features; molecules use the atom-type embedding).
    def _dfeat(shape):
        return shape["d_feat"] if shape is not None else 0

    return ModelBundle(
        name=cfg.arch,
        config=cfg,
        init=lambda rng, shape=None: init_params(rng, cfg, _dfeat(shape)),
        param_specs=lambda shape=None: param_specs(cfg, _dfeat(shape)),
        param_pspecs=lambda shape=None: param_pspecs(cfg, _dfeat(shape), mesh),
        step=step,
        opt_specs=lambda shape=None: adamw_state_specs(param_specs(cfg, _dfeat(shape))),
        opt_pspecs=lambda shape=None: adamw_state_pspecs(param_pspecs(cfg, _dfeat(shape), mesh)),
    )
