"""RecSys architectures: DeepFM, AutoInt, MIND, DLRM-RM2.

JAX has no native EmbeddingBag or CSR sparse — the sharded EmbeddingBag here
(take + segment/bag-sum inside shard_map, tables row-sharded over "model",
psum combine) IS part of the system (kernel_taxonomy §RecSys).

Distribution: embedding tables [F, V, dim] sharded P(None, "model", None) —
each model shard owns a contiguous V-range of every field's table; lookups
mask to the local range and psum over "model". Dense MLPs are data-parallel
with replicated weights. ``retrieval_cand`` scores 1M candidates through the
FULL interaction model (batch = candidates) and finishes with a global top-k;
the LIRA-accelerated variant (the paper's technique applied to this arch) is
in repro/serving and §Perf.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import RecsysConfig
from repro.models.api import ModelBundle, ShapeSpec, StepDef, adamw_state_pspecs, adamw_state_specs, sds
from repro.train import optimizer as opt

from repro.utils.compat import shard_map


# ------------------------------------------------------------ embedding bag

def embedding_bag(tables, ids, mesh, batch_axes):
    """tables: [F, V, dim] sharded P(None, 'model', None); ids: [B, F, nnz]
    sharded on batch. Returns [B, F, dim] (bag-sum over nnz)."""
    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)
    model_n = mesh.shape.get("model", 1)
    v = tables.shape[1]
    v_loc = v // model_n

    def f(tab_loc, ids_loc):
        # tab_loc: [F, V_loc, dim]; ids_loc: [B_loc, F, nnz]
        v0 = jax.lax.axis_index("model") * v_loc if model_n > 1 else 0
        rel = ids_loc - v0
        ok = (rel >= 0) & (rel < v_loc)
        g = _gather_fields(tab_loc, jnp.clip(rel, 0, v_loc - 1))  # [B, F, nnz, dim]
        g = jnp.where(ok[..., None], g, 0.0)
        out = g.sum(2)  # bag-sum over nnz -> [B_loc, F, dim]
        if model_n > 1:
            out = jax.lax.psum(out, "model")
        return out

    return shard_map(
        f, mesh=mesh,
        in_specs=(P(None, "model", None), P(bspec, None, None)),
        out_specs=P(bspec, None, None),
        check_vma=False,
    )(tables, ids)


def _gather_fields(tab_loc, rel):
    """tab_loc [F, V_loc, dim], rel [B, F, nnz] -> [B, F, nnz, dim]."""
    def per_field(tab_f, ids_f):  # [V_loc, dim], [B, nnz]
        return tab_f[ids_f]       # [B, nnz, dim]
    out = jax.vmap(per_field, in_axes=(0, 1), out_axes=1)(tab_loc, rel)
    return out  # [B, F, nnz, dim]


def _mlp(params, x, act=jax.nn.relu, final_act=False):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if final_act or i + 1 < len(params):
            x = act(x)
    return x


def _mlp_defs(prefix, sizes):
    out = {}
    for i, (fi, fo) in enumerate(zip(sizes[:-1], sizes[1:])):
        out[f"{prefix}.{i}.w"] = ((fi, fo), None)
        out[f"{prefix}.{i}.b"] = ((fo,), None)
    return out


# ------------------------------------------------------------ interactions

def fm_interaction(emb):
    """emb [B, F, dim] -> scalar FM 2nd-order term (sum-square trick)."""
    s = emb.sum(1)
    return 0.5 * (s * s - (emb * emb).sum(1)).sum(-1)


def dot_interaction(z):
    """z [B, F, dim] -> lower-triangle pairwise dots [B, F(F-1)/2]."""
    b, f, d = z.shape
    g = jnp.einsum("bfd,bgd->bfg", z, z)
    iu, ju = np.tril_indices(f, k=-1)
    return g[:, iu, ju]


def autoint_layer(x, wq, wk, wv, wres, n_heads: int):
    """x [B, F, dim] -> multi-head field self-attention (AutoInt eq. 6-8)."""
    b, f, d = x.shape
    q = (x @ wq).reshape(b, f, n_heads, -1)
    k = (x @ wk).reshape(b, f, n_heads, -1)
    v = (x @ wv).reshape(b, f, n_heads, -1)
    att = jax.nn.softmax(jnp.einsum("bfhd,bghd->bhfg", q, k) / math.sqrt(q.shape[-1]), -1)
    o = jnp.einsum("bhfg,bghd->bfhd", att, v).reshape(b, f, -1)
    return jax.nn.relu(o + x @ wres)


def capsule_routing(hist_emb, hist_mask, s_bilinear, n_interests: int, iters: int):
    """MIND B2I dynamic routing. hist_emb [B, T, dim] -> interests [B, K, dim]."""
    b, t, d = hist_emb.shape
    u = hist_emb @ s_bilinear                                    # [B, T, dim]
    blogit = jnp.zeros((b, n_interests, t), jnp.float32)
    neg = jnp.where(hist_mask[:, None, :] > 0, 0.0, -1e30)
    caps = jnp.zeros((b, n_interests, d), u.dtype)
    for _ in range(iters):
        w = jax.nn.softmax(blogit + neg, axis=1)                 # over interests
        caps = jnp.einsum("bkt,btd->bkd", w, u)
        norm2 = jnp.sum(caps * caps, -1, keepdims=True)
        caps = caps * (norm2 / (1 + norm2)) / jnp.sqrt(norm2 + 1e-9)  # squash
        blogit = blogit + jnp.einsum("bkd,btd->bkt", caps, u)
    return caps


# ------------------------------------------------------------ param defs

def _param_defs(cfg: RecsysConfig) -> dict:
    f, v, d = cfg.n_sparse, cfg.vocab_per_field, cfg.embed_dim
    defs = {"tables": ((f, v, d), (None, "rows", None))}
    if cfg.interaction == "fm":               # DeepFM
        defs["wide"] = ((f, v, 1), (None, "rows", None))
        defs.update(_mlp_defs("deep", (f * d, *cfg.mlp, 1)))
    elif cfg.interaction == "self-attn":      # AutoInt
        da = cfg.d_attn * cfg.n_heads
        for i in range(cfg.n_attn_layers):
            d_in = d if i == 0 else da
            defs.update({
                f"attn.{i}.wq": ((d_in, da), None), f"attn.{i}.wk": ((d_in, da), None),
                f"attn.{i}.wv": ((d_in, da), None), f"attn.{i}.wres": ((d_in, da), None),
            })
        defs.update(_mlp_defs("head", (f * da, 1)))
    elif cfg.interaction == "multi-interest":  # MIND
        defs["s_bilinear"] = ((d, d), None)
        defs.update(_mlp_defs("head", (d, 2 * d, d)))
    elif cfg.interaction == "dot":            # DLRM
        defs.update(_mlp_defs("bot", tuple(cfg.bot_mlp)))
        n_f = cfg.n_sparse + 1
        d_int = n_f * (n_f - 1) // 2 + cfg.bot_mlp[-1]
        defs.update(_mlp_defs("top", (d_int, *cfg.top_mlp)))
    else:
        raise ValueError(cfg.interaction)
    return defs


def _nest(flat):
    out = {}
    for k, val in flat.items():
        node = out
        parts = k.split(".")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return out


def param_specs(cfg: RecsysConfig):
    return _nest({k: sds(s, jnp.float32) for k, (s, _) in _param_defs(cfg).items()})


def param_pspecs(cfg: RecsysConfig, mesh):
    from repro.distributed.sharding import logical_to_pspec

    out = {}
    for k, (shape, ax) in _param_defs(cfg).items():
        if ax is None:
            out[k] = P()
        else:
            out[k] = logical_to_pspec(ax, mesh)
    return _nest(out)


def init_params(rng, cfg: RecsysConfig):
    defs = _param_defs(cfg)
    keys = jax.random.split(rng, len(defs))
    flat = {}
    for key, (path, (shape, _)) in zip(keys, defs.items()):
        if path.endswith(".b"):
            flat[path] = jnp.zeros(shape, jnp.float32)
        else:
            scale = 0.01 if path in ("tables", "wide") else 1.0 / math.sqrt(shape[-2] if len(shape) > 1 else shape[-1])
            flat[path] = jax.random.normal(key, shape, jnp.float32) * scale
    return _nest(flat)


def _collect_mlp(params, prefix):
    node = params.get(prefix, {})
    layers = []
    i = 0
    while str(i) in node:
        layers.append(node[str(i)])
        i += 1
    return layers


# ------------------------------------------------------------ forward

def forward(params, batch, cfg: RecsysConfig, mesh, batch_axes):
    """Returns per-example score [B]."""
    emb = embedding_bag(params["tables"], batch["sparse_ids"], mesh, batch_axes)  # [B, F, d]
    b = emb.shape[0]
    if cfg.interaction == "fm":
        wide = embedding_bag(params["wide"], batch["sparse_ids"], mesh, batch_axes)[..., 0].sum(-1)
        fm = fm_interaction(emb)
        deep = _mlp(_collect_mlp(params, "deep"), emb.reshape(b, -1))[:, 0]
        return wide + fm + deep
    if cfg.interaction == "self-attn":
        x = emb
        for i in range(cfg.n_attn_layers):
            a = params["attn"][str(i)]
            x = autoint_layer(x, a["wq"], a["wk"], a["wv"], a["wres"], cfg.n_heads)
        return _mlp(_collect_mlp(params, "head"), x.reshape(b, -1))[:, 0]
    if cfg.interaction == "multi-interest":
        hist = embedding_bag(
            params["tables"], batch["hist_ids"][:, None, :], mesh, batch_axes
        )  # [B, 1, T(dim?)] — hist_ids as one "field" of nnz=T WITHOUT bag-sum:
        raise RuntimeError("MIND uses mind_forward")
    if cfg.interaction == "dot":
        dense = _mlp(_collect_mlp(params, "bot"), batch["dense"], final_act=True)  # [B, d]
        z = jnp.concatenate([dense[:, None, :], emb], 1)
        inter = dot_interaction(z)
        top_in = jnp.concatenate([dense, inter], -1)
        return _mlp(_collect_mlp(params, "top"), top_in)[:, 0]
    raise ValueError(cfg.interaction)


def embedding_seq(tables, ids, mesh, batch_axes, field: int = 0):
    """Sequence lookup WITHOUT bag-sum: ids [B, T] -> [B, T, dim] (MIND hist)."""
    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)
    model_n = mesh.shape.get("model", 1)
    v = tables.shape[1]
    v_loc = v // model_n

    def f(tab_loc, ids_loc):
        v0 = jax.lax.axis_index("model") * v_loc if model_n > 1 else 0
        rel = ids_loc - v0
        ok = (rel >= 0) & (rel < v_loc)
        g = tab_loc[field][jnp.clip(rel, 0, v_loc - 1)]
        g = jnp.where(ok[..., None], g, 0.0)
        if model_n > 1:
            g = jax.lax.psum(g, "model")
        return g

    return shard_map(
        f, mesh=mesh,
        in_specs=(P(None, "model", None), P(bspec, None)),
        out_specs=P(bspec, None, None),
        check_vma=False,
    )(tables, ids)


def mind_forward(params, batch, cfg: RecsysConfig, mesh, batch_axes):
    """MIND: behaviour seq -> K interests; score = max_k <interest, target>."""
    hist = embedding_seq(params["tables"], batch["hist_ids"], mesh, batch_axes)   # [B, T, d]
    caps = capsule_routing(hist, batch["hist_mask"], params["s_bilinear"],
                           cfg.n_interests, cfg.capsule_iters)                     # [B, K, d]
    caps = _mlp(_collect_mlp(params, "head"), caps, final_act=False)
    target = embedding_seq(params["tables"], batch["target_id"][:, None], mesh, batch_axes)[:, 0]
    return jnp.max(jnp.einsum("bkd,bd->bk", caps, target), -1)                     # [B]


# ------------------------------------------------------------ steps

def make_train_step(cfg: RecsysConfig, mesh, tx, batch_axes):
    fwd = mind_forward if cfg.interaction == "multi-interest" else forward

    def train_step(state, batch):
        params, opt_state = state

        def loss_fn(p):
            score = fwd(p, batch, cfg, mesh, batch_axes)
            y = batch["label"]
            return -jnp.mean(y * jax.nn.log_sigmoid(score) + (1 - y) * jax.nn.log_sigmoid(-score))

        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads, gnorm = opt.clip_by_global_norm(grads, 1.0)
        updates, opt_state = tx.update(grads, opt_state, params)
        params = opt.apply_updates(params, updates)
        return (params, opt_state), {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_serve_step(cfg: RecsysConfig, mesh, batch_axes, *, topk: int = 0):
    fwd = mind_forward if cfg.interaction == "multi-interest" else forward

    def serve_step(params, batch):
        score = fwd(params, batch, cfg, mesh, batch_axes)
        if topk:
            vals, idx = jax.lax.top_k(score, topk)
            return vals, idx.astype(jnp.int32)
        return score

    return serve_step


def _batch_specs(cfg: RecsysConfig, b: int, bspec):
    specs = {
        "sparse_ids": sds((b, cfg.n_sparse, cfg.nnz), jnp.int32),
        "label": sds((b,)),
    }
    pspecs = {"sparse_ids": P(bspec, None, None), "label": P(bspec)}
    if cfg.n_dense:
        specs["dense"] = sds((b, cfg.n_dense))
        pspecs["dense"] = P(bspec, None)
    if cfg.interaction == "multi-interest":
        specs.update({
            "hist_ids": sds((b, cfg.hist_len), jnp.int32),
            "hist_mask": sds((b, cfg.hist_len)),
            "target_id": sds((b,), jnp.int32),
        })
        pspecs.update({"hist_ids": P(bspec, None), "hist_mask": P(bspec, None), "target_id": P(bspec)})
    return specs, pspecs


def make_bundle(cfg: RecsysConfig, mesh) -> ModelBundle:
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)
    tx = opt.adamw(opt.cosine_schedule(1e-3, 100, 100_000))

    def step(shape: ShapeSpec) -> StepDef:
        if shape.kind == "rec_train":
            b = shape["batch"]
            specs, pspecs = _batch_specs(cfg, b, bspec)
            return StepDef(fn=make_train_step(cfg, mesh, tx, batch_axes),
                           input_specs=specs, input_pspecs=pspecs, out_pspecs=None)
        if shape.kind == "rec_serve":
            b = shape["batch"]
            specs, pspecs = _batch_specs(cfg, b, bspec)
            return StepDef(fn=make_serve_step(cfg, mesh, batch_axes),
                           input_specs=specs, input_pspecs=pspecs, out_pspecs=None)
        if shape.kind == "retrieval":
            b = shape["n_candidates"]  # score every candidate through the model
            specs, pspecs = _batch_specs(cfg, b, bspec)
            return StepDef(fn=make_serve_step(cfg, mesh, batch_axes, topk=100),
                           input_specs=specs, input_pspecs=pspecs, out_pspecs=None)
        raise ValueError(shape.kind)

    return ModelBundle(
        name=cfg.arch,
        config=cfg,
        init=lambda rng, shape=None: init_params(rng, cfg),
        param_specs=lambda shape=None: param_specs(cfg),
        param_pspecs=lambda shape=None: param_pspecs(cfg, mesh),
        step=step,
        opt_specs=lambda shape=None: adamw_state_specs(param_specs(cfg)),
        opt_pspecs=lambda shape=None: adamw_state_pspecs(param_pspecs(cfg, mesh)),
    )
