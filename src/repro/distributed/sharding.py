"""Logical-axis sharding rules (MaxText-style), resolved to mesh axes.

The production mesh axes are ("pod", "data", "model") — see launch/mesh.py.
Logical axis names annotate every parameter/activation dimension; the rules
below map them to mesh axes. Single-pod meshes simply lack the "pod" axis;
``logical_to_pspec`` drops missing axes automatically.

Scheme (DESIGN.md §5):
  * activations: batch -> ("pod","data"), sequence -> "model" (2D batch-seq
    parallelism; uniform across train / prefill / decode)
  * params: "fsdp" -> "data" (ZeRO-3 via GSPMD all-gather), wide dims
    ("mlp", "heads_flat", "expert", "vocab", "rows") -> "model"
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import PartitionSpec as P

LOGICAL_RULES: dict[str, Optional[str | tuple]] = {
    # activations
    "batch": ("pod", "data"),
    "seq": "model",
    "flat_batch": ("pod", "data", "model"),  # fully flattened (GNN edges, bulk scoring)
    # params
    "fsdp": "data",
    "mlp": "model",
    "heads_flat": "model",     # flattened H*Dh projection output dim
    "expert": "model",
    "vocab": "model",
    "rows": "model",           # embedding-table / partition-store rows
    "stack": None,             # scanned layer axis — never sharded
    "embed": None,
    "kv": None,
    "head_dim": None,
    "none": None,
}


def logical_to_pspec(axes: Sequence[Optional[str]], mesh: jax.sharding.Mesh) -> P:
    """Map logical axis names to a PartitionSpec valid on `mesh` (axes missing
    from the mesh are dropped; None stays unsharded)."""
    mesh_axes = set(mesh.axis_names)
    out = []
    for ax in axes:
        if ax is None:
            out.append(None)
            continue
        rule = LOGICAL_RULES.get(ax, None)
        if rule is None:
            out.append(None)
        elif isinstance(rule, tuple):
            present = tuple(r for r in rule if r in mesh_axes)
            out.append(present if len(present) > 1 else (present[0] if present else None))
        else:
            out.append(rule if rule in mesh_axes else None)
    return P(*out)


def batch_axes(mesh: jax.sharding.Mesh):
    """Mesh axes that shard the batch dimension."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def seq_axis(mesh: jax.sharding.Mesh):
    return "model" if "model" in mesh.axis_names else None


def constraint(x, mesh, *axes):
    """with_sharding_constraint via logical names."""
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, logical_to_pspec(axes, mesh))
    )
