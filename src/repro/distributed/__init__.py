from repro.distributed.sharding import LOGICAL_RULES, logical_to_pspec, batch_axes, seq_axis  # noqa: F401
