from repro.distributed.fault import (  # noqa: F401  (replica-group policies)
    Replica,
    ReplicaFailure,
    ReplicaRouter,
    StragglerMitigator,
)
from repro.distributed.sharding import LOGICAL_RULES, logical_to_pspec, batch_axes, seq_axis  # noqa: F401
