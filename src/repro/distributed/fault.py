"""Failure & straggler simulation harness (serving side).

A 1000+-node serving deployment of LIRA is pod-replicated (DESIGN.md §5):
each pod holds a full index replica; a front-end router spreads query batches.
This module simulates that control plane so the policies are testable without
hardware:

  * ReplicaRouter — power-of-two-choices load balancing over healthy replicas,
    heartbeat-based failure detection, automatic failover and re-queue of
    in-flight batches from a dead replica;
  * StragglerMitigator — hedged requests: if a replica exceeds the p95-based
    hedge deadline, the batch is re-issued to the next-least-loaded replica
    and the first response wins (classic tail-at-scale mitigation).

Training-side fault tolerance (checkpoint/restart, deterministic data replay)
lives in repro.train.trainer + repro.ckpt.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Optional

import numpy as np


@dataclasses.dataclass
class Replica:
    rid: int
    healthy: bool = True
    inflight: int = 0
    served: int = 0
    latency_scale: float = 1.0     # >1 = straggler
    ewma: float = 1.0              # latency EWMA (hedge target selection)


class ReplicaRouter:
    def __init__(self, n_replicas: int, seed: int = 0):
        self.replicas = [Replica(i) for i in range(n_replicas)]
        self.rng = np.random.default_rng(seed)
        self.requeued = 0

    def healthy(self):
        return [r for r in self.replicas if r.healthy]

    def pick(self) -> Replica:
        """Power-of-two-choices on in-flight depth."""
        h = self.healthy()
        if not h:
            raise RuntimeError("no healthy replicas")
        if len(h) == 1:
            return h[0]
        a, b = self.rng.choice(len(h), 2, replace=False)
        return h[a] if h[a].inflight <= h[b].inflight else h[b]

    def mark_failed(self, rid: int) -> int:
        """Heartbeat loss: fail the replica, re-queue its in-flight batches.
        Returns number of batches to replay."""
        r = self.replicas[rid]
        r.healthy = False
        lost = r.inflight
        r.inflight = 0
        self.requeued += lost
        return lost

    def recover(self, rid: int):
        self.replicas[rid].healthy = True

    def dispatch(self, n_batches: int, fail_at: Optional[tuple[int, int]] = None):
        """Simulate dispatching batches; fail_at=(batch_idx, rid) kills that
        replica WITH the batch in flight — the batch is re-queued and served
        by a healthy replica. Returns per-replica served counts (every batch
        is served exactly once)."""
        from collections import deque

        pending = deque(range(n_batches))
        while pending:
            i = pending.popleft()
            if fail_at is not None and i == fail_at[0] and self.replicas[fail_at[1]].healthy:
                victim = self.replicas[fail_at[1]]
                victim.inflight += 1          # batch lands on the doomed node
                self.mark_failed(victim.rid)  # heartbeat loss mid-serve
                pending.appendleft(i)         # replay on a healthy replica
                continue
            r = self.pick()
            r.served += 1
        return {r.rid: r.served for r in self.replicas}


class StragglerMitigator:
    """Hedged requests: if the primary exceeds a robust deadline (3× median —
    median is robust to a slow-node-polluted history), the batch is re-issued
    to the healthy replica with the best latency EWMA and the first response
    wins (tail-at-scale hedging)."""

    def __init__(self, router: ReplicaRouter, hedge_factor: float = 3.0):
        self.router = router
        self.hedge_factor = hedge_factor
        self.latencies: list[float] = []
        self.hedges = 0

    def serve(self, base_latency: float) -> float:
        r = self.router.pick()
        lat = base_latency * r.latency_scale
        if len(self.latencies) >= 20:
            deadline = self.hedge_factor * float(np.median(self.latencies))
            if lat > deadline:
                others = [x for x in self.router.healthy() if x.rid != r.rid]
                if others:
                    r2 = min(others, key=lambda x: x.ewma)
                    lat2 = deadline + base_latency * r2.latency_scale
                    lat = min(lat, lat2)
                    r2.ewma = 0.9 * r2.ewma + 0.1 * (base_latency * r2.latency_scale)
                    self.hedges += 1
        r.ewma = 0.9 * r.ewma + 0.1 * (base_latency * r.latency_scale)
        self.latencies.append(lat)
        r.served += 1
        return lat
