"""Failure & straggler policies for the pod-replicated serving plane.

A 1000+-node serving deployment of LIRA is pod-replicated (DESIGN.md §5):
each pod holds a full index replica; a front-end router spreads query batches.
This module is the shared policy implementation behind
``serving/cluster.py``'s real replica groups — and it remains runnable as a
pure simulation (``dispatch``/``serve``) so the policies stay testable
without hardware:

  * ReplicaRouter — power-of-two-choices load balancing over healthy
    replicas, heartbeat-based failure detection (``check_heartbeats`` against
    an injectable clock), automatic failover and re-queue of in-flight
    batches from a dead replica. ``route(fn)`` drives a REAL dispatch
    callable: a ``ReplicaFailure`` raised mid-serve fails the replica and
    replays the in-flight batch on a healthy sibling, so no batch is lost;
  * StragglerMitigator — hedged requests: if the primary exceeds the robust
    hedge deadline (3× median history), the batch is re-issued to the
    healthy replica with the best latency EWMA and the first response wins
    (classic tail-at-scale mitigation). ``run(fn)`` is the real-dispatch
    form; ``serve(base_latency)`` the synthetic-latency simulation.

The ad-hoc counters (``requeued``, ``hedges``) are kept as cheap mirrors, but
the canonical series live in the obs metrics registry, labeled
``shard=<router name>`` (and ``replica=`` where per-replica):

  * ``lira_failovers_total``     — in-flight batches replayed off dead replicas
  * ``lira_hedges_total``        — hedge requests issued
  * ``lira_hedge_wins_total``    — hedges that beat the primary
  * ``lira_replica_inflight``    — per-replica in-flight gauge
  * ``lira_replica_healthy``     — per-replica liveness gauge (1/0)

Training-side fault tolerance (checkpoint/restart, deterministic data replay)
lives in repro.train.trainer + repro.ckpt.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np

from repro.obs import metrics as obs_metrics


class ReplicaFailure(RuntimeError):
    """Raised by a dispatch callable when its replica dies mid-serve
    (connection loss / heartbeat timeout with the batch in flight). The
    router treats it as a failure event: the replica is failed, its
    in-flight batch re-queued and replayed on a healthy sibling."""


@dataclasses.dataclass
class Replica:
    rid: int
    healthy: bool = True
    inflight: int = 0
    served: int = 0
    latency_scale: float = 1.0     # >1 = straggler
    ewma: float = 1.0              # latency EWMA (hedge target selection)
    last_heartbeat: float = 0.0    # injectable-clock stamp of last liveness


class ReplicaRouter:
    """Routing + failover policy for one replica group.

    ``clock`` is any zero-arg callable returning seconds (``time.monotonic``
    in production, ``repro.utils.clock.FakeClock`` in tests); heartbeats are
    stamped against it. ``metrics`` is an obs registry (None → the
    process-wide default); series are labeled ``shard=<name>`` so several
    groups (one per cluster shard) sharing a registry never mix."""

    def __init__(self, n_replicas: int, seed: int = 0, *,
                 clock: Optional[Callable[[], float]] = None,
                 metrics=None, name: str = "default"):
        self.clock = clock if clock is not None else time.monotonic
        self.metrics = metrics
        self.name = name
        self._lbl = {"shard": name}
        self.replicas = [Replica(i, last_heartbeat=self.clock())
                         for i in range(n_replicas)]
        self.rng = np.random.default_rng(seed)
        self.requeued = 0

    def _m(self) -> obs_metrics.MetricsRegistry:
        return (self.metrics if self.metrics is not None
                else obs_metrics.default_registry())

    def _g_inflight(self):
        return self._m().gauge("lira_replica_inflight",
                               "in-flight batches per replica")

    def _g_healthy(self):
        return self._m().gauge("lira_replica_healthy",
                               "replica liveness (1 healthy, 0 failed)")

    def _c_failovers(self):
        return self._m().counter(
            "lira_failovers_total",
            "in-flight batches replayed off failed replicas")

    def healthy(self):
        return [r for r in self.replicas if r.healthy]

    def pick(self) -> Replica:
        """Power-of-two-choices on in-flight depth."""
        h = self.healthy()
        if not h:
            raise RuntimeError("no healthy replicas")
        if len(h) == 1:
            return h[0]
        a, b = self.rng.choice(len(h), 2, replace=False)
        return h[a] if h[a].inflight <= h[b].inflight else h[b]

    def mark_failed(self, rid: int) -> int:
        """Heartbeat loss: fail the replica, re-queue its in-flight batches.
        Returns number of batches to replay."""
        r = self.replicas[rid]
        r.healthy = False
        lost = r.inflight
        r.inflight = 0
        self.requeued += lost
        self._c_failovers().inc(lost, **self._lbl)
        self._g_inflight().set(0, replica=str(rid), **self._lbl)
        self._g_healthy().set(0, replica=str(rid), **self._lbl)
        return lost

    def recover(self, rid: int):
        r = self.replicas[rid]
        r.healthy = True
        r.last_heartbeat = self.clock()
        self._g_healthy().set(1, replica=str(rid), **self._lbl)

    # ------------------------------------------------------------ heartbeats

    def heartbeat(self, rid: int) -> None:
        """Stamp replica liveness at the injected clock's now (successful
        serves do this implicitly via ``call``)."""
        self.replicas[rid].last_heartbeat = self.clock()

    def check_heartbeats(self, timeout_s: float) -> list[tuple[int, int]]:
        """Fail every healthy replica whose last heartbeat is older than
        ``timeout_s`` — the detection half of failover for replicas that
        stall silently instead of erroring. Returns ``[(rid, lost), ...]``
        for the newly failed (lost = in-flight batches re-queued)."""
        now = self.clock()
        return [(r.rid, self.mark_failed(r.rid)) for r in self.replicas
                if r.healthy and now - r.last_heartbeat > timeout_s]

    # --------------------------------------------------------- real dispatch

    def call(self, r: Replica, fn: Callable[[Replica], object]):
        """Run one dispatch on a specific replica with in-flight accounting:
        ``fn(r)`` executes with the batch counted in flight, so a
        ``ReplicaFailure`` mid-serve re-queues it (``mark_failed`` collects
        in-flight) before re-raising to the caller's replay loop. Success
        stamps the replica's heartbeat."""
        r.inflight += 1
        self._g_inflight().set(r.inflight, replica=str(r.rid), **self._lbl)
        try:
            out = fn(r)
        except ReplicaFailure:
            self.mark_failed(r.rid)
            raise
        r.inflight -= 1
        self._g_inflight().set(r.inflight, replica=str(r.rid), **self._lbl)
        r.served += 1
        r.last_heartbeat = self.clock()
        return out

    def route(self, fn: Callable[[Replica], object]):
        """Failover-transparent dispatch: pick a live replica by
        power-of-two-choices and run ``fn`` on it; on ``ReplicaFailure`` the
        batch (re-queued by ``call``/``mark_failed``) is replayed on the
        remaining healthy replicas. Raises RuntimeError("no healthy
        replicas") only when the whole group is dead. Returns
        ``(fn's result, serving replica)``."""
        while True:
            r = self.pick()
            try:
                return self.call(r, fn), r
            except ReplicaFailure:
                continue  # in-flight batch was re-queued: replay elsewhere

    # ----------------------------------------------------------- simulation

    def dispatch(self, n_batches: int, fail_at: Optional[tuple[int, int]] = None):
        """Simulate dispatching batches; fail_at=(batch_idx, rid) kills that
        replica WITH the batch in flight — the batch is re-queued and served
        by a healthy replica. Returns per-replica served counts (every batch
        is served exactly once)."""
        from collections import deque

        pending = deque(range(n_batches))
        while pending:
            i = pending.popleft()
            if fail_at is not None and i == fail_at[0] and self.replicas[fail_at[1]].healthy:
                victim = self.replicas[fail_at[1]]
                victim.inflight += 1          # batch lands on the doomed node
                self.mark_failed(victim.rid)  # heartbeat loss mid-serve
                pending.appendleft(i)         # replay on a healthy replica
                continue
            r = self.pick()
            r.served += 1
        return {r.rid: r.served for r in self.replicas}


class StragglerMitigator:
    """Hedged requests: if the primary exceeds a robust deadline (3× median —
    median is robust to a slow-node-polluted history), the batch is re-issued
    to the healthy replica with the best latency EWMA and the first response
    wins (tail-at-scale hedging). ``run`` drives real dispatch callables;
    ``serve`` is the synthetic-latency simulation form."""

    def __init__(self, router: ReplicaRouter, hedge_factor: float = 3.0,
                 warmup: int = 20):
        self.router = router
        self.hedge_factor = hedge_factor
        self.warmup = warmup
        self.latencies: list[float] = []
        self.hedges = 0
        self.hedge_wins = 0

    def _c_hedges(self):
        return self.router._m().counter("lira_hedges_total",
                                        "hedge requests issued")

    def _c_hedge_wins(self):
        return self.router._m().counter("lira_hedge_wins_total",
                                        "hedges that beat the primary")

    def deadline(self) -> Optional[float]:
        """Current hedge deadline, or None while the latency history is
        shorter than ``warmup`` (hedging on a cold median would misfire)."""
        if len(self.latencies) < self.warmup:
            return None
        return self.hedge_factor * float(np.median(self.latencies))

    def _hedge_target(self, primary: Replica) -> Optional[Replica]:
        others = [x for x in self.router.healthy() if x.rid != primary.rid]
        return min(others, key=lambda x: x.ewma) if others else None

    # --------------------------------------------------------- real dispatch

    def run(self, fn: Callable[[Replica], tuple]):
        """Hedged real dispatch. ``fn(replica) -> (result, service_s)`` serves
        one batch on one replica and reports its service time; replica
        failures raise ``ReplicaFailure`` (the router's ``route`` replays
        them). When the primary's service exceeds the hedge deadline, the
        batch is re-issued to the best-EWMA healthy sibling: the earlier
        completion (primary at ``service``, hedge at ``deadline + service2``)
        wins and the loser is discounted — with bit-identical replicas only
        latency, never the answer, depends on the winner. Returns
        ``(result, winner replica, effective service_s, hedged)``."""
        (result, lat), r = self.router.route(fn)
        winner, eff, hedged = r, float(lat), False
        deadline = self.deadline()
        if deadline is not None and eff > deadline:
            r2 = self._hedge_target(r)
            if r2 is not None:
                hedged = True
                self.hedges += 1
                self._c_hedges().inc(**self.router._lbl)
                try:
                    res2, lat2 = self.router.call(r2, fn)
                except ReplicaFailure:
                    pass  # hedge died; the primary's answer stands
                else:
                    r2.ewma = 0.9 * r2.ewma + 0.1 * float(lat2)
                    if deadline + float(lat2) < eff:
                        winner, result = r2, res2
                        eff = deadline + float(lat2)
                        self.hedge_wins += 1
                        self._c_hedge_wins().inc(**self.router._lbl)
        r.ewma = 0.9 * r.ewma + 0.1 * float(lat)
        self.latencies.append(eff)
        return result, winner, eff, hedged

    # ----------------------------------------------------------- simulation

    def serve(self, base_latency: float) -> float:
        r = self.router.pick()
        lat = base_latency * r.latency_scale
        deadline = self.deadline()
        if deadline is not None and lat > deadline:
            r2 = self._hedge_target(r)
            if r2 is not None:
                lat2 = deadline + base_latency * r2.latency_scale
                if lat2 < lat:
                    self.hedge_wins += 1
                    self._c_hedge_wins().inc(**self.router._lbl)
                lat = min(lat, lat2)
                r2.ewma = 0.9 * r2.ewma + 0.1 * (base_latency * r2.latency_scale)
                self.hedges += 1
                self._c_hedges().inc(**self.router._lbl)
        r.ewma = 0.9 * r.ewma + 0.1 * (base_latency * r.latency_scale)
        self.latencies.append(lat)
        r.served += 1
        return lat
