"""Atomic, step-numbered checkpointing (fault tolerance substrate).

Protocol (crash-safe at every point):
  1. write all arrays to  <dir>/step_N.tmp/  (one .npy per flattened leaf)
  2. write manifest.json (tree structure + dtypes + step + extra metadata)
  3. fsync, then atomic rename  step_N.tmp -> step_N
  4. update LATEST marker via write-tmp + rename
  5. GC: keep last `keep` checkpoints

``restore()`` returns the latest complete checkpoint; a crash mid-write leaves
only a .tmp directory which is ignored (and cleaned on the next save). On a
real pod each host saves its local shards (`process_index` suffix); in this
container there is one host, but the layout already carries the suffix so the
multi-host path is exercised.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
from typing import Any, Optional

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str | pathlib.Path, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.proc = jax.process_index()

    # ------------------------------------------------------------- save

    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> pathlib.Path:
        leaves, treedef = jax.tree.flatten(tree)
        tmp = self.dir / f"step_{step:010d}.tmp"
        final = self.dir / f"step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        meta = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(leaves),
            "dtypes": [str(np.asarray(l).dtype) for l in leaves],
            "shapes": [list(np.asarray(l).shape) for l in leaves],
            "extra": extra or {},
        }
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            with open(tmp / f"leaf_{i:05d}.p{self.proc}.npy", "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())
        with open(tmp / "manifest.json", "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, final)                      # atomic commit
        self._write_latest(step)
        self._gc()
        return final

    def _write_latest(self, step: int):
        tmp = self.dir / "LATEST.tmp"
        tmp.write_text(str(step))
        os.rename(tmp, self.dir / "LATEST")

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # ------------------------------------------------------------- restore

    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.iterdir():
            if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp"):
                if (p / "manifest.json").exists():
                    out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None):
        """Returns (tree_like_template, step, extra) or (None, None, None)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None, None
        d = self.dir / f"step_{step:010d}"
        meta = json.loads((d / "manifest.json").read_text())
        leaves_t, treedef = jax.tree.flatten(template)
        assert len(leaves_t) == meta["n_leaves"], (
            f"checkpoint has {meta['n_leaves']} leaves, template has {len(leaves_t)}")
        leaves = []
        for i, tl in enumerate(leaves_t):
            arr = np.load(d / f"leaf_{i:05d}.p{self.proc}.npy")
            leaves.append(jax.device_put(arr.astype(np.asarray(tl).dtype) if hasattr(tl, "dtype") else arr))
        return jax.tree.unflatten(treedef, leaves), step, meta["extra"]
