"""Fused L2-distance + running-top-k scan — THE partitioned-ANN hot path.

Given a query tile and a stream of candidate blocks (gathered partition rows),
computes squared-L2 distances on the MXU (||q||² - 2 q·cᵀ + ||c||²) and folds
each block into a running top-k held in VMEM scratch — candidates never round-
trip to HBM as a full [Q, C] distance matrix. This is the TPU-native
replacement for Faiss's scan_codes + heap (DESIGN.md §3).

Tiling:
  grid = (Q_tiles, C_blocks); C is the inner ("arbitrary") dimension so the
  running top-k scratch for a query tile stays resident across the scan.
  Block shapes: q [TQ, d], c [TC, d], distance tile [TQ, TC] — TQ, TC multiples
  of 128 keep the MXU fully fed; d should be padded to a lane multiple by the
  caller (ops.py does this).

VMEM working set per step ≈ TQ·d + TC·d + TQ·TC + 2·TQ·(k+TC) f32
(e.g. TQ=TC=256, d=128, k=128 → ~1.1 MB, well under the ~16 MB/core budget).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._util import NEG_BIG


def _l2_topk_kernel(q_ref, c_ref, cid_ref, od_ref, oi_ref, run_d, run_i, *, k: int, n_cblocks: int):
    """One (q_tile, c_block) grid step."""
    cb = pl.program_id(1)

    @pl.when(cb == 0)
    def _init():
        run_d[...] = jnp.full_like(run_d, NEG_BIG)
        run_i[...] = jnp.full_like(run_i, -1)

    q = q_ref[...].astype(jnp.float32)          # [TQ, d]
    c = c_ref[...].astype(jnp.float32)          # [TC, d]
    cid = cid_ref[...]                          # [TC] int32

    # negated squared L2 so the running reduce is a plain max-top-k
    d2 = (
        2.0 * jax.lax.dot_general(q, c, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        - jnp.sum(q * q, axis=-1, keepdims=True)
        - jnp.sum(c * c, axis=-1)[None, :]
    )  # [TQ, TC] = -dist²
    d2 = jnp.where(cid[None, :] < 0, NEG_BIG, d2)  # mask padded candidates

    merged_d = jnp.concatenate([run_d[...], d2], axis=1)                 # [TQ, k+TC]
    merged_i = jnp.concatenate([run_i[...], jnp.broadcast_to(cid[None, :], d2.shape)], axis=1)
    top_d, pos = jax.lax.top_k(merged_d, k)
    run_d[...] = top_d
    run_i[...] = jnp.take_along_axis(merged_i, pos, axis=1)

    @pl.when(cb == n_cblocks - 1)
    def _flush():
        # back to positive squared distances; slots never filled by a valid
        # candidate flush as inf/-1 exactly like the jnp oracle
        invalid = run_d[...] <= NEG_BIG / 2
        od_ref[...] = jnp.where(invalid, jnp.inf, -run_d[...])
        oi_ref[...] = jnp.where(invalid, -1, run_i[...])


@functools.partial(jax.jit, static_argnames=("k", "tq", "tc", "interpret"))
def l2_topk(
    q: jax.Array,         # [Q, d] — Q multiple of tq
    cands: jax.Array,     # [C, d] — C multiple of tc
    cand_ids: jax.Array,  # [C] int32, -1 = padding
    k: int,
    *,
    tq: int = 256,
    tc: int = 256,
    interpret: bool = True,
):
    qn, d = q.shape
    cn = cands.shape[0]
    assert qn % tq == 0 and cn % tc == 0, (qn, tq, cn, tc)
    n_cblocks = cn // tc
    kernel = functools.partial(_l2_topk_kernel, k=k, n_cblocks=n_cblocks)
    return pl.pallas_call(
        kernel,
        grid=(qn // tq, n_cblocks),
        in_specs=[
            pl.BlockSpec((tq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tc, d), lambda i, j: (j, 0)),
            pl.BlockSpec((tc,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((tq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qn, k), jnp.float32),
            jax.ShapeDtypeStruct((qn, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tq, k), jnp.float32),
            pltpu.VMEM((tq, k), jnp.int32),
        ],
        interpret=interpret,
    )(q, cands, cand_ids)


def _l2_topk_batched_kernel(q_ref, c_ref, cid_ref, od_ref, oi_ref, run_d, run_i,
                            *, k: int, n_cblocks: int):
    """One (bucket, q_tile, c_block) grid step — same running-top-k scheme as
    the flat kernel; the scratch re-initializes per (bucket, q_tile) because the
    c_block axis is innermost."""
    cb = pl.program_id(2)

    @pl.when(cb == 0)
    def _init():
        run_d[...] = jnp.full_like(run_d, NEG_BIG)
        run_i[...] = jnp.full_like(run_i, -1)

    q = q_ref[0].astype(jnp.float32)            # [TQ, d]
    c = c_ref[0].astype(jnp.float32)            # [TC, d]
    cid = cid_ref[0]                            # [TC] int32

    d2 = (
        2.0 * jax.lax.dot_general(q, c, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        - jnp.sum(q * q, axis=-1, keepdims=True)
        - jnp.sum(c * c, axis=-1)[None, :]
    )  # [TQ, TC] = -dist²
    d2 = jnp.where(cid[None, :] < 0, NEG_BIG, d2)

    merged_d = jnp.concatenate([run_d[...], d2], axis=1)
    merged_i = jnp.concatenate([run_i[...], jnp.broadcast_to(cid[None, :], d2.shape)], axis=1)
    top_d, pos = jax.lax.top_k(merged_d, k)
    run_d[...] = top_d
    run_i[...] = jnp.take_along_axis(merged_i, pos, axis=1)

    @pl.when(cb == n_cblocks - 1)
    def _flush():
        invalid = run_d[...] <= NEG_BIG / 2
        od_ref[0] = jnp.where(invalid, jnp.inf, -run_d[...])
        oi_ref[0] = jnp.where(invalid, -1, run_i[...])


@functools.partial(jax.jit, static_argnames=("k", "tq", "tc", "interpret"))
def l2_topk_batched(
    q: jax.Array,         # [B, Q, d] — Q multiple of tq
    cands: jax.Array,     # [B, C, d] — C multiple of tc
    cand_ids: jax.Array,  # [B, C] int32, -1 = padding
    k: int,
    *,
    tq: int = 256,
    tc: int = 256,
    interpret: bool = True,
):
    """Grid-batched l2_topk: scans all B (query-bucket, candidate-set) pairs in
    ONE pallas launch — the serve step's per-partition scan shape."""
    bn, qn, d = q.shape
    cn = cands.shape[1]
    assert qn % tq == 0 and cn % tc == 0, (qn, tq, cn, tc)
    n_cblocks = cn // tc
    kernel = functools.partial(_l2_topk_batched_kernel, k=k, n_cblocks=n_cblocks)
    return pl.pallas_call(
        kernel,
        grid=(bn, qn // tq, n_cblocks),
        in_specs=[
            pl.BlockSpec((1, tq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, tc, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, tc), lambda b, i, j: (b, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, tq, k), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, tq, k), lambda b, i, j: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bn, qn, k), jnp.float32),
            jax.ShapeDtypeStruct((bn, qn, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tq, k), jnp.float32),
            pltpu.VMEM((tq, k), jnp.int32),
        ],
        interpret=interpret,
    )(q, cands, cand_ids)


def _l2_topk_qbuf_kernel(qb_ref, q_hbm, vec_hbm, cid_ref, od_ref, oi_ref,
                         q_s, vbuf, sem_q, sem_vec,
                         *, k: int, tc: int, n_cblocks: int, n_slots: int):
    """One bucket per grid step: scalar-prefetched query-row gather (the
    dispatch-buffer rows land in SMEM ahead of the body, so `.at[qb_ref[b,s]]`
    is a plain dynamic DMA index) followed by double-buffered candidate-block
    streaming into the running top-k — same merge scheme as the grid-batched
    kernel, same arithmetic order, so distances stay bit-identical."""
    b = pl.program_id(0)

    # phase 1: gather this bucket's S query rows from the compact plane
    def gather(s, carry):
        cp = pltpu.make_async_copy(q_hbm.at[qb_ref[b, s]], q_s.at[s], sem_q)
        cp.start()
        cp.wait()
        return carry

    jax.lax.fori_loop(0, n_slots, gather, 0)
    q = q_s[...].astype(jnp.float32)            # [S, d]

    # phase 2: stream candidate blocks through a 2-deep VMEM ring
    def copy_block(j, slot):
        return pltpu.make_async_copy(vec_hbm.at[b, pl.ds(j * tc, tc)],
                                     vbuf.at[slot], sem_vec.at[slot])

    copy_block(0, 0).start()

    def body(j, carry):
        run_d, run_i = carry
        slot = jax.lax.rem(j, 2)

        @pl.when(j + 1 < n_cblocks)
        def _prefetch_next():
            copy_block(j + 1, jax.lax.rem(j + 1, 2)).start()

        copy_block(j, slot).wait()
        c = vbuf[slot].astype(jnp.float32)      # [TC, d]
        cid = cid_ref[0, pl.ds(j * tc, tc)]     # [TC] int32, -1 = padding
        d2 = (
            2.0 * jax.lax.dot_general(q, c, (((1,), (1,)), ((), ())),
                                      preferred_element_type=jnp.float32)
            - jnp.sum(q * q, axis=-1, keepdims=True)
            - jnp.sum(c * c, axis=-1)[None, :]
        )  # [S, TC] = -dist²
        d2 = jnp.where(cid[None, :] < 0, NEG_BIG, d2)
        merged_d = jnp.concatenate([run_d, d2], axis=1)
        merged_i = jnp.concatenate(
            [run_i, jnp.broadcast_to(cid[None, :], d2.shape)], axis=1)
        top_d, pos = jax.lax.top_k(merged_d, k)
        return top_d, jnp.take_along_axis(merged_i, pos, axis=1)

    init = (jnp.full((n_slots, k), NEG_BIG, jnp.float32),
            jnp.full((n_slots, k), -1, jnp.int32))
    run_d, run_i = jax.lax.fori_loop(0, n_cblocks, body, init)
    invalid = run_d <= NEG_BIG / 2
    od_ref[0] = jnp.where(invalid, jnp.inf, -run_d)
    oi_ref[0] = jnp.where(invalid, -1, run_i)


@functools.partial(jax.jit, static_argnames=("k", "tc", "interpret"))
def l2_topk_qbuf(
    q_pad: jax.Array,     # [q_row+1, d] compact queries + sentinel row
    qbuf: jax.Array,      # [B, S] int32 query row per dispatch slot
    cands: jax.Array,     # [B, C, d] — C multiple of tc
    cand_ids: jax.Array,  # [B, C] int32, -1 = padding
    k: int,
    *,
    tc: int = 256,
    interpret: bool = True,
):
    """Dispatch-buffer form of ``l2_topk_batched``: takes the compact
    ``q_pad`` plane plus ``qbuf`` indices instead of a host-expanded
    ``[B, S, d]`` query stack, so the staged operand footprint is
    O(q_row·d) + O(B·S) indices rather than O(B·S·d). Rows for empty slots
    (``qbuf == q_row``) compute against the sentinel query; callers mask
    them out downstream exactly as with the expanded form."""
    bn, n_slots = qbuf.shape
    cn, d = cands.shape[1], cands.shape[2]
    assert cn % tc == 0, (cn, tc)
    n_cblocks = cn // tc
    kernel = functools.partial(_l2_topk_qbuf_kernel, k=k, tc=tc,
                               n_cblocks=n_cblocks, n_slots=n_slots)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bn,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),         # q_pad stays in HBM
            pl.BlockSpec(memory_space=pltpu.ANY),         # cands stay in HBM
            pl.BlockSpec((1, cn), lambda b, qb: (b, 0)),  # cand_ids
        ],
        out_specs=[
            pl.BlockSpec((1, n_slots, k), lambda b, qb: (b, 0, 0)),
            pl.BlockSpec((1, n_slots, k), lambda b, qb: (b, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((n_slots, d), q_pad.dtype),
            pltpu.VMEM((2, tc, d), cands.dtype),
            pltpu.SemaphoreType.DMA(()),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    od, oi = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bn, n_slots, k), jnp.float32),
            jax.ShapeDtypeStruct((bn, n_slots, k), jnp.int32),
        ],
        interpret=interpret,
    )(qbuf, q_pad, cands, cand_ids)
    return od, oi
