"""Fused L2-distance + running-top-k scan — THE partitioned-ANN hot path.

Given a query tile and a stream of candidate blocks (gathered partition rows),
computes squared-L2 distances on the MXU (||q||² - 2 q·cᵀ + ||c||²) and folds
each block into a running top-k held in VMEM scratch — candidates never round-
trip to HBM as a full [Q, C] distance matrix. This is the TPU-native
replacement for Faiss's scan_codes + heap (DESIGN.md §3).

Tiling:
  grid = (Q_tiles, C_blocks); C is the inner ("arbitrary") dimension so the
  running top-k scratch for a query tile stays resident across the scan.
  Block shapes: q [TQ, d], c [TC, d], distance tile [TQ, TC] — TQ, TC multiples
  of 128 keep the MXU fully fed; d should be padded to a lane multiple by the
  caller (ops.py does this).

VMEM working set per step ≈ TQ·d + TC·d + TQ·TC + 2·TQ·(k+TC) f32
(e.g. TQ=TC=256, d=128, k=128 → ~1.1 MB, well under the ~16 MB/core budget).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._util import NEG_BIG


def _l2_topk_kernel(q_ref, c_ref, cid_ref, od_ref, oi_ref, run_d, run_i, *, k: int, n_cblocks: int):
    """One (q_tile, c_block) grid step."""
    cb = pl.program_id(1)

    @pl.when(cb == 0)
    def _init():
        run_d[...] = jnp.full_like(run_d, NEG_BIG)
        run_i[...] = jnp.full_like(run_i, -1)

    q = q_ref[...].astype(jnp.float32)          # [TQ, d]
    c = c_ref[...].astype(jnp.float32)          # [TC, d]
    cid = cid_ref[...]                          # [TC] int32

    # negated squared L2 so the running reduce is a plain max-top-k
    d2 = (
        2.0 * jax.lax.dot_general(q, c, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        - jnp.sum(q * q, axis=-1, keepdims=True)
        - jnp.sum(c * c, axis=-1)[None, :]
    )  # [TQ, TC] = -dist²
    d2 = jnp.where(cid[None, :] < 0, NEG_BIG, d2)  # mask padded candidates

    merged_d = jnp.concatenate([run_d[...], d2], axis=1)                 # [TQ, k+TC]
    merged_i = jnp.concatenate([run_i[...], jnp.broadcast_to(cid[None, :], d2.shape)], axis=1)
    top_d, pos = jax.lax.top_k(merged_d, k)
    run_d[...] = top_d
    run_i[...] = jnp.take_along_axis(merged_i, pos, axis=1)

    @pl.when(cb == n_cblocks - 1)
    def _flush():
        # back to positive squared distances; slots never filled by a valid
        # candidate flush as inf/-1 exactly like the jnp oracle
        invalid = run_d[...] <= NEG_BIG / 2
        od_ref[...] = jnp.where(invalid, jnp.inf, -run_d[...])
        oi_ref[...] = jnp.where(invalid, -1, run_i[...])


@functools.partial(jax.jit, static_argnames=("k", "tq", "tc", "interpret"))
def l2_topk(
    q: jax.Array,         # [Q, d] — Q multiple of tq
    cands: jax.Array,     # [C, d] — C multiple of tc
    cand_ids: jax.Array,  # [C] int32, -1 = padding
    k: int,
    *,
    tq: int = 256,
    tc: int = 256,
    interpret: bool = True,
):
    qn, d = q.shape
    cn = cands.shape[0]
    assert qn % tq == 0 and cn % tc == 0, (qn, tq, cn, tc)
    n_cblocks = cn // tc
    kernel = functools.partial(_l2_topk_kernel, k=k, n_cblocks=n_cblocks)
    return pl.pallas_call(
        kernel,
        grid=(qn // tq, n_cblocks),
        in_specs=[
            pl.BlockSpec((tq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tc, d), lambda i, j: (j, 0)),
            pl.BlockSpec((tc,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((tq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qn, k), jnp.float32),
            jax.ShapeDtypeStruct((qn, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tq, k), jnp.float32),
            pltpu.VMEM((tq, k), jnp.int32),
        ],
        interpret=interpret,
    )(q, cands, cand_ids)


def _l2_topk_batched_kernel(q_ref, c_ref, cid_ref, od_ref, oi_ref, run_d, run_i,
                            *, k: int, n_cblocks: int):
    """One (bucket, q_tile, c_block) grid step — same running-top-k scheme as
    the flat kernel; the scratch re-initializes per (bucket, q_tile) because the
    c_block axis is innermost."""
    cb = pl.program_id(2)

    @pl.when(cb == 0)
    def _init():
        run_d[...] = jnp.full_like(run_d, NEG_BIG)
        run_i[...] = jnp.full_like(run_i, -1)

    q = q_ref[0].astype(jnp.float32)            # [TQ, d]
    c = c_ref[0].astype(jnp.float32)            # [TC, d]
    cid = cid_ref[0]                            # [TC] int32

    d2 = (
        2.0 * jax.lax.dot_general(q, c, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        - jnp.sum(q * q, axis=-1, keepdims=True)
        - jnp.sum(c * c, axis=-1)[None, :]
    )  # [TQ, TC] = -dist²
    d2 = jnp.where(cid[None, :] < 0, NEG_BIG, d2)

    merged_d = jnp.concatenate([run_d[...], d2], axis=1)
    merged_i = jnp.concatenate([run_i[...], jnp.broadcast_to(cid[None, :], d2.shape)], axis=1)
    top_d, pos = jax.lax.top_k(merged_d, k)
    run_d[...] = top_d
    run_i[...] = jnp.take_along_axis(merged_i, pos, axis=1)

    @pl.when(cb == n_cblocks - 1)
    def _flush():
        invalid = run_d[...] <= NEG_BIG / 2
        od_ref[0] = jnp.where(invalid, jnp.inf, -run_d[...])
        oi_ref[0] = jnp.where(invalid, -1, run_i[...])


@functools.partial(jax.jit, static_argnames=("k", "tq", "tc", "interpret"))
def l2_topk_batched(
    q: jax.Array,         # [B, Q, d] — Q multiple of tq
    cands: jax.Array,     # [B, C, d] — C multiple of tc
    cand_ids: jax.Array,  # [B, C] int32, -1 = padding
    k: int,
    *,
    tq: int = 256,
    tc: int = 256,
    interpret: bool = True,
):
    """Grid-batched l2_topk: scans all B (query-bucket, candidate-set) pairs in
    ONE pallas launch — the serve step's per-partition scan shape."""
    bn, qn, d = q.shape
    cn = cands.shape[1]
    assert qn % tq == 0 and cn % tc == 0, (qn, tq, cn, tc)
    n_cblocks = cn // tc
    kernel = functools.partial(_l2_topk_batched_kernel, k=k, n_cblocks=n_cblocks)
    return pl.pallas_call(
        kernel,
        grid=(bn, qn // tq, n_cblocks),
        in_specs=[
            pl.BlockSpec((1, tq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, tc, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, tc), lambda b, i, j: (b, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, tq, k), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, tq, k), lambda b, i, j: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bn, qn, k), jnp.float32),
            jax.ShapeDtypeStruct((bn, qn, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tq, k), jnp.float32),
            pltpu.VMEM((tq, k), jnp.int32),
        ],
        interpret=interpret,
    )(q, cands, cand_ids)
