"""PQ asymmetric-distance (ADC) Pallas kernel.

dist[q, n] = Σ_m LUT[q, m, codes[n, m]] — a gather-accumulate over the per-
query lookup table. On TPU the gather over the ks lane axis is realized as a
one-hot contraction on the MXU (ks ≤ 256 keeps the one-hot tile cheap and
turns random access into a dense dot — the standard TPU adaptation of the
Faiss LUT scan; see DESIGN.md §3).

Tiling: grid = (Q_tiles, N_blocks); LUT tile [TQ, m·ks] stays in VMEM across
the candidate scan, codes stream in as [TN, m] int32 blocks.
VMEM per step ≈ TQ·m·ks + TN·m·ks (one-hot) + TQ·TN f32
(TQ=128, TN=128, m=16, ks=256 → ~4.5 MB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pq_adc_kernel(lut_ref, codes_ref, out_ref, *, ks: int):
    lut = lut_ref[...]        # [TQ, m, ks] f32
    codes = codes_ref[...]    # [TN, m] int32
    onehot = jax.nn.one_hot(codes, ks, dtype=lut.dtype)        # [TN, m, ks]
    # dist[q, n] = Σ_m Σ_k lut[q,m,k]·onehot[n,m,k]  — a dense MXU contraction
    out_ref[...] = jax.lax.dot_general(
        lut.reshape(lut.shape[0], -1),
        onehot.reshape(onehot.shape[0], -1),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("tq", "tn", "interpret"))
def pq_adc(
    lut: jax.Array,    # [Q, m, ks] f32 per-query subspace distance tables
    codes: jax.Array,  # [N, m] int32 PQ codes
    *,
    tq: int = 128,
    tn: int = 128,
    interpret: bool = True,
) -> jax.Array:
    qn, m, ks = lut.shape
    n = codes.shape[0]
    assert qn % tq == 0 and n % tn == 0, (qn, tq, n, tn)
    kernel = functools.partial(_pq_adc_kernel, ks=ks)
    return pl.pallas_call(
        kernel,
        grid=(qn // tq, n // tn),
        in_specs=[
            pl.BlockSpec((tq, m, ks), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((tn, m), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tq, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qn, n), jnp.float32),
        interpret=interpret,
    )(lut, codes.astype(jnp.int32))
