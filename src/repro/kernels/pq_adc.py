"""PQ asymmetric-distance (ADC) Pallas kernels.

dist[q, n] = Σ_m LUT[q, m, codes[n, m]] — a gather-accumulate over the per-
query lookup table. On TPU the gather over the ks lane axis is realized as a
one-hot contraction on the MXU (ks ≤ 256 keeps the one-hot tile cheap and
turns random access into a dense dot — the standard TPU adaptation of the
Faiss LUT scan; see DESIGN.md §3).

Three entry points:
  * ``pq_adc``       — full [Q, N] ADC distance matrix;
  * ``pq_adc_topk``  — fused LUT-scan + running top-k shortlist (the quantized
    serving tier's stage 1): the [Q, N] distance tile never round-trips to
    HBM, only the [Q, k] shortlist survives — same scratch scheme as l2_topk;
  * ``pq_adc_topk_qbuf`` — the batched serve-step form that takes the COMPACT
    ``lut_pad [q_row+1, m, ks]`` plane plus the ``qbuf [b_loc, q_cap]``
    dispatch buffer instead of a pre-expanded ``[b_loc, q_cap, m, ks]`` LUT
    stack. ``qbuf`` rides as a scalar-prefetch operand
    (``pltpu.PrefetchScalarGridSpec``), so each bucket's grid step DMAs only
    its own slots' LUT rows from HBM into VMEM — the host never materializes
    the ≈nprobe·q_cap_factor× amplified operand the old path staged — and the
    codes stream through a double-buffered in-kernel pipeline.

Tiling: grid = (Q_tiles, N_blocks); LUT tile [TQ, m·ks] stays in VMEM across
the candidate scan, codes stream in as [TN, m] int blocks.
VMEM per step ≈ TQ·m·ks + TN·m·ks (one-hot) + TQ·TN f32
(TQ=128, TN=128, m=16, ks=256 → ~4.5 MB).

Both wrappers pad Q/N to tile multiples internally (and strip the padding from
outputs), and default ``interpret`` from the backend exactly like
repro.kernels.ops: native compile on TPU, interpreter elsewhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._util import NEG_BIG, pad_dim, pad_rows as _pad_rows


def _detect_interpret(interpret: bool | None) -> bool:
    return jax.default_backend() != "tpu" if interpret is None else interpret


def _pq_adc_kernel(lut_ref, codes_ref, out_ref, *, ks: int):
    lut = lut_ref[...]        # [TQ, m, ks] f32
    codes = codes_ref[...]    # [TN, m] int32
    onehot = jax.nn.one_hot(codes, ks, dtype=lut.dtype)        # [TN, m, ks]
    # dist[q, n] = Σ_m Σ_k lut[q,m,k]·onehot[n,m,k]  — a dense MXU contraction
    out_ref[...] = jax.lax.dot_general(
        lut.reshape(lut.shape[0], -1),
        onehot.reshape(onehot.shape[0], -1),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("tq", "tn", "interpret"))
def pq_adc(
    lut: jax.Array,    # [Q, m, ks] f32 per-query subspace distance tables
    codes: jax.Array,  # [N, m] integer PQ codes (uint8/uint16/int32)
    *,
    tq: int = 128,
    tn: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    qn, m, ks = lut.shape
    n = codes.shape[0]
    interpret = _detect_interpret(interpret)
    tq = min(tq, max(8, qn))
    tn = min(tn, max(8, n))
    lp = _pad_rows(lut, tq, 0.0)
    cp = _pad_rows(codes.astype(jnp.int32), tn, 0)
    kernel = functools.partial(_pq_adc_kernel, ks=ks)
    out = pl.pallas_call(
        kernel,
        grid=(lp.shape[0] // tq, cp.shape[0] // tn),
        in_specs=[
            pl.BlockSpec((tq, m, ks), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((tn, m), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tq, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((lp.shape[0], cp.shape[0]), jnp.float32),
        interpret=interpret,
    )(lp, cp)
    return out[:qn, :n]


def _pq_adc_topk_kernel(lut_ref, codes_ref, cid_ref, coff_ref, qoff_ref,
                        od_ref, oi_ref, run_d, run_i,
                        *, k: int, ks: int, n_nblocks: int):
    nb = pl.program_id(1)

    @pl.when(nb == 0)
    def _init():
        run_d[...] = jnp.full_like(run_d, NEG_BIG)
        run_i[...] = jnp.full_like(run_i, -1)

    lut = lut_ref[...]        # [TQ, m, ks] f32
    codes = codes_ref[...]    # [TN, m] int32
    cid = cid_ref[...]        # [TN] int32, -1 = padding
    coff = coff_ref[...]      # [TN] f32 per-candidate offset (residual cterm)
    qoff = qoff_ref[...]      # [TQ] f32 per-query offset (residual ‖c‖²−2qc)
    onehot = jax.nn.one_hot(codes, ks, dtype=lut.dtype)
    d = jax.lax.dot_general(
        lut.reshape(lut.shape[0], -1),
        onehot.reshape(onehot.shape[0], -1),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [TQ, TN]
    d = d + qoff[:, None] + coff[None, :]
    negd = jnp.where(cid[None, :] < 0, NEG_BIG, -d)
    merged_d = jnp.concatenate([run_d[...], negd], axis=1)               # [TQ, k+TN]
    merged_i = jnp.concatenate(
        [run_i[...], jnp.broadcast_to(cid[None, :], negd.shape)], axis=1)
    top_d, pos = jax.lax.top_k(merged_d, k)
    run_d[...] = top_d
    run_i[...] = jnp.take_along_axis(merged_i, pos, axis=1)

    @pl.when(nb == n_nblocks - 1)
    def _flush():
        invalid = run_d[...] <= NEG_BIG / 2
        od_ref[...] = jnp.where(invalid, jnp.inf, -run_d[...])
        oi_ref[...] = jnp.where(invalid, -1, run_i[...])


@functools.partial(jax.jit, static_argnames=("k", "tq", "tn", "interpret"))
def pq_adc_topk(
    lut: jax.Array,       # [Q, m, ks] f32 per-query subspace distance tables
    codes: jax.Array,     # [N, m] integer PQ codes
    cand_ids: jax.Array,  # [N] int32, -1 = padding
    k: int,
    *,
    cand_off: jax.Array | None = None,  # [N] f32 added per candidate
    q_off: jax.Array | None = None,     # [Q] f32 added per query
    tq: int = 128,
    tn: int = 128,
    interpret: bool | None = None,
):
    """Fused ADC scan + running top-k: ([Q, k] dists asc, [Q, k] ids).

    The optional offsets implement residual PQ (core.pq residual identity):
    ``cand_off`` carries the per-slot cross term 2⟨c, r̂⟩ — it re-ranks the
    shortlist — while ``q_off`` carries the per-query ‖c‖²−2⟨q, c⟩ scalar so
    the returned distances equal exact L2 to the reconstruction."""
    qn, m, ks = lut.shape
    n = codes.shape[0]
    interpret = _detect_interpret(interpret)
    tq = min(tq, max(8, qn))
    tn = min(tn, max(8, n))
    lp = _pad_rows(lut, tq, 0.0)
    cp = _pad_rows(codes.astype(jnp.int32), tn, 0)
    ip = _pad_rows(cand_ids.astype(jnp.int32), tn, -1)
    if cand_off is None:
        cand_off = jnp.zeros((n,), jnp.float32)
    if q_off is None:
        q_off = jnp.zeros((qn,), jnp.float32)
    cop = _pad_rows(cand_off.astype(jnp.float32), tn, 0.0)
    qop = _pad_rows(q_off.astype(jnp.float32), tq, 0.0)
    n_nblocks = cp.shape[0] // tn
    kernel = functools.partial(_pq_adc_topk_kernel, k=k, ks=ks, n_nblocks=n_nblocks)
    od, oi = pl.pallas_call(
        kernel,
        grid=(lp.shape[0] // tq, n_nblocks),
        in_specs=[
            pl.BlockSpec((tq, m, ks), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((tn, m), lambda i, j: (j, 0)),
            pl.BlockSpec((tn,), lambda i, j: (j,)),
            pl.BlockSpec((tn,), lambda i, j: (j,)),
            pl.BlockSpec((tq,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((tq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((lp.shape[0], k), jnp.float32),
            jax.ShapeDtypeStruct((lp.shape[0], k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tq, k), jnp.float32),
            pltpu.VMEM((tq, k), jnp.int32),
        ],
        interpret=interpret,
    )(lp, cp, ip, cop, qop)
    return od[:qn], oi[:qn]


def _pq_adc_topk_batched_kernel(lut_ref, codes_ref, cid_ref, coff_ref, qoff_ref,
                                od_ref, oi_ref, run_d, run_i,
                                *, k: int, ks: int, n_nblocks: int):
    """One (bucket, q_tile, n_block) grid step; scratch re-initializes per
    (bucket, q_tile) because the candidate-block axis is innermost."""
    nb = pl.program_id(2)

    @pl.when(nb == 0)
    def _init():
        run_d[...] = jnp.full_like(run_d, NEG_BIG)
        run_i[...] = jnp.full_like(run_i, -1)

    lut = lut_ref[0]          # [TQ, m, ks] f32
    codes = codes_ref[0]      # [TN, m] int32
    cid = cid_ref[0]          # [TN] int32, -1 = padding
    coff = coff_ref[0]        # [TN] f32
    qoff = qoff_ref[0]        # [TQ] f32
    onehot = jax.nn.one_hot(codes, ks, dtype=lut.dtype)
    d = jax.lax.dot_general(
        lut.reshape(lut.shape[0], -1),
        onehot.reshape(onehot.shape[0], -1),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [TQ, TN]
    d = d + qoff[:, None] + coff[None, :]
    negd = jnp.where(cid[None, :] < 0, NEG_BIG, -d)
    merged_d = jnp.concatenate([run_d[...], negd], axis=1)
    merged_i = jnp.concatenate(
        [run_i[...], jnp.broadcast_to(cid[None, :], negd.shape)], axis=1)
    top_d, pos = jax.lax.top_k(merged_d, k)
    run_d[...] = top_d
    run_i[...] = jnp.take_along_axis(merged_i, pos, axis=1)

    @pl.when(nb == n_nblocks - 1)
    def _flush():
        invalid = run_d[...] <= NEG_BIG / 2
        od_ref[0] = jnp.where(invalid, jnp.inf, -run_d[...])
        oi_ref[0] = jnp.where(invalid, -1, run_i[...])


@functools.partial(jax.jit, static_argnames=("k", "tq", "tn", "interpret"))
def pq_adc_topk_batched(
    lut: jax.Array,       # [B, Q, m, ks] per-bucket per-query LUTs
    codes: jax.Array,     # [B, N, m] integer PQ codes
    cand_ids: jax.Array,  # [B, N] int32, -1 = padding
    k: int,
    *,
    cand_off: jax.Array | None = None,  # [B, N] f32 added per candidate
    q_off: jax.Array | None = None,     # [B, Q] f32 added per query
    tq: int = 128,
    tn: int = 128,
    interpret: bool | None = None,
):
    """Grid-batched pq_adc_topk: all B (query-bucket, code-block) pairs in ONE
    pallas launch — the quantized serve step's per-partition shortlist shape.
    Offsets carry the residual-PQ corrections exactly like the flat kernel."""
    bn, qn, m, ks = lut.shape
    n = codes.shape[1]
    interpret = _detect_interpret(interpret)
    tq = min(tq, max(8, qn))
    tn = min(tn, max(8, n))
    lp = pad_dim(lut, 1, tq, 0.0)
    cp = pad_dim(codes.astype(jnp.int32), 1, tn, 0)
    ip = pad_dim(cand_ids.astype(jnp.int32), 1, tn, -1)
    if cand_off is None:
        cand_off = jnp.zeros((bn, n), jnp.float32)
    if q_off is None:
        q_off = jnp.zeros((bn, qn), jnp.float32)
    cop = pad_dim(cand_off.astype(jnp.float32), 1, tn, 0.0)
    qop = pad_dim(q_off.astype(jnp.float32), 1, tq, 0.0)
    n_nblocks = cp.shape[1] // tn
    kernel = functools.partial(_pq_adc_topk_batched_kernel, k=k, ks=ks,
                               n_nblocks=n_nblocks)
    od, oi = pl.pallas_call(
        kernel,
        grid=(bn, lp.shape[1] // tq, n_nblocks),
        in_specs=[
            pl.BlockSpec((1, tq, m, ks), lambda b, i, j: (b, i, 0, 0)),
            pl.BlockSpec((1, tn, m), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, tn), lambda b, i, j: (b, j)),
            pl.BlockSpec((1, tn), lambda b, i, j: (b, j)),
            pl.BlockSpec((1, tq), lambda b, i, j: (b, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, tq, k), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, tq, k), lambda b, i, j: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bn, lp.shape[1], k), jnp.float32),
            jax.ShapeDtypeStruct((bn, lp.shape[1], k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tq, k), jnp.float32),
            pltpu.VMEM((tq, k), jnp.int32),
        ],
        interpret=interpret,
    )(lp, cp, ip, cop, qop)
    return od[:, :qn], oi[:, :qn]


def _pq_adc_topk_qbuf_kernel(qb_ref, lut_hbm, codes_hbm, cid_ref, coff_ref,
                             qoff_ref, od_ref, oi_ref, lut_s, cbuf,
                             sem_lut, sem_codes,
                             *, k: int, ks: int, tn: int, n_nblocks: int,
                             n_slots: int):
    """One bucket per grid step. Two-phase body:

    1. scalar-prefetched LUT gather — ``qb_ref`` (SMEM) names each dispatch
       slot's query row; the rows are DMA'd one by one from the compact
       ``lut_pad`` plane in HBM into the ``lut_s`` VMEM scratch. Empty slots
       (``q_row``) fetch the zero sentinel row.
    2. double-buffered candidate streaming — code blocks of ``tn`` slots are
       DMA'd into the 2-deep ``cbuf`` ring; block j+1's copy is in flight
       while block j feeds the one-hot MXU contraction and the running
       top-k merge (carried through the fori_loop, no cross-step scratch).
    """
    b = pl.program_id(0)

    def gather(s, carry):
        cp = pltpu.make_async_copy(lut_hbm.at[qb_ref[b, s]], lut_s.at[s],
                                   sem_lut)
        cp.start()
        cp.wait()
        return carry

    jax.lax.fori_loop(0, n_slots, gather, 0)
    lut = lut_s[...].reshape(n_slots, -1)       # [S, m·ks] f32
    qoff = qoff_ref[0]                          # [S] f32

    def copy_block(j, slot):
        return pltpu.make_async_copy(codes_hbm.at[b, pl.ds(j * tn, tn)],
                                     cbuf.at[slot], sem_codes.at[slot])

    copy_block(0, 0).start()

    def body(j, carry):
        run_d, run_i = carry
        slot = jax.lax.rem(j, 2)

        @pl.when(j + 1 < n_nblocks)
        def _prefetch_next():
            copy_block(j + 1, jax.lax.rem(j + 1, 2)).start()

        copy_block(j, slot).wait()
        codes = cbuf[slot]                      # [tn, m] int32
        cid = cid_ref[0, pl.ds(j * tn, tn)]     # [tn] int32, -1 = padding
        coff = coff_ref[0, pl.ds(j * tn, tn)]   # [tn] f32
        onehot = jax.nn.one_hot(codes, ks, dtype=lut_s.dtype)
        d = jax.lax.dot_general(
            lut, onehot.reshape(onehot.shape[0], -1),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [S, tn]
        d = d + qoff[:, None] + coff[None, :]
        negd = jnp.where(cid[None, :] < 0, NEG_BIG, -d)
        merged_d = jnp.concatenate([run_d, negd], axis=1)
        merged_i = jnp.concatenate(
            [run_i, jnp.broadcast_to(cid[None, :], negd.shape)], axis=1)
        top_d, pos = jax.lax.top_k(merged_d, k)
        return top_d, jnp.take_along_axis(merged_i, pos, axis=1)

    init = (jnp.full((n_slots, k), NEG_BIG, jnp.float32),
            jnp.full((n_slots, k), -1, jnp.int32))
    run_d, run_i = jax.lax.fori_loop(0, n_nblocks, body, init)
    invalid = run_d <= NEG_BIG / 2
    od_ref[0] = jnp.where(invalid, jnp.inf, -run_d)
    oi_ref[0] = jnp.where(invalid, -1, run_i)


@functools.partial(jax.jit, static_argnames=("k", "tn", "interpret"))
def pq_adc_topk_qbuf(
    lut_pad: jax.Array,   # [q_row+1, m, ks] compact LUTs + zero sentinel row
    qbuf: jax.Array,      # [B, S] int32 query row per dispatch slot
    codes: jax.Array,     # [B, N, m] int32 PQ codes (N multiple of tn)
    cand_ids: jax.Array,  # [B, N] int32, -1 = padding
    k: int,
    *,
    cand_off: jax.Array,  # [B, N] f32 residual cterm plane (zeros when unused)
    q_off: jax.Array,     # [B, S] f32 per-slot residual offset (zeros when unused)
    tn: int = 128,
    interpret: bool | None = None,
):
    """Scalar-prefetch-gathered, streaming form of ``pq_adc_topk_batched``.

    Staged operand footprint is O(q_row·m·ks) + O(B·S) indices — independent
    of dispatch fan-out — instead of the O(B·S·m·ks) HBM stack the dense
    batched kernel needs its caller to gather. Rows for empty slots
    (``qbuf == q_row``) hold garbage; callers drop them, exactly like the
    serve step's scatter. VMEM holds one bucket's gathered LUT rows
    (S·m·ks·4 bytes) — S is the dispatch q_cap, small by construction.
    """
    bn, n_slots = qbuf.shape
    _, m, ks = lut_pad.shape
    n = codes.shape[1]
    assert n % tn == 0, (n, tn)
    interpret = _detect_interpret(interpret)
    n_nblocks = n // tn
    kernel = functools.partial(_pq_adc_topk_qbuf_kernel, k=k, ks=ks, tn=tn,
                               n_nblocks=n_nblocks, n_slots=n_slots)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bn,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),            # lut_pad (HBM)
            pl.BlockSpec(memory_space=pltpu.ANY),            # codes (HBM)
            pl.BlockSpec((1, n), lambda b, qb: (b, 0)),      # cand_ids
            pl.BlockSpec((1, n), lambda b, qb: (b, 0)),      # cand_off
            pl.BlockSpec((1, n_slots), lambda b, qb: (b, 0)),  # q_off
        ],
        out_specs=[
            pl.BlockSpec((1, n_slots, k), lambda b, qb: (b, 0, 0)),
            pl.BlockSpec((1, n_slots, k), lambda b, qb: (b, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((n_slots, m, ks), jnp.float32),  # gathered LUT rows
            pltpu.VMEM((2, tn, m), jnp.int32),          # code stream ring
            pltpu.SemaphoreType.DMA(()),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    od, oi = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bn, n_slots, k), jnp.float32),
            jax.ShapeDtypeStruct((bn, n_slots, k), jnp.int32),
        ],
        interpret=interpret,
    )(qbuf, lut_pad, codes, cand_ids, cand_off, q_off)
    return od, oi
