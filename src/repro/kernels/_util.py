"""Shared kernel-side helpers. ops.py imports every kernel module, so these
live below both layers to avoid import cycles."""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Sentinel for negated-distance running top-k scratch: far below any real
# -dist² so masked/uninitialized slots can never be selected.
NEG_BIG = -1e30


def pad_rows(a: jax.Array, mult: int, fill) -> jax.Array:
    """Pad axis 0 up to a multiple of ``mult`` with ``fill``."""
    pad = (-a.shape[0]) % mult
    if pad == 0:
        return a
    return jnp.concatenate([a, jnp.full((pad, *a.shape[1:]), fill, a.dtype)], axis=0)
