"""Shared kernel-side helpers. ops.py imports every kernel module, so these
live below both layers to avoid import cycles."""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Sentinel for negated-distance running top-k scratch: far below any real
# -dist² so masked/uninitialized slots can never be selected.
NEG_BIG = -1e30


def pad_dim(a: jax.Array, axis: int, mult: int, fill) -> jax.Array:
    """Pad ``axis`` up to a multiple of ``mult`` with ``fill`` (batched kernels
    pad the per-bucket axes; axis 0 stays the bucket count)."""
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    shape = list(a.shape)
    shape[axis] = pad
    return jnp.concatenate([a, jnp.full(shape, fill, a.dtype)], axis=axis)


def pad_rows(a: jax.Array, mult: int, fill) -> jax.Array:
    """Pad axis 0 up to a multiple of ``mult`` with ``fill``."""
    return pad_dim(a, 0, mult, fill)
