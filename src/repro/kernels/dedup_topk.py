"""Replica-aware dedup'd merge: exact global top-k over candidate pools.

LIRA's learned redundancy (paper §3.3) stores replicas of boundary points in
several partitions under the SAME id, so every merge of per-partition top-k
pools must collapse duplicate ids down to their best distance before taking
the global top-k. The host evaluation engine used to do this with per-query
Python set-loops; this kernel is the vectorized primitive that replaces them
(and plugs the serving engine's missing dedup).

Algorithm (sort-based, no hash tables — TPU/XLA friendly):
  1. remap invalid entries (id < 0 padding, non-finite distance = masked-out
     partition) to an id sentinel that sorts last;
  2. sort each row by (id, dist) lexicographically — a bitonic network here,
     two stable argsorts in the jnp reference (ref.dedup_topk_ref);
  3. first-occurrence mask: after the sort every duplicate id is adjacent and
     the best (smallest-distance) copy comes first; kill the rest;
  4. top-k by distance over the survivors.

Grid: (Q_tiles,) — the pool axis stays fully resident in VMEM so the bitonic
network runs on-chip per query tile. Pool width must be a power of two
(ops.py pads). VMEM per step ≈ 2·TQ·P·4 B (TQ=8, P=8192 → 512 KiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

PAD_ID = -1            # matches repro.core.partitions.PAD_ID
BIG = 1e30             # finite distance sentinel (inf arithmetic is unsafe on VPU)
ID_SENTINEL = 2**30    # id sentinel: sorts after every real id


def dedup_topk_np(dists: np.ndarray, ids: np.ndarray, k: int):
    """Numpy twin of ref.dedup_topk_ref for host-side callers (the evaluation
    engine), where numpy sorts are ~20× faster than XLA:CPU's.

    One sort instead of two: pack (id, dist) into a single uint64 key — the
    high 32 bits are the id, the low 32 the IEEE-754 total-order image of the
    float32 distance (sign bit set for non-negative floats, bitwise-NOT for
    negative ones — a monotone uint32 map incl. ±0/inf/nan). Sorting the key
    groups ids with the best distance first, exactly like the lexicographic
    bitonic network in the Pallas kernel.
    """
    q, p = dists.shape
    d = np.ascontiguousarray(dists, dtype=np.float32)
    ids = np.asarray(ids, np.int32)
    valid = (ids >= 0) & np.isfinite(d)
    d_s = np.where(valid, d, np.inf)
    ids_s = np.where(valid, ids, ID_SENTINEL)
    u = np.ascontiguousarray(d_s).view(np.uint32)
    du = np.where(u & 0x80000000, ~u, u | 0x80000000).astype(np.uint64)
    key = (ids_s.astype(np.uint64) << np.uint64(32)) | du
    order = np.argsort(key, axis=1)
    k2 = np.take_along_axis(key, order, 1)
    i2 = np.take_along_axis(ids_s, order, 1)
    d2 = np.take_along_axis(d_s, order, 1)
    first = np.concatenate([np.ones((q, 1), bool), i2[:, 1:] != i2[:, :-1]], axis=1)
    keep = first & (i2 != ID_SENTINEL)
    d3 = np.where(keep, d2, np.inf)
    # final selection orders by (dist, id) — swap the key halves so distance
    # leads and ids break exact-distance ties deterministically (matches the
    # jnp ref / bitonic kernel, which inherit this from the grouped sort)
    fkey = np.where(keep, (k2 << np.uint64(32)) | (k2 >> np.uint64(32)),
                    np.uint64(0xFFFFFFFFFFFFFFFF))
    kk = min(k, p)
    if kk < p:
        part = np.argpartition(fkey, kk - 1, axis=1)[:, :kk]
        fkey = np.take_along_axis(fkey, part, 1)
        d3 = np.take_along_axis(d3, part, 1)
        i2 = np.take_along_axis(i2, part, 1)
    o3 = np.argsort(fkey, axis=1)
    out_d = np.full((q, k), np.inf, np.float32)
    out_i = np.full((q, k), PAD_ID, np.int32)
    out_d[:, :kk] = np.take_along_axis(d3, o3, 1)
    oi = np.take_along_axis(i2, o3, 1)
    out_i[:, :kk] = np.where(np.isfinite(out_d[:, :kk]), oi, PAD_ID)
    return out_d, out_i


def _lex_le(id_a, d_a, id_b, d_b):
    """Lexicographic (id, dist) <=."""
    return (id_a < id_b) | ((id_a == id_b) & (d_a <= d_b))


def _bitonic_sort_by_id_dist(ids, d):
    """Ascending (id, dist) bitonic sort along the last axis (power-of-two P).

    The compare-exchange partner (index XOR 2^t) is materialized by reshaping
    to [..., P/(2^(t+1)), 2, 2^t] and swapping the middle halves — no gathers.
    Static Python loops: the O(log² P) network unrolls at trace time.
    """
    q, p = ids.shape
    n_stage = p.bit_length() - 1
    for s in range(1, n_stage + 1):          # merge blocks of size 2^s
        for t in range(s - 1, -1, -1):       # partner distance 2^t
            j = 1 << t
            i4 = ids.reshape(q, p // (2 * j), 2, j)
            d4 = d.reshape(q, p // (2 * j), 2, j)
            id_lo, id_hi = i4[:, :, 0, :], i4[:, :, 1, :]
            d_lo, d_hi = d4[:, :, 0, :], d4[:, :, 1, :]
            # ascending iff bit s of the flat index is 0; the flat index is
            # blk·2^(t+1) + h·2^t + w, so bit s == bit (s-t-1) of blk
            blk = jax.lax.broadcasted_iota(jnp.int32, id_lo.shape, 1)
            asc = ((blk >> (s - t - 1)) & 1) == 0
            keep = _lex_le(id_lo, d_lo, id_hi, d_hi) == asc
            ids = jnp.stack(
                [jnp.where(keep, id_lo, id_hi), jnp.where(keep, id_hi, id_lo)], axis=2
            ).reshape(q, p)
            d = jnp.stack(
                [jnp.where(keep, d_lo, d_hi), jnp.where(keep, d_hi, d_lo)], axis=2
            ).reshape(q, p)
    return ids, d


def _dedup_topk_kernel(d_ref, i_ref, od_ref, oi_ref, *, k: int):
    d = d_ref[...].astype(jnp.float32)
    ids = i_ref[...]
    invalid = (ids < 0) | ~(d < BIG)          # padding, masked-out (inf), or nan
    ids = jnp.where(invalid, ID_SENTINEL, ids)
    d = jnp.where(invalid, BIG, d)
    ids, d = _bitonic_sort_by_id_dist(ids, d)
    # adjacent-duplicate kill: the first copy of each id carries its best dist
    prev = jnp.concatenate([jnp.full((ids.shape[0], 1), -2, ids.dtype), ids[:, :-1]], axis=1)
    d = jnp.where((ids == prev) | (ids == ID_SENTINEL), BIG, d)
    neg, pos = jax.lax.top_k(-d, k)
    od = -neg
    good = od < BIG
    od_ref[...] = jnp.where(good, od, jnp.inf)
    oi_ref[...] = jnp.where(good, jnp.take_along_axis(ids, pos, axis=1), PAD_ID)


@functools.partial(jax.jit, static_argnames=("k", "tq", "interpret"))
def dedup_topk(
    dists: jax.Array,   # [Q, P] f32 — Q multiple of tq, P a power of two
    ids: jax.Array,     # [Q, P] i32, <0 = padding
    k: int,
    *,
    tq: int = 8,
    interpret: bool = True,
):
    qn, p = dists.shape
    assert qn % tq == 0 and p & (p - 1) == 0, (qn, tq, p)
    assert 0 < k <= p, (k, p)
    kernel = functools.partial(_dedup_topk_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid=(qn // tq,),
        in_specs=[
            pl.BlockSpec((tq, p), lambda i: (i, 0)),
            pl.BlockSpec((tq, p), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tq, k), lambda i: (i, 0)),
            pl.BlockSpec((tq, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qn, k), jnp.float32),
            jax.ShapeDtypeStruct((qn, k), jnp.int32),
        ],
        interpret=interpret,
    )(dists, ids)
