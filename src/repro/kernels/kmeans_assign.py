"""Fused K-Means assignment kernel: distance + running argmin over centroid blocks.

assign[n] = argmin_b ||x_n − c_b||², min_d2[n] = the minimum. The full [N, B]
distance matrix is never materialized in HBM: each grid step computes a
[TN, TB] tile on the MXU and folds it into running (min, argmin) VMEM scratch.

Used by index construction (repro.core.kmeans with use_kernel=True) — at 50M+
points the assignment pass dominates K-Means cost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BIG = 1e30


def _assign_kernel(x_ref, c_ref, oa_ref, od_ref, run_d, run_i, *, tb: int, n_bblocks: int):
    bb = pl.program_id(1)

    @pl.when(bb == 0)
    def _init():
        run_d[...] = jnp.full_like(run_d, BIG)
        run_i[...] = jnp.zeros_like(run_i)

    x = x_ref[...].astype(jnp.float32)   # [TN, d]
    c = c_ref[...].astype(jnp.float32)   # [TB, d]
    d2 = (
        jnp.sum(x * x, axis=-1, keepdims=True)
        - 2.0 * jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        + jnp.sum(c * c, axis=-1)[None, :]
    )  # [TN, TB]
    blk_min = jnp.min(d2, axis=1)
    blk_arg = jnp.argmin(d2, axis=1).astype(jnp.int32) + bb * tb
    better = blk_min < run_d[...]
    run_d[...] = jnp.where(better, blk_min, run_d[...])
    run_i[...] = jnp.where(better, blk_arg, run_i[...])

    @pl.when(bb == n_bblocks - 1)
    def _flush():
        oa_ref[...] = run_i[...]
        od_ref[...] = run_d[...]


@functools.partial(jax.jit, static_argnames=("tn", "tb", "interpret"))
def kmeans_assign(
    x: jax.Array,          # [N, d] — N multiple of tn
    centroids: jax.Array,  # [B, d] — B multiple of tb
    *,
    tn: int = 512,
    tb: int = 128,
    interpret: bool = True,
):
    n, d = x.shape
    b = centroids.shape[0]
    assert n % tn == 0 and b % tb == 0, (n, tn, b, tb)
    n_bblocks = b // tb
    kernel = functools.partial(_assign_kernel, tb=tb, n_bblocks=n_bblocks)
    assign, mind = pl.pallas_call(
        kernel,
        grid=(n // tn, n_bblocks),
        in_specs=[
            pl.BlockSpec((tn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tb, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tn,), lambda i, j: (i,)),
            pl.BlockSpec((tn,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tn,), jnp.float32),
            pltpu.VMEM((tn,), jnp.int32),
        ],
        interpret=interpret,
    )(x, centroids)
    return assign, mind
