"""Pallas TPU kernels for the ANN scoring hot path.

  l2_topk       — fused gather-score-topk partition scan (serving hot path)
  dedup_topk    — replica-aware merge: bitonic (id, dist) sort + first-
                  occurrence mask + top-k (redundancy dedup, paper §3.3)
  pq_adc        — PQ LUT scan as one-hot MXU contraction (IVFPQ)
  pq_adc_topk   — fused LUT scan + running top-k shortlist (quantized tier
                  stage 1: the [Q, N] ADC tile never leaves VMEM); optional
                  per-candidate/per-query offset operands carry the residual
                  PQ correction terms (core/pq.py residual ADC identity)
  kmeans_assign — fused distance+argmin (index build at 50M+ points)

Each kernel: <name>.py (pl.pallas_call + BlockSpec), oracle in ref.py,
jit'd public wrapper with padding + impl dispatch in ops.py.
"""
