"""Jit'd public wrappers around the Pallas kernels.

On this container (CPU) kernels run in interpret mode for validation; the
jnp reference path (`impl="ref"`) is the fast CPU fallback used by benches.
On a real TPU backend, `impl="pallas"` compiles the kernels natively.

All wrappers pad inputs to tile multiples and strip padding from outputs, so
callers never worry about alignment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels import dedup_topk as _dd
from repro.kernels import l2_topk as _l2
from repro.kernels import pq_adc as _adc
from repro.kernels import kmeans_assign as _km


from repro.kernels import autotune as _autotune
from repro.kernels._util import pad_dim as _pad_dim, pad_rows as _pad_rows


def default_impl() -> str:
    """One backend-selection policy for every dispatch layer (incl.
    serving/scan.py): fused kernels on TPU, jnp reference elsewhere."""
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def l2_topk(q, cands, cand_ids, k: int, *, impl: str | None = None, tq: int = 256, tc: int = 256):
    """Top-k nearest candidates per query. Handles arbitrary Q/C via padding."""
    impl = impl or default_impl()
    if impl == "ref":
        return _ref.l2_topk_ref(q, cands, cand_ids, k)
    interpret = impl == "interpret" or jax.default_backend() != "tpu"
    qn = q.shape[0]
    tq_eff = min(tq, max(8, qn))
    qp = _pad_rows(q, tq_eff, 0.0)
    cp = _pad_rows(cands, tc, 0.0)
    ip = _pad_rows(cand_ids.astype(jnp.int32), tc, -1)
    k_eff = min(k, cp.shape[0])
    d, i = _l2.l2_topk(qp, cp, ip, k_eff, tq=tq_eff, tc=min(tc, cp.shape[0]), interpret=interpret)
    d, i = d[:qn], i[:qn]
    if k_eff < k:  # degenerate pools: inf/-1 fill matches the ref oracle
        d = jnp.concatenate([d, jnp.full((qn, k - k_eff), jnp.inf, d.dtype)], axis=1)
        i = jnp.concatenate([i, jnp.full((qn, k - k_eff), -1, i.dtype)], axis=1)
    return d, i


def l2_topk_batched(q, cands, cand_ids, k: int, *, impl: str | None = None,
                    tq: int = 256, tc: int = 256):
    """Grid-batched top-k scan: [B, Q, d] query buckets vs [B, C, d] candidate
    sets → ([B, Q, k], [B, Q, k]) in one kernel launch (the serve step's
    per-partition scan shape). Pads Q/C to tile multiples internally."""
    impl = impl or default_impl()
    if impl == "ref":
        return _ref.l2_topk_batched_ref(q, cands, cand_ids, k)
    interpret = impl == "interpret" or jax.default_backend() != "tpu"
    _, qn, _ = q.shape
    cn = cands.shape[1]
    tq_eff = min(tq, max(8, qn))
    tc_eff = min(tc, max(8, cn))
    qp = _pad_dim(q, 1, tq_eff, 0.0)
    cp = _pad_dim(cands, 1, tc_eff, 0.0)
    ip = _pad_dim(cand_ids.astype(jnp.int32), 1, tc_eff, -1)
    d, i = _l2.l2_topk_batched(qp, cp, ip, k, tq=tq_eff, tc=tc_eff,
                               interpret=interpret)
    return d[:, :qn], i[:, :qn]


def l2_topk_qbuf(q_pad, qbuf, cands, cand_ids, k: int, *,
                 impl: str | None = None, tc: int | None = None):
    """Dispatch-buffer top-k scan: compact ``q_pad`` [q_row+1, d] + ``qbuf``
    [B, S] indices vs [B, C, d] candidate sets → ([B, S, k], [B, S, k]).
    Replaces the host-side ``q_pad[qbuf]`` expansion — the kernel gathers each
    bucket's rows itself via scalar prefetch. ``tc=None`` consults the
    measured-sweep autotune cache (keyed on the store shape C/d/k)."""
    impl = impl or default_impl()
    qbuf = qbuf.astype(jnp.int32)
    if impl == "ref":
        return _ref.l2_topk_qbuf_ref(q_pad, qbuf, cands, cand_ids, k)
    interpret = impl == "interpret" or jax.default_backend() != "tpu"
    cn, d = cands.shape[1], cands.shape[2]
    if tc is None:
        tc = _autotune.lookup(_autotune.l2_key(cn, d, k))
    tc_eff = min(tc, max(8, cn))
    cp = _pad_dim(cands, 1, tc_eff, 0.0)
    ip = _pad_dim(cand_ids.astype(jnp.int32), 1, tc_eff, -1)
    return _l2.l2_topk_qbuf(q_pad, qbuf, cp, ip, k, tc=tc_eff,
                            interpret=interpret)


def pq_adc_topk_qbuf(lut_pad, qbuf, codes, cand_ids, k: int, *, cand_off=None,
                     q_off=None, impl: str | None = None, tn: int | None = None):
    """Dispatch-buffer fused ADC shortlist: compact ``lut_pad`` [q_row+1, m, ks]
    + ``qbuf`` [B, S] indices vs [B, N, m] code sets → ([B, S, k], [B, S, k]),
    threading the residual ``cand_off`` [B, N] / ``q_off`` [B, S] offsets.
    Replaces the host-side ``lut_pad[qbuf]`` expansion (the O(B·S·m·ks)
    amplification); the kernel gathers each bucket's LUT rows via scalar
    prefetch. ``tn=None`` consults the autotune cache (store shape N/m/ks/k)."""
    impl = impl or default_impl()
    qbuf = qbuf.astype(jnp.int32)
    if impl == "ref":
        return _ref.pq_adc_topk_qbuf_ref(lut_pad, qbuf, codes, cand_ids, k,
                                         cand_off=cand_off, q_off=q_off)
    interpret = impl == "interpret" or jax.default_backend() != "tpu"
    bn, n_slots = qbuf.shape
    nn, m = codes.shape[1], codes.shape[2]
    ks = lut_pad.shape[2]
    if tn is None:
        tn = _autotune.lookup(_autotune.pq_adc_key(nn, m, ks, k))
    tn_eff = min(tn, max(8, nn))
    cp = _pad_dim(codes.astype(jnp.int32), 1, tn_eff, 0)
    ip = _pad_dim(cand_ids.astype(jnp.int32), 1, tn_eff, -1)
    if cand_off is None:
        cand_off = jnp.zeros((bn, nn), jnp.float32)
    if q_off is None:
        q_off = jnp.zeros((bn, n_slots), jnp.float32)
    cop = _pad_dim(cand_off.astype(jnp.float32), 1, tn_eff, 0.0)
    return _adc.pq_adc_topk_qbuf(lut_pad, qbuf, cp, ip, k, cand_off=cop,
                                 q_off=q_off.astype(jnp.float32), tn=tn_eff,
                                 interpret=interpret)


def pq_adc_topk_batched(lut, codes, cand_ids, k: int, *, cand_off=None,
                        q_off=None, impl: str | None = None,
                        tq: int = 128, tn: int = 128):
    """Grid-batched fused ADC shortlist: [B, Q, m, ks] LUT buckets vs [B, N, m]
    code sets → ([B, Q, k], [B, Q, k]) in one launch, threading the residual
    ``cand_off`` [B, N] / ``q_off`` [B, Q] offset operands."""
    impl = impl or default_impl()
    if impl == "ref":
        return _ref.pq_adc_topk_batched_ref(lut, codes, cand_ids, k,
                                            cand_off=cand_off, q_off=q_off)
    return _adc.pq_adc_topk_batched(lut, codes, cand_ids, k, cand_off=cand_off,
                                    q_off=q_off, tq=tq, tn=tn,
                                    interpret=True if impl == "interpret" else None)


def dedup_topk(dists, ids, k: int, *, impl: str | None = None, tq: int = 8):
    """Replica-aware merge: collapse duplicate ids to their best distance, then
    exact global top-k. Handles arbitrary Q/P via row + power-of-two padding."""
    impl = impl or default_impl()
    if impl == "ref":
        return _ref.dedup_topk_ref(dists, ids, k)
    interpret = impl == "interpret" or jax.default_backend() != "tpu"
    qn, p = dists.shape
    p2 = max(2, 1 << (max(p, k) - 1).bit_length())
    dists = dists.astype(jnp.float32)
    ids = ids.astype(jnp.int32)
    if p2 > p:  # pad the pool with invalid entries
        dists = jnp.concatenate([dists, jnp.full((qn, p2 - p), jnp.inf, jnp.float32)], axis=1)
        ids = jnp.concatenate([ids, jnp.full((qn, p2 - p), -1, jnp.int32)], axis=1)
    tq_eff = min(tq, max(8, qn))
    dp = _pad_rows(dists, tq_eff, jnp.inf)
    ip = _pad_rows(ids, tq_eff, -1)
    d, i = _dd.dedup_topk(dp, ip, k, tq=tq_eff, interpret=interpret)
    return d[:qn], i[:qn]


def pq_adc(lut, codes, *, impl: str | None = None, tq: int = 128, tn: int = 128):
    """ADC distances [Q, N] from per-query LUTs and PQ codes."""
    impl = impl or default_impl()
    if impl == "ref":
        return _ref.pq_adc_ref(lut, codes)
    # interpret=None defers to the kernel's own backend detection (one policy)
    return _adc.pq_adc(lut, codes, tq=tq, tn=tn,
                       interpret=True if impl == "interpret" else None)


def pq_adc_topk(lut, codes, cand_ids, k: int, *, cand_off=None, q_off=None,
                impl: str | None = None, tq: int = 128, tn: int = 128):
    """Fused ADC scan + top-k shortlist: the quantized tier's stage 1.
    Returns ([Q, k] ascending dists inf-padded, [Q, k] ids -1-padded); the
    kernel's NEG_BIG-initialized scratch handles k > N pools natively.
    ``cand_off`` [N] / ``q_off`` [Q] are the residual-PQ offset terms
    (core.pq residual identity): cand_off re-ranks, q_off shifts distances."""
    impl = impl or default_impl()
    if impl == "ref":
        return _ref.pq_adc_topk_ref(lut, codes, cand_ids, k,
                                    cand_off=cand_off, q_off=q_off)
    return _adc.pq_adc_topk(lut, codes, cand_ids, k, cand_off=cand_off,
                            q_off=q_off, tq=tq, tn=tn,
                            interpret=True if impl == "interpret" else None)


def kmeans_assign(x, centroids, *, impl: str | None = None, tn: int = 512, tb: int = 128):
    """(argmin centroid, min sq-dist) per point."""
    impl = impl or default_impl()
    if impl == "ref":
        return _ref.kmeans_assign_ref(x, centroids)
    interpret = impl == "interpret" or jax.default_backend() != "tpu"
    n, b = x.shape[0], centroids.shape[0]
    tn_eff = min(tn, max(8, n))
    tb_eff = min(tb, b)
    xp = _pad_rows(x, tn_eff, 0.0)
    # pad centroids with far-away rows so they never win the argmin
    cp = _pad_rows(centroids, tb_eff, 1e6)
    a, d = _km.kmeans_assign(xp, cp, tn=tn_eff, tb=tb_eff, interpret=interpret)
    return a[:n], d[:n]
