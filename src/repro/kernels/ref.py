"""Pure-jnp oracles for every Pallas kernel (per-kernel allclose tests sweep
shapes/dtypes against these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def l2_topk_ref(q: jax.Array, cands: jax.Array, cand_ids: jax.Array, k: int):
    """[Q,d] x [C,d] -> (top-k sq dists [Q,k], ids [Q,k]); cand_ids<0 = padding."""
    q = q.astype(jnp.float32)
    c = cands.astype(jnp.float32)
    d2 = (
        jnp.sum(q * q, -1, keepdims=True)
        - 2.0 * q @ c.T
        + jnp.sum(c * c, -1)[None, :]
    )
    ids = cand_ids.astype(jnp.int32)
    d2 = jnp.where(ids[None, :] < 0, jnp.inf, d2)
    if d2.shape[1] < k:  # degenerate pools: pad so top_k is well-defined
        pad = k - d2.shape[1]
        d2 = jnp.concatenate([d2, jnp.full((d2.shape[0], pad), jnp.inf, d2.dtype)], axis=1)
        ids = jnp.concatenate([ids, jnp.full((pad,), -1, jnp.int32)])
    neg, pos = jax.lax.top_k(-d2, k)
    out_d = -neg
    return out_d, jnp.where(jnp.isfinite(out_d), ids[pos], -1)


def pq_adc_ref(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """dist[q, n] = sum_m lut[q, m, codes[n, m]]."""
    codes_t = codes.astype(jnp.int32).T  # [m, N]

    def per_query(lq):  # [m, ks]
        return jnp.sum(jnp.take_along_axis(lq, codes_t, axis=1), axis=0)

    return jax.vmap(per_query)(lut.astype(jnp.float32))


def pq_adc_topk_ref(lut: jax.Array, codes: jax.Array, cand_ids: jax.Array, k: int,
                    cand_off: jax.Array | None = None,
                    q_off: jax.Array | None = None):
    """Fused ADC + top-k oracle: ([Q,k] asc dists inf-padded, [Q,k] ids -1-padded).
    Optional residual-PQ offsets (see core.pq): ``cand_off`` [N] adds the
    per-slot cross term, ``q_off`` [Q] the per-query partition scalar."""
    d = pq_adc_ref(lut, codes)
    if cand_off is not None:
        d = d + cand_off.astype(jnp.float32)[None, :]
    if q_off is not None:
        d = d + q_off.astype(jnp.float32)[:, None]
    ids = cand_ids.astype(jnp.int32)
    d = jnp.where(ids[None, :] < 0, jnp.inf, d)
    if d.shape[1] < k:  # degenerate pools: pad so top_k is well-defined
        pad = k - d.shape[1]
        d = jnp.concatenate([d, jnp.full((d.shape[0], pad), jnp.inf, d.dtype)], axis=1)
        ids = jnp.concatenate([ids, jnp.full((pad,), -1, jnp.int32)])
    neg, pos = jax.lax.top_k(-d, k)
    out_d = -neg
    out_i = jnp.where(jnp.isfinite(out_d), ids[pos], -1)
    return out_d, out_i


def l2_topk_batched_ref(q: jax.Array, cands: jax.Array, cand_ids: jax.Array, k: int):
    """[B,Q,d] x [B,C,d] -> ([B,Q,k], [B,Q,k]): l2_topk_ref vmapped over the
    leading bucket axis (the batched kernels' oracle)."""
    return jax.vmap(lambda qb, cb, ib: l2_topk_ref(qb, cb, ib, k))(q, cands, cand_ids)


def pq_adc_topk_batched_ref(lut: jax.Array, codes: jax.Array, cand_ids: jax.Array,
                            k: int, cand_off: jax.Array | None = None,
                            q_off: jax.Array | None = None):
    """[B,Q,m,ks] x [B,N,m] -> ([B,Q,k], [B,Q,k]): pq_adc_topk_ref vmapped over
    the leading bucket axis, incl. the residual-PQ offset operands."""
    if cand_off is None:
        cand_off = jnp.zeros(codes.shape[:2], jnp.float32)
    if q_off is None:
        q_off = jnp.zeros(lut.shape[:2], jnp.float32)
    return jax.vmap(
        lambda lb, cb, ib, cob, qob: pq_adc_topk_ref(lb, cb, ib, k, cand_off=cob, q_off=qob)
    )(lut, codes, cand_ids, cand_off, q_off)


def l2_topk_qbuf_ref(q_pad: jax.Array, qbuf: jax.Array, cands: jax.Array,
                     cand_ids: jax.Array, k: int):
    """Oracle for the scalar-prefetch entry point: materializes the dense
    ``[B,S,d]`` gather the kernel avoids, then defers to the batched oracle —
    the old host-side-expansion semantics, kept as the parity reference."""
    return l2_topk_batched_ref(q_pad[qbuf], cands, cand_ids, k)


def pq_adc_topk_qbuf_ref(lut_pad: jax.Array, qbuf: jax.Array, codes: jax.Array,
                         cand_ids: jax.Array, k: int,
                         cand_off: jax.Array | None = None,
                         q_off: jax.Array | None = None):
    """Oracle for the scalar-prefetch ADC entry point: dense ``lut_pad[qbuf]``
    gather + batched oracle (old host-side-expansion semantics)."""
    return pq_adc_topk_batched_ref(lut_pad[qbuf], codes, cand_ids, k,
                                   cand_off=cand_off, q_off=q_off)


def dedup_topk_ref(dists: jax.Array, ids: jax.Array, k: int):
    """Exact replica-aware merge of a candidate pool (jnp oracle).

    [Q,P] dists (non-finite = masked/invalid) × [Q,P] ids (<0 = padding) →
    ([Q,k] ascending dists inf-padded, [Q,k] ids -1-padded). Each id appears at
    most once per row, carrying its smallest distance. Same sort-based scheme
    as the Pallas kernel: sort by dist, stable-sort by id (so per-id groups
    stay distance-ordered), kill adjacent duplicates, top-k the survivors.
    """
    q, p = dists.shape
    d = dists.astype(jnp.float32)
    ids = ids.astype(jnp.int32)
    if p < k:  # degenerate pools: pad so top_k is well-defined
        d = jnp.concatenate([d, jnp.full((q, k - p), jnp.inf, jnp.float32)], axis=1)
        ids = jnp.concatenate([ids, jnp.full((q, k - p), -1, jnp.int32)], axis=1)
        p = k
    sentinel = jnp.int32(2**30)
    valid = (ids >= 0) & jnp.isfinite(d)
    ids = jnp.where(valid, ids, sentinel)
    d = jnp.where(valid, d, jnp.inf)
    o1 = jnp.argsort(d, axis=1)
    i1 = jnp.take_along_axis(ids, o1, 1)
    d1 = jnp.take_along_axis(d, o1, 1)
    o2 = jnp.argsort(i1, axis=1, stable=True)
    i2 = jnp.take_along_axis(i1, o2, 1)
    d2 = jnp.take_along_axis(d1, o2, 1)
    first = jnp.concatenate([jnp.ones((q, 1), bool), i2[:, 1:] != i2[:, :-1]], axis=1)
    d3 = jnp.where(first & (i2 != sentinel), d2, jnp.inf)
    neg, pos = jax.lax.top_k(-d3, k)
    out_d = -neg
    out_i = jnp.where(jnp.isfinite(out_d), jnp.take_along_axis(i2, pos, 1), -1)
    return out_d, out_i


def kmeans_assign_ref(x: jax.Array, centroids: jax.Array):
    x = x.astype(jnp.float32)
    c = centroids.astype(jnp.float32)
    d2 = (
        jnp.sum(x * x, -1, keepdims=True)
        - 2.0 * x @ c.T
        + jnp.sum(c * c, -1)[None, :]
    )
    return jnp.argmin(d2, -1).astype(jnp.int32), jnp.min(d2, -1)
