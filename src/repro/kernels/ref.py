"""Pure-jnp oracles for every Pallas kernel (per-kernel allclose tests sweep
shapes/dtypes against these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def l2_topk_ref(q: jax.Array, cands: jax.Array, cand_ids: jax.Array, k: int):
    """[Q,d] x [C,d] -> (top-k sq dists [Q,k], ids [Q,k]); cand_ids<0 = padding."""
    q = q.astype(jnp.float32)
    c = cands.astype(jnp.float32)
    d2 = (
        jnp.sum(q * q, -1, keepdims=True)
        - 2.0 * q @ c.T
        + jnp.sum(c * c, -1)[None, :]
    )
    d2 = jnp.where(cand_ids[None, :] < 0, jnp.inf, d2)
    neg, pos = jax.lax.top_k(-d2, k)
    return -neg, cand_ids[pos]


def pq_adc_ref(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """dist[q, n] = sum_m lut[q, m, codes[n, m]]."""
    codes_t = codes.astype(jnp.int32).T  # [m, N]

    def per_query(lq):  # [m, ks]
        return jnp.sum(jnp.take_along_axis(lq, codes_t, axis=1), axis=0)

    return jax.vmap(per_query)(lut.astype(jnp.float32))


def kmeans_assign_ref(x: jax.Array, centroids: jax.Array):
    x = x.astype(jnp.float32)
    c = centroids.astype(jnp.float32)
    d2 = (
        jnp.sum(x * x, -1, keepdims=True)
        - 2.0 * x @ c.T
        + jnp.sum(c * c, -1)[None, :]
    )
    return jnp.argmin(d2, -1).astype(jnp.int32), jnp.min(d2, -1)
