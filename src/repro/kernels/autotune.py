"""Measured-sweep tile autotuner for the scalar-prefetch scan kernels.

The qbuf entry points (`ops.l2_topk_qbuf`, `ops.pq_adc_topk_qbuf`) stream
candidate blocks through a double-buffered VMEM ring; the block size (`tc` /
`tn`) trades DMA granularity against compute-tile shape and is the one knob
whose best value depends on the store, not the batch. This module runs a
small measured sweep over candidate tiles on synthetic operands shaped like
the store, caches the winner per *store shape* (kernel, cap, operand dims, k
— deliberately NOT b_loc/q_cap, which vary per pow2 batch bucket), and keeps
an auditable record of every sweep for the bench JSON.

Timing happens eagerly (outside jit) — benches and engines call
``autotune_*`` up front; the ops wrappers then do a Python-level cache lookup
at trace time, so compiled steps bake the tile in. A step compiled before a
sweep keeps its old tile until re-trace (documented, acceptable: tiles only
change when the store shape does).
"""
from __future__ import annotations

import time

import jax
import numpy as np

_CACHE: dict[tuple, int] = {}
_RECORDS: list[dict] = []

_DEFAULT_TN = 128   # pq_adc_topk_qbuf code-block tile when no sweep has run
_DEFAULT_TC = 256   # l2_topk_qbuf vector-block tile when no sweep has run


def clear() -> None:
    """Drop all cached tiles and sweep records (tests use this)."""
    _CACHE.clear()
    _RECORDS.clear()


def records() -> list[dict]:
    """Auditable sweep log: one dict per autotune call (persisted by benches)."""
    return list(_RECORDS)


def pq_adc_key(cap: int, m: int, ks: int, k: int) -> tuple:
    return ("pq_adc_topk_qbuf", int(cap), int(m), int(ks), int(k))


def l2_key(cap: int, d: int, k: int) -> tuple:
    return ("l2_topk_qbuf", int(cap), int(d), int(k))


def lookup(key: tuple, default: int | None = None) -> int:
    """Trace-time tile lookup; falls back to the kernel's static default."""
    if key in _CACHE:
        return _CACHE[key]
    if default is not None:
        return default
    return _DEFAULT_TN if key and key[0] == "pq_adc_topk_qbuf" else _DEFAULT_TC


def _time_call(fn, *args, repeats: int = 3, **kwargs) -> float:
    """Median wall time of ``fn`` (jit'd; first call compiles, excluded)."""
    out = fn(*args, **kwargs)
    jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _sweep(key: tuple, run_one, candidates: tuple[int, ...]) -> int:
    if key in _CACHE:
        _RECORDS.append({"key": list(key), "cached": True,
                         "tile": _CACHE[key], "timings_s": None})
        return _CACHE[key]
    timings = {int(t): _time_call(run_one, t) for t in candidates}
    best = min(timings, key=timings.get)
    _CACHE[key] = best
    _RECORDS.append({"key": list(key), "cached": False, "tile": best,
                     "timings_s": {str(t): v for t, v in timings.items()}})
    return best


def autotune_pq_adc_qbuf(cap: int, m: int, ks: int, k: int, *,
                         impl: str = "interpret",
                         candidates: tuple[int, ...] = (64, 128, 256),
                         b_loc: int = 4, q_cap: int = 8,
                         q_row: int = 16, seed: int = 0) -> int:
    """Sweep ``tn`` for the ADC qbuf kernel on synthetic operands shaped like
    the store (cap/m/ks/k); returns the winning tile and caches it."""
    from repro.kernels import ops  # local import: ops imports this module

    key = pq_adc_key(cap, m, ks, k)
    if key in _CACHE:
        return _sweep(key, None, candidates)
    rng = np.random.default_rng(seed)
    lut_pad = jax.numpy.asarray(
        rng.standard_normal((q_row + 1, m, ks)).astype(np.float32))
    qbuf = jax.numpy.asarray(
        rng.integers(0, q_row + 1, (b_loc, q_cap)).astype(np.int32))
    codes = jax.numpy.asarray(
        rng.integers(0, ks, (b_loc, cap, m)).astype(np.int32))
    cand_ids = jax.numpy.asarray(
        rng.integers(0, 10 * cap, (b_loc, cap)).astype(np.int32))

    def run_one(tn):
        return ops.pq_adc_topk_qbuf(lut_pad, qbuf, codes, cand_ids, k,
                                    impl=impl, tn=int(tn))

    return _sweep(key, run_one, tuple(int(t) for t in candidates))


def autotune_l2_qbuf(cap: int, d: int, k: int, *,
                     impl: str = "interpret",
                     candidates: tuple[int, ...] = (128, 256, 512),
                     b_loc: int = 4, q_cap: int = 8,
                     q_row: int = 16, seed: int = 0) -> int:
    """Sweep ``tc`` for the f32 qbuf kernel on synthetic operands shaped like
    the store (cap/d/k); returns the winning tile and caches it."""
    from repro.kernels import ops

    key = l2_key(cap, d, k)
    if key in _CACHE:
        return _sweep(key, None, candidates)
    rng = np.random.default_rng(seed)
    q_pad = jax.numpy.asarray(
        rng.standard_normal((q_row + 1, d)).astype(np.float32))
    qbuf = jax.numpy.asarray(
        rng.integers(0, q_row + 1, (b_loc, q_cap)).astype(np.int32))
    cands = jax.numpy.asarray(
        rng.standard_normal((b_loc, cap, d)).astype(np.float32))
    cand_ids = jax.numpy.asarray(
        rng.integers(0, 10 * cap, (b_loc, cap)).astype(np.int32))

    def run_one(tc):
        return ops.l2_topk_qbuf(q_pad, qbuf, cands, cand_ids, k,
                                impl=impl, tc=int(tc))

    return _sweep(key, run_one, tuple(int(t) for t in candidates))
