from repro.data.synthetic import make_vector_dataset, VectorDataset  # noqa: F401
