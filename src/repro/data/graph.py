"""Graph batch construction for DimeNet: padding, triplet alignment, sampling.

Triplets are sorted so that triplet t lives on the mesh shard owning edge
ji[t] (DESIGN.md §5 — makes the triplet→edge segment_sum collective-free);
`trip_ji_local` holds the LOCAL edge offset within that shard.
"""
from __future__ import annotations

import numpy as np

from repro.data.synthetic import build_triplets, make_geometric_graph


def _pad_to(n, mult):
    return int(-(-n // mult) * mult)


def build_graph_batch(
    rng: np.ndarray,
    *,
    n_nodes: int,
    n_edges: int,
    d_feat: int,
    triplet_mult: int,
    n_graphs: int = 1,
    n_shards: int = 1,
    avg_degree: int | None = None,
):
    """Returns a dict matching dimenet.make_bundle input_specs (real data)."""
    host = np.random.default_rng(rng if isinstance(rng, int) else 0)
    total_nodes = n_nodes * n_graphs
    deg = avg_degree or max(1, n_edges // max(n_nodes, 1))

    pos_l, ei_l = [], []
    for g in range(n_graphs):
        p, _, ei = make_geometric_graph(host, n_nodes, deg, d_feat=1)
        pos_l.append(p)
        ei_l.append(ei + g * n_nodes)
    pos = np.concatenate(pos_l)
    ei = np.concatenate(ei_l, axis=1)
    # trim/pad edges to the target count
    e_target = _pad_to(n_edges * n_graphs, max(n_shards, 256) if total_nodes > 64 else n_shards)
    if ei.shape[1] > e_target:
        ei = ei[:, :e_target]
    src, dst = ei
    e_real = ei.shape[1]

    kj, ji = build_triplets(ei, max_triplets=triplet_mult * e_real)
    t_target = _pad_to(max(len(kj), 1), max(n_shards, 256) if total_nodes > 64 else n_shards)
    t_target = max(t_target, _pad_to(triplet_mult * e_real, n_shards))

    # pad edges
    e_pad = _pad_to(e_real, n_shards)
    src_p = np.zeros(e_pad, np.int32); src_p[:e_real] = src
    dst_p = np.zeros(e_pad, np.int32); dst_p[:e_real] = dst
    emask = np.zeros(e_pad, np.int32); emask[:e_real] = 1

    # align triplets with the shard of their ji edge
    e_loc = e_pad // n_shards
    owner = ji // e_loc
    order = np.argsort(owner, kind="stable")
    kj, ji = kj[order], ji[order]
    # pad per-shard so each shard gets t_loc triplets holding only its edges
    t_loc = t_target // n_shards
    kj_p = np.zeros(t_target, np.int32)
    ji_p = np.zeros(t_target, np.int32)
    jil_p = np.zeros(t_target, np.int32)
    tmask = np.zeros(t_target, np.int32)
    for s in range(n_shards):
        sel = np.where(owner[order] == s)[0][:t_loc]
        out0 = s * t_loc
        nsel = len(sel)
        kj_p[out0 : out0 + nsel] = kj[sel]
        ji_p[out0 : out0 + nsel] = ji[sel]
        jil_p[out0 : out0 + nsel] = ji[sel] - s * e_loc
        tmask[out0 : out0 + nsel] = 1

    batch = {
        "pos": pos.astype(np.float32),
        "src": src_p, "dst": dst_p, "edge_mask": emask,
        "trip_kj": kj_p, "trip_ji": ji_p, "trip_ji_local": jil_p, "trip_mask": tmask,
        "node_mask": np.ones(total_nodes, np.int32),
        "target": host.normal(0, 1, total_nodes).astype(np.float32),
    }
    if d_feat > 0:
        batch["feat"] = host.normal(0, 1, (total_nodes, d_feat)).astype(np.float32)
    else:
        batch["z"] = host.integers(0, 100, total_nodes).astype(np.int32)
    return batch


class NeighborSampler:
    """CSR uniform fanout sampler (GraphSAGE-style) for minibatch training.

    Produces padded subgraph batches with the same layout as build_graph_batch;
    deterministic given (seed, step) — resumable (DESIGN.md §5 fault tolerance).
    """

    def __init__(self, n_nodes: int, edge_index: np.ndarray, fanout=(15, 10), seed: int = 0):
        src, dst = edge_index
        order = np.argsort(dst, kind="stable")
        self.nbr = src[order]
        counts = np.bincount(dst, minlength=n_nodes)
        self.offsets = np.concatenate([[0], np.cumsum(counts)])
        self.n_nodes = n_nodes
        self.fanout = fanout
        self.seed = seed

    def sample(self, step: int, batch_nodes: int):
        rng = np.random.default_rng((self.seed, step))
        seeds = rng.integers(0, self.n_nodes, batch_nodes)
        nodes = [seeds]
        edges_src, edges_dst = [], []
        frontier = seeds
        for f in self.fanout:
            nxt = []
            for u in frontier:
                lo, hi = self.offsets[u], self.offsets[u + 1]
                if hi == lo:
                    continue
                take = rng.integers(lo, hi, min(f, hi - lo))
                nb = self.nbr[take]
                nxt.append(nb)
                edges_src.append(nb)
                edges_dst.append(np.full(len(nb), u))
            frontier = np.concatenate(nxt) if nxt else np.empty(0, np.int64)
            nodes.append(frontier)
        all_nodes, inv = np.unique(np.concatenate(nodes), return_inverse=False), None
        remap = {int(g): i for i, g in enumerate(all_nodes)}
        es = np.array([remap[int(x)] for x in np.concatenate(edges_src)] if edges_src else [], np.int32)
        ed = np.array([remap[int(x)] for x in np.concatenate(edges_dst)] if edges_dst else [], np.int32)
        return all_nodes.astype(np.int32), np.stack([es, ed]) if len(es) else np.zeros((2, 0), np.int32)
