"""Deterministic synthetic datasets (container is offline — DESIGN.md §7.4).

``make_vector_dataset`` builds a SIFT-like high-dimensional mixture:
  * ``n_modes`` anisotropic Gaussian clusters with power-law weights (local
    density variation — the paper's source of long-tail kNN),
  * a fraction of points placed on *segments between* cluster centers
    (boundary points — these become the long-tail data points),
  * a uniform background floor.
Queries are drawn from the same process (held out), matching the benchmark
convention that queries follow the data distribution.

Also: token streams (LM), criteo-like click logs (recsys), random geometric
graphs (GNN smoke data).
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class VectorDataset(NamedTuple):
    base: np.ndarray     # [N, d] f32
    queries: np.ndarray  # [Q, d] f32
    name: str


def make_vector_dataset(
    name: str = "sift-like",
    n: int = 100_000,
    n_queries: int = 1_000,
    dim: int = 128,
    *,
    n_modes: int = 200,
    boundary_frac: float = 0.4,
    noise_frac: float = 0.02,
    center_scale: float = 1.5,
    spread: float = 2.0,
    seed: int = 0,
) -> VectorDataset:
    """Hardness calibrated against the paper's SIFT statistics (B=64, k=100):
    nprobe* ≈ 5, centroid-rank probing waste ≈ 7, long-tail queries ≈ 54% —
    heavily overlapping anisotropic modes + boundary segments."""
    rng = np.random.default_rng(seed)
    total = n + n_queries

    centers = rng.normal(0, 1.0, (n_modes, dim)).astype(np.float32) * center_scale
    # anisotropic scales per mode (curse-of-dim local density variation)
    scales = (0.3 + rng.gamma(2.0, 0.25, (n_modes, dim))).astype(np.float32) * spread
    weights = rng.pareto(1.5, n_modes) + 0.05
    weights /= weights.sum()

    n_bound = int(total * boundary_frac)
    n_noise = int(total * noise_frac)
    n_core = total - n_bound - n_noise

    modes = rng.choice(n_modes, n_core, p=weights)
    core = centers[modes] + rng.normal(0, 1, (n_core, dim)).astype(np.float32) * scales[modes]

    # boundary points: on segments between pairs of (near) cluster centers
    a = rng.choice(n_modes, n_bound, p=weights)
    # partner = nearest-ish other mode (random among 5 nearest)
    c2 = ((centers[:, None] - centers[None]) ** 2).sum(-1)
    np.fill_diagonal(c2, np.inf)
    near5 = np.argsort(c2, 1)[:, :5]
    b = near5[a, rng.integers(0, 5, n_bound)]
    t = rng.beta(2, 2, n_bound).astype(np.float32)[:, None]
    bound = centers[a] * (1 - t) + centers[b] * t
    bound += rng.normal(0, 1, (n_bound, dim)).astype(np.float32) * 0.5 * (scales[a] + scales[b]) / 2

    lo, hi = centers.min(), centers.max()
    noise = rng.uniform(lo, hi, (n_noise, dim)).astype(np.float32)

    x = np.concatenate([core, bound, noise]).astype(np.float32)
    rng.shuffle(x)
    return VectorDataset(base=x[:n], queries=x[n:], name=name)


def make_token_dataset(n_tokens: int, vocab: int, seed: int = 0) -> np.ndarray:
    """Zipf-distributed token stream for LM smoke training."""
    rng = np.random.default_rng(seed)
    ranks = rng.zipf(1.3, n_tokens).astype(np.int64)
    return np.clip(ranks, 1, vocab - 1).astype(np.int32)


def make_recsys_batch(
    rng: np.random.Generator,
    batch: int,
    n_dense: int,
    n_sparse: int,
    vocab: int,
    *,
    multi_hot: int = 1,
):
    """Criteo-like log: zipfian sparse ids, log-normal dense, ctr-ish labels."""
    dense = rng.lognormal(0, 1, (batch, n_dense)).astype(np.float32) if n_dense else np.zeros((batch, 0), np.float32)
    ids = np.minimum(rng.zipf(1.2, (batch, n_sparse, multi_hot)), vocab - 1).astype(np.int32)
    # labels correlated with a random linear model over hashed ids
    w = rng.normal(0, 1, n_sparse)
    logit = (np.sin(ids[..., 0] * 0.37) * w).sum(-1) * 0.5
    label = (rng.uniform(size=batch) < 1 / (1 + np.exp(-logit))).astype(np.float32)
    return {"dense": dense, "sparse_ids": ids, "label": label}


def make_geometric_graph(rng: np.random.Generator, n_nodes: int, avg_degree: int, d_feat: int):
    """Random geometric-ish graph via kNN in a latent 3D space (gives DimeNet
    meaningful angles). Returns positions, features, edge_index [2, E]."""
    pos = rng.normal(0, 1, (n_nodes, 3)).astype(np.float32)
    k = max(1, avg_degree)
    d2 = ((pos[:, None] - pos[None]) ** 2).sum(-1)
    np.fill_diagonal(d2, np.inf)
    nbr = np.argsort(d2, 1)[:, :k]                      # [N, k]
    src = np.repeat(np.arange(n_nodes), k)
    dst = nbr.reshape(-1)
    edge_index = np.stack([src, dst]).astype(np.int32)  # j -> i convention: row0=src j, row1=dst i
    feat = rng.normal(0, 1, (n_nodes, d_feat)).astype(np.float32)
    return pos, feat, edge_index


def build_triplets(edge_index: np.ndarray, max_triplets: int | None = None, seed: int = 0):
    """DimeNet triplet list: for each directed edge (j→i), all edges (k→j), k≠i.
    Returns (edge_kj, edge_ji) index pairs [T]."""
    rng = np.random.default_rng(seed)
    src, dst = edge_index
    e = len(src)
    # edges into j: group edge ids by their dst
    by_dst: dict[int, list[int]] = {}
    for eid in range(e):
        by_dst.setdefault(int(dst[eid]), []).append(eid)
    kj, ji = [], []
    for eid in range(e):
        j, i = int(src[eid]), int(dst[eid])
        for eid2 in by_dst.get(j, ()):
            if int(src[eid2]) != i:
                kj.append(eid2)
                ji.append(eid)
    kj = np.asarray(kj, np.int32)
    ji = np.asarray(ji, np.int32)
    if max_triplets is not None and len(kj) > max_triplets:
        sel = rng.choice(len(kj), max_triplets, replace=False)
        kj, ji = kj[sel], ji[sel]
    return kj, ji
