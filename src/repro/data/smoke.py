"""Realistic random batches for smoke tests — one generator per step kind."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.configs.base import GNNConfig, LiraSystemConfig, LMConfig, RecsysConfig
from repro.data.graph import build_graph_batch


def make_smoke_inputs(config, shape, mesh, seed: int = 0):
    """Returns kwargs dict for StepDef.fn's data arguments."""
    host = np.random.default_rng(seed)
    nshard = int(np.prod(list(mesh.shape.values())))

    if isinstance(config, LMConfig):
        gb, s = shape["global_batch"], shape["seq_len"]
        if shape.kind == "train":
            toks = host.integers(1, config.vocab, (gb, s + 1)).astype(np.int32)
            return {"batch": {"tokens": jnp.asarray(toks[:, :-1]), "labels": jnp.asarray(toks[:, 1:])}}
        if shape.kind == "prefill":
            return {"tokens": jnp.asarray(host.integers(1, config.vocab, (gb, s)).astype(np.int32))}
        if shape.kind == "decode":
            cache = {
                "k": jnp.asarray(host.normal(0, 1, (config.n_layers, gb, s, config.n_kv_heads, config.head_dim)).astype(np.float32), jnp.dtype(config.dtype)),
                "v": jnp.asarray(host.normal(0, 1, (config.n_layers, gb, s, config.n_kv_heads, config.head_dim)).astype(np.float32), jnp.dtype(config.dtype)),
            }
            return {"cache": cache,
                    "tokens": jnp.asarray(host.integers(1, config.vocab, (gb, 1)).astype(np.int32)),
                    "pos": jnp.asarray(s // 2, jnp.int32)}

    if isinstance(config, GNNConfig):
        batch = build_graph_batch(
            seed,
            n_nodes=shape["n_nodes"], n_edges=shape["n_edges"],
            d_feat=shape["d_feat"], triplet_mult=shape["triplet_mult"],
            n_graphs=shape.dims.get("batch", 1), n_shards=nshard,
        )
        return {"batch": {k: jnp.asarray(v) for k, v in batch.items()}}

    if isinstance(config, RecsysConfig):
        b = shape["batch"] if shape.kind != "retrieval" else shape["n_candidates"]
        batch = {
            "sparse_ids": jnp.asarray(host.integers(0, config.vocab_per_field, (b, config.n_sparse, config.nnz)).astype(np.int32)),
            "label": jnp.asarray((host.uniform(size=b) < 0.3).astype(np.float32)),
        }
        if config.n_dense:
            batch["dense"] = jnp.asarray(host.lognormal(0, 1, (b, config.n_dense)).astype(np.float32))
        if config.interaction == "multi-interest":
            batch["hist_ids"] = jnp.asarray(host.integers(0, config.vocab_per_field, (b, config.hist_len)).astype(np.int32))
            batch["hist_mask"] = jnp.asarray((host.uniform(size=(b, config.hist_len)) < 0.8).astype(np.float32))
            batch["target_id"] = jnp.asarray(host.integers(0, config.vocab_per_field, b).astype(np.int32))
        return {"batch": batch}

    if isinstance(config, LiraSystemConfig):
        if shape.kind == "lira_serve":
            # the serving tier declares which store planes exist (and their
            # dtypes) — iterate its specs so registry-driven smoke inputs
            # track new tiers with zero edits here
            from repro.serving.engine import store_specs

            nq = shape["n_queries"]
            specs = store_specs(config)
            vecs = host.normal(0, 1, (config.n_partitions, config.capacity, config.dim)).astype(np.float32)
            ids = np.arange(config.n_partitions * config.capacity, dtype=np.int32).reshape(
                config.n_partitions, config.capacity)
            # mark some tail rows as padding
            ids[:, -max(1, config.capacity // 8):] = -1
            store = {
                "centroids": jnp.asarray(vecs.mean(1)),
                "vectors": jnp.asarray(vecs, specs["vectors"].dtype),
                "ids": jnp.asarray(ids),
            }
            for name, spec in specs.items():
                if name in store:
                    continue
                if name == "occupancy":  # live slots = the non-padding ids
                    store[name] = jnp.asarray(ids >= 0)
                elif name == "codes":  # PQ codewords, bounded by pq_ks
                    store[name] = jnp.asarray(host.integers(
                        0, config.pq_ks, spec.shape).astype(spec.dtype))
                elif jnp.issubdtype(spec.dtype, jnp.integer):
                    store[name] = jnp.zeros(spec.shape, spec.dtype)
                else:
                    store[name] = jnp.asarray(
                        host.normal(0, 1, spec.shape).astype(np.float32),
                        spec.dtype)
            return {"store": store,
                    "queries": jnp.asarray(host.normal(0, 1, (nq, config.dim)).astype(np.float32))}
        if shape.kind == "lira_train":
            b = shape["batch"]
            return {"batch": {
                "q": jnp.asarray(host.normal(0, 1, (b, config.dim)).astype(np.float32)),
                "cent_dist": jnp.asarray(host.uniform(1, 10, (b, config.n_partitions)).astype(np.float32)),
                "labels": jnp.asarray((host.uniform(size=(b, config.n_partitions)) < 0.1).astype(np.float32)),
            }}
    raise ValueError((type(config), shape.kind))
