"""Deterministic, resumable, sharded host data pipeline.

Every batch is a pure function of (seed, step, host_shard) — no iterator state
to checkpoint: after restart, training resumes at step N and the pipeline
regenerates exactly the batches it would have produced (the fault-tolerance
contract tested in tests/test_fault_tolerance.py). On a real multi-host pod,
each host materializes only its `host_shard` slice of the global batch
(`jax.process_index()`-derived); device placement uses the same global
shardings as the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class PipelineSpec:
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


class TokenPipeline:
    """Next-token LM batches from a (synthetic) token stream."""

    def __init__(self, spec: PipelineSpec, seq_len: int, vocab: int):
        self.spec = spec
        self.seq_len = seq_len
        self.vocab = vocab

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.spec.seed, step, self.spec.host_id))
        toks = np.minimum(rng.zipf(1.3, (self.spec.host_batch, self.seq_len + 1)),
                          self.vocab - 1).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class ProbingPipeline:
    """Probing-model training batches: samples (query, cent_dist, labels) rows
    from a precomputed label matrix; deterministic per step."""

    def __init__(self, spec: PipelineSpec, x: np.ndarray, cent_dist: np.ndarray, labels: np.ndarray):
        self.spec = spec
        self.x, self.cd, self.labels = x, cent_dist, labels

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.spec.seed, step, self.spec.host_id))
        sel = rng.integers(0, len(self.x), self.spec.host_batch)
        return {"q": self.x[sel], "cent_dist": self.cd[sel], "labels": self.labels[sel]}


class RecsysPipeline:
    def __init__(self, spec: PipelineSpec, config):
        self.spec = spec
        self.cfg = config

    def batch_at(self, step: int) -> dict:
        from repro.data.synthetic import make_recsys_batch

        rng = np.random.default_rng((self.spec.seed, step, self.spec.host_id))
        b = make_recsys_batch(rng, self.spec.host_batch, self.cfg.n_dense,
                              self.cfg.n_sparse, self.cfg.vocab_per_field,
                              multi_hot=self.cfg.nnz)
        out = {"sparse_ids": b["sparse_ids"], "label": b["label"]}
        if self.cfg.n_dense:
            out["dense"] = b["dense"]
        return out
