"""Backend-dispatched per-partition scan — the serve step's hot stage.

The serve step turns query→partition routing into static-shape dispatch
buckets: ``qbuf [b_loc, q_cap]`` holds the queries assigned to each local
partition (``q_row`` = empty slot). This module owns everything after that:
scanning each partition's candidates for every query in its bucket and
returning per-(partition, slot) top-k, behind ONE signature with three
interchangeable implementations:

  * ``ref``       — portable jnp paths under ``lax.map`` (every backend; the
                    parity oracle for the kernels);
  * ``pallas``    — the fused Pallas kernels, grid-batched over the whole
                    ``[b_loc, q_cap]`` dispatch buffer in one launch
                    (``kernels.l2_topk_qbuf`` for the f32 tier,
                    ``kernels.pq_adc_topk_qbuf`` for the quantized tiers,
                    threading the residual ``cand_off``/``q_off`` operands).
                    The compact ``q_pad`` / ``lut_pad`` planes and the
                    ``qbuf`` index buffer go straight into the kernels as
                    scalar-prefetch operands — the host never expands them to
                    one copy per occupied dispatch slot, so stage-1 staging is
                    O(q_row·row) + O(b_loc·q_cap) indices instead of
                    O(b_loc·q_cap·row) (see ``staged_operand_bytes``).
                    Compiles natively on TPU, interprets elsewhere;
  * ``interpret`` — the kernels forced through the Pallas interpreter on any
                    backend (what CI's parity suite and bench smoke run).

Tier semantics (identical across impls — the parity suite asserts bit-equal
distances and set-equal ids):

  f32:        fused L2 + running top-k over the partition's vectors;
  quantized:  stage 1 ADC shortlist of ``rk`` slots from the shared per-query
              LUT (+ residual per-slot ``cterm`` and per-(query, partition)
              offset when given), stage 2 exact f32 rerank of the shortlist.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

IMPLS = ("ref", "pallas", "interpret")


def resolve_impl(impl: str | None) -> str:
    """Map the config knob to a concrete impl: auto defers to the kernels'
    shared backend policy (kops.default_impl). Fails fast on typos."""
    if impl in (None, "auto"):
        return kops.default_impl()
    if impl not in IMPLS:
        raise ValueError(f"unknown scan impl {impl!r}; expected one of "
                         f"('auto', {', '.join(repr(s) for s in IMPLS)})")
    return impl


def run(impl: str | None, qbuf, q_pad, vecs_loc, ids_loc, k: int, *,
        lut_pad=None, codes_loc=None, rk: int | None = None,
        cterm_loc=None, off_loc=None):
    """Scan every local partition's candidates for its dispatched queries.

    qbuf      [b_loc, q_cap] int32 — query row per slot, ``q_row`` = empty
    q_pad     [q_row + 1, d]       — queries + sentinel row for empty slots
    vecs_loc  [b_loc, cap, d]      — partition vectors (rerank operand)
    ids_loc   [b_loc, cap] int32   — point ids, -1 = padding
    lut_pad   [q_row + 1, m, ks]   — quantized only: shared ADC LUTs + zero row
    codes_loc [b_loc, cap, m]      — quantized only: PQ codes
    rk        int                  — quantized only: shortlist depth
    cterm_loc [b_loc, cap]         — residual only: per-slot cross terms
    off_loc   [b_loc, q_row + 1]   — residual only: per-(partition, query)
                                     offsets, zero row for empty slots

    Returns ([b_loc, q_cap, k] dists, [b_loc, q_cap, k] ids); rows for empty
    slots hold garbage — the serve step's scatter drops them.
    """
    impl = resolve_impl(impl)
    if lut_pad is not None:
        if impl == "ref":
            return _quantized_ref(qbuf, q_pad, vecs_loc, ids_loc, k,
                                  lut_pad, codes_loc, rk, cterm_loc, off_loc)
        return _quantized_kernel(qbuf, q_pad, vecs_loc, ids_loc, k,
                                 lut_pad, codes_loc, rk, cterm_loc, off_loc, impl)
    if impl == "ref":
        return _f32_ref(qbuf, q_pad, vecs_loc, ids_loc, k)
    return _f32_kernel(qbuf, q_pad, vecs_loc, ids_loc, k, impl)


# ------------------------------------------------------------------ f32 tier

def _f32_ref(qbuf, q_pad, vecs_loc, ids_loc, k):
    def scan_partition(args):
        qi, vec_b, id_b = args                               # [q_cap], [cap, d], [cap]
        qs = q_pad[qi].astype(vec_b.dtype)                   # [q_cap, d]
        # bf16 operands + f32 accumulation (store_dtype=bfloat16 halves the
        # dominant vector-read traffic; exact rerank happens at f32)
        d2 = (
            jnp.sum(qs.astype(jnp.float32) ** 2, -1, keepdims=True)
            - 2.0 * jax.lax.dot_general(qs, vec_b, (((1,), (1,)), ((), ())),
                                        preferred_element_type=jnp.float32)
            + jnp.sum(vec_b.astype(jnp.float32) ** 2, -1)[None, :]
        )
        d2 = jnp.where(id_b[None, :] < 0, jnp.inf, d2)
        neg, posk = jax.lax.top_k(-d2, k)
        return -neg, id_b[posk]                              # [q_cap, k] ×2

    return jax.lax.map(scan_partition, (qbuf, vecs_loc, ids_loc))


def _f32_kernel(qbuf, q_pad, vecs_loc, ids_loc, k, impl):
    # cast the COMPACT plane to the store dtype (same quantization point as
    # the ref path's per-slot cast); the kernel gathers each bucket's rows
    # itself via the scalar-prefetched qbuf — no [b_loc, q_cap, d] expansion
    qp = q_pad.astype(vecs_loc.dtype)                        # [q_row + 1, d]
    return kops.l2_topk_qbuf(qp, qbuf, vecs_loc, ids_loc, k, impl=impl)


# ------------------------------------------------------------ quantized tiers

def _quantized_ref(qbuf, q_pad, vecs_loc, ids_loc, k, lut_pad, codes_loc, rk,
                   cterm_loc, off_loc):
    m = codes_loc.shape[-1]
    m_idx = jnp.arange(m)[:, None]
    residual = cterm_loc is not None

    def scan_partition(args):
        if residual:
            qi, codes_b, vec_b, id_b, ct_b, off_b = args
        else:
            qi, codes_b, vec_b, id_b = args    # [q_cap], [cap, m], [cap, d], [cap]
        # stage 1: ADC shortlist over the partition's codes from the shared LUT
        lq = lut_pad[qi]                                     # [q_cap, m, ks]
        ad = lq[:, m_idx, codes_b.astype(jnp.int32).T].sum(1)  # [q_cap, cap]
        if residual:
            # offset add order mirrors the kernel (q_off then cand_off) so the
            # shortlist selection agrees bitwise across impls
            ad = ad + off_b[qi][:, None] + ct_b[None, :]
        ad = jnp.where(id_b[None, :] < 0, jnp.inf, ad)
        _, sl = jax.lax.top_k(-ad, rk)                       # shortlist slots
        # stage 2: exact f32 rerank on the shortlist only
        qs = q_pad[qi].astype(jnp.float32)
        cand = vec_b[sl].astype(jnp.float32)                 # [q_cap, rk, d]
        cid = id_b[sl]
        d2 = (
            jnp.sum(qs * qs, -1)[:, None]
            - 2.0 * jnp.einsum("qd,qrd->qr", qs, cand)
            + jnp.sum(cand * cand, -1)
        )
        d2 = jnp.where(cid < 0, jnp.inf, d2)
        neg, posk = jax.lax.top_k(-d2, k)
        return -neg, jnp.take_along_axis(cid, posk, axis=1)  # [q_cap, k] ×2

    scan_args = (qbuf, codes_loc, vecs_loc, ids_loc)
    if residual:
        scan_args = scan_args + (cterm_loc, off_loc)
    return jax.lax.map(scan_partition, scan_args)


def _quantized_kernel(qbuf, q_pad, vecs_loc, ids_loc, k, lut_pad, codes_loc, rk,
                      cterm_loc, off_loc, impl):
    b_loc, _ = qbuf.shape
    cap = vecs_loc.shape[1]
    # stage 1: one fused launch over all buckets. The kernel ranks by ADC and
    # returns the ids it was given — feed it SLOT indices so the shortlist can
    # gather the f32 rerank operands (invalid slots come back as -1). The
    # compact lut_pad plane + qbuf go in directly; the kernel's scalar-
    # prefetch gather replaces the old host-side lut_pad[qbuf] expansion
    # (one LUT copy per occupied slot, ≈nprobe·q_cap_factor× amplification).
    slots = jnp.broadcast_to(jnp.arange(cap, dtype=jnp.int32)[None, :], (b_loc, cap))
    slots = jnp.where(ids_loc < 0, -1, slots)
    coff = qoff = None
    if cterm_loc is not None:
        coff = cterm_loc                                     # [b_loc, cap]
        qoff = jnp.take_along_axis(off_loc, qbuf, axis=1)    # [b_loc, q_cap]
    _, sl = kops.pq_adc_topk_qbuf(lut_pad, qbuf, codes_loc, slots, rk,
                                  cand_off=coff, q_off=qoff, impl=impl)
    # stage 2: exact f32 rerank of the shortlist (same math as the ref path)
    safe = jnp.maximum(sl, 0)                                # [b_loc, q_cap, rk]
    cid = jnp.where(sl >= 0,
                    jnp.take_along_axis(ids_loc[:, None, :], safe, axis=2), -1)
    cand = jnp.take_along_axis(vecs_loc[:, None], safe[..., None],
                               axis=2).astype(jnp.float32)   # [b_loc, q_cap, rk, d]
    qs = q_pad[qbuf].astype(jnp.float32)                     # [b_loc, q_cap, d]
    d2 = (
        jnp.sum(qs * qs, -1)[..., None]
        - 2.0 * jnp.einsum("bqd,bqrd->bqr", qs, cand)
        + jnp.sum(cand * cand, -1)
    )
    d2 = jnp.where(cid < 0, jnp.inf, d2)
    neg, posk = jax.lax.top_k(-d2, k)
    return -neg, jnp.take_along_axis(cid, posk, axis=-1)


# ----------------------------------------------------------- bytes accounting

def staged_operand_bytes(qbuf, plane) -> dict:
    """Stage-1 per-query operand staging footprint for a dispatch shape.

    ``plane`` is the compact per-query operand the kernel path stages —
    ``q_pad [q_row+1, d]`` for the f32 tier, ``lut_pad [q_row+1, m, ks]`` for
    the quantized tiers. Returns:

      compact_bytes  — what the qbuf entry points stage: the plane itself
                       plus the int32 ``qbuf`` index buffer
                       (O(q_row·row) + O(b_loc·q_cap));
      expanded_bytes — what the retired host-side ``plane[qbuf]`` gather
                       materialized: one plane row per dispatch slot
                       (O(b_loc·q_cap·row)).

    The ratio is the input amplification the scalar-prefetch rewrite removed;
    benches persist both so the improvement is auditable. Accepts arrays or
    ``jax.ShapeDtypeStruct``s (only ``.shape``/``.dtype`` are read).
    """
    b_loc, q_cap = qbuf.shape
    row_elems = 1
    for s in plane.shape[1:]:
        row_elems *= int(s)
    row_bytes = row_elems * jnp.dtype(plane.dtype).itemsize
    return {
        "compact_bytes": int(plane.shape[0]) * row_bytes + b_loc * q_cap * 4,
        "expanded_bytes": b_loc * q_cap * row_bytes,
    }
