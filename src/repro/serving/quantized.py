"""Quantized two-stage serving tier: PQ/ADC shortlist + exact f32 rerank.

The serve step's in-partition scan is memory-bandwidth bound: the f32 path
reads ``capacity · d · 4`` bytes per probed partition. This tier shrinks the
scan store 8–32× by scanning uint8 PQ codes instead (the HARMONY / LANNS
compressed-scan-then-rerank split):

  stage 0 (per query, once):  ADC LUT  [m, ks] subspace distance table;
  stage 1 (per probed partition): LUT scan over the partition's codes →
          shortlist of ``r·k`` candidate slots. The scan is backend-dispatched
          through ``serving/scan.py``: ``kernels.pq_adc_topk_batched`` fuses
          it over every dispatch bucket in one launch (native on TPU,
          interpretable anywhere); the jnp gather path is the portable
          reference and parity oracle;
  stage 2: exact f32 distances on the shortlist only → top-k, then the usual
          replica-aware ``dedup_topk`` local + cross-shard merges.

Two PQ modes share this pipeline:

  * non-residual (default): codebooks trained on raw vectors, so one LUT per
    query is valid across every partition — the shared-LUT fast case with no
    extra per-slot state;
  * residual (``residual=True``): codebooks trained on x − centroid[assign],
    which spends the whole code budget on the within-partition residual —
    the win on clustered data where centroids carry most of the norm. The
    cross terms that a per-partition LUT would normally absorb fold into a
    per-slot scalar plane ``cterm[b, n] = 2⟨c_b, decode(codes[b, n])⟩``
    (precomputed here at build time) plus a per-(query, partition) scalar
    added inside the serve step's scan — see the residual ADC identity in
    ``core/pq.py``. Stage 1 stays a single shared-LUT gather + offset adds.

The full-precision store stays resident as the rerank operand and as the
exact fallback/oracle path in both modes.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pq as pqmod


class QuantizedStore(NamedTuple):
    """PQ codes per partition slot + the shared codebooks.

    ``codes`` rows beyond a partition's fill are real encodings of the padding
    sentinel vectors; they are masked at scan time by ``ids < 0`` exactly like
    the f32 path, so no separate validity plane is needed.

    ``residual=True`` means codes encode x − centroid[assign] and ``cterm``
    holds the per-slot cross-term plane of the residual ADC identity
    (core/pq.py); non-residual stores leave ``cterm`` as None.
    """

    codes: jax.Array      # [B, capacity, m] uint8 (ks ≤ 256) / uint16
    codebooks: jax.Array  # [m, ks, d_sub] f32
    cterm: jax.Array | None = None  # [B, capacity] f32, residual mode only
    residual: bool = False

    @property
    def ks(self) -> int:
        return self.codebooks.shape[1]


# per-query subspace distance tables [Q, m, ks] from raw codebook arrays (the
# serve step holds codebooks as a plain array, not a PQCodebook)
adc_lut = pqmod.adc_lut_raw


def build_quantized_store(
    rng: jax.Array,
    vectors,              # [B, capacity, d] np/jax — the padded partition store
    ids,                  # [B, capacity] int32, -1 = padding
    *,
    m: int = 16,
    ks: int = 256,
    train_n: int = 32768,
    n_iters: int = 12,
    residual: bool = False,
    centroids=None,       # [B, d] — required when residual=True
) -> QuantizedStore:
    """Train PQ on a sample of the valid slots, encode every slot.

    ``ks`` is clamped to the number of valid training rows so tiny stores
    (tests, smoke configs) build without under-determined codebooks.

    With ``residual=True`` the codebooks are trained on (and codes encode)
    x − centroid[partition], and the per-slot cross-term plane ``cterm`` is
    precomputed so serve-time scans keep one shared LUT per query.
    """
    vec = np.asarray(vectors, np.float32)
    idv = np.asarray(ids)
    b, cap, d = vec.shape
    assert d % m == 0, f"dim {d} not divisible by pq_m={m}"
    flat = vec.reshape(-1, d)
    cents_rep = None
    if residual:
        assert centroids is not None, "residual PQ needs the partition centroids"
        cents_rep = np.repeat(np.asarray(centroids, np.float32), cap, axis=0)  # [B·cap, d]
        flat = flat - cents_rep
    rows = np.flatnonzero(idv.reshape(-1) >= 0)
    ks = int(min(ks, max(2, len(rows) // 2)))
    rng_sample, rng_train = jax.random.split(rng)
    if len(rows) > train_n:
        host = np.random.default_rng(int(jax.random.randint(rng_sample, (), 0, 2**31 - 1)))
        rows = host.choice(rows, train_n, replace=False)
    pq = pqmod.train_pq(rng_train, flat[rows], m=m, ks=ks, n_iters=n_iters)
    codes = pqmod.encode(pq, flat)  # [B·cap, m] narrow integer dtype
    cterm = None
    if residual:
        cterm = jnp.asarray(
            pqmod.residual_cross_terms(pq, cents_rep, codes).reshape(b, cap))
    return QuantizedStore(codes=jnp.asarray(codes.reshape(b, cap, m)),
                          codebooks=pq.codebooks, cterm=cterm, residual=residual)


def scan_store_bytes(store: dict) -> dict:
    """Bytes each scan path reads per full pass over the store (the quantized
    tier's raison d'être: this ratio is the bandwidth win)."""
    vec = store["vectors"]
    f32_bytes = vec.size * vec.dtype.itemsize
    out = {"f32": int(f32_bytes)}
    if "codes" in store:
        codes = store["codes"]
        q_bytes = codes.size * codes.dtype.itemsize
        if "cterm" in store:  # residual mode reads the offset plane too
            q_bytes += store["cterm"].size * store["cterm"].dtype.itemsize
        out["quantized"] = int(q_bytes)
        out["ratio"] = f32_bytes / max(1, q_bytes)
    return out
