"""Quantized two-stage serving tier: PQ/ADC shortlist + exact f32 rerank.

The serve step's in-partition scan is memory-bandwidth bound: the f32 path
reads ``capacity · d · 4`` bytes per probed partition. This tier shrinks the
scan store 8–32× by scanning uint8 PQ codes instead (the HARMONY / LANNS
compressed-scan-then-rerank split):

  stage 0 (per query, once):  ADC LUT  [m, ks] subspace distance table;
  stage 1 (per probed partition): LUT scan over the partition's codes →
          shortlist of ``r·k`` candidate slots (``kernels.pq_adc_topk`` fuses
          this on TPU; the jnp gather path runs everywhere);
  stage 2: exact f32 distances on the shortlist only → top-k, then the usual
          replica-aware ``dedup_topk`` local + cross-shard merges.

PQ here is NON-residual (codebooks trained on raw vectors), so one LUT per
query is valid across every partition — the property that lets the LUT be
computed once outside the partition loop. The full-precision store stays
resident as the rerank operand and as the exact fallback/oracle path.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pq as pqmod


class QuantizedStore(NamedTuple):
    """PQ codes per partition slot + the shared codebooks.

    ``codes`` rows beyond a partition's fill are real encodings of the padding
    sentinel vectors; they are masked at scan time by ``ids < 0`` exactly like
    the f32 path, so no separate validity plane is needed.
    """

    codes: jax.Array      # [B, capacity, m] uint8 (ks ≤ 256) / uint16
    codebooks: jax.Array  # [m, ks, d_sub] f32

    @property
    def ks(self) -> int:
        return self.codebooks.shape[1]


# per-query subspace distance tables [Q, m, ks] from raw codebook arrays (the
# serve step holds codebooks as a plain array, not a PQCodebook)
adc_lut = pqmod.adc_lut_raw


def build_quantized_store(
    rng: jax.Array,
    vectors,              # [B, capacity, d] np/jax — the padded partition store
    ids,                  # [B, capacity] int32, -1 = padding
    *,
    m: int = 16,
    ks: int = 256,
    train_n: int = 32768,
    n_iters: int = 12,
) -> QuantizedStore:
    """Train PQ on a sample of the valid slots, encode every slot.

    ``ks`` is clamped to the number of valid training rows so tiny stores
    (tests, smoke configs) build without under-determined codebooks.
    """
    vec = np.asarray(vectors, np.float32)
    idv = np.asarray(ids)
    b, cap, d = vec.shape
    assert d % m == 0, f"dim {d} not divisible by pq_m={m}"
    flat = vec.reshape(-1, d)
    rows = np.flatnonzero(idv.reshape(-1) >= 0)
    ks = int(min(ks, max(2, len(rows) // 2)))
    rng_sample, rng_train = jax.random.split(rng)
    if len(rows) > train_n:
        host = np.random.default_rng(int(jax.random.randint(rng_sample, (), 0, 2**31 - 1)))
        rows = host.choice(rows, train_n, replace=False)
    pq = pqmod.train_pq(rng_train, flat[rows], m=m, ks=ks, n_iters=n_iters)
    codes = pqmod.encode(pq, flat)  # [B·cap, m] narrow integer dtype
    return QuantizedStore(codes=jnp.asarray(codes.reshape(b, cap, m)),
                          codebooks=pq.codebooks)


def scan_store_bytes(store: dict) -> dict:
    """Bytes each scan path reads per full pass over the store (the quantized
    tier's raison d'être: this ratio is the bandwidth win)."""
    vec = store["vectors"]
    f32_bytes = vec.size * vec.dtype.itemsize
    out = {"f32": int(f32_bytes)}
    if "codes" in store:
        codes = store["codes"]
        q_bytes = codes.size * codes.dtype.itemsize
        out["quantized"] = int(q_bytes)
        out["ratio"] = f32_bytes / max(1, q_bytes)
    return out
