"""Host-side mutation planning for the epoch-versioned mutable store.

The serving store is a static-shape [B, capacity] slot grid (per-slot planes
declared by the tier registry, serving/tiers.py) — mutations therefore reduce
to SLOT bookkeeping, planned here on the host in numpy and applied by
``LiraEngine.insert/delete/compact/maybe_repartition`` (serving/engine.py):

  * ``plan_insert``    — greedy nearest-partition-with-free-slot placement of
    appended rows; reports which rows landed off their argmin partition (the
    staleness signal IRLI-style re-partitioning consumes) and which found no
    slot at all (the grow signal);
  * ``grow_store``     — widen every per-slot plane to a new capacity, padding
    with the same sentinels ``core.partitions.build_store`` uses;
  * ``compact_store``  — repack live slots to the front of each partition and
    shrink capacity to the max live count, erasing tombstones;
  * ``layout_rows``    — a full (partition → slots) layout for re-partition
    rebuilds: stable within-partition ordering, contiguous slots.

Everything here is pure host math over occupancy/id planes — no jit, no mesh.
The invariant the engine maintains on top: a slot is LIVE iff occupancy is
True; a tombstone is occupancy=False with a non-negative id left behind (the
id plane is only healed when the slot is reused or compacted away); the serve
step masks ``ids`` with occupancy before the scan, so holes reuse the scan
layer's universal ``id < 0`` invalid sentinel.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

# how many nearest partitions an inserted row may spill into before the
# engine grows the store instead (spilling further than this would plant
# rows so far off their argmin partition that probing rarely finds them)
PLACE_WINDOW = 4

# pad sentinels per slot plane — mirrors core.partitions.build_store (vector
# sentinel 1e6 keeps padding out of any top-k; PAD_ID=-1 is the scan layer's
# invalid marker). Planes not named here (codes, cterm, ...) zero-fill: their
# slots are unreachable once ids/occupancy mark them dead.
_FILL = {"vectors": 1e6, "ids": -1, "occupancy": False}


def fill_value(name: str):
    return _FILL.get(name, 0)


class InsertPlan(NamedTuple):
    parts: np.ndarray        # [n] destination partition (-1 = no slot found)
    slots: np.ndarray        # [n] destination slot within the partition
    misassigned: np.ndarray  # [n] bool: placed, but not in argmin partition
    ok: np.ndarray           # [n] bool: a slot was found within the window


def plan_insert(occ: np.ndarray, dist: np.ndarray, *,
                window: int = PLACE_WINDOW) -> InsertPlan:
    """Place ``n`` new rows into free slots: each row tries its ``window``
    nearest partitions in order and takes the lowest free slot of the first
    one with room. ``occ`` is the [B, capacity] occupancy plane (not
    modified); ``dist`` the [n, B] row→centroid squared distances. Rows are
    placed in input order — earlier rows claim contested slots first."""
    n, nb = dist.shape
    order = np.argsort(dist, axis=1, kind="stable")[:, :max(1, window)]
    parts = np.full(n, -1, np.int64)
    slots = np.full(n, -1, np.int64)
    # per-partition free-slot stacks, lowest slot on top
    free = [list(np.flatnonzero(~occ[b])[::-1]) for b in range(nb)]
    for i in range(n):
        for b in order[i]:
            if free[b]:
                parts[i], slots[i] = b, free[b].pop()
                break
    ok = parts >= 0
    return InsertPlan(parts=parts, slots=slots,
                      misassigned=ok & (parts != order[:, 0]), ok=ok)


def grow_store(planes: dict, new_cap: int) -> dict:
    """Widen every per-slot plane (leading dims [B, cap, ...]) to
    ``new_cap`` slots, sentinel-padded. Host numpy in, host numpy out."""
    out = {}
    for name, arr in planes.items():
        arr = np.asarray(arr)
        if new_cap < arr.shape[1]:
            raise ValueError(f"grow_store cannot shrink {name}: "
                             f"{arr.shape[1]} -> {new_cap} (use compact_store)")
        pad = np.full((arr.shape[0], new_cap - arr.shape[1], *arr.shape[2:]),
                      fill_value(name), arr.dtype)
        out[name] = np.concatenate([arr, pad], axis=1)
    return out


def pack_order(occ: np.ndarray):
    """Per-partition permutation that moves live slots to the front (stable:
    live slots keep their relative order). Returns (perm [B, cap], live [B])."""
    perm = np.argsort(~occ, axis=1, kind="stable")
    return perm, occ.sum(1).astype(np.int64)


def compact_store(planes: dict, occ: np.ndarray, *,
                  min_capacity: int = 1) -> tuple[dict, int]:
    """Repack live slots to the front of each partition and shrink capacity
    to the max live count: tombstones and free holes are squeezed out, dead
    tail slots reset to their pad sentinels. Returns (planes, new_cap)."""
    perm, live = pack_order(occ)
    new_cap = max(int(min_capacity), int(live.max(initial=0)))
    rows = np.arange(occ.shape[0])[:, None]
    dead = np.arange(new_cap)[None, :] >= live[:, None]     # [B, new_cap]
    out = {}
    for name, arr in planes.items():
        arr = np.asarray(arr)
        g = arr[rows, perm][:, :new_cap]
        if g.shape[1] < new_cap:        # min_capacity floor exceeds the old
            g = grow_store({name: g}, new_cap)[name]        # capacity: widen
        mask = dead.reshape(dead.shape + (1,) * (g.ndim - 2))
        out[name] = np.where(mask, np.asarray(fill_value(name), g.dtype), g)
    return out, new_cap


def layout_rows(assign: np.ndarray, n_partitions: int):
    """Contiguous slot layout for a full rebuild: rows with the same
    partition get slots 0..count-1 in stable input order. Returns
    (slots [n], counts [B])."""
    assign = np.asarray(assign, np.int64)
    order = np.argsort(assign, kind="stable")
    counts = np.bincount(assign, minlength=n_partitions).astype(np.int64)
    start = np.zeros(n_partitions + 1, np.int64)
    np.cumsum(counts, out=start[1:])
    slots = np.empty(len(assign), np.int64)
    slots[order] = np.arange(len(assign)) - start[assign[order]]
    return slots, counts
