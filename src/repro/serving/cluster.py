"""Cluster serving: LANNS-style two-level sharding × replica groups.

One ``LiraEngine`` serves one partition mesh. Web-scale corpora exceed it
(LANNS, arxiv 2010.09426), and heavy traffic exceeds one replica (HARMONY,
arxiv 2506.14707) — so the production topology is a ``LiraCluster``:

    LiraCluster
      ├── shard 0  (level-1 LANNS shard: its own k-means, probing model,
      │            tier store over its slice of the corpus)
      │     ├── replica 0 ─┐  ReplicaRouter: power-of-two-choices on
      │     └── replica 1 ─┘  in-flight depth, heartbeat failover
      ├── shard 1
      │     ├── replica 0 ─┐  StragglerMitigator: hedged dispatch,
      │     └── replica 1 ─┘  first response wins
      └── cross-shard top-k merge (dedup_topk primitive)

**Sharding** happens at build time (``plan_shards``): ``hash`` spreads rows
content-independently by a multiplicative hash of their global id (LANNS's
random sharder — balanced by construction), ``kmeans`` clusters rows into S
coarse groups with a balance cap (LANNS's clustered sharder — each query
could then prune shards, though this module always fans out so results stay
exact). Each shard is a FULL engine build over its rows: own centroids, own
probing model, own tier store (η replicas included), with a local→global id
map kept alongside.

**Serving** fans a query batch to every shard group. Within a group the
router picks a live replica (power-of-two-choices on in-flight depth) and
the mitigator hedges stragglers: when the primary's measured service exceeds
3× the median history, the batch re-issues to the best-EWMA sibling and the
first completion wins — replicas of a shard serve the same store, so only
latency, never the answer, depends on the winner. A replica that dies
mid-serve (``ReplicaFailure``) has its in-flight batch replayed on a healthy
sibling, and silently-stalled replicas are caught by heartbeat timeout at
the next ``tick()`` — zero batches are lost either way, which the
fault-injection bench (benchmarks/cluster.py) gates.

**Merge** pools the S per-shard top-k lists (global ids) and reduces them
through the ``dedup_topk`` primitive's host-side numpy twin — the same
selection-by-(dist, id) the in-graph merge uses, so duplicate ids (η>0
replicas, overlapping custom shard plans) collapse to their best distance.

**Exactness.** Per-shard answers are exact over each shard's rows whenever
the scan is (σ=-1 full fan-out; for PQ tiers a shortlist covering the
partition, i.e. rerank·k ≥ capacity), and the global top-k of a union is
contained in the union of per-shard top-k — so the merged cluster answer is
bit-identical in distances (and set-identical in ids) to a single-engine
oracle built over the union corpus. tests/test_cluster.py gates this across
{f32, pq, residual_pq} × {ref, interpret}, including mid-stream replica
failure.

Time is injectable throughout (``clock`` for heartbeats/failover,
``service_timer`` for measured service; ``fixed_service_s`` replaces the
measurement entirely for deterministic policy tests), so the whole failover
story runs under ``repro.utils.clock.FakeClock`` in tier-1 with no sleeps.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np

from repro.distributed.fault import ReplicaFailure, ReplicaRouter, StragglerMitigator
from repro.kernels.dedup_topk import dedup_topk_np
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serving import api, scan, tiers
from repro.serving.engine import LiraEngine

__all__ = ["ClusterConfig", "LiraCluster", "ShardPlan", "plan_shards"]


# ---------------------------------------------------------------- sharding

@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Level-1 shard assignment: which coarse shard owns each row."""

    mode: str                       # "hash" | "kmeans"
    n_shards: int
    assign: np.ndarray              # [n] shard index per row
    centroids: Optional[np.ndarray] = None  # [S, dim] (kmeans mode only)


def _hash_shard(ids: np.ndarray, n_shards: int) -> np.ndarray:
    """Content-independent Fibonacci hash of the global id — LANNS's random
    sharder: balanced in expectation, stable under re-build."""
    h = (ids.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)) >> np.uint64(32)
    return (h % np.uint64(n_shards)).astype(np.int32)


def plan_shards(x: np.ndarray, n_shards: int, *, mode: str = "hash",
                ids: Optional[np.ndarray] = None, seed: int = 0,
                balance_slack: float = 1.2, iters: int = 10) -> ShardPlan:
    """LANNS-style level-1 sharding of ``x`` into ``n_shards`` coarse shards.

    ``hash`` ignores geometry (ids hashed, balanced in expectation);
    ``kmeans`` runs a small numpy Lloyd's over the rows and assigns each row
    to its nearest shard centroid subject to a balance cap of
    ``ceil(n / S · balance_slack)`` rows — overflowing rows spill to their
    next-nearest shard with space, so no shard engine build degenerates."""
    n = len(x)
    if not 1 <= n_shards <= n:
        raise ValueError(f"n_shards={n_shards} must be in [1, {n}]")
    ids = np.arange(n, dtype=np.int64) if ids is None else np.asarray(ids)
    if mode == "hash":
        return ShardPlan("hash", n_shards, _hash_shard(ids, n_shards))
    if mode != "kmeans":
        raise ValueError(f"unknown shard mode {mode!r}; expected hash|kmeans")
    rng = np.random.default_rng(seed)
    xf = np.asarray(x, np.float32)
    cents = xf[rng.choice(n, n_shards, replace=False)].copy()
    for _ in range(iters):
        d2 = ((xf * xf).sum(1)[:, None] - 2.0 * xf @ cents.T
              + (cents * cents).sum(1)[None, :])
        a = d2.argmin(1)
        for s in range(n_shards):
            m = a == s
            if m.any():
                cents[s] = xf[m].mean(0)
    # balanced greedy assignment: rows in a seeded random order take their
    # nearest shard with remaining capacity (spill to next-nearest)
    d2 = ((xf * xf).sum(1)[:, None] - 2.0 * xf @ cents.T
          + (cents * cents).sum(1)[None, :])
    prefs = np.argsort(d2, axis=1)
    cap = int(np.ceil(n / n_shards * balance_slack))
    left = np.full(n_shards, cap, np.int64)
    assign = np.empty(n, np.int32)
    for row in rng.permutation(n):
        for s in prefs[row]:
            if left[s] > 0:
                assign[row] = s
                left[s] -= 1
                break
        else:  # caps sum to ≥ n·slack > n, so space always exists somewhere
            raise AssertionError("balance caps exhausted")
    return ShardPlan("kmeans", n_shards, assign, centroids=cents)


# ----------------------------------------------------------------- cluster

@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """Topology + control-plane policy for a ``LiraCluster``."""

    n_shards: int = 2               # level-1 LANNS shards (S)
    n_replicas: int = 2             # replicas per shard group (R)
    shard_mode: str = "hash"        # plan_shards mode: hash | kmeans
    hedging: bool = True            # hedge stragglers via StragglerMitigator
    hedge_factor: float = 3.0       # deadline = factor × median history
    hedge_warmup: int = 20          # history before hedging may fire
    heartbeat_timeout_s: float = 10.0  # tick() fails replicas staler than this
    seed: int = 0


@dataclasses.dataclass
class ShardReplica:
    """Control-plane wrapper for one replica of one shard. Replicas of a
    shard share the shard's built engine (same store, bit-identical answers);
    what differs is health, load and fault injection."""

    sid: int
    rid: int
    engine: LiraEngine
    armed_failure: bool = False     # next dispatch raises ReplicaFailure
    stalled: bool = False           # stops heartbeating (silent stall)
    busy_s: float = 0.0             # effective service charged to this replica


@dataclasses.dataclass
class ShardGroup:
    """One level-1 shard: the engine, its local→global id map, and the
    replica-group control plane."""

    sid: int
    engine: LiraEngine
    row_ids: np.ndarray             # [n_shard] local store id → global id
    router: ReplicaRouter
    mitigator: StragglerMitigator
    members: list


def _dup_count_np(ids_pool: np.ndarray) -> int:
    """Duplicate valid ids in the cross-shard candidate pool (what the merge
    collapses) — the cluster-level mirror of the engine's dedup_hits."""
    i = np.sort(np.asarray(ids_pool, np.int64), axis=1)
    return int(((i[:, 1:] == i[:, :-1]) & (i[:, 1:] >= 0)).sum())


class LiraCluster:
    """S coarse shards × R replicas per shard over a union corpus, served
    scatter-gather with routed/hedged/failover-replayed dispatch and an exact
    cross-shard merge. Duck-types the engine surface the serving front-end
    needs (``search``/``search_one``/``_batch_bucket``/``attach_frontend``),
    so ``ServingFrontend`` batches single-query traffic onto a cluster
    exactly as onto one engine."""

    def __init__(self, engines: list, row_ids: list, config: ClusterConfig
                 | None = None, *, plan: Optional[ShardPlan] = None,
                 clock: Optional[Callable[[], float]] = None,
                 charge_service: bool = False,
                 service_timer: Callable[[], float] = time.perf_counter,
                 fixed_service_s: Optional[float] = None,
                 tracer=None, metrics=None):
        if len(engines) != len(row_ids) or not engines:
            raise ValueError("need one row_ids map per engine (≥1 shard)")
        ccfg = config if config is not None else ClusterConfig(
            n_shards=len(engines))
        if ccfg.n_shards != len(engines):
            raise ValueError(f"config says {ccfg.n_shards} shards, "
                             f"got {len(engines)} engines")
        self.ccfg = ccfg
        self.plan = plan
        self.clock = clock if clock is not None else time.monotonic
        if charge_service and not hasattr(self.clock, "advance"):
            raise TypeError("charge_service=True needs a clock with .advance "
                            "(e.g. FakeClock)")
        self.charge_service = charge_service
        self.service_timer = service_timer
        self.fixed_service_s = fixed_service_s
        self.tracer = tracer
        self.metrics = metrics
        self.frontend = None
        self.groups: list[ShardGroup] = []
        for s, (eng, rmap) in enumerate(zip(engines, row_ids)):
            router = ReplicaRouter(
                ccfg.n_replicas, seed=ccfg.seed + s, clock=self.clock,
                metrics=metrics, name=f"shard{s}")
            self.groups.append(ShardGroup(
                sid=s, engine=eng, row_ids=np.asarray(rmap, np.int32),
                router=router,
                mitigator=StragglerMitigator(
                    router, hedge_factor=ccfg.hedge_factor,
                    warmup=ccfg.hedge_warmup),
                members=[ShardReplica(sid=s, rid=r, engine=eng)
                         for r in range(ccfg.n_replicas)]))

    # ------------------------------------------------------------ build

    @classmethod
    def build(cls, mesh, x: np.ndarray, config: api.BuildConfig,
              cluster: ClusterConfig | None = None, *,
              ids: Optional[np.ndarray] = None, **kwargs) -> "LiraCluster":
        """Shard ``x`` per the cluster config (LANNS level-1), build one full
        engine per shard (its own k-means/probing model/tier store, seeded
        per shard), and wire the replica-group control plane. ``ids`` are the
        global point ids (default ``arange``); each shard keeps the
        local→global map so merged answers speak global ids. Extra kwargs go
        to ``LiraCluster.__init__`` (clock, metrics, tracer, ...)."""
        ccfg = cluster if cluster is not None else ClusterConfig()
        n = len(x)
        gids = (np.arange(n, dtype=np.int64) if ids is None
                else np.asarray(ids, np.int64))
        plan = plan_shards(x, ccfg.n_shards, mode=ccfg.shard_mode, ids=gids,
                           seed=ccfg.seed)
        engines, row_ids = [], []
        for s in range(ccfg.n_shards):
            rows = np.flatnonzero(plan.assign == s)
            engines.append(LiraEngine.build(
                mesh, x[rows],
                dataclasses.replace(config, seed=config.seed + s)))
            row_ids.append(gids[rows])
        return cls(engines, row_ids, ccfg, plan=plan, **kwargs)

    # ---------------------------------------------------- engine duck-typing

    @property
    def cfg(self):
        return self.groups[0].engine.cfg

    @property
    def sigma(self) -> float:
        return self.groups[0].engine.sigma

    def _batch_bucket(self, nq: int) -> int:
        return self.groups[0].engine._batch_bucket(nq)

    def _tracer(self):
        return self.tracer if self.tracer is not None else obs_trace.NOOP

    def _registry(self) -> obs_metrics.MetricsRegistry:
        return (self.metrics if self.metrics is not None
                else obs_metrics.default_registry())

    def search_one(self, request: api.SearchRequest) -> api.SearchResult:
        """Single-query entry, mirroring ``LiraEngine.search_one``: routes
        through the attached front-end (dynamic batching) when present."""
        if not isinstance(request, api.SearchRequest):
            raise TypeError("search_one takes a SearchRequest; for raw query "
                            "batches use search()")
        q = np.asarray(request.queries)
        if q.ndim == 1:
            request = dataclasses.replace(request, queries=q[None, :])
        elif q.ndim != 2 or q.shape[0] != 1:
            raise ValueError("search_one serves exactly one query "
                             f"(got shape {q.shape}); use search() for batches")
        if self.frontend is not None:
            return self.frontend.submit(request).result()
        return self.search(request)

    def attach_frontend(self, config=None, **kwargs):
        """Attach a ``ServingFrontend`` over the whole cluster — the
        front-end routing hook: coalesced batches fan out to every shard
        group through the routed/hedged dispatch path. Detach with
        ``cluster.frontend = None``."""
        from repro.serving.frontend import ServingFrontend

        self.frontend = ServingFrontend(self, config, **kwargs)
        return self.frontend

    # -------------------------------------------------------- fault control

    def _member(self, shard: int, rid: int) -> ShardReplica:
        return self.groups[shard].members[rid]

    def fail_replica(self, shard: int, rid: int, *,
                     inflight: bool = False) -> None:
        """Fault injection. ``inflight=False`` fails the replica between
        batches (clean heartbeat loss); ``inflight=True`` arms a one-shot
        mid-serve failure — the NEXT batch routed to it raises
        ``ReplicaFailure`` with the batch in flight, exercising the re-queue
        + replay path."""
        if inflight:
            self._member(shard, rid).armed_failure = True
        else:
            self.groups[shard].router.mark_failed(rid)

    def stall_replica(self, shard: int, rid: int) -> None:
        """Silent stall: the replica stops heartbeating (but never errors);
        ``tick()`` fails it once ``heartbeat_timeout_s`` passes on the
        injected clock — the detection path crash failures skip."""
        self._member(shard, rid).stalled = True

    def recover_replica(self, shard: int, rid: int) -> None:
        m = self._member(shard, rid)
        m.armed_failure = m.stalled = False
        self.groups[shard].router.recover(rid)

    def tick(self) -> list[tuple[int, int, int]]:
        """Heartbeat pass, run before every search (and callable as the
        deployment's liveness prober): live, non-stalled replicas stamp their
        heartbeat; replicas staler than ``heartbeat_timeout_s`` are failed
        and their in-flight batches re-queued. Returns
        ``[(shard, rid, lost), ...]`` for newly failed replicas."""
        failed = []
        for g in self.groups:
            for m, pol in zip(g.members, g.router.replicas):
                if pol.healthy and not m.stalled:
                    g.router.heartbeat(m.rid)
            failed.extend((g.sid, rid, lost) for rid, lost in
                          g.router.check_heartbeats(
                              self.ccfg.heartbeat_timeout_s))
        return failed

    # -------------------------------------------------------------- serving

    def _resolve(self, req: api.SearchRequest):
        """Resolve per-call overrides against shard 0's config (all shards
        are built from one BuildConfig, so any shard works), mirroring
        ``ServingFrontend._resolve_key``."""
        eng = self.groups[0].engine
        k = eng.cfg.k if req.k is None else int(req.k)
        sigma = float(eng.sigma if req.sigma is None else req.sigma)
        tier = tiers.resolve(req.tier if req.tier is not None
                             else eng.cfg.tier).name
        impl = scan.resolve_impl(req.impl if req.impl is not None
                                 else getattr(eng.cfg, "impl", "auto"))
        return k, sigma, tier, impl

    def _dispatch_shard(self, g: ShardGroup, req: api.SearchRequest, tr):
        """Serve one shard group: route → (optionally) hedge → failover
        replay. Returns (SearchResult, winner rid, effective service_s,
        hedged, failovers)."""
        requeued0 = g.router.requeued

        def fn(pol):
            m = g.members[pol.rid]
            if m.armed_failure:
                m.armed_failure = False  # one-shot: the batch dies in flight
                raise ReplicaFailure(
                    f"shard {g.sid} replica {pol.rid} died mid-serve")
            t0 = self.service_timer()
            res = m.engine.search(req)
            meas = (self.service_timer() - t0 if self.fixed_service_s is None
                    else self.fixed_service_s)
            return res, meas * pol.latency_scale

        with tr.span("cluster.shard", shard=g.sid):
            if self.ccfg.hedging:
                res, winner, eff, hedged = g.mitigator.run(fn)
            else:
                (res, eff), winner = g.router.route(fn)
                hedged = False
        g.members[winner.rid].busy_s += eff
        failovers = g.router.requeued - requeued0
        self._registry().counter(
            "lira_cluster_replica_served_total",
            "batches served, by winning replica").inc(
                shard=str(g.sid), replica=str(winner.rid))
        return res, winner.rid, float(eff), hedged, failovers

    def search(self, queries, *, sigma: Optional[float] = None,
               tier: Optional[str] = None, impl: Optional[str] = None,
               k: Optional[int] = None) -> api.SearchResult:
        """Serve one query batch across every shard and merge. ``queries``
        is an [nq, dim] array or a ``SearchRequest`` (then no keyword
        overrides). The merged result speaks global ids;
        ``stats.routes`` records ``(shard, replica, hedged, failovers)`` per
        shard, ``stats.latency_ms`` the effective cluster service time — the
        max over shard groups, since shards are parallel pods (hedging
        already folded in)."""
        if isinstance(queries, api.SearchRequest):
            if any(a is not None for a in (sigma, tier, impl, k)):
                raise TypeError(
                    "pass either a SearchRequest or keyword overrides, not both")
            req = queries
        else:
            req = api.SearchRequest(queries=np.asarray(queries), k=k,
                                    sigma=sigma, tier=tier, impl=impl)
        q = np.asarray(req.queries, np.float32)
        if q.ndim == 1:
            q = q[None, :]
        k_res, sigma_res, tier_res, impl_res = self._resolve(req)
        shard_req = api.SearchRequest(queries=q, k=k_res, sigma=sigma_res,
                                      tier=tier_res, impl=impl_res)
        self.tick()
        tr = self._tracer()
        outs = []
        with tr.span("cluster.search", shards=len(self.groups),
                     rows=q.shape[0]) as sp_root:
            for g in self.groups:
                res, rid, eff, hedged, failovers = self._dispatch_shard(
                    g, shard_req, tr)
                loc = res.ids
                gid = np.where(loc >= 0,
                               g.row_ids[np.clip(loc, 0, None)],
                               np.int32(-1))
                outs.append((g.sid, res, gid, rid, eff, hedged, failovers))
            with tr.span("cluster.merge"):
                pool_d = np.concatenate([o[1].dists for o in outs], axis=1)
                pool_i = np.concatenate([o[2] for o in outs], axis=1)
                cross_dups = _dup_count_np(pool_i)
                dists, ids = dedup_topk_np(pool_d, pool_i, k_res)
            sp_root.set(tier=tier_res, impl=impl_res)

        eff_cluster = max(o[4] for o in outs)  # shards serve in parallel pods
        if self.charge_service:
            self.clock.advance(eff_cluster)
        routes = tuple((o[0], o[3], o[5], o[6]) for o in outs)
        nprobe_eff = np.sum([o[1].nprobe_eff for o in outs], axis=0)
        overflow = sum(o[1].overflow for o in outs)
        dedup_hits = sum(o[1].stats.dedup_hits for o in outs) + cross_dups

        lbl = {"tier": tier_res, "impl": impl_res}
        m = self._registry()
        m.counter("lira_cluster_searches_total",
                  "cluster.search calls").inc(**lbl)
        m.counter("lira_cluster_rows_total",
                  "query rows served by the cluster").inc(q.shape[0], **lbl)
        m.counter("lira_cluster_merge_dedup_hits_total",
                  "duplicate ids collapsed by the cross-shard merge").inc(
                      cross_dups, **lbl)

        return api.SearchResult(
            dists=dists, ids=ids, nprobe_eff=nprobe_eff, overflow=overflow,
            stats=api.SearchStats(
                tier=tier_res, impl=impl_res, k=k_res, sigma=sigma_res,
                bucket=outs[0][1].stats.bucket,
                cache_hit=all(o[1].stats.cache_hit for o in outs),
                dedup_hits=dedup_hits, latency_ms=eff_cluster * 1e3,
                epoch=max(o[1].stats.epoch for o in outs),
                hedged=any(o[5] for o in outs),
                failovers=sum(o[6] for o in outs),
                routes=routes))

    # ------------------------------------------------------------ telemetry

    def replica_table(self) -> list[dict]:
        """Control-plane snapshot: one row per (shard, replica) with health,
        load and effective busy time — what the launcher prints."""
        return [{"shard": g.sid, "replica": pol.rid, "healthy": pol.healthy,
                 "served": pol.served, "ewma": pol.ewma,
                 "busy_s": m.busy_s, "stalled": m.stalled}
                for g in self.groups
                for m, pol in zip(g.members, g.router.replicas)]
