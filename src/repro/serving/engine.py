"""Distributed LIRA serving engine — the paper's system on a TPU pod.

Key insight of the TPU mapping (DESIGN.md §3): the probing model's output is a
query→partition ROUTING problem, identical in structure to MoE token dispatch.
serve_step:

  1. queries sharded over ("pod","data"); partition store sharded over "model"
     (each chip owns B/16 partitions); probing model + centroids replicated;
  2. per chip: probing probabilities → top-`nprobe_max` partitions, σ-masked
     (query-adaptive nprobe, paper §3.4);
  3. sort-based dispatch of queries into per-local-partition buckets of static
     capacity `q_cap` (the MoE-dispatch trick applied to ANN — compute scales
     with Q·nprobe·cap, NOT Q·N: partition pruning materializes as real FLOP
     savings under static shapes). Batch-padding rows are masked out of
     dispatch via the `valid` operand so they never steal q_cap slots from
     real queries, and probes dropped by bucket overflow are COUNTED and
     returned (the serve step's 4th output; `LiraEngine.search` surfaces the
     total) instead of being silently swallowed;
  4. per local partition: the scan stage is backend-dispatched through
     serving/scan.py (cfg.impl: auto | ref | pallas | interpret). "ref" is the
     portable jnp path under lax.map; "pallas" runs the fused kernels
     grid-batched over the whole [b_loc, q_cap] dispatch buffer in one launch
     (kernels.l2_topk_batched for f32; native on TPU, interpreted elsewhere).
     WHAT is scanned is declared by the serving tier (serving/tiers.py): the
     engine resolves cfg.tier from the registry and iterates the tier's store
     field + scan operand declarations — it never branches on tier-specific
     booleans, so a new storage/quantization strategy is one registered Tier
     class with zero edits here. The "pq" tier threads a shared ADC LUT +
     shortlist depth (two-stage scan, serving/quantized.py); "residual_pq"
     adds the residual ADC identity's cterm plane and per-(query, partition)
     offsets (core/pq.py);
  5. scatter back per query, local top-k, all-gather(k·shards) over "model",
     final merge. Collective volume is O(Q·k), independent of N.

Multi-pod: each pod holds a full index replica; the front-end routes query
batches to pods (repro.distributed.fault simulates replica failover).

Host-side callers use the typed surface in serving/api.py: LiraEngine.build
takes a BuildConfig, search takes queries or a SearchRequest and returns a
SearchResult (the legacy 4-tuple unpacking survives one release behind a
DeprecationWarning shim).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import LiraSystemConfig, ShapeSpec
from repro.core import probing
from repro.kernels import ops as kops
from repro.models.api import ModelBundle, StepDef, adamw_state_pspecs, adamw_state_specs, sds
from repro.train import optimizer as opt

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serving import api
from repro.serving import scan
from repro.serving import tiers
from repro.utils.compat import shard_map


def batch_mesh_info(mesh):
    """(batch_axes, bspec, bprod) for the query-batch axes of a mesh — the
    single source for how serve steps and batch bucketing split queries."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)
    bprod = int(np.prod([mesh.shape[a] for a in batch_axes])) if batch_axes else 1
    return batch_axes, bspec, bprod


def probing_param_specs(cfg: LiraSystemConfig):
    pc = probing.ProbingConfig(dim=cfg.dim, n_partitions=cfg.n_partitions,
                               q_hidden=tuple(cfg.q_hidden), i_hidden=tuple(cfg.i_hidden),
                               p_hidden=tuple(cfg.p_hidden))
    return jax.eval_shape(lambda: probing.init(jax.random.PRNGKey(0), pc))


def store_specs(cfg: LiraSystemConfig):
    """Store field shape specs for cfg's serving tier — a pure delegation to
    the tier registry (serving/tiers.py declares WHAT each tier stores)."""
    return tiers.resolve(cfg.tier).store_specs(cfg)


def store_pspecs(mesh, cfg: LiraSystemConfig | None = None):
    """Mesh PartitionSpecs per store field; cfg=None means the base f32 tier.
    (mesh is unused — pspecs name axes symbolically; the parameter is kept
    only so existing callers' signatures stay valid.)"""
    del mesh
    tier = tiers.resolve(cfg.tier if cfg is not None else "f32")
    return tier.store_pspecs(cfg)


# ------------------------------------------------------------- serve step

def _dup_count(ids_pool):
    """Count duplicate id slots per candidate pool row ([nq, pool]): valid
    slots (id ≥ 0) minus distinct ids, summed over queries. This is the
    replica-dedup hit count — how many candidate slots the η-redundancy
    replicas burned on ids another partition already supplied.

    Counted at each merge the serve step actually runs (local pool, then the
    gathered cross-shard top-k), so under model sharding it is a lower bound
    on the full-pool duplicate count: a cross-shard duplicate pair where one
    copy misses its shard's local top-k is never observed (counting it would
    require gathering whole pools — O(Q·pool·shards) traffic instead of the
    O(Q·k) the merge is designed around). Results stay bit-identical across
    shardings; only this telemetry is merge-local."""
    s = jnp.sort(ids_pool, axis=1)
    valid = s >= 0
    first = jnp.concatenate(
        [jnp.ones_like(s[:, :1], jnp.bool_), s[:, 1:] != s[:, :-1]], axis=1)
    return (valid.sum(1) - (valid & first).sum(1)).sum().astype(jnp.int32)


def make_serve_step(cfg: LiraSystemConfig, mesh, n_queries: int, *, sigma: float = 0.5,
                    q_cap_factor: float | None = None,
                    tier: str | tiers.Tier | None = None,
                    impl: str | None = None,
                    k: int | None = None,
                    count_dedup: bool = False):
    _, bspec, bprod = batch_mesh_info(mesh)
    model_n = mesh.shape.get("model", 1)
    q_row = n_queries // bprod
    b_loc = cfg.n_partitions // model_n
    q_cap_factor = q_cap_factor if q_cap_factor is not None else getattr(cfg, "q_cap_factor", 2.0)
    q_cap = max(8, int(q_row * cfg.nprobe_max / cfg.n_partitions * q_cap_factor))
    k = cfg.k if k is None else int(k)
    tier = tiers.resolve(tier if tier is not None else cfg.tier)
    impl = getattr(cfg, "impl", "auto") if impl is None else impl
    scan_impl = scan.resolve_impl(impl)  # fail fast on typos, not at trace time
    # the tier declares its store fields; everything beyond the probing /
    # dispatch / rerank operands (BASE_FIELDS) is threaded through untouched
    # and handed back to the tier when it assembles the scan operands
    pspec_map = tier.store_pspecs(cfg)
    extra_fields = tuple(n for n in tier.store_specs(cfg)
                         if n not in tiers.BASE_FIELDS)

    def f(q_loc, valid_loc, params, cents, vecs_loc, ids_loc, occ_loc, *extras):
        # q_loc: [q_row, d]; valid_loc: [q_row] bool (False = batch padding);
        # vecs_loc: [b_loc, cap, d]; ids_loc/occ_loc: [b_loc, cap]
        # extras: the tier's non-base store fields, in declaration order
        # tombstoned/free slots must never surface ids: composing occupancy
        # into the id plane up front reuses the scan layer's universal id<0
        # invalid sentinel, so every impl × tier masks holes identically —
        # and a fully-occupied store is bit-identical to the static path
        ids_loc = jnp.where(occ_loc, ids_loc, -1)
        # jax.named_scope labels the serving stages in profiler captures
        # (TensorBoard op_profile groups HLO ops under these names — the
        # --profile-dir recipe in README "Observability"); it is a pure
        # metadata annotation with zero effect on the computation
        with jax.named_scope("lira.probing"):
            cd = (
                jnp.sum(q_loc * q_loc, -1, keepdims=True)
                - 2.0 * q_loc @ cents.T
                + jnp.sum(cents * cents, -1)[None, :]
            )
            p = jax.nn.sigmoid(probing.apply(params, q_loc, cd))    # [q_row, B]
            vals, pidx = jax.lax.top_k(p, cfg.nprobe_max)           # global partitions
            probe_ok = vals > sigma
            probe_ok = probe_ok.at[:, 0].set(True)                  # always ≥1 partition
            # batch-padding rows must not probe: a pad query occupying q_cap
            # slots can evict a real query's probes in small buckets
            probe_ok = probe_ok & valid_loc[:, None]

        # ---- dispatch (sort-based, local partition range only)
        with jax.named_scope("lira.dispatch"):
            b0 = jax.lax.axis_index("model") * b_loc if model_n > 1 else 0
            flat_p = pidx.reshape(-1) - b0
            flat_ok = probe_ok.reshape(-1) & (flat_p >= 0) & (flat_p < b_loc)
            flat_q = jnp.broadcast_to(jnp.arange(q_row)[:, None], pidx.shape).reshape(-1)
            key = jnp.where(flat_ok, flat_p, b_loc)
            order = jnp.argsort(key, stable=True)
            skey = key[order]
            start = jnp.searchsorted(skey, jnp.arange(b_loc + 1))
            pos = jnp.arange(skey.shape[0]) - start[jnp.clip(skey, 0, b_loc)]
            keep = (skey < b_loc) & (pos < q_cap)
            # probes beyond a hot partition's q_cap are dropped — count them so
            # recall degradation is reported, not silent (raise q_cap_factor or
            # rebalance partitions when this is persistently > 0)
            overflow = ((skey < b_loc) & (pos >= q_cap)).sum().astype(jnp.int32)
            row = jnp.where(keep, skey, b_loc)
            col = jnp.where(keep, pos, 0)
            qbuf = jnp.full((b_loc, q_cap), q_row, jnp.int32).at[row, col].set(
                flat_q[order], mode="drop")                          # q_row = invalid

        # ---- per-partition scan: backend-dispatched (serving/scan.py); the
        # tier derives its extra scan operands (ADC LUTs, shortlist depth,
        # residual offsets, …) from the serve-step context — {} = plain f32
        with jax.named_scope("lira.scan"):
            q_pad = jnp.concatenate([q_loc, jnp.full((1, q_loc.shape[1]), 1e9, q_loc.dtype)], 0)
            ctx = tiers.ScanContext(q_loc=q_loc, q_pad=q_pad, cd=cd, b0=b0,
                                    b_loc=b_loc, k=k)
            scan_kw = tier.scan_kwargs(cfg, ctx, dict(zip(extra_fields, extras)))
            dists, rids = scan.run(scan_impl, qbuf, q_pad, vecs_loc, ids_loc, k,
                                   **scan_kw)

        # ---- scatter back per query, local merge
        with jax.named_scope("lira.merge"):
            out_d = jnp.full((q_row + 1, b_loc, k), jnp.inf, jnp.float32)
            out_i = jnp.full((q_row + 1, b_loc, k), -1, jnp.int32)
            cols = jnp.broadcast_to(jnp.arange(b_loc)[:, None], qbuf.shape)
            out_d = out_d.at[qbuf, cols].set(dists, mode="drop")
            out_i = out_i.at[qbuf, cols].set(rids, mode="drop")
            pool_i = out_i[:q_row].reshape(q_row, -1)
            # replica-dedup hit rate (only when asked for: the extra output
            # changes the step signature, so make_bundle and direct callers
            # keep the 4-output form) — measured BEFORE each dedup pass so it
            # counts exactly the duplicate slots the merges collapse
            dedup_hits = _dup_count(pool_i) if count_dedup else None
            # replica-aware local merge: redundancy (η>0) stores the same id in
            # several partitions, so a plain top-k would return duplicate ids
            # and corrupt recall@k — dedup to best-distance-per-id instead
            # (backend dispatch: bitonic Pallas kernel on TPU, jnp elsewhere)
            loc_d, loc_i = kops.dedup_topk(
                out_d[:q_row].reshape(q_row, -1), pool_i, k)

            # ---- cross-shard merge (O(Q·k·shards) bytes — independent of N);
            # replicas of one id can live on different shards, so dedup again
            if model_n > 1:
                all_d = jax.lax.all_gather(loc_d, "model", axis=1, tiled=True)   # [q_row, 16k]
                all_i = jax.lax.all_gather(loc_i, "model", axis=1, tiled=True)
                if count_dedup:
                    # local hits differ per shard → psum; the gathered pool is
                    # identical on every model shard → count it exactly once
                    dedup_hits = (jax.lax.psum(dedup_hits, "model")
                                  + _dup_count(all_i))
                loc_d, loc_i = kops.dedup_topk(all_d, all_i, k)
                overflow = jax.lax.psum(overflow, "model")
        nprobe_eff = probe_ok.sum(-1).astype(jnp.float32)
        if count_dedup:
            return loc_d, loc_i, nprobe_eff, overflow[None], dedup_hits[None]
        return loc_d, loc_i, nprobe_eff, overflow[None]

    param_spec = jax.tree.map(lambda _: P(), probing_param_specs_cache(cfg))
    in_specs = (P(bspec, None), P(bspec), param_spec,
                pspec_map["centroids"], pspec_map["vectors"], pspec_map["ids"],
                pspec_map["occupancy"],
                *(pspec_map[n] for n in extra_fields))

    out_specs = (P(bspec, None), P(bspec, None), P(bspec), P(bspec))
    if count_dedup:
        out_specs = out_specs + (P(bspec),)

    def serve_step(params, store, queries, valid=None):
        if valid is None:
            valid = jnp.ones((n_queries,), jnp.bool_)
        # stores built before the mutable-index refactor (and raw test store
        # dicts) carry no occupancy plane: a dense store's occupancy is
        # exactly its id validity, so synthesize it
        occ = store.get("occupancy")
        if occ is None:
            occ = store["ids"] >= 0
        args = (queries, valid, params, store["centroids"], store["vectors"],
                store["ids"], occ, *(store[n] for n in extra_fields))
        return shard_map(
            f, mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_vma=False,
        )(*args)

    return serve_step


@functools.lru_cache(maxsize=None)
def _probing_specs_cached(dim, b, qh, ih, ph):
    pc = probing.ProbingConfig(dim=dim, n_partitions=b, q_hidden=qh, i_hidden=ih, p_hidden=ph)
    return jax.eval_shape(lambda: probing.init(jax.random.PRNGKey(0), pc))


def probing_param_specs_cache(cfg: LiraSystemConfig):
    return _probing_specs_cached(cfg.dim, cfg.n_partitions, tuple(cfg.q_hidden),
                                 tuple(cfg.i_hidden), tuple(cfg.p_hidden))


# ------------------------------------------------------------- train step

def make_probe_train_step(cfg: LiraSystemConfig, mesh, tx):
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def train_step(state, batch):
        params, opt_state = state

        def loss_fn(p):
            return probing.bce_loss(p, batch["q"], batch["cent_dist"], batch["labels"])

        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads, gnorm = opt.clip_by_global_norm(grads, 1.0)
        updates, opt_state = tx.update(grads, opt_state, params)
        params = opt.apply_updates(params, updates)
        return (params, opt_state), {"loss": loss, "grad_norm": gnorm}

    return train_step


# ------------------------------------------------------------- bundle

def make_bundle(cfg: LiraSystemConfig, mesh) -> ModelBundle:
    _, bspec, _ = batch_mesh_info(mesh)
    tx = opt.adamw(opt.cosine_schedule(1e-3, 50, 5000))
    pc = probing.ProbingConfig(dim=cfg.dim, n_partitions=cfg.n_partitions,
                               q_hidden=tuple(cfg.q_hidden), i_hidden=tuple(cfg.i_hidden),
                               p_hidden=tuple(cfg.p_hidden))

    def step(shape: ShapeSpec) -> StepDef:
        if shape.kind == "lira_serve":
            nq = shape["n_queries"]
            fn_inner = make_serve_step(cfg, mesh, nq)

            def fn(params, store, queries):
                return fn_inner(params, store, queries)

            return StepDef(
                fn=fn,
                input_specs={"store": store_specs(cfg), "queries": sds((nq, cfg.dim))},
                input_pspecs={"store": store_pspecs(mesh, cfg), "queries": P(bspec, None)},
                out_pspecs=None,
            )
        if shape.kind == "lira_train":
            b = shape["batch"]
            return StepDef(
                fn=make_probe_train_step(cfg, mesh, tx),
                input_specs={
                    "q": sds((b, cfg.dim)),
                    "cent_dist": sds((b, cfg.n_partitions)),
                    "labels": sds((b, cfg.n_partitions)),
                },
                input_pspecs={"q": P(bspec, None), "cent_dist": P(bspec, None),
                              "labels": P(bspec, None)},
                out_pspecs=None,
            )
        raise ValueError(shape.kind)

    return ModelBundle(
        name=cfg.arch,
        config=cfg,
        init=lambda rng, shape=None: probing.init(rng, pc),
        param_specs=lambda shape=None: probing_param_specs_cache(cfg),
        param_pspecs=lambda shape=None: jax.tree.map(lambda _: P(), probing_param_specs_cache(cfg)),
        step=step,
        opt_specs=lambda shape=None: adamw_state_specs(probing_param_specs_cache(cfg)),
        opt_pspecs=lambda shape=None: adamw_state_pspecs(
            jax.tree.map(lambda _: P(), probing_param_specs_cache(cfg))),
    )


# ------------------------------------------------------------- host engine

@dataclasses.dataclass
class LiraEngine:
    """End-to-end host-driven engine: build (k-means → train probe → redundancy
    → tier store construction) then serve batches via the distributed
    serve_step. The typed surface lives in serving/api.py — ``build`` takes a
    BuildConfig, ``search`` takes queries or a SearchRequest and returns a
    SearchResult; which store planes exist and what the scan reads is declared
    by the serving tier (serving/tiers.py).

    Jitted serve steps are cached per (bucket, σ, tier, impl, k, q_cap) key:
    query batches are padded to power-of-two buckets so repeated traffic of
    varying size hits the jit cache instead of recompiling every call, and the
    pad rows are masked out of dispatch (they never probe or take q_cap slots).
    With ``cfg.auto_q_cap`` the engine doubles ``q_cap_factor`` after
    ``_AUTO_Q_CAP_AFTER`` consecutive overflowing calls and drops the cache,
    so the next bucket recompiles with the extra dispatch slack.
    """

    cfg: LiraSystemConfig
    params: dict
    store: dict
    mesh: jax.sharding.Mesh
    sigma: float = 0.5
    # store epoch: bumped by every mutation (insert/delete/compact/
    # repartition). Searches stamp it into SearchStats.epoch; shape-changing
    # mutations additionally enter the serve-fn cache key via cfg.capacity —
    # same-shape mutations MUST keep hitting the compiled steps (new device
    # arrays of unchanged shape/dtype never retrace a jitted fn).
    epoch: int = 0
    # attached serving front-end (serving/frontend.py); search_one routes
    # through it when present. Not part of engine identity or checkpoints.
    frontend: Optional[object] = dataclasses.field(default=None, repr=False,
                                                   compare=False)
    # observability (repro.obs): tracer=None means spans are free no-ops
    # (obs_trace.NOOP); metrics=None records into the process-wide
    # default_registry(). Neither participates in identity or checkpoints.
    tracer: Optional[object] = dataclasses.field(default=None, repr=False,
                                                 compare=False)
    metrics: Optional[object] = dataclasses.field(default=None, repr=False,
                                                  compare=False)
    _serve_cache: dict = dataclasses.field(default_factory=dict, repr=False,
                                           compare=False)
    _overflow_streak: int = dataclasses.field(default=0, repr=False,
                                              compare=False)
    # per-partition count of inserts that landed OFF their argmin partition
    # (no free slot nearer): the drift half of the staleness signal, reset by
    # repartition. None = lazily zeros (stores built before this field).
    _stale_inserts: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False, compare=False)

    def _tracer(self):
        return self.tracer if self.tracer is not None else obs_trace.NOOP

    def _registry(self) -> obs_metrics.MetricsRegistry:
        return (self.metrics if self.metrics is not None
                else obs_metrics.default_registry())

    @classmethod
    def build(cls, mesh, x: np.ndarray, config: api.BuildConfig | None = None,
              **legacy_kwargs):
        """Build an index over ``x`` per the BuildConfig recipe.

        Legacy surface (one release): keyword arguments matching BuildConfig
        fields are still accepted when no config object is given, and the
        retired ``quantized=`` / ``residual=`` booleans map onto ``tier=``
        with a DeprecationWarning.
        """
        from repro.core import build_store, ground_truth as gt, kmeans_fit
        from repro.core.redundancy import plan_redundancy, replica_rows
        from repro.core.train_probing import train_probing_model

        if "quantized" in legacy_kwargs or "residual" in legacy_kwargs:
            api.warn_deprecated(
                "build-tier-kwargs",
                "LiraEngine.build(quantized=, residual=) is deprecated; pass "
                "BuildConfig(tier='pq') / BuildConfig(tier='residual_pq')")
            residual = bool(legacy_kwargs.pop("residual", False))
            quantized = bool(legacy_kwargs.pop("quantized", False))
            legacy_kwargs.setdefault(
                "tier", tiers.legacy_tier_name(quantized, residual))
        if config is None:
            config = api.BuildConfig(**legacy_kwargs)
        elif legacy_kwargs:
            raise TypeError("pass either a BuildConfig or keyword arguments, "
                            f"not both (got {sorted(legacy_kwargs)})")

        tier = tiers.resolve(config.tier)
        rng = jax.random.PRNGKey(config.seed)
        host = np.random.default_rng(config.seed)
        n_partitions = config.n_partitions
        st = kmeans_fit(rng, jnp.asarray(x), n_clusters=n_partitions, n_iters=20)
        assign, cents = np.asarray(st.assign), np.asarray(st.centroids)

        sub = host.choice(len(x), int(len(x) * config.train_frac), replace=False)
        xs = x[sub]
        _, sti = gt.exact_knn(xs, xs, config.k, exclude_self=True)
        part_of = assign[sub]
        lab = np.zeros((len(sub), n_partitions), np.float32)
        rows = np.repeat(np.arange(len(sub)), sti.shape[1])
        np.add.at(lab, (rows, part_of[sti].reshape(-1)), 1.0)
        lab = (lab > 0).astype(np.float32)
        params, _ = train_probing_model(rng, xs, lab, cents,
                                        epochs=config.epochs, log=config.log)

        ids = np.arange(len(x), dtype=np.int32)
        plan = plan_redundancy(params, x, assign, cents, eta=config.eta)
        extra = replica_rows(plan, x, ids)
        store_h = build_store(x, ids, assign, cents, extra=extra)
        dim = x.shape[1]
        cfg = LiraSystemConfig(
            arch="lira", dim=dim, n_partitions=n_partitions,
            capacity=store_h.capacity, k=config.k,
            nprobe_max=min(n_partitions,
                           config.nprobe_max or max(8, n_partitions // 8)),
            tier=tier.name, pq_m=config.pq_m or 0, pq_ks=config.pq_ks,
            rerank=config.rerank, impl=config.impl,
            store_dtype=config.store_dtype, q_cap_factor=config.q_cap_factor,
            auto_q_cap=config.auto_q_cap, eta=config.eta,
        )
        # the tier owns store construction (and may amend cfg: PQ resolves
        # pq_m, clamps pq_ks for tiny stores)
        store, cfg = tier.build_store(jax.random.fold_in(rng, 1), cfg, store_h)
        if not cfg.pq_m:  # tiers without PQ leave the knob at its default
            cfg = dataclasses.replace(cfg, pq_m=16)
        return cls(cfg=cfg, params=params, store=store, mesh=mesh,
                   sigma=config.sigma)

    def _batch_bucket(self, nq: int) -> int:
        """Pad batch sizes to power-of-two buckets (≥8, rounded up to a
        multiple of the batch-mesh product so shard_map can split the batch)
        so the jitted serve step is reused across nearby batch sizes."""
        _, _, bprod = batch_mesh_info(self.mesh)
        bucket = max(8, 1 << max(0, nq - 1).bit_length())
        return -(-bucket // bprod) * bprod

    _SERVE_CACHE_MAX = 32  # σ sweeps must not accumulate compiled steps forever
    _AUTO_Q_CAP_AFTER = 2  # consecutive overflowing calls before a bump

    def serve_fn(self, nq_pad: int, sigma: float, tier: str = "f32",
                 impl: Optional[str] = None, k: Optional[int] = None):
        """The cached jitted serve step for one (bucket, σ, tier, impl, k,
        q_cap) key. Returns (fn, cache_hit, resolved_impl)."""
        # normalize before keying: None, "auto" and the resolved backend name
        # must share one compiled step; ditto tier aliases and k=None
        impl = scan.resolve_impl(
            impl if impl is not None else getattr(self.cfg, "impl", "auto"))
        tier = tiers.resolve(tier).name
        k = self.cfg.k if k is None else int(k)
        # capacity is the store-shape lever mutations move: growing/compacting
        # changes every per-slot plane's shape (and PQ's rerank clamp), so it
        # must key the cache — while same-shape mutations (insert into free
        # slots, delete) leave the key intact and keep hitting compiled steps
        key = (nq_pad, float(sigma), tier, impl, k,
               float(self.cfg.q_cap_factor), int(self.cfg.capacity))
        fn = self._serve_cache.pop(key, None)
        cache_hit = fn is not None
        if fn is None:
            fn = jax.jit(make_serve_step(self.cfg, self.mesh, nq_pad,
                                         sigma=float(sigma), tier=tier,
                                         impl=impl, k=k, count_dedup=True))
        self._serve_cache[key] = fn  # re-insert: dict order doubles as LRU
        while len(self._serve_cache) > self._SERVE_CACHE_MAX:
            self._serve_cache.pop(next(iter(self._serve_cache)))
        return fn, cache_hit, impl

    def search(self, queries, sigma: Optional[float] = None,
               quantized: Optional[bool] = None, impl: Optional[str] = None,
               *, tier: Optional[str] = None,
               k: Optional[int] = None) -> api.SearchResult:
        """Serve one query batch; see serving/api.py for the typed contract.

        ``queries`` is an [nq, dim] array or a SearchRequest (then no other
        arguments are allowed). Plain keywords mirror the request fields;
        ``quantized=`` is the retired boolean knob, mapped onto ``tier=`` with
        a DeprecationWarning for one release."""
        if isinstance(queries, api.SearchRequest):
            if any(a is not None for a in (sigma, quantized, impl, tier, k)):
                raise TypeError(
                    "pass either a SearchRequest or keyword overrides, not both")
            req = queries
        else:
            queries = np.asarray(queries)
            if queries.ndim == 1 or queries.shape[0] == 1:
                # single-query traffic belongs on the canonical entry point
                # (it routes through the batching front-end when one is
                # attached); raw 1-row arrays + loose kwargs survive one
                # release behind the shim
                api.warn_deprecated(
                    "search-single-query",
                    "passing a single query as a raw array to "
                    "LiraEngine.search is deprecated; use "
                    "search_one(SearchRequest(queries=q, ...))")
                if queries.ndim == 1:
                    queries = queries[None, :]
            if quantized is not None:
                api.warn_deprecated(
                    "search-quantized-kwarg",
                    "LiraEngine.search(quantized=) is deprecated; pass "
                    "tier='f32' / 'pq' / 'residual_pq' (or a SearchRequest)")
                if tier is None:
                    tier = tiers.legacy_tier_name(
                        quantized, quantized and self.cfg.residual_pq)
            req = api.SearchRequest(queries=queries, k=k, sigma=sigma,
                                    tier=tier, impl=impl)

        tr = self._tracer()
        # tracing wraps host-side stage boundaries in spans but never alters
        # the computation: the device call and the unconditional
        # block_until_ready run identically traced or not, which is what
        # makes tracing-on bit-identical to tracing-off (pinned in
        # tests/test_obs.py)
        with tr.span("engine.search") as sp_root:
            with tr.span("engine.prepare") as sp_prep:
                sigma = self.sigma if req.sigma is None else req.sigma
                tier_obj = tiers.resolve(
                    req.tier if req.tier is not None else self.cfg.tier)
                k = self.cfg.k if req.k is None else int(req.k)
                self._ensure_occupancy()
                missing = [f for f in tier_obj.store_specs(self.cfg)
                           if f not in self.store]
                if missing:
                    raise ValueError(
                        f"engine store lacks {missing} required by tier "
                        f"{tier_obj.name!r}; build with tier={tier_obj.name!r}")
                tier_obj.check_servable(self.cfg)  # e.g. pq refuses residual codes
                nq = req.queries.shape[0]
                nq_pad = self._batch_bucket(nq)
                fn, cache_hit, impl = self.serve_fn(nq_pad, sigma,
                                                    tier_obj.name, req.impl, k)
                qp = np.zeros((nq_pad, self.cfg.dim), np.float32)
                qp[:nq] = req.queries
                # pad rows are masked out of dispatch: they must not probe
                # partitions or occupy q_cap slots that real queries need
                valid = np.zeros((nq_pad,), bool)
                valid[:nq] = True
            with tr.span("engine.device", tier=tier_obj.name, impl=impl,
                         bucket=nq_pad, cache_hit=cache_hit) as sp_dev:
                with self.mesh:
                    out = fn(self.params, self.store, jnp.asarray(qp),
                             jnp.asarray(valid))
                d, i, npb, ovf, dups = jax.block_until_ready(out)
            with tr.span("engine.post") as sp_post:
                npb_np = np.asarray(npb)[:nq]
                overflow = int(np.asarray(ovf).sum())
                dedup_hits = int(np.asarray(dups).sum())
                dists = np.asarray(d)[:nq]
                ids_np = np.asarray(i)[:nq]
            sp_root.set(tier=tier_obj.name, impl=impl, rows=nq)

        stages = None
        if tr.enabled:
            stages = {"prepare": sp_prep.duration_ms,
                      "device": sp_dev.duration_ms,
                      "post": sp_post.duration_ms}

        lbl = {"tier": tier_obj.name, "impl": impl}
        m = self._registry()
        m.counter("lira_engine_searches_total",
                  "engine.search calls").inc(**lbl)
        m.counter("lira_engine_rows_total",
                  "query rows served (pre-padding)").inc(nq, **lbl)
        m.counter("lira_engine_probes_total",
                  "partition probes attempted (pre q_cap drops — includes "
                  "any counted by overflow_probes_total)").inc(
                      float(npb_np.sum()), **lbl)
        m.counter("lira_engine_overflow_probes_total",
                  "probes dropped by q_cap bucket overflow").inc(
                      overflow, **lbl)
        m.counter("lira_engine_dedup_hits_total",
                  "replica-duplicate candidate slots merged away").inc(
                      dedup_hits, **lbl)
        m.counter("lira_engine_jit_cache_hits_total" if cache_hit
                  else "lira_engine_jit_cache_misses_total",
                  "serve-step jit cache").inc(**lbl)
        m.histogram("lira_engine_nprobe_eff",
                    "effective probes per query (σ-adaptive fan-out)",
                    buckets=obs_metrics.NPROBE_BUCKETS).observe_many(
                        npb_np, **lbl)
        m.gauge("lira_engine_q_cap_factor",
                "current dispatch-slack factor").set(
                    float(self.cfg.q_cap_factor))

        result = api.SearchResult(
            dists=dists, ids=ids_np,
            nprobe_eff=npb_np, overflow=overflow,
            stats=api.SearchStats(
                tier=tier_obj.name, impl=impl, k=k, sigma=float(sigma),
                bucket=nq_pad, cache_hit=cache_hit, dedup_hits=dedup_hits,
                latency_ms=sp_root.duration_ms, stages=stages,
                epoch=self.epoch))
        if getattr(self.cfg, "auto_q_cap", False):
            self._maybe_bump_q_cap(result.overflow)
        return result

    def overflow_rate(self) -> float:
        """Cumulative q_cap overflow rate: dropped probes / attempted probes,
        across every tier/impl this engine's registry has seen. 0.0 until any
        search ran. ``lira_engine_probes_total`` counts ATTEMPTED probes —
        ``nprobe_eff`` is summed from ``probe_ok`` before q_cap drops — so it
        is the denominator by itself; adding ``dropped`` to it would count
        every dropped probe twice and under-report the rate."""
        m = self._registry()
        dropped = m.counter("lira_engine_overflow_probes_total").total()
        attempted = m.counter("lira_engine_probes_total").total()
        return dropped / attempted if attempted > 0 else 0.0

    # ------------------------------------------------------------ front-end

    def search_one(self, request: api.SearchRequest) -> api.SearchResult:
        """The canonical single-query entry point. With a front-end attached
        (``attach_frontend``) the request joins the dynamic-batching queue and
        ``result()`` is demanded immediately — coalescing with whatever
        compatible traffic is already waiting; without one it falls back to a
        1-row batch through ``search``. ``request.queries`` is one query:
        ``[dim]`` or ``[1, dim]``."""
        if not isinstance(request, api.SearchRequest):
            raise TypeError("search_one takes a SearchRequest; for raw query "
                            "batches use search()")
        q = np.asarray(request.queries)
        if q.ndim == 1:
            request = dataclasses.replace(request, queries=q[None, :])
        elif q.ndim != 2 or q.shape[0] != 1:
            raise ValueError("search_one serves exactly one query "
                             f"(got shape {q.shape}); use search() for batches")
        if self.frontend is not None:
            return self.frontend.submit(request).result()
        return self.search(request)

    def attach_frontend(self, config=None, **kwargs):
        """Create and attach a ``ServingFrontend`` over this engine (see
        serving/frontend.py for the batching/admission/telemetry contract);
        returns it. Detach with ``engine.frontend = None``."""
        from repro.serving.frontend import ServingFrontend

        self.frontend = ServingFrontend(self, config, **kwargs)
        return self.frontend

    def _maybe_bump_q_cap(self, overflow: int) -> None:
        """Adaptive dispatch slack: after _AUTO_Q_CAP_AFTER consecutive
        overflowing calls, double q_cap_factor and drop the serve cache so the
        next call compiles with the wider buckets (the overflow counter the
        PR 4 dispatch fix surfaced, closed into a control loop)."""
        if overflow <= 0:
            self._overflow_streak = 0
            return
        self._overflow_streak += 1
        if self._overflow_streak >= self._AUTO_Q_CAP_AFTER:
            self.cfg = dataclasses.replace(
                self.cfg, q_cap_factor=self.cfg.q_cap_factor * 2.0)
            self._serve_cache.clear()
            self._overflow_streak = 0
            # adaptation events are observable, not silent cache drops: the
            # bump counter + gauge pair shows WHEN the control loop fired and
            # WHERE the slack factor ended up
            m = self._registry()
            m.counter("lira_engine_q_cap_bumps_total",
                      "auto_q_cap adaptations (doubled q_cap_factor, "
                      "dropped serve cache)").inc()
            m.gauge("lira_engine_q_cap_factor",
                    "current dispatch-slack factor").set(
                        float(self.cfg.q_cap_factor))

    # ------------------------------------------------------------- mutation
    #
    # The store lifecycle is epoch-versioned: every mutation drains the
    # front-end (no coalesced batch may span two epochs), rewrites the
    # per-slot planes the tier declares (tiers.Tier.slot_fields), and bumps
    # ``epoch``. Shape is the only thing that invalidates compiled serve
    # steps: growing or compacting ``capacity`` changes plane shapes (and
    # PQ's rerank clamp), so it enters the serve-fn cache key and clears the
    # cache; same-shape mutations swap in new device arrays of identical
    # shape/dtype, which jitted fns accept without retracing.

    def _ensure_occupancy(self) -> None:
        """Stores predating the mutable-index refactor (and raw test store
        dicts) carry no occupancy plane — a dense store's occupancy is
        exactly its id validity."""
        if "occupancy" not in self.store:
            self.store = dict(self.store)
            self.store["occupancy"] = self.store["ids"] >= 0

    def _staleness_counters(self) -> np.ndarray:
        if (self._stale_inserts is None
                or len(self._stale_inserts) != self.cfg.n_partitions):
            self._stale_inserts = np.zeros(self.cfg.n_partitions, np.int64)
        return self._stale_inserts

    def _quiesce_frontend(self) -> None:
        """Epoch-swap atomicity: flush the front-end's in-flight coalesced
        batches BEFORE mutating, so every batch is served wholly within one
        epoch (its results carry the pre-mutation SearchStats.epoch; requests
        submitted after the mutation see the bumped one)."""
        if self.frontend is not None:
            self.frontend.quiesce()

    def _bump_epoch(self, *, shape_changed: bool = False) -> None:
        self.epoch += 1
        if shape_changed:
            self._serve_cache.clear()
        m = self._registry()
        m.counter("lira_engine_epoch_bumps_total",
                  "store mutations (insert/delete/compact/repartition)").inc()
        if shape_changed:
            m.counter("lira_engine_shape_epoch_bumps_total",
                      "shape-changing mutations (capacity moved; compiled "
                      "serve steps invalidated)").inc()
        m.gauge("lira_engine_epoch", "current store epoch").set(
            float(self.epoch))

    def _tombstones_per_partition(self) -> np.ndarray:
        """A tombstone is a cleared-occupancy slot still holding an id ≥ 0
        (delete leaves the id plane behind; reuse or compaction heals it)."""
        occ = np.asarray(self.store["occupancy"])
        ids = np.asarray(self.store["ids"])
        return (~occ & (ids >= 0)).sum(1).astype(np.int64)

    def _update_store_gauges(self) -> None:
        occ = np.asarray(self.store["occupancy"])
        live = int(occ.sum())
        tomb = int(self._tombstones_per_partition().sum())
        m = self._registry()
        m.gauge("lira_engine_live_slots", "occupied store slots").set(live)
        m.gauge("lira_engine_tombstone_slots",
                "deleted-but-uncompacted slots (insertable, id not yet "
                "healed)").set(tomb)
        m.gauge("lira_engine_free_slots",
                "never-written or compacted-away slots").set(
                    occ.size - live - tomb)

    _GROW_SLACK = 1.5  # capacity overshoot per grow, so steady insert
    #                    streams amortize recompiles instead of growing (and
    #                    recompiling) once per insert batch

    def insert(self, x, ids) -> int:
        """Append rows to the live index. Each row takes a free slot in the
        nearest partition that has one (within ``mutable.PLACE_WINDOW``
        nearest); rows that land off their argmin partition count toward the
        staleness that triggers ``maybe_repartition``. When some row finds no
        slot, every per-slot plane grows (with ``_GROW_SLACK``) — a shape
        change that invalidates compiled serve steps; otherwise the mutation
        is same-shape and the jit cache keeps hitting. New rows get no η
        replicas until the next repartition refreshes the whole replica set.
        Callers own id uniqueness (an id inserted twice becomes two live
        rows, deduped at merge time like a replica). Returns rows inserted."""
        from repro.serving import mutable

        x = np.asarray(x, np.float32)
        if x.ndim == 1:
            x = x[None, :]
        ids = np.atleast_1d(np.asarray(ids, np.int32))
        if x.shape[0] != ids.shape[0]:
            raise ValueError(f"{x.shape[0]} rows but {ids.shape[0]} ids")
        if x.shape[1] != self.cfg.dim:
            raise ValueError(f"rows have dim {x.shape[1]}, index has "
                             f"dim {self.cfg.dim}")
        if x.shape[0] == 0:
            return 0
        self._ensure_occupancy()
        self._quiesce_frontend()
        tier = tiers.resolve(self.cfg.tier)
        tr = self._tracer()
        with tr.span("engine.insert", rows=int(x.shape[0])) as sp:
            occ = np.asarray(self.store["occupancy"])
            cents = np.asarray(self.store["centroids"], np.float32)
            d2 = ((x * x).sum(1)[:, None] - 2.0 * x @ cents.T
                  + (cents * cents).sum(1)[None, :])
            plan = mutable.plan_insert(occ, d2)
            parts, slots, mis = plan.parts, plan.slots, plan.misassigned
            shape_changed = not bool(plan.ok.all())
            if shape_changed:
                # grow so every unplaced row fits in its argmin partition
                occ_w = occ.copy()
                occ_w[parts[plan.ok], slots[plan.ok]] = True
                fail = ~plan.ok
                demand = occ_w.sum(1) + np.bincount(
                    d2[fail].argmin(1), minlength=self.cfg.n_partitions)
                new_cap = max(int(demand.max()),
                              int(np.ceil(self.cfg.capacity
                                          * self._GROW_SLACK)))
                planes = mutable.grow_store(
                    {n: self.store[n] for n in tier.slot_fields(self.cfg)},
                    new_cap)
                self.store = dict(self.store)
                self.store.update(
                    {n: jnp.asarray(a) for n, a in planes.items()})
                self.cfg = dataclasses.replace(self.cfg, capacity=new_cap)
                occ_w = mutable.grow_store({"occupancy": occ_w},
                                           new_cap)["occupancy"]
                replan = mutable.plan_insert(occ_w, d2[fail])
                assert bool(replan.ok.all()), "grown store must fit all rows"
                parts = np.where(plan.ok, parts, -1)
                slots = np.where(plan.ok, slots, -1)
                parts[fail], slots[fail] = replan.parts, replan.slots
                mis = mis.copy()
                mis[fail] = replan.misassigned
            # the tier re-encodes content planes for the destination
            # partitions; ids/occupancy are engine bookkeeping
            rows = tier.encode_rows(self.cfg, self.store, x, parts)
            store = dict(self.store)
            p, s = jnp.asarray(parts), jnp.asarray(slots)
            for name, vals in rows.items():
                store[name] = store[name].at[p, s].set(
                    jnp.asarray(vals).astype(store[name].dtype))
            store["ids"] = store["ids"].at[p, s].set(jnp.asarray(ids))
            store["occupancy"] = store["occupancy"].at[p, s].set(True)
            self.store = store
            np.add.at(self._staleness_counters(), parts[mis], 1)
            sp.set(misassigned=int(mis.sum()), grew=shape_changed)
        self._bump_epoch(shape_changed=shape_changed)
        m = self._registry()
        m.counter("lira_engine_inserts_total", "rows inserted").inc(
            int(x.shape[0]))
        m.counter("lira_engine_misassigned_inserts_total",
                  "inserts placed off their argmin partition (staleness "
                  "source)").inc(int(mis.sum()))
        if shape_changed:
            m.counter("lira_engine_capacity_grows_total",
                      "insert-driven capacity growths").inc()
        self._update_store_gauges()
        return int(x.shape[0])

    def delete(self, ids) -> int:
        """Tombstone every live slot holding one of ``ids`` (replicas
        included): occupancy clears, the id plane keeps the id until the slot
        is reused or compacted. Same-shape — zero recompiles. Returns the
        number of slots tombstoned (0 for wholly unknown ids, no epoch
        bump)."""
        self._ensure_occupancy()
        ids = np.unique(np.atleast_1d(np.asarray(ids, np.int64)))
        occ = np.asarray(self.store["occupancy"])
        hit = occ & np.isin(np.asarray(self.store["ids"]), ids)
        removed = int(hit.sum())
        m = self._registry()
        m.counter("lira_engine_deletes_total", "ids passed to delete").inc(
            len(ids))
        m.counter("lira_engine_deleted_slots_total",
                  "live slots tombstoned by delete").inc(removed)
        if not removed:
            return 0
        self._quiesce_frontend()
        tr = self._tracer()
        with tr.span("engine.delete", slots=removed):
            self.store = dict(self.store)
            self.store["occupancy"] = jnp.asarray(occ & ~hit)
        self._bump_epoch()
        self._update_store_gauges()
        return removed

    def compact(self) -> int:
        """Repack live slots to the front of every partition and shrink
        capacity to the max live count (floored at cfg.k — the scan's top-k
        needs that many candidate slots): tombstones and holes are erased,
        dead tails reset to pad sentinels. Usually a shape change (compiled
        serve steps invalidated). Returns reclaimed slots (Δcapacity · B)."""
        from repro.serving import mutable

        self._ensure_occupancy()
        self._quiesce_frontend()
        tier = tiers.resolve(self.cfg.tier)
        tr = self._tracer()
        with tr.span("engine.compact",
                     capacity=int(self.cfg.capacity)) as sp:
            occ = np.asarray(self.store["occupancy"])
            packed, new_cap = mutable.compact_store(
                {n: self.store[n] for n in tier.slot_fields(self.cfg)}, occ,
                min_capacity=self.cfg.k)
            shape_changed = new_cap != self.cfg.capacity
            reclaimed = (self.cfg.capacity - new_cap) * self.cfg.n_partitions
            store = dict(self.store)
            store.update({n: jnp.asarray(a) for n, a in packed.items()})
            self.store = store
            if shape_changed:
                self.cfg = dataclasses.replace(self.cfg, capacity=new_cap)
            sp.set(new_capacity=new_cap, reclaimed=reclaimed)
        self._bump_epoch(shape_changed=shape_changed)
        m = self._registry()
        m.counter("lira_engine_compactions_total", "compaction passes").inc()
        m.counter("lira_engine_reclaimed_slots_total",
                  "slots reclaimed by compaction").inc(reclaimed)
        self._update_store_gauges()
        return reclaimed

    def staleness(self) -> float:
        """(misassigned inserts + tombstoned slots) / live rows — the drift
        measure ``maybe_repartition`` gates on (cfg.repartition_threshold).
        Tombstones count because holes dilute every probe of their partition;
        misassigned inserts because the probing model ranks partitions by
        content the argmin says belongs elsewhere (the boundary drift IRLI's
        re-assignment loop repairs)."""
        self._ensure_occupancy()
        live = int(np.asarray(self.store["occupancy"]).sum())
        tomb = int(self._tombstones_per_partition().sum())
        return (int(self._staleness_counters().sum()) + tomb) / max(1, live)

    def maybe_repartition(self, *, force: bool = False,
                          max_moves: Optional[int] = None) -> bool:
        """IRLI-style iterative re-assignment (arxiv 2103.09944), gated on
        staleness: when (misassigned inserts + tombstones) / live rows
        reaches ``cfg.repartition_threshold`` (or ``force=True``), re-assign
        every live row to its argmin partition (``max_moves`` caps the pass
        to the most-misassigned rows, by margin), re-encode through the tier,
        refresh the η replica set via core.redundancy.plan_redundancy, and
        rebuild the slot layout — erasing tombstones and resetting staleness.
        Centroids, codebooks and the probing model are unchanged: drift is
        repaired by moving rows, not retraining. Returns True iff a
        repartition ran."""
        self._ensure_occupancy()
        occ = np.asarray(self.store["occupancy"])
        frac = ((self._staleness_counters()
                 + self._tombstones_per_partition())
                / np.maximum(1, occ.sum(1)))
        m = self._registry()
        m.histogram("lira_engine_partition_staleness",
                    "per-partition staleness fraction at repartition checks",
                    buckets=obs_metrics.STALENESS_BUCKETS).observe_many(frac)
        if not force and self.staleness() < getattr(
                self.cfg, "repartition_threshold", 0.25):
            return False
        self._repartition(max_moves=max_moves)
        return True

    def _repartition(self, max_moves: Optional[int] = None) -> None:
        from repro.core.redundancy import plan_redundancy, replica_rows
        from repro.serving import mutable

        self._quiesce_frontend()
        tier = tiers.resolve(self.cfg.tier)
        tr = self._tracer()
        with tr.span("engine.repartition") as sp:
            occ = np.asarray(self.store["occupancy"])
            ids = np.asarray(self.store["ids"])
            cents = np.asarray(self.store["centroids"], np.float32)
            nb, cap = occ.shape
            pb, ps = np.nonzero(occ)
            if len(pb) == 0:
                return
            x = np.asarray(self.store["vectors"])[pb, ps].astype(np.float32)
            rid = ids[pb, ps]
            # one primary copy per id (η replicas are regenerated below):
            # keep the copy nearest its own partition's centroid
            d_own = ((x - cents[pb]) ** 2).sum(1)
            order = np.lexsort((d_own, rid))
            keep_first = np.ones(len(order), bool)
            keep_first[1:] = rid[order][1:] != rid[order][:-1]
            keep = order[keep_first]
            xu, idu, cur = x[keep], rid[keep], pb[keep].astype(np.int64)
            d2 = ((xu * xu).sum(1)[:, None] - 2.0 * xu @ cents.T
                  + (cents * cents).sum(1)[None, :])
            best = d2.argmin(1).astype(np.int64)
            assign, mis = best, best != cur
            if max_moves is not None and int(mis.sum()) > int(max_moves):
                # partial pass: only the most-misassigned rows move, ranked
                # by how much closer their argmin centroid is
                rows_i = np.arange(len(xu))
                margin = d2[rows_i, cur] - d2[rows_i, best]
                cand = np.flatnonzero(mis)
                top = cand[np.argsort(-margin[cand],
                                      kind="stable")[:int(max_moves)]]
                assign = cur.copy()
                assign[top] = best[top]
            moved = int((assign != cur).sum())
            x_all, id_all, a_all = xu, idu, assign
            if getattr(self.cfg, "eta", 0.0) > 0:
                # replica refresh: boundary points re-picked by the probing
                # model against the DRIFTED assignment, so replicas track
                # the boundaries the churn moved
                plan = plan_redundancy(self.params, xu,
                                       assign.astype(np.int32), cents,
                                       eta=self.cfg.eta, sigma=self.sigma)
                rv, ri, ra = replica_rows(plan, xu, idu)
                x_all = np.concatenate([xu, rv], 0)
                id_all = np.concatenate([idu, ri], 0)
                a_all = np.concatenate([assign, ra.astype(np.int64)], 0)
            slots, counts = mutable.layout_rows(a_all, nb)
            needed = max(int(counts.max(initial=1)), self.cfg.k)
            # capacity only grows when the new layout demands it — a layout
            # that still fits keeps the shape (and the compiled serve steps)
            shape_changed = needed > cap
            new_cap = needed if shape_changed else cap
            if shape_changed:
                self.cfg = dataclasses.replace(self.cfg, capacity=new_cap)
            # full re-encode through the tier: codebooks/centroids/probing
            # are unchanged, so unmoved rows keep bit-identical codes
            rows = tier.encode_rows(self.cfg, self.store, x_all, a_all)
            rows["ids"] = id_all.astype(np.int32)
            store = dict(self.store)
            for name in tier.slot_fields(self.cfg):
                old = np.asarray(self.store[name])
                plane = np.full((nb, new_cap, *old.shape[2:]),
                                mutable.fill_value(name), old.dtype)
                if name == "occupancy":
                    plane[a_all, slots] = True
                else:
                    plane[a_all, slots] = np.asarray(
                        rows[name]).astype(old.dtype)
                store[name] = jnp.asarray(plane)
            self.store = store
            self._stale_inserts = np.zeros(nb, np.int64)
            sp.set(rows=len(xu), moved=moved, replicas=len(x_all) - len(xu),
                   capacity=new_cap)
        self._bump_epoch(shape_changed=shape_changed)
        m = self._registry()
        m.counter("lira_engine_repartitions_total",
                  "IRLI-style re-assignment passes").inc()
        m.counter("lira_engine_repartition_moved_rows_total",
                  "rows moved to their argmin partition").inc(moved)
        self._update_store_gauges()

    # ------------------------------------------------------------ persistence

    def save(self, directory, step: int = 0):
        """Persist params + store + config via repro.ckpt (atomic, crash-safe)
        so built indexes stop being rebuilt per process. bfloat16 planes are
        upcast to f32 on disk (npy has no bf16); ``load`` restores the tier
        dtype from the config."""
        from repro.ckpt import CheckpointManager

        def _savable(leaf):
            if jnp.dtype(getattr(leaf, "dtype", np.float32)) == jnp.bfloat16:
                return np.asarray(jnp.asarray(leaf).astype(jnp.float32))
            return np.asarray(leaf)

        self._ensure_occupancy()  # mutable-index state always round-trips
        tree = jax.tree.map(_savable, {"params": self.params,
                                       "store": dict(self.store)})
        extra = {"config": dataclasses.asdict(self.cfg), "sigma": self.sigma,
                 "epoch": int(self.epoch),
                 "stale_inserts": [int(v) for v in
                                   self._staleness_counters()]}
        return CheckpointManager(directory).save(step, tree, extra=extra)

    @classmethod
    def load(cls, directory, mesh, step: Optional[int] = None):
        """Rebuild an engine from a ``save`` checkpoint: config comes from the
        manifest, the restore template (tree structure + dtypes) is derived
        from the config's tier declarations."""
        import json
        import pathlib

        from repro.ckpt import CheckpointManager

        if not pathlib.Path(directory).is_dir():
            # check before CheckpointManager, whose constructor mkdirs — a
            # typo'd path must not leave an empty directory tree behind
            raise FileNotFoundError(f"no engine checkpoint under {directory}")
        mgr = CheckpointManager(directory)
        step = step if step is not None else mgr.latest_step()
        if step is None:
            raise FileNotFoundError(f"no engine checkpoint under {directory}")
        meta = json.loads(
            (mgr.dir / f"step_{step:010d}" / "manifest.json").read_text())
        raw = {key: tuple(val) if isinstance(val, list) else val
               for key, val in meta["extra"]["config"].items()}
        cfg = LiraSystemConfig(**raw)
        template = {
            "params": jax.tree.map(lambda s: jnp.zeros((), s.dtype),
                                   probing_param_specs_cache(cfg)),
            "store": {name: jnp.zeros((), spec.dtype)
                      for name, spec in store_specs(cfg).items()},
        }
        tree, _, extra = mgr.restore(template, step=step)
        stale = extra.get("stale_inserts")
        return cls(cfg=cfg, params=tree["params"], store=tree["store"],
                   mesh=mesh, sigma=float(extra.get("sigma", 0.5)),
                   epoch=int(extra.get("epoch", 0)),
                   _stale_inserts=(np.asarray(stale, np.int64)
                                   if stale is not None else None))
