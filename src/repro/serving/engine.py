"""Distributed LIRA serving engine — the paper's system on a TPU pod.

Key insight of the TPU mapping (DESIGN.md §3): the probing model's output is a
query→partition ROUTING problem, identical in structure to MoE token dispatch.
serve_step:

  1. queries sharded over ("pod","data"); partition store sharded over "model"
     (each chip owns B/16 partitions); probing model + centroids replicated;
  2. per chip: probing probabilities → top-`nprobe_max` partitions, σ-masked
     (query-adaptive nprobe, paper §3.4);
  3. sort-based dispatch of queries into per-local-partition buckets of static
     capacity `q_cap` (the MoE-dispatch trick applied to ANN — compute scales
     with Q·nprobe·cap, NOT Q·N: partition pruning materializes as real FLOP
     savings under static shapes). Batch-padding rows are masked out of
     dispatch via the `valid` operand so they never steal q_cap slots from
     real queries, and probes dropped by bucket overflow are COUNTED and
     returned (the serve step's 4th output; `LiraEngine.search` surfaces the
     total) instead of being silently swallowed;
  4. per local partition: the scan stage is backend-dispatched through
     serving/scan.py (cfg.impl: auto | ref | pallas | interpret). "ref" is the
     portable jnp path under lax.map; "pallas" runs the fused kernels
     grid-batched over the whole [b_loc, q_cap] dispatch buffer in one launch
     (kernels.l2_topk_batched for f32; native on TPU, interpreted elsewhere).
     WHAT is scanned is declared by the serving tier (serving/tiers.py): the
     engine resolves cfg.tier from the registry and iterates the tier's store
     field + scan operand declarations — it never branches on tier-specific
     booleans, so a new storage/quantization strategy is one registered Tier
     class with zero edits here. The "pq" tier threads a shared ADC LUT +
     shortlist depth (two-stage scan, serving/quantized.py); "residual_pq"
     adds the residual ADC identity's cterm plane and per-(query, partition)
     offsets (core/pq.py);
  5. scatter back per query, local top-k, all-gather(k·shards) over "model",
     final merge. Collective volume is O(Q·k), independent of N.

Multi-pod: each pod holds a full index replica; the front-end routes query
batches to pods (repro.distributed.fault simulates replica failover).

Host-side callers use the typed surface in serving/api.py: LiraEngine.build
takes a BuildConfig, search takes queries or a SearchRequest and returns a
SearchResult (the legacy 4-tuple unpacking survives one release behind a
DeprecationWarning shim).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import LiraSystemConfig, ShapeSpec
from repro.core import probing
from repro.kernels import ops as kops
from repro.models.api import ModelBundle, StepDef, adamw_state_pspecs, adamw_state_specs, sds
from repro.train import optimizer as opt

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serving import api
from repro.serving import scan
from repro.serving import tiers
from repro.utils.compat import shard_map


def batch_mesh_info(mesh):
    """(batch_axes, bspec, bprod) for the query-batch axes of a mesh — the
    single source for how serve steps and batch bucketing split queries."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)
    bprod = int(np.prod([mesh.shape[a] for a in batch_axes])) if batch_axes else 1
    return batch_axes, bspec, bprod


def probing_param_specs(cfg: LiraSystemConfig):
    pc = probing.ProbingConfig(dim=cfg.dim, n_partitions=cfg.n_partitions,
                               q_hidden=tuple(cfg.q_hidden), i_hidden=tuple(cfg.i_hidden),
                               p_hidden=tuple(cfg.p_hidden))
    return jax.eval_shape(lambda: probing.init(jax.random.PRNGKey(0), pc))


def store_specs(cfg: LiraSystemConfig):
    """Store field shape specs for cfg's serving tier — a pure delegation to
    the tier registry (serving/tiers.py declares WHAT each tier stores)."""
    return tiers.resolve(cfg.tier).store_specs(cfg)


def store_pspecs(mesh, cfg: LiraSystemConfig | None = None):
    """Mesh PartitionSpecs per store field; cfg=None means the base f32 tier.
    (mesh is unused — pspecs name axes symbolically; the parameter is kept
    only so existing callers' signatures stay valid.)"""
    del mesh
    tier = tiers.resolve(cfg.tier if cfg is not None else "f32")
    return tier.store_pspecs(cfg)


# ------------------------------------------------------------- serve step

def _dup_count(ids_pool):
    """Count duplicate id slots per candidate pool row ([nq, pool]): valid
    slots (id ≥ 0) minus distinct ids, summed over queries. This is the
    replica-dedup hit count — how many candidate slots the η-redundancy
    replicas burned on ids another partition already supplied.

    Counted at each merge the serve step actually runs (local pool, then the
    gathered cross-shard top-k), so under model sharding it is a lower bound
    on the full-pool duplicate count: a cross-shard duplicate pair where one
    copy misses its shard's local top-k is never observed (counting it would
    require gathering whole pools — O(Q·pool·shards) traffic instead of the
    O(Q·k) the merge is designed around). Results stay bit-identical across
    shardings; only this telemetry is merge-local."""
    s = jnp.sort(ids_pool, axis=1)
    valid = s >= 0
    first = jnp.concatenate(
        [jnp.ones_like(s[:, :1], jnp.bool_), s[:, 1:] != s[:, :-1]], axis=1)
    return (valid.sum(1) - (valid & first).sum(1)).sum().astype(jnp.int32)


def make_serve_step(cfg: LiraSystemConfig, mesh, n_queries: int, *, sigma: float = 0.5,
                    q_cap_factor: float | None = None,
                    tier: str | tiers.Tier | None = None,
                    impl: str | None = None,
                    k: int | None = None,
                    count_dedup: bool = False):
    _, bspec, bprod = batch_mesh_info(mesh)
    model_n = mesh.shape.get("model", 1)
    q_row = n_queries // bprod
    b_loc = cfg.n_partitions // model_n
    q_cap_factor = q_cap_factor if q_cap_factor is not None else getattr(cfg, "q_cap_factor", 2.0)
    q_cap = max(8, int(q_row * cfg.nprobe_max / cfg.n_partitions * q_cap_factor))
    k = cfg.k if k is None else int(k)
    tier = tiers.resolve(tier if tier is not None else cfg.tier)
    impl = getattr(cfg, "impl", "auto") if impl is None else impl
    scan_impl = scan.resolve_impl(impl)  # fail fast on typos, not at trace time
    # the tier declares its store fields; everything beyond the probing /
    # dispatch / rerank operands (BASE_FIELDS) is threaded through untouched
    # and handed back to the tier when it assembles the scan operands
    pspec_map = tier.store_pspecs(cfg)
    extra_fields = tuple(n for n in tier.store_specs(cfg)
                         if n not in tiers.BASE_FIELDS)

    def f(q_loc, valid_loc, params, cents, vecs_loc, ids_loc, *extras):
        # q_loc: [q_row, d]; valid_loc: [q_row] bool (False = batch padding);
        # vecs_loc: [b_loc, cap, d]; ids_loc: [b_loc, cap]
        # extras: the tier's non-base store fields, in declaration order
        # jax.named_scope labels the serving stages in profiler captures
        # (TensorBoard op_profile groups HLO ops under these names — the
        # --profile-dir recipe in README "Observability"); it is a pure
        # metadata annotation with zero effect on the computation
        with jax.named_scope("lira.probing"):
            cd = (
                jnp.sum(q_loc * q_loc, -1, keepdims=True)
                - 2.0 * q_loc @ cents.T
                + jnp.sum(cents * cents, -1)[None, :]
            )
            p = jax.nn.sigmoid(probing.apply(params, q_loc, cd))    # [q_row, B]
            vals, pidx = jax.lax.top_k(p, cfg.nprobe_max)           # global partitions
            probe_ok = vals > sigma
            probe_ok = probe_ok.at[:, 0].set(True)                  # always ≥1 partition
            # batch-padding rows must not probe: a pad query occupying q_cap
            # slots can evict a real query's probes in small buckets
            probe_ok = probe_ok & valid_loc[:, None]

        # ---- dispatch (sort-based, local partition range only)
        with jax.named_scope("lira.dispatch"):
            b0 = jax.lax.axis_index("model") * b_loc if model_n > 1 else 0
            flat_p = pidx.reshape(-1) - b0
            flat_ok = probe_ok.reshape(-1) & (flat_p >= 0) & (flat_p < b_loc)
            flat_q = jnp.broadcast_to(jnp.arange(q_row)[:, None], pidx.shape).reshape(-1)
            key = jnp.where(flat_ok, flat_p, b_loc)
            order = jnp.argsort(key, stable=True)
            skey = key[order]
            start = jnp.searchsorted(skey, jnp.arange(b_loc + 1))
            pos = jnp.arange(skey.shape[0]) - start[jnp.clip(skey, 0, b_loc)]
            keep = (skey < b_loc) & (pos < q_cap)
            # probes beyond a hot partition's q_cap are dropped — count them so
            # recall degradation is reported, not silent (raise q_cap_factor or
            # rebalance partitions when this is persistently > 0)
            overflow = ((skey < b_loc) & (pos >= q_cap)).sum().astype(jnp.int32)
            row = jnp.where(keep, skey, b_loc)
            col = jnp.where(keep, pos, 0)
            qbuf = jnp.full((b_loc, q_cap), q_row, jnp.int32).at[row, col].set(
                flat_q[order], mode="drop")                          # q_row = invalid

        # ---- per-partition scan: backend-dispatched (serving/scan.py); the
        # tier derives its extra scan operands (ADC LUTs, shortlist depth,
        # residual offsets, …) from the serve-step context — {} = plain f32
        with jax.named_scope("lira.scan"):
            q_pad = jnp.concatenate([q_loc, jnp.full((1, q_loc.shape[1]), 1e9, q_loc.dtype)], 0)
            ctx = tiers.ScanContext(q_loc=q_loc, q_pad=q_pad, cd=cd, b0=b0,
                                    b_loc=b_loc, k=k)
            scan_kw = tier.scan_kwargs(cfg, ctx, dict(zip(extra_fields, extras)))
            dists, rids = scan.run(scan_impl, qbuf, q_pad, vecs_loc, ids_loc, k,
                                   **scan_kw)

        # ---- scatter back per query, local merge
        with jax.named_scope("lira.merge"):
            out_d = jnp.full((q_row + 1, b_loc, k), jnp.inf, jnp.float32)
            out_i = jnp.full((q_row + 1, b_loc, k), -1, jnp.int32)
            cols = jnp.broadcast_to(jnp.arange(b_loc)[:, None], qbuf.shape)
            out_d = out_d.at[qbuf, cols].set(dists, mode="drop")
            out_i = out_i.at[qbuf, cols].set(rids, mode="drop")
            pool_i = out_i[:q_row].reshape(q_row, -1)
            # replica-dedup hit rate (only when asked for: the extra output
            # changes the step signature, so make_bundle and direct callers
            # keep the 4-output form) — measured BEFORE each dedup pass so it
            # counts exactly the duplicate slots the merges collapse
            dedup_hits = _dup_count(pool_i) if count_dedup else None
            # replica-aware local merge: redundancy (η>0) stores the same id in
            # several partitions, so a plain top-k would return duplicate ids
            # and corrupt recall@k — dedup to best-distance-per-id instead
            # (backend dispatch: bitonic Pallas kernel on TPU, jnp elsewhere)
            loc_d, loc_i = kops.dedup_topk(
                out_d[:q_row].reshape(q_row, -1), pool_i, k)

            # ---- cross-shard merge (O(Q·k·shards) bytes — independent of N);
            # replicas of one id can live on different shards, so dedup again
            if model_n > 1:
                all_d = jax.lax.all_gather(loc_d, "model", axis=1, tiled=True)   # [q_row, 16k]
                all_i = jax.lax.all_gather(loc_i, "model", axis=1, tiled=True)
                if count_dedup:
                    # local hits differ per shard → psum; the gathered pool is
                    # identical on every model shard → count it exactly once
                    dedup_hits = (jax.lax.psum(dedup_hits, "model")
                                  + _dup_count(all_i))
                loc_d, loc_i = kops.dedup_topk(all_d, all_i, k)
                overflow = jax.lax.psum(overflow, "model")
        nprobe_eff = probe_ok.sum(-1).astype(jnp.float32)
        if count_dedup:
            return loc_d, loc_i, nprobe_eff, overflow[None], dedup_hits[None]
        return loc_d, loc_i, nprobe_eff, overflow[None]

    param_spec = jax.tree.map(lambda _: P(), probing_param_specs_cache(cfg))
    in_specs = (P(bspec, None), P(bspec), param_spec,
                pspec_map["centroids"], pspec_map["vectors"], pspec_map["ids"],
                *(pspec_map[n] for n in extra_fields))

    out_specs = (P(bspec, None), P(bspec, None), P(bspec), P(bspec))
    if count_dedup:
        out_specs = out_specs + (P(bspec),)

    def serve_step(params, store, queries, valid=None):
        if valid is None:
            valid = jnp.ones((n_queries,), jnp.bool_)
        args = (queries, valid, params, store["centroids"], store["vectors"],
                store["ids"], *(store[n] for n in extra_fields))
        return shard_map(
            f, mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_vma=False,
        )(*args)

    return serve_step


@functools.lru_cache(maxsize=None)
def _probing_specs_cached(dim, b, qh, ih, ph):
    pc = probing.ProbingConfig(dim=dim, n_partitions=b, q_hidden=qh, i_hidden=ih, p_hidden=ph)
    return jax.eval_shape(lambda: probing.init(jax.random.PRNGKey(0), pc))


def probing_param_specs_cache(cfg: LiraSystemConfig):
    return _probing_specs_cached(cfg.dim, cfg.n_partitions, tuple(cfg.q_hidden),
                                 tuple(cfg.i_hidden), tuple(cfg.p_hidden))


# ------------------------------------------------------------- train step

def make_probe_train_step(cfg: LiraSystemConfig, mesh, tx):
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def train_step(state, batch):
        params, opt_state = state

        def loss_fn(p):
            return probing.bce_loss(p, batch["q"], batch["cent_dist"], batch["labels"])

        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads, gnorm = opt.clip_by_global_norm(grads, 1.0)
        updates, opt_state = tx.update(grads, opt_state, params)
        params = opt.apply_updates(params, updates)
        return (params, opt_state), {"loss": loss, "grad_norm": gnorm}

    return train_step


# ------------------------------------------------------------- bundle

def make_bundle(cfg: LiraSystemConfig, mesh) -> ModelBundle:
    _, bspec, _ = batch_mesh_info(mesh)
    tx = opt.adamw(opt.cosine_schedule(1e-3, 50, 5000))
    pc = probing.ProbingConfig(dim=cfg.dim, n_partitions=cfg.n_partitions,
                               q_hidden=tuple(cfg.q_hidden), i_hidden=tuple(cfg.i_hidden),
                               p_hidden=tuple(cfg.p_hidden))

    def step(shape: ShapeSpec) -> StepDef:
        if shape.kind == "lira_serve":
            nq = shape["n_queries"]
            fn_inner = make_serve_step(cfg, mesh, nq)

            def fn(params, store, queries):
                return fn_inner(params, store, queries)

            return StepDef(
                fn=fn,
                input_specs={"store": store_specs(cfg), "queries": sds((nq, cfg.dim))},
                input_pspecs={"store": store_pspecs(mesh, cfg), "queries": P(bspec, None)},
                out_pspecs=None,
            )
        if shape.kind == "lira_train":
            b = shape["batch"]
            return StepDef(
                fn=make_probe_train_step(cfg, mesh, tx),
                input_specs={
                    "q": sds((b, cfg.dim)),
                    "cent_dist": sds((b, cfg.n_partitions)),
                    "labels": sds((b, cfg.n_partitions)),
                },
                input_pspecs={"q": P(bspec, None), "cent_dist": P(bspec, None),
                              "labels": P(bspec, None)},
                out_pspecs=None,
            )
        raise ValueError(shape.kind)

    return ModelBundle(
        name=cfg.arch,
        config=cfg,
        init=lambda rng, shape=None: probing.init(rng, pc),
        param_specs=lambda shape=None: probing_param_specs_cache(cfg),
        param_pspecs=lambda shape=None: jax.tree.map(lambda _: P(), probing_param_specs_cache(cfg)),
        step=step,
        opt_specs=lambda shape=None: adamw_state_specs(probing_param_specs_cache(cfg)),
        opt_pspecs=lambda shape=None: adamw_state_pspecs(
            jax.tree.map(lambda _: P(), probing_param_specs_cache(cfg))),
    )


# ------------------------------------------------------------- host engine

@dataclasses.dataclass
class LiraEngine:
    """End-to-end host-driven engine: build (k-means → train probe → redundancy
    → tier store construction) then serve batches via the distributed
    serve_step. The typed surface lives in serving/api.py — ``build`` takes a
    BuildConfig, ``search`` takes queries or a SearchRequest and returns a
    SearchResult; which store planes exist and what the scan reads is declared
    by the serving tier (serving/tiers.py).

    Jitted serve steps are cached per (bucket, σ, tier, impl, k, q_cap) key:
    query batches are padded to power-of-two buckets so repeated traffic of
    varying size hits the jit cache instead of recompiling every call, and the
    pad rows are masked out of dispatch (they never probe or take q_cap slots).
    With ``cfg.auto_q_cap`` the engine doubles ``q_cap_factor`` after
    ``_AUTO_Q_CAP_AFTER`` consecutive overflowing calls and drops the cache,
    so the next bucket recompiles with the extra dispatch slack.
    """

    cfg: LiraSystemConfig
    params: dict
    store: dict
    mesh: jax.sharding.Mesh
    sigma: float = 0.5
    # attached serving front-end (serving/frontend.py); search_one routes
    # through it when present. Not part of engine identity or checkpoints.
    frontend: Optional[object] = dataclasses.field(default=None, repr=False,
                                                   compare=False)
    # observability (repro.obs): tracer=None means spans are free no-ops
    # (obs_trace.NOOP); metrics=None records into the process-wide
    # default_registry(). Neither participates in identity or checkpoints.
    tracer: Optional[object] = dataclasses.field(default=None, repr=False,
                                                 compare=False)
    metrics: Optional[object] = dataclasses.field(default=None, repr=False,
                                                  compare=False)
    _serve_cache: dict = dataclasses.field(default_factory=dict, repr=False,
                                           compare=False)
    _overflow_streak: int = dataclasses.field(default=0, repr=False,
                                              compare=False)

    def _tracer(self):
        return self.tracer if self.tracer is not None else obs_trace.NOOP

    def _registry(self) -> obs_metrics.MetricsRegistry:
        return (self.metrics if self.metrics is not None
                else obs_metrics.default_registry())

    @classmethod
    def build(cls, mesh, x: np.ndarray, config: api.BuildConfig | None = None,
              **legacy_kwargs):
        """Build an index over ``x`` per the BuildConfig recipe.

        Legacy surface (one release): keyword arguments matching BuildConfig
        fields are still accepted when no config object is given, and the
        retired ``quantized=`` / ``residual=`` booleans map onto ``tier=``
        with a DeprecationWarning.
        """
        from repro.core import build_store, ground_truth as gt, kmeans_fit
        from repro.core.redundancy import plan_redundancy, replica_rows
        from repro.core.train_probing import train_probing_model

        if "quantized" in legacy_kwargs or "residual" in legacy_kwargs:
            api.warn_deprecated(
                "build-tier-kwargs",
                "LiraEngine.build(quantized=, residual=) is deprecated; pass "
                "BuildConfig(tier='pq') / BuildConfig(tier='residual_pq')")
            residual = bool(legacy_kwargs.pop("residual", False))
            quantized = bool(legacy_kwargs.pop("quantized", False))
            legacy_kwargs.setdefault(
                "tier", tiers.legacy_tier_name(quantized, residual))
        if config is None:
            config = api.BuildConfig(**legacy_kwargs)
        elif legacy_kwargs:
            raise TypeError("pass either a BuildConfig or keyword arguments, "
                            f"not both (got {sorted(legacy_kwargs)})")

        tier = tiers.resolve(config.tier)
        rng = jax.random.PRNGKey(config.seed)
        host = np.random.default_rng(config.seed)
        n_partitions = config.n_partitions
        st = kmeans_fit(rng, jnp.asarray(x), n_clusters=n_partitions, n_iters=20)
        assign, cents = np.asarray(st.assign), np.asarray(st.centroids)

        sub = host.choice(len(x), int(len(x) * config.train_frac), replace=False)
        xs = x[sub]
        _, sti = gt.exact_knn(xs, xs, config.k, exclude_self=True)
        part_of = assign[sub]
        lab = np.zeros((len(sub), n_partitions), np.float32)
        rows = np.repeat(np.arange(len(sub)), sti.shape[1])
        np.add.at(lab, (rows, part_of[sti].reshape(-1)), 1.0)
        lab = (lab > 0).astype(np.float32)
        params, _ = train_probing_model(rng, xs, lab, cents,
                                        epochs=config.epochs, log=config.log)

        ids = np.arange(len(x), dtype=np.int32)
        plan = plan_redundancy(params, x, assign, cents, eta=config.eta)
        extra = replica_rows(plan, x, ids)
        store_h = build_store(x, ids, assign, cents, extra=extra)
        dim = x.shape[1]
        cfg = LiraSystemConfig(
            arch="lira", dim=dim, n_partitions=n_partitions,
            capacity=store_h.capacity, k=config.k,
            nprobe_max=min(n_partitions,
                           config.nprobe_max or max(8, n_partitions // 8)),
            tier=tier.name, pq_m=config.pq_m or 0, pq_ks=config.pq_ks,
            rerank=config.rerank, impl=config.impl,
            store_dtype=config.store_dtype, q_cap_factor=config.q_cap_factor,
            auto_q_cap=config.auto_q_cap,
        )
        # the tier owns store construction (and may amend cfg: PQ resolves
        # pq_m, clamps pq_ks for tiny stores)
        store, cfg = tier.build_store(jax.random.fold_in(rng, 1), cfg, store_h)
        if not cfg.pq_m:  # tiers without PQ leave the knob at its default
            cfg = dataclasses.replace(cfg, pq_m=16)
        return cls(cfg=cfg, params=params, store=store, mesh=mesh,
                   sigma=config.sigma)

    def _batch_bucket(self, nq: int) -> int:
        """Pad batch sizes to power-of-two buckets (≥8, rounded up to a
        multiple of the batch-mesh product so shard_map can split the batch)
        so the jitted serve step is reused across nearby batch sizes."""
        _, _, bprod = batch_mesh_info(self.mesh)
        bucket = max(8, 1 << max(0, nq - 1).bit_length())
        return -(-bucket // bprod) * bprod

    _SERVE_CACHE_MAX = 32  # σ sweeps must not accumulate compiled steps forever
    _AUTO_Q_CAP_AFTER = 2  # consecutive overflowing calls before a bump

    def serve_fn(self, nq_pad: int, sigma: float, tier: str = "f32",
                 impl: Optional[str] = None, k: Optional[int] = None):
        """The cached jitted serve step for one (bucket, σ, tier, impl, k,
        q_cap) key. Returns (fn, cache_hit, resolved_impl)."""
        # normalize before keying: None, "auto" and the resolved backend name
        # must share one compiled step; ditto tier aliases and k=None
        impl = scan.resolve_impl(
            impl if impl is not None else getattr(self.cfg, "impl", "auto"))
        tier = tiers.resolve(tier).name
        k = self.cfg.k if k is None else int(k)
        key = (nq_pad, float(sigma), tier, impl, k,
               float(self.cfg.q_cap_factor))
        fn = self._serve_cache.pop(key, None)
        cache_hit = fn is not None
        if fn is None:
            fn = jax.jit(make_serve_step(self.cfg, self.mesh, nq_pad,
                                         sigma=float(sigma), tier=tier,
                                         impl=impl, k=k, count_dedup=True))
        self._serve_cache[key] = fn  # re-insert: dict order doubles as LRU
        while len(self._serve_cache) > self._SERVE_CACHE_MAX:
            self._serve_cache.pop(next(iter(self._serve_cache)))
        return fn, cache_hit, impl

    def search(self, queries, sigma: Optional[float] = None,
               quantized: Optional[bool] = None, impl: Optional[str] = None,
               *, tier: Optional[str] = None,
               k: Optional[int] = None) -> api.SearchResult:
        """Serve one query batch; see serving/api.py for the typed contract.

        ``queries`` is an [nq, dim] array or a SearchRequest (then no other
        arguments are allowed). Plain keywords mirror the request fields;
        ``quantized=`` is the retired boolean knob, mapped onto ``tier=`` with
        a DeprecationWarning for one release."""
        if isinstance(queries, api.SearchRequest):
            if any(a is not None for a in (sigma, quantized, impl, tier, k)):
                raise TypeError(
                    "pass either a SearchRequest or keyword overrides, not both")
            req = queries
        else:
            queries = np.asarray(queries)
            if queries.ndim == 1 or queries.shape[0] == 1:
                # single-query traffic belongs on the canonical entry point
                # (it routes through the batching front-end when one is
                # attached); raw 1-row arrays + loose kwargs survive one
                # release behind the shim
                api.warn_deprecated(
                    "search-single-query",
                    "passing a single query as a raw array to "
                    "LiraEngine.search is deprecated; use "
                    "search_one(SearchRequest(queries=q, ...))")
                if queries.ndim == 1:
                    queries = queries[None, :]
            if quantized is not None:
                api.warn_deprecated(
                    "search-quantized-kwarg",
                    "LiraEngine.search(quantized=) is deprecated; pass "
                    "tier='f32' / 'pq' / 'residual_pq' (or a SearchRequest)")
                if tier is None:
                    tier = tiers.legacy_tier_name(
                        quantized, quantized and self.cfg.residual_pq)
            req = api.SearchRequest(queries=queries, k=k, sigma=sigma,
                                    tier=tier, impl=impl)

        tr = self._tracer()
        # tracing wraps host-side stage boundaries in spans but never alters
        # the computation: the device call and the unconditional
        # block_until_ready run identically traced or not, which is what
        # makes tracing-on bit-identical to tracing-off (pinned in
        # tests/test_obs.py)
        with tr.span("engine.search") as sp_root:
            with tr.span("engine.prepare") as sp_prep:
                sigma = self.sigma if req.sigma is None else req.sigma
                tier_obj = tiers.resolve(
                    req.tier if req.tier is not None else self.cfg.tier)
                k = self.cfg.k if req.k is None else int(req.k)
                missing = [f for f in tier_obj.store_specs(self.cfg)
                           if f not in self.store]
                if missing:
                    raise ValueError(
                        f"engine store lacks {missing} required by tier "
                        f"{tier_obj.name!r}; build with tier={tier_obj.name!r}")
                tier_obj.check_servable(self.cfg)  # e.g. pq refuses residual codes
                nq = req.queries.shape[0]
                nq_pad = self._batch_bucket(nq)
                fn, cache_hit, impl = self.serve_fn(nq_pad, sigma,
                                                    tier_obj.name, req.impl, k)
                qp = np.zeros((nq_pad, self.cfg.dim), np.float32)
                qp[:nq] = req.queries
                # pad rows are masked out of dispatch: they must not probe
                # partitions or occupy q_cap slots that real queries need
                valid = np.zeros((nq_pad,), bool)
                valid[:nq] = True
            with tr.span("engine.device", tier=tier_obj.name, impl=impl,
                         bucket=nq_pad, cache_hit=cache_hit) as sp_dev:
                with self.mesh:
                    out = fn(self.params, self.store, jnp.asarray(qp),
                             jnp.asarray(valid))
                d, i, npb, ovf, dups = jax.block_until_ready(out)
            with tr.span("engine.post") as sp_post:
                npb_np = np.asarray(npb)[:nq]
                overflow = int(np.asarray(ovf).sum())
                dedup_hits = int(np.asarray(dups).sum())
                dists = np.asarray(d)[:nq]
                ids_np = np.asarray(i)[:nq]
            sp_root.set(tier=tier_obj.name, impl=impl, rows=nq)

        stages = None
        if tr.enabled:
            stages = {"prepare": sp_prep.duration_ms,
                      "device": sp_dev.duration_ms,
                      "post": sp_post.duration_ms}

        lbl = {"tier": tier_obj.name, "impl": impl}
        m = self._registry()
        m.counter("lira_engine_searches_total",
                  "engine.search calls").inc(**lbl)
        m.counter("lira_engine_rows_total",
                  "query rows served (pre-padding)").inc(nq, **lbl)
        m.counter("lira_engine_probes_total",
                  "partition probes attempted (pre q_cap drops — includes "
                  "any counted by overflow_probes_total)").inc(
                      float(npb_np.sum()), **lbl)
        m.counter("lira_engine_overflow_probes_total",
                  "probes dropped by q_cap bucket overflow").inc(
                      overflow, **lbl)
        m.counter("lira_engine_dedup_hits_total",
                  "replica-duplicate candidate slots merged away").inc(
                      dedup_hits, **lbl)
        m.counter("lira_engine_jit_cache_hits_total" if cache_hit
                  else "lira_engine_jit_cache_misses_total",
                  "serve-step jit cache").inc(**lbl)
        m.histogram("lira_engine_nprobe_eff",
                    "effective probes per query (σ-adaptive fan-out)",
                    buckets=obs_metrics.NPROBE_BUCKETS).observe_many(
                        npb_np, **lbl)
        m.gauge("lira_engine_q_cap_factor",
                "current dispatch-slack factor").set(
                    float(self.cfg.q_cap_factor))

        result = api.SearchResult(
            dists=dists, ids=ids_np,
            nprobe_eff=npb_np, overflow=overflow,
            stats=api.SearchStats(
                tier=tier_obj.name, impl=impl, k=k, sigma=float(sigma),
                bucket=nq_pad, cache_hit=cache_hit, dedup_hits=dedup_hits,
                latency_ms=sp_root.duration_ms, stages=stages))
        if getattr(self.cfg, "auto_q_cap", False):
            self._maybe_bump_q_cap(result.overflow)
        return result

    def overflow_rate(self) -> float:
        """Cumulative q_cap overflow rate: dropped probes / attempted probes,
        across every tier/impl this engine's registry has seen. 0.0 until any
        search ran. ``lira_engine_probes_total`` counts ATTEMPTED probes —
        ``nprobe_eff`` is summed from ``probe_ok`` before q_cap drops — so it
        is the denominator by itself; adding ``dropped`` to it would count
        every dropped probe twice and under-report the rate."""
        m = self._registry()
        dropped = m.counter("lira_engine_overflow_probes_total").total()
        attempted = m.counter("lira_engine_probes_total").total()
        return dropped / attempted if attempted > 0 else 0.0

    # ------------------------------------------------------------ front-end

    def search_one(self, request: api.SearchRequest) -> api.SearchResult:
        """The canonical single-query entry point. With a front-end attached
        (``attach_frontend``) the request joins the dynamic-batching queue and
        ``result()`` is demanded immediately — coalescing with whatever
        compatible traffic is already waiting; without one it falls back to a
        1-row batch through ``search``. ``request.queries`` is one query:
        ``[dim]`` or ``[1, dim]``."""
        if not isinstance(request, api.SearchRequest):
            raise TypeError("search_one takes a SearchRequest; for raw query "
                            "batches use search()")
        q = np.asarray(request.queries)
        if q.ndim == 1:
            request = dataclasses.replace(request, queries=q[None, :])
        elif q.ndim != 2 or q.shape[0] != 1:
            raise ValueError("search_one serves exactly one query "
                             f"(got shape {q.shape}); use search() for batches")
        if self.frontend is not None:
            return self.frontend.submit(request).result()
        return self.search(request)

    def attach_frontend(self, config=None, **kwargs):
        """Create and attach a ``ServingFrontend`` over this engine (see
        serving/frontend.py for the batching/admission/telemetry contract);
        returns it. Detach with ``engine.frontend = None``."""
        from repro.serving.frontend import ServingFrontend

        self.frontend = ServingFrontend(self, config, **kwargs)
        return self.frontend

    def _maybe_bump_q_cap(self, overflow: int) -> None:
        """Adaptive dispatch slack: after _AUTO_Q_CAP_AFTER consecutive
        overflowing calls, double q_cap_factor and drop the serve cache so the
        next call compiles with the wider buckets (the overflow counter the
        PR 4 dispatch fix surfaced, closed into a control loop)."""
        if overflow <= 0:
            self._overflow_streak = 0
            return
        self._overflow_streak += 1
        if self._overflow_streak >= self._AUTO_Q_CAP_AFTER:
            self.cfg = dataclasses.replace(
                self.cfg, q_cap_factor=self.cfg.q_cap_factor * 2.0)
            self._serve_cache.clear()
            self._overflow_streak = 0
            # adaptation events are observable, not silent cache drops: the
            # bump counter + gauge pair shows WHEN the control loop fired and
            # WHERE the slack factor ended up
            m = self._registry()
            m.counter("lira_engine_q_cap_bumps_total",
                      "auto_q_cap adaptations (doubled q_cap_factor, "
                      "dropped serve cache)").inc()
            m.gauge("lira_engine_q_cap_factor",
                    "current dispatch-slack factor").set(
                        float(self.cfg.q_cap_factor))

    # ------------------------------------------------------------ persistence

    def save(self, directory, step: int = 0):
        """Persist params + store + config via repro.ckpt (atomic, crash-safe)
        so built indexes stop being rebuilt per process. bfloat16 planes are
        upcast to f32 on disk (npy has no bf16); ``load`` restores the tier
        dtype from the config."""
        from repro.ckpt import CheckpointManager

        def _savable(leaf):
            if jnp.dtype(getattr(leaf, "dtype", np.float32)) == jnp.bfloat16:
                return np.asarray(jnp.asarray(leaf).astype(jnp.float32))
            return np.asarray(leaf)

        tree = jax.tree.map(_savable, {"params": self.params,
                                       "store": dict(self.store)})
        extra = {"config": dataclasses.asdict(self.cfg), "sigma": self.sigma}
        return CheckpointManager(directory).save(step, tree, extra=extra)

    @classmethod
    def load(cls, directory, mesh, step: Optional[int] = None):
        """Rebuild an engine from a ``save`` checkpoint: config comes from the
        manifest, the restore template (tree structure + dtypes) is derived
        from the config's tier declarations."""
        import json
        import pathlib

        from repro.ckpt import CheckpointManager

        if not pathlib.Path(directory).is_dir():
            # check before CheckpointManager, whose constructor mkdirs — a
            # typo'd path must not leave an empty directory tree behind
            raise FileNotFoundError(f"no engine checkpoint under {directory}")
        mgr = CheckpointManager(directory)
        step = step if step is not None else mgr.latest_step()
        if step is None:
            raise FileNotFoundError(f"no engine checkpoint under {directory}")
        meta = json.loads(
            (mgr.dir / f"step_{step:010d}" / "manifest.json").read_text())
        raw = {key: tuple(val) if isinstance(val, list) else val
               for key, val in meta["extra"]["config"].items()}
        cfg = LiraSystemConfig(**raw)
        template = {
            "params": jax.tree.map(lambda s: jnp.zeros((), s.dtype),
                                   probing_param_specs_cache(cfg)),
            "store": {name: jnp.zeros((), spec.dtype)
                      for name, spec in store_specs(cfg).items()},
        }
        tree, _, extra = mgr.restore(template, step=step)
        return cls(cfg=cfg, params=tree["params"], store=tree["store"],
                   mesh=mesh, sigma=float(extra.get("sigma", 0.5)))
