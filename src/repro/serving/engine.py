"""Distributed LIRA serving engine — the paper's system on a TPU pod.

Key insight of the TPU mapping (DESIGN.md §3): the probing model's output is a
query→partition ROUTING problem, identical in structure to MoE token dispatch.
serve_step:

  1. queries sharded over ("pod","data"); partition store sharded over "model"
     (each chip owns B/16 partitions); probing model + centroids replicated;
  2. per chip: probing probabilities → top-`nprobe_max` partitions, σ-masked
     (query-adaptive nprobe, paper §3.4);
  3. sort-based dispatch of queries into per-local-partition buckets of static
     capacity `q_cap` (the MoE-dispatch trick applied to ANN — compute scales
     with Q·nprobe·cap, NOT Q·N: partition pruning materializes as real FLOP
     savings under static shapes). Batch-padding rows are masked out of
     dispatch via the `valid` operand so they never steal q_cap slots from
     real queries, and probes dropped by bucket overflow are COUNTED and
     returned (the serve step's 4th output; `LiraEngine.search` surfaces the
     total) instead of being silently swallowed;
  4. per local partition: the scan stage is backend-dispatched through
     serving/scan.py (cfg.impl: auto | ref | pallas | interpret). "ref" is the
     portable jnp path under lax.map; "pallas" runs the fused kernels
     grid-batched over the whole [b_loc, q_cap] dispatch buffer in one launch
     (kernels.l2_topk_batched for f32; native on TPU, interpreted elsewhere).
     With cfg.quantized the scan is two-stage: per-query ADC LUT (computed
     once) → PQ-code shortlist of r·k candidates (kernels.pq_adc_topk_batched
     on the kernel path) → exact f32 rerank of the shortlist only, cutting
     the dominant vector-read traffic 8–32× (serving/quantized.py). With
     cfg.residual_pq the codes encode x − centroid and the scan adds the two
     scalar corrections of the residual ADC identity (core/pq.py): a
     precomputed per-slot cterm plane plus a per-(query, partition) offset
     derived from the probing cd matrix — threaded to the kernels as their
     cand_off/q_off operands;
  5. scatter back per query, local top-k, all-gather(k·shards) over "model",
     final merge. Collective volume is O(Q·k), independent of N.

Multi-pod: each pod holds a full index replica; the front-end routes query
batches to pods (repro.distributed.fault simulates replica failover).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import LiraSystemConfig, ShapeSpec
from repro.core import probing
from repro.kernels import ops as kops
from repro.models.api import ModelBundle, StepDef, adamw_state_pspecs, adamw_state_specs, sds
from repro.train import optimizer as opt

from repro.serving import quantized as quantized_tier
from repro.serving import scan
from repro.utils.compat import shard_map


def batch_mesh_info(mesh):
    """(batch_axes, bspec, bprod) for the query-batch axes of a mesh — the
    single source for how serve steps and batch bucketing split queries."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)
    bprod = int(np.prod([mesh.shape[a] for a in batch_axes])) if batch_axes else 1
    return batch_axes, bspec, bprod


def probing_param_specs(cfg: LiraSystemConfig):
    pc = probing.ProbingConfig(dim=cfg.dim, n_partitions=cfg.n_partitions,
                               q_hidden=tuple(cfg.q_hidden), i_hidden=tuple(cfg.i_hidden),
                               p_hidden=tuple(cfg.p_hidden))
    return jax.eval_shape(lambda: probing.init(jax.random.PRNGKey(0), pc))


def store_specs(cfg: LiraSystemConfig):
    b, c, d = cfg.n_partitions, cfg.capacity, cfg.dim
    specs = {
        "centroids": sds((b, d)),
        "vectors": sds((b, c, d), jnp.dtype(getattr(cfg, "store_dtype", "float32"))),
        "ids": sds((b, c), jnp.int32),
    }
    if getattr(cfg, "quantized", False):
        from repro.core.pq import code_dtype

        specs["codes"] = sds((b, c, cfg.pq_m), jnp.dtype(code_dtype(cfg.pq_ks)))
        specs["codebooks"] = sds((cfg.pq_m, cfg.pq_ks, d // cfg.pq_m))
        if getattr(cfg, "residual_pq", False):
            specs["cterm"] = sds((b, c))  # per-slot residual cross terms
    return specs


def store_pspecs(mesh, cfg: LiraSystemConfig | None = None):
    sp = {
        "centroids": P(None, None),
        "vectors": P("model", None, None),
        "ids": P("model", None),
    }
    if cfg is not None and getattr(cfg, "quantized", False):
        sp["codes"] = P("model", None, None)   # codes shard with their vectors
        sp["codebooks"] = P(None, None, None)  # replicated like centroids
        if getattr(cfg, "residual_pq", False):
            sp["cterm"] = P("model", None)     # rides with its codes
    return sp


# ------------------------------------------------------------- serve step

def make_serve_step(cfg: LiraSystemConfig, mesh, n_queries: int, *, sigma: float = 0.5,
                    q_cap_factor: float | None = None,
                    quantized: bool | None = None,
                    impl: str | None = None):
    _, bspec, bprod = batch_mesh_info(mesh)
    model_n = mesh.shape.get("model", 1)
    q_row = n_queries // bprod
    b_loc = cfg.n_partitions // model_n
    q_cap_factor = q_cap_factor if q_cap_factor is not None else getattr(cfg, "q_cap_factor", 2.0)
    q_cap = max(8, int(q_row * cfg.nprobe_max / cfg.n_partitions * q_cap_factor))
    k = cfg.k
    quantized = getattr(cfg, "quantized", False) if quantized is None else quantized
    residual = quantized and getattr(cfg, "residual_pq", False)
    impl = getattr(cfg, "impl", "auto") if impl is None else impl
    scan_impl = scan.resolve_impl(impl)  # fail fast on typos, not at trace time

    def f(q_loc, valid_loc, params, cents, vecs_loc, ids_loc, *qargs):
        # q_loc: [q_row, d]; valid_loc: [q_row] bool (False = batch padding);
        # vecs_loc: [b_loc, cap, d]; ids_loc: [b_loc, cap]
        # qargs (quantized only): codes_loc [b_loc, cap, m], codebooks
        # [m, ks, d_sub] (+ cterm_loc [b_loc, cap] in residual mode)
        cd = (
            jnp.sum(q_loc * q_loc, -1, keepdims=True)
            - 2.0 * q_loc @ cents.T
            + jnp.sum(cents * cents, -1)[None, :]
        )
        p = jax.nn.sigmoid(probing.apply(params, q_loc, cd))        # [q_row, B]
        vals, pidx = jax.lax.top_k(p, cfg.nprobe_max)               # global partitions
        probe_ok = vals > sigma
        probe_ok = probe_ok.at[:, 0].set(True)                      # always ≥1 partition
        # batch-padding rows must not probe: a pad query occupying q_cap slots
        # can evict a real query's probes in small buckets
        probe_ok = probe_ok & valid_loc[:, None]

        # ---- dispatch (sort-based, local partition range only)
        b0 = jax.lax.axis_index("model") * b_loc if model_n > 1 else 0
        flat_p = pidx.reshape(-1) - b0
        flat_ok = probe_ok.reshape(-1) & (flat_p >= 0) & (flat_p < b_loc)
        flat_q = jnp.broadcast_to(jnp.arange(q_row)[:, None], pidx.shape).reshape(-1)
        key = jnp.where(flat_ok, flat_p, b_loc)
        order = jnp.argsort(key, stable=True)
        skey = key[order]
        start = jnp.searchsorted(skey, jnp.arange(b_loc + 1))
        pos = jnp.arange(skey.shape[0]) - start[jnp.clip(skey, 0, b_loc)]
        keep = (skey < b_loc) & (pos < q_cap)
        # probes beyond a hot partition's q_cap are dropped — count them so
        # recall degradation is reported, not silent (raise q_cap_factor or
        # rebalance partitions when this is persistently > 0)
        overflow = ((skey < b_loc) & (pos >= q_cap)).sum().astype(jnp.int32)
        row = jnp.where(keep, skey, b_loc)
        col = jnp.where(keep, pos, 0)
        qbuf = jnp.full((b_loc, q_cap), q_row, jnp.int32).at[row, col].set(
            flat_q[order], mode="drop")                              # q_row = invalid

        # ---- per-partition scan: backend-dispatched (serving/scan.py)
        q_pad = jnp.concatenate([q_loc, jnp.full((1, q_loc.shape[1]), 1e9, q_loc.dtype)], 0)

        if quantized:
            if residual:
                codes_loc, codebooks, cterm_loc = qargs
            else:
                codes_loc, codebooks = qargs
                cterm_loc = None
            m = codes_loc.shape[-1]
            cap = vecs_loc.shape[1]
            rk = min(cap, max(k, int(getattr(cfg, "rerank", 4)) * k))
            # stage 0: per-query ADC LUT, once — valid across all partitions.
            # Non-residual codebooks make this exact; residual codebooks make
            # it exact up to the two scalar corrections of the residual ADC
            # identity (core/pq.py), added inside the scan stage.
            lut_pad = jnp.concatenate(
                [quantized_tier.adc_lut(codebooks, q_loc),
                 jnp.zeros((1, m, codebooks.shape[1]), jnp.float32)], 0)
            off_loc = None
            if residual:
                # ‖c_b‖² − 2⟨q, c_b⟩ = cd − ‖q‖², per (query, partition); the
                # centroid-distance matrix cd is already here for probing.
                off = cd - jnp.sum(q_loc * q_loc, -1, keepdims=True)   # [q_row, B]
                off_pad = jnp.concatenate(
                    [off, jnp.zeros((1, off.shape[1]), off.dtype)], 0)
                off_loc = jax.lax.dynamic_slice_in_dim(
                    off_pad, b0, b_loc, axis=1).T                      # [b_loc, q_row+1]
            dists, rids = scan.run(scan_impl, qbuf, q_pad, vecs_loc, ids_loc, k,
                                   lut_pad=lut_pad, codes_loc=codes_loc, rk=rk,
                                   cterm_loc=cterm_loc, off_loc=off_loc)
        else:
            dists, rids = scan.run(scan_impl, qbuf, q_pad, vecs_loc, ids_loc, k)

        # ---- scatter back per query, local merge
        out_d = jnp.full((q_row + 1, b_loc, k), jnp.inf, jnp.float32)
        out_i = jnp.full((q_row + 1, b_loc, k), -1, jnp.int32)
        cols = jnp.broadcast_to(jnp.arange(b_loc)[:, None], qbuf.shape)
        out_d = out_d.at[qbuf, cols].set(dists, mode="drop")
        out_i = out_i.at[qbuf, cols].set(rids, mode="drop")
        # replica-aware local merge: redundancy (η>0) stores the same id in
        # several partitions, so a plain top-k would return duplicate ids and
        # corrupt recall@k — dedup to best-distance-per-id instead (backend
        # dispatch: bitonic Pallas kernel on TPU, jnp sorts elsewhere)
        loc_d, loc_i = kops.dedup_topk(
            out_d[:q_row].reshape(q_row, -1), out_i[:q_row].reshape(q_row, -1), k)

        # ---- cross-shard merge (O(Q·k·shards) bytes — independent of N);
        # replicas of one id can live on different shards, so dedup again
        if model_n > 1:
            all_d = jax.lax.all_gather(loc_d, "model", axis=1, tiled=True)   # [q_row, 16k]
            all_i = jax.lax.all_gather(loc_i, "model", axis=1, tiled=True)
            loc_d, loc_i = kops.dedup_topk(all_d, all_i, k)
            overflow = jax.lax.psum(overflow, "model")
        nprobe_eff = probe_ok.sum(-1).astype(jnp.float32)
        return loc_d, loc_i, nprobe_eff, overflow[None]

    param_spec = jax.tree.map(lambda _: P(), probing_param_specs_cache(cfg))
    in_specs = (P(bspec, None), P(bspec), param_spec, P(None, None),
                P("model", None, None), P("model", None))
    if quantized:
        in_specs = in_specs + (P("model", None, None), P(None, None, None))
        if residual:
            in_specs = in_specs + (P("model", None),)

    def serve_step(params, store, queries, valid=None):
        if valid is None:
            valid = jnp.ones((n_queries,), jnp.bool_)
        args = (queries, valid, params, store["centroids"], store["vectors"],
                store["ids"])
        if quantized:
            args = args + (store["codes"], store["codebooks"])
            if residual:
                args = args + (store["cterm"],)
        return shard_map(
            f, mesh=mesh,
            in_specs=in_specs,
            out_specs=(P(bspec, None), P(bspec, None), P(bspec), P(bspec)),
            check_vma=False,
        )(*args)

    return serve_step


@functools.lru_cache(maxsize=None)
def _probing_specs_cached(dim, b, qh, ih, ph):
    pc = probing.ProbingConfig(dim=dim, n_partitions=b, q_hidden=qh, i_hidden=ih, p_hidden=ph)
    return jax.eval_shape(lambda: probing.init(jax.random.PRNGKey(0), pc))


def probing_param_specs_cache(cfg: LiraSystemConfig):
    return _probing_specs_cached(cfg.dim, cfg.n_partitions, tuple(cfg.q_hidden),
                                 tuple(cfg.i_hidden), tuple(cfg.p_hidden))


# ------------------------------------------------------------- train step

def make_probe_train_step(cfg: LiraSystemConfig, mesh, tx):
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def train_step(state, batch):
        params, opt_state = state

        def loss_fn(p):
            return probing.bce_loss(p, batch["q"], batch["cent_dist"], batch["labels"])

        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads, gnorm = opt.clip_by_global_norm(grads, 1.0)
        updates, opt_state = tx.update(grads, opt_state, params)
        params = opt.apply_updates(params, updates)
        return (params, opt_state), {"loss": loss, "grad_norm": gnorm}

    return train_step


# ------------------------------------------------------------- bundle

def make_bundle(cfg: LiraSystemConfig, mesh) -> ModelBundle:
    _, bspec, _ = batch_mesh_info(mesh)
    tx = opt.adamw(opt.cosine_schedule(1e-3, 50, 5000))
    pc = probing.ProbingConfig(dim=cfg.dim, n_partitions=cfg.n_partitions,
                               q_hidden=tuple(cfg.q_hidden), i_hidden=tuple(cfg.i_hidden),
                               p_hidden=tuple(cfg.p_hidden))

    def step(shape: ShapeSpec) -> StepDef:
        if shape.kind == "lira_serve":
            nq = shape["n_queries"]
            fn_inner = make_serve_step(cfg, mesh, nq)

            def fn(params, store, queries):
                return fn_inner(params, store, queries)

            return StepDef(
                fn=fn,
                input_specs={"store": store_specs(cfg), "queries": sds((nq, cfg.dim))},
                input_pspecs={"store": store_pspecs(mesh, cfg), "queries": P(bspec, None)},
                out_pspecs=None,
            )
        if shape.kind == "lira_train":
            b = shape["batch"]
            return StepDef(
                fn=make_probe_train_step(cfg, mesh, tx),
                input_specs={
                    "q": sds((b, cfg.dim)),
                    "cent_dist": sds((b, cfg.n_partitions)),
                    "labels": sds((b, cfg.n_partitions)),
                },
                input_pspecs={"q": P(bspec, None), "cent_dist": P(bspec, None),
                              "labels": P(bspec, None)},
                out_pspecs=None,
            )
        raise ValueError(shape.kind)

    return ModelBundle(
        name=cfg.arch,
        config=cfg,
        init=lambda rng, shape=None: probing.init(rng, pc),
        param_specs=lambda shape=None: probing_param_specs_cache(cfg),
        param_pspecs=lambda shape=None: jax.tree.map(lambda _: P(), probing_param_specs_cache(cfg)),
        step=step,
        opt_specs=lambda shape=None: adamw_state_specs(probing_param_specs_cache(cfg)),
        opt_pspecs=lambda shape=None: adamw_state_pspecs(
            jax.tree.map(lambda _: P(), probing_param_specs_cache(cfg))),
    )


# ------------------------------------------------------------- host engine

@dataclasses.dataclass
class LiraEngine:
    """End-to-end host-driven engine: build (k-means → train probe → redundancy
    → store [→ PQ codes]) then serve batches via the distributed serve_step.

    Jitted serve steps are cached per (padded batch size, σ, tier, scan impl):
    query batches are padded to power-of-two buckets so repeated traffic of
    varying size hits the jit cache instead of recompiling every call, and the
    pad rows are masked out of dispatch (they never probe or take q_cap slots).
    """

    cfg: LiraSystemConfig
    params: dict
    store: dict
    mesh: jax.sharding.Mesh
    sigma: float = 0.5
    _serve_cache: dict = dataclasses.field(default_factory=dict, repr=False,
                                           compare=False)

    @classmethod
    def build(cls, mesh, x: np.ndarray, *, n_partitions: int, k: int = 100,
              eta: float = 0.03, train_frac: float = 0.5, epochs: int = 8,
              nprobe_max: Optional[int] = None, seed: int = 0, log: bool = False,
              quantized: bool = False, pq_m: Optional[int] = None,
              pq_ks: int = 256, rerank: int = 4, residual: bool = False,
              impl: str = "auto"):
        from repro.core import build_store, ground_truth as gt, kmeans_fit
        from repro.core.redundancy import plan_redundancy, replica_rows
        from repro.core.train_probing import train_probing_model

        quantized = quantized or residual  # residual is a mode OF the PQ tier
        rng = jax.random.PRNGKey(seed)
        host = np.random.default_rng(seed)
        st = kmeans_fit(rng, jnp.asarray(x), n_clusters=n_partitions, n_iters=20)
        assign, cents = np.asarray(st.assign), np.asarray(st.centroids)

        sub = host.choice(len(x), int(len(x) * train_frac), replace=False)
        xs = x[sub]
        _, sti = gt.exact_knn(xs, xs, k, exclude_self=True)
        part_of = assign[sub]
        lab = np.zeros((len(sub), n_partitions), np.float32)
        rows = np.repeat(np.arange(len(sub)), sti.shape[1])
        np.add.at(lab, (rows, part_of[sti].reshape(-1)), 1.0)
        lab = (lab > 0).astype(np.float32)
        params, _ = train_probing_model(rng, xs, lab, cents, epochs=epochs, log=log)

        ids = np.arange(len(x), dtype=np.int32)
        plan = plan_redundancy(params, x, assign, cents, eta=eta)
        extra = replica_rows(plan, x, ids)
        store_h = build_store(x, ids, assign, cents, extra=extra)
        store = {"centroids": store_h.centroids, "vectors": store_h.vectors,
                 "ids": store_h.ids}
        dim = x.shape[1]
        if quantized:
            # largest divisor of dim ≤ 16 (subspaces must tile the dim exactly)
            pq_m = pq_m or max(m for m in range(1, min(16, dim) + 1) if dim % m == 0)
            qs = quantized_tier.build_quantized_store(
                jax.random.fold_in(rng, 1), store_h.vectors, store_h.ids,
                m=pq_m, ks=pq_ks, residual=residual,
                centroids=store_h.centroids if residual else None)
            store["codes"], store["codebooks"] = qs.codes, qs.codebooks
            if residual:
                store["cterm"] = qs.cterm
            pq_ks = qs.ks  # may have been clamped for tiny stores
        cfg = LiraSystemConfig(
            arch="lira", dim=dim, n_partitions=n_partitions,
            capacity=store_h.capacity, k=k,
            nprobe_max=min(n_partitions, nprobe_max or max(8, n_partitions // 8)),
            quantized=quantized, pq_m=pq_m or 16, pq_ks=pq_ks, rerank=rerank,
            residual_pq=quantized and residual, impl=impl,
        )
        return cls(cfg=cfg, params=params, store=store, mesh=mesh)

    def _batch_bucket(self, nq: int) -> int:
        """Pad batch sizes to power-of-two buckets (≥8, rounded up to a
        multiple of the batch-mesh product so shard_map can split the batch)
        so the jitted serve step is reused across nearby batch sizes."""
        _, _, bprod = batch_mesh_info(self.mesh)
        bucket = max(8, 1 << max(0, nq - 1).bit_length())
        return -(-bucket // bprod) * bprod

    _SERVE_CACHE_MAX = 32  # σ sweeps must not accumulate compiled steps forever

    def serve_fn(self, nq_pad: int, sigma: float, quantized: bool,
                 impl: Optional[str] = None):
        """The cached jitted serve step for one (bucket, σ, tier, impl) key."""
        # normalize before keying: None, "auto" and the resolved backend name
        # must share one compiled step
        impl = scan.resolve_impl(
            impl if impl is not None else getattr(self.cfg, "impl", "auto"))
        key = (nq_pad, float(sigma), bool(quantized), impl)
        fn = self._serve_cache.pop(key, None)
        if fn is None:
            fn = jax.jit(make_serve_step(self.cfg, self.mesh, nq_pad,
                                         sigma=float(sigma), quantized=quantized,
                                         impl=impl))
        self._serve_cache[key] = fn  # re-insert: dict order doubles as LRU
        while len(self._serve_cache) > self._SERVE_CACHE_MAX:
            self._serve_cache.pop(next(iter(self._serve_cache)))
        return fn

    def search(self, queries: np.ndarray, sigma: Optional[float] = None,
               quantized: Optional[bool] = None, impl: Optional[str] = None):
        """Returns (dists [nq, k], ids [nq, k], nprobe_eff [nq], overflow).

        ``overflow`` is the total number of probes dropped because a hot
        partition's dispatch bucket filled up (q_cap) — 0 means every
        requested probe was scanned; persistent overflow means recall is
        degraded and q_cap_factor should be raised. ``impl`` overrides the
        config's partition-scan backend (scan.py) for this call."""
        sigma = self.sigma if sigma is None else sigma
        quantized = getattr(self.cfg, "quantized", False) if quantized is None else quantized
        if quantized and "codes" not in self.store:
            raise ValueError("engine has no quantized store; build with quantized=True")
        nq = queries.shape[0]
        nq_pad = self._batch_bucket(nq)
        fn = self.serve_fn(nq_pad, sigma, quantized, impl)
        qp = np.zeros((nq_pad, self.cfg.dim), np.float32)
        qp[:nq] = queries
        # pad rows are masked out of dispatch: they must not probe partitions
        # or occupy q_cap slots that real queries need
        valid = np.zeros((nq_pad,), bool)
        valid[:nq] = True
        with self.mesh:
            d, i, npb, ovf = fn(self.params, self.store, jnp.asarray(qp),
                                jnp.asarray(valid))
        return (np.asarray(d)[:nq], np.asarray(i)[:nq], np.asarray(npb)[:nq],
                int(np.asarray(ovf).sum()))
