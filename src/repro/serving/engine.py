"""Distributed LIRA serving engine — the paper's system on a TPU pod.

Key insight of the TPU mapping (DESIGN.md §3): the probing model's output is a
query→partition ROUTING problem, identical in structure to MoE token dispatch.
serve_step:

  1. queries sharded over ("pod","data"); partition store sharded over "model"
     (each chip owns B/16 partitions); probing model + centroids replicated;
  2. per chip: probing probabilities → top-`nprobe_max` partitions, σ-masked
     (query-adaptive nprobe, paper §3.4);
  3. sort-based dispatch of queries into per-local-partition buckets of static
     capacity `q_cap` (the MoE-dispatch trick applied to ANN — compute scales
     with Q·nprobe·cap, NOT Q·N: partition pruning materializes as real FLOP
     savings under static shapes);
  4. per local partition: fused L2+top-k scan (repro.kernels.l2_topk on TPU;
     jnp path under lax.map on CPU);
  5. scatter back per query, local top-k, all-gather(k·shards) over "model",
     final merge. Collective volume is O(Q·k), independent of N.

Multi-pod: each pod holds a full index replica; the front-end routes query
batches to pods (repro.distributed.fault simulates replica failover).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import LiraSystemConfig, ShapeSpec
from repro.core import probing
from repro.kernels import ops as kops
from repro.models.api import ModelBundle, StepDef, adamw_state_pspecs, adamw_state_specs, sds
from repro.train import optimizer as opt

from repro.utils.compat import shard_map


def probing_param_specs(cfg: LiraSystemConfig):
    pc = probing.ProbingConfig(dim=cfg.dim, n_partitions=cfg.n_partitions,
                               q_hidden=tuple(cfg.q_hidden), i_hidden=tuple(cfg.i_hidden),
                               p_hidden=tuple(cfg.p_hidden))
    return jax.eval_shape(lambda: probing.init(jax.random.PRNGKey(0), pc))


def store_specs(cfg: LiraSystemConfig):
    b, c, d = cfg.n_partitions, cfg.capacity, cfg.dim
    return {
        "centroids": sds((b, d)),
        "vectors": sds((b, c, d), jnp.dtype(getattr(cfg, "store_dtype", "float32"))),
        "ids": sds((b, c), jnp.int32),
    }


def store_pspecs(mesh):
    return {
        "centroids": P(None, None),
        "vectors": P("model", None, None),
        "ids": P("model", None),
    }


# ------------------------------------------------------------- serve step

def make_serve_step(cfg: LiraSystemConfig, mesh, n_queries: int, *, sigma: float = 0.5,
                    use_kernel: bool = False, q_cap_factor: float | None = None):
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)
    model_n = mesh.shape.get("model", 1)
    bprod = int(np.prod([mesh.shape[a] for a in batch_axes])) if batch_axes else 1
    q_row = n_queries // bprod
    b_loc = cfg.n_partitions // model_n
    q_cap_factor = q_cap_factor if q_cap_factor is not None else getattr(cfg, "q_cap_factor", 2.0)
    q_cap = max(8, int(q_row * cfg.nprobe_max / cfg.n_partitions * q_cap_factor))
    k = cfg.k

    def f(q_loc, params, cents, vecs_loc, ids_loc):
        # q_loc: [q_row, d]; vecs_loc: [b_loc, cap, d]; ids_loc: [b_loc, cap]
        cd = (
            jnp.sum(q_loc * q_loc, -1, keepdims=True)
            - 2.0 * q_loc @ cents.T
            + jnp.sum(cents * cents, -1)[None, :]
        )
        p = jax.nn.sigmoid(probing.apply(params, q_loc, cd))        # [q_row, B]
        vals, pidx = jax.lax.top_k(p, cfg.nprobe_max)               # global partitions
        probe_ok = vals > sigma
        probe_ok = probe_ok.at[:, 0].set(True)                      # always ≥1 partition

        # ---- dispatch (sort-based, local partition range only)
        b0 = jax.lax.axis_index("model") * b_loc if model_n > 1 else 0
        flat_p = pidx.reshape(-1) - b0
        flat_ok = probe_ok.reshape(-1) & (flat_p >= 0) & (flat_p < b_loc)
        flat_q = jnp.broadcast_to(jnp.arange(q_row)[:, None], pidx.shape).reshape(-1)
        key = jnp.where(flat_ok, flat_p, b_loc)
        order = jnp.argsort(key, stable=True)
        skey = key[order]
        start = jnp.searchsorted(skey, jnp.arange(b_loc + 1))
        pos = jnp.arange(skey.shape[0]) - start[jnp.clip(skey, 0, b_loc)]
        keep = (skey < b_loc) & (pos < q_cap)
        row = jnp.where(keep, skey, b_loc)
        col = jnp.where(keep, pos, 0)
        qbuf = jnp.full((b_loc, q_cap), q_row, jnp.int32).at[row, col].set(
            flat_q[order], mode="drop")                              # q_row = invalid

        # ---- per-partition fused scan (l2 + top-k)
        q_pad = jnp.concatenate([q_loc, jnp.full((1, q_loc.shape[1]), 1e9, q_loc.dtype)], 0)

        def scan_partition(args):
            qi, vec_b, id_b = args                                   # [q_cap], [cap, d], [cap]
            qs = q_pad[qi].astype(vec_b.dtype)                       # [q_cap, d]
            # bf16 operands + f32 accumulation (store_dtype=bfloat16 halves
            # the dominant vector-read traffic; exact rerank happens at f32)
            d2 = (
                jnp.sum(qs.astype(jnp.float32) ** 2, -1, keepdims=True)
                - 2.0 * jax.lax.dot_general(qs, vec_b, (((1,), (1,)), ((), ())),
                                            preferred_element_type=jnp.float32)
                + jnp.sum(vec_b.astype(jnp.float32) ** 2, -1)[None, :]
            )
            d2 = jnp.where(id_b[None, :] < 0, jnp.inf, d2)
            neg, posk = jax.lax.top_k(-d2, k)
            return -neg, id_b[posk]                                  # [q_cap, k] ×2

        dists, rids = jax.lax.map(scan_partition, (qbuf, vecs_loc, ids_loc))  # [b_loc, q_cap, k]

        # ---- scatter back per query, local merge
        out_d = jnp.full((q_row + 1, b_loc, k), jnp.inf, jnp.float32)
        out_i = jnp.full((q_row + 1, b_loc, k), -1, jnp.int32)
        cols = jnp.broadcast_to(jnp.arange(b_loc)[:, None], qbuf.shape)
        out_d = out_d.at[qbuf, cols].set(dists, mode="drop")
        out_i = out_i.at[qbuf, cols].set(rids, mode="drop")
        # replica-aware local merge: redundancy (η>0) stores the same id in
        # several partitions, so a plain top-k would return duplicate ids and
        # corrupt recall@k — dedup to best-distance-per-id instead (backend
        # dispatch: bitonic Pallas kernel on TPU, jnp sorts elsewhere)
        loc_d, loc_i = kops.dedup_topk(
            out_d[:q_row].reshape(q_row, -1), out_i[:q_row].reshape(q_row, -1), k)

        # ---- cross-shard merge (O(Q·k·shards) bytes — independent of N);
        # replicas of one id can live on different shards, so dedup again
        if model_n > 1:
            all_d = jax.lax.all_gather(loc_d, "model", axis=1, tiled=True)   # [q_row, 16k]
            all_i = jax.lax.all_gather(loc_i, "model", axis=1, tiled=True)
            loc_d, loc_i = kops.dedup_topk(all_d, all_i, k)
        nprobe_eff = probe_ok.sum(-1).astype(jnp.float32)
        return loc_d, loc_i, nprobe_eff

    param_spec = jax.tree.map(lambda _: P(), probing_param_specs_cache(cfg))

    def serve_step(params, store, queries):
        return shard_map(
            f, mesh=mesh,
            in_specs=(P(bspec, None), param_spec, P(None, None),
                      P("model", None, None), P("model", None)),
            out_specs=(P(bspec, None), P(bspec, None), P(bspec)),
            check_vma=False,
        )(queries, params, store["centroids"], store["vectors"], store["ids"])

    return serve_step


@functools.lru_cache(maxsize=None)
def _probing_specs_cached(dim, b, qh, ih, ph):
    pc = probing.ProbingConfig(dim=dim, n_partitions=b, q_hidden=qh, i_hidden=ih, p_hidden=ph)
    return jax.eval_shape(lambda: probing.init(jax.random.PRNGKey(0), pc))


def probing_param_specs_cache(cfg: LiraSystemConfig):
    return _probing_specs_cached(cfg.dim, cfg.n_partitions, tuple(cfg.q_hidden),
                                 tuple(cfg.i_hidden), tuple(cfg.p_hidden))


# ------------------------------------------------------------- train step

def make_probe_train_step(cfg: LiraSystemConfig, mesh, tx):
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def train_step(state, batch):
        params, opt_state = state

        def loss_fn(p):
            return probing.bce_loss(p, batch["q"], batch["cent_dist"], batch["labels"])

        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads, gnorm = opt.clip_by_global_norm(grads, 1.0)
        updates, opt_state = tx.update(grads, opt_state, params)
        params = opt.apply_updates(params, updates)
        return (params, opt_state), {"loss": loss, "grad_norm": gnorm}

    return train_step


# ------------------------------------------------------------- bundle

def make_bundle(cfg: LiraSystemConfig, mesh) -> ModelBundle:
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)
    tx = opt.adamw(opt.cosine_schedule(1e-3, 50, 5000))
    pc = probing.ProbingConfig(dim=cfg.dim, n_partitions=cfg.n_partitions,
                               q_hidden=tuple(cfg.q_hidden), i_hidden=tuple(cfg.i_hidden),
                               p_hidden=tuple(cfg.p_hidden))

    def step(shape: ShapeSpec) -> StepDef:
        if shape.kind == "lira_serve":
            nq = shape["n_queries"]
            fn_inner = make_serve_step(cfg, mesh, nq)

            def fn(params, store, queries):
                return fn_inner(params, store, queries)

            return StepDef(
                fn=fn,
                input_specs={"store": store_specs(cfg), "queries": sds((nq, cfg.dim))},
                input_pspecs={"store": store_pspecs(mesh), "queries": P(bspec, None)},
                out_pspecs=None,
            )
        if shape.kind == "lira_train":
            b = shape["batch"]
            return StepDef(
                fn=make_probe_train_step(cfg, mesh, tx),
                input_specs={
                    "q": sds((b, cfg.dim)),
                    "cent_dist": sds((b, cfg.n_partitions)),
                    "labels": sds((b, cfg.n_partitions)),
                },
                input_pspecs={"q": P(bspec, None), "cent_dist": P(bspec, None),
                              "labels": P(bspec, None)},
                out_pspecs=None,
            )
        raise ValueError(shape.kind)

    return ModelBundle(
        name=cfg.arch,
        config=cfg,
        init=lambda rng, shape=None: probing.init(rng, pc),
        param_specs=lambda shape=None: probing_param_specs_cache(cfg),
        param_pspecs=lambda shape=None: jax.tree.map(lambda _: P(), probing_param_specs_cache(cfg)),
        step=step,
        opt_specs=lambda shape=None: adamw_state_specs(probing_param_specs_cache(cfg)),
        opt_pspecs=lambda shape=None: adamw_state_pspecs(
            jax.tree.map(lambda _: P(), probing_param_specs_cache(cfg))),
    )


# ------------------------------------------------------------- host engine

@dataclasses.dataclass
class LiraEngine:
    """End-to-end host-driven engine: build (k-means → train probe → redundancy
    → store) then serve batches via the distributed serve_step."""

    cfg: LiraSystemConfig
    params: dict
    store: dict
    mesh: jax.sharding.Mesh
    sigma: float = 0.5

    @classmethod
    def build(cls, mesh, x: np.ndarray, *, n_partitions: int, k: int = 100,
              eta: float = 0.03, train_frac: float = 0.5, epochs: int = 8,
              nprobe_max: Optional[int] = None, seed: int = 0, log: bool = False):
        from repro.core import build_store, ground_truth as gt, kmeans_fit
        from repro.core.redundancy import plan_redundancy, replica_rows
        from repro.core.train_probing import train_probing_model

        rng = jax.random.PRNGKey(seed)
        host = np.random.default_rng(seed)
        st = kmeans_fit(rng, jnp.asarray(x), n_clusters=n_partitions, n_iters=20)
        assign, cents = np.asarray(st.assign), np.asarray(st.centroids)

        sub = host.choice(len(x), int(len(x) * train_frac), replace=False)
        xs = x[sub]
        _, sti = gt.exact_knn(xs, xs, k, exclude_self=True)
        part_of = assign[sub]
        lab = np.zeros((len(sub), n_partitions), np.float32)
        rows = np.repeat(np.arange(len(sub)), sti.shape[1])
        np.add.at(lab, (rows, part_of[sti].reshape(-1)), 1.0)
        lab = (lab > 0).astype(np.float32)
        params, _ = train_probing_model(rng, xs, lab, cents, epochs=epochs, log=log)

        ids = np.arange(len(x), dtype=np.int32)
        plan = plan_redundancy(params, x, assign, cents, eta=eta)
        extra = replica_rows(plan, x, ids)
        store_h = build_store(x, ids, assign, cents, extra=extra)
        cfg = LiraSystemConfig(
            arch="lira", dim=x.shape[1], n_partitions=n_partitions,
            capacity=store_h.capacity, k=k,
            nprobe_max=nprobe_max or max(8, n_partitions // 8),
        )
        store = {"centroids": store_h.centroids, "vectors": store_h.vectors,
                 "ids": store_h.ids}
        return cls(cfg=cfg, params=params, store=store, mesh=mesh)

    def search(self, queries: np.ndarray, sigma: Optional[float] = None):
        nq = queries.shape[0]
        fn = make_serve_step(self.cfg, self.mesh, nq, sigma=sigma or self.sigma)
        with self.mesh:
            d, i, npb = jax.jit(fn)(self.params, self.store, jnp.asarray(queries, jnp.float32))
        return np.asarray(d), np.asarray(i), np.asarray(npb)
