"""Online serving front-end: dynamic batching, admission control, telemetry.

The engine underneath is a batch-synchronous ``search()`` — fast once a batch
exists, but production traffic is a stream of single-query ``SearchRequest``s
arriving at wildly varying rates (the HARMONY/LANNS observation: at web scale
the batching/routing layer above the index, not the scan kernel, dominates
tail latency). ``ServingFrontend`` is that layer:

  * **dynamic batching** — requests accumulate per compatibility group
    (resolved ``(k, σ, tier, impl)`` — batching is an optimization, never a
    semantics change, so incompatible requests never share a serve step) and
    flush on whichever trigger fires first: size (``max_batch`` coalesced
    rows, rounded up to the engine's pow2 jit-cache bucket so flushes land on
    already-compiled steps) or deadline (``max_wait_ms`` since enqueue,
    tightened per request by ``SearchRequest.deadline_ms``, which also arms
    dead-on-arrival expiry — see ``submit``);
  * **admission control** — a bounded queue (``max_queue`` requests). Beyond
    it, load is SHED instead of queued: the lowest-priority waiting request
    (or the newcomer, if nothing queued outranks it) resolves immediately
    with an empty answer marked ``SearchStats.shed=True``, keeping tail
    latency bounded for the traffic that is admitted;
  * **latency telemetry** — every served request records its queue wait and
    end-to-end latency against the injected clock; ``stats()`` snapshots
    rolling p50/p99, QPS, shed/served counters and mean coalesced batch size
    as a ``FrontendStats``.

Scatter is exact: each coalesced batch's rows are sliced back into
per-request ``SearchResult``s that are bit-identical to a solo
``engine.search()`` of the same query (the serve step is row-independent;
tests/test_frontend.py gates this across {f32, pq, residual_pq} ×
{ref, interpret}). The one shared field is ``overflow``: q_cap drops are
counted per serve step, so a batched result reports its whole batch's total.

The scheduler never sleeps or reads wall clock on its own: time comes from an
injectable ``clock`` callable (``FakeClock`` for deterministic tests and
simulation, ``time.monotonic`` in production). Because the engine call is
synchronous, flushes happen inside ``submit`` (size trigger), ``poll``
(deadline trigger — drivers call it as their event loop tick) or
``PendingSearch.result()`` (a caller demanding its answer flushes its own
group early rather than deadlocking).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Optional

import numpy as np

from repro.configs.base import FrontendConfig
from repro.serving import api, scan, tiers

__all__ = ["FakeClock", "FrontendConfig", "FrontendStats", "PendingSearch",
           "ServingFrontend", "simulate_open_loop"]


class FakeClock:
    """Deterministic injectable clock: time moves only via ``advance``. Used
    by the scheduler tests (no wall-clock sleeps in tier-1) and the open-loop
    load simulation, where measured service time is charged explicitly."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def __call__(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"clock cannot go backwards (dt={dt})")
        self._t += float(dt)
        return self._t


@dataclasses.dataclass(frozen=True)
class FrontendStats:
    """Telemetry snapshot (``ServingFrontend.stats()``). Latency quantiles are
    over the rolling reservoir of the last ``latency_window`` served requests;
    QPS is served rows over the first-submit → last-completion span."""

    submitted: int                  # requests accepted into the front-end
    served: int                     # requests answered (excludes shed)
    shed: int                       # requests dropped by admission control
    batches: int                    # engine serve calls issued
    depth: int                      # requests currently queued
    mean_batch: float               # mean coalesced rows per serve call
    p50_ms: float                   # rolling median end-to-end latency
    p99_ms: float                   # rolling tail latency
    qps: float                      # served query rows / observed span


@dataclasses.dataclass
class PendingSearch:
    """Handle returned by ``submit``: resolves to a per-request SearchResult
    once its batch is served (or immediately, when shed). ``result()`` on a
    still-queued request force-flushes its group — demanding an answer is
    itself a deadline."""

    request: api.SearchRequest
    _frontend: "ServingFrontend" = dataclasses.field(repr=False)
    key: tuple = ()
    rows: int = 1
    seq: int = 0
    t_enq: float = 0.0
    flush_by: float = 0.0
    expire_at: Optional[float] = None       # explicit deadline_ms SLO, else None
    _result: Optional[api.SearchResult] = dataclasses.field(
        default=None, repr=False)

    def done(self) -> bool:
        return self._result is not None

    def result(self) -> api.SearchResult:
        if self._result is None:
            self._frontend._flush_group(self.key)
        assert self._result is not None
        return self._result


class ServingFrontend:
    """Dynamic-batching request queue in front of one ``LiraEngine``.

    ``clock`` is any zero-arg callable returning seconds. With
    ``charge_service=True`` the wall time of each engine call (measured by
    ``service_timer``) is charged onto the clock via ``clock.advance`` — how
    the open-loop simulation keeps deterministic arrivals while latencies
    still reflect real serve cost.
    """

    def __init__(self, engine, config: FrontendConfig | None = None, *,
                 clock: Callable[[], float] = time.monotonic,
                 charge_service: bool = False,
                 service_timer: Callable[[], float] = time.perf_counter):
        self.engine = engine
        self.cfg = config if config is not None else FrontendConfig()
        if charge_service and not hasattr(clock, "advance"):
            raise TypeError("charge_service=True needs a clock with .advance "
                            "(e.g. FakeClock)")
        self.clock = clock
        self.charge_service = charge_service
        self.service_timer = service_timer
        # flush sizes land on compiled steps: round the size trigger up into
        # the engine's pow2 jit-cache buckets (engine.py:_batch_bucket)
        self.max_batch = int(engine._batch_bucket(self.cfg.max_batch))
        self._groups: dict[tuple, list[PendingSearch]] = {}
        self._seq = 0
        self._n_submitted = 0
        self._n_served = 0
        self._n_shed = 0
        self._n_batches = 0
        self._rows_served = 0
        self._rows_batched = 0
        self._lat_ms: collections.deque = collections.deque(
            maxlen=self.cfg.latency_window)
        self._t_first: Optional[float] = None
        self._t_last_done: Optional[float] = None

    # ------------------------------------------------------------- intake

    def _resolve_key(self, req: api.SearchRequest) -> tuple:
        """Canonical compatibility key. Mirrors ``engine.serve_fn``'s
        normalization (tier aliases, impl="auto", k/σ=None) so requests that
        would hit the same compiled step coalesce into the same group."""
        eng = self.engine
        k = eng.cfg.k if req.k is None else int(req.k)
        sigma = float(eng.sigma if req.sigma is None else req.sigma)
        tier = tiers.resolve(req.tier if req.tier is not None
                             else eng.cfg.tier).name
        impl = scan.resolve_impl(req.impl if req.impl is not None
                                 else getattr(eng.cfg, "impl", "auto"))
        return (k, sigma, tier, impl)

    @staticmethod
    def _rows(req: api.SearchRequest) -> np.ndarray:
        q = np.asarray(req.queries)
        return q[None, :] if q.ndim == 1 else q

    def depth(self) -> int:
        """Requests currently queued (the admission-control measure)."""
        return sum(len(g) for g in self._groups.values())

    def submit(self, request: api.SearchRequest, *,
               t_arrival: Optional[float] = None) -> PendingSearch:
        """Enqueue one request; returns its handle. Size-triggered flushes run
        inline; sheds resolve the handle immediately with ``stats.shed=True``.

        ``t_arrival`` backdates the request to its true arrival time (the
        open-loop simulation uses this when intake lags behind the clock):
        queue wait and the flush deadline then measure from arrival.

        ``deadline_ms`` is an SLO, not just a flush hint: it tightens the
        flush trigger to ``min(max_wait_ms, deadline_ms)`` AND arms expiry —
        a request whose explicit deadline already passed before it could be
        enqueued is shed outright (dead on arrival), because serving
        provably-late traffic would only burn drain capacity the on-time
        queue needs. Requests without an explicit deadline never expire: the
        default ``max_wait_ms`` window is a batching knob, and an admitted
        request is always answered, merely late, when the engine falls
        behind."""
        key = self._resolve_key(request)
        now = self.clock()
        t_enq = now if t_arrival is None else float(t_arrival)
        wait_s = self.cfg.max_wait_ms / 1e3
        expire_at = None
        if request.deadline_ms is not None:
            slo_s = float(request.deadline_ms) / 1e3
            wait_s = min(wait_s, slo_s)
            expire_at = t_enq + slo_s
        self._seq += 1
        pending = PendingSearch(request=request, _frontend=self, key=key,
                                rows=len(self._rows(request)), seq=self._seq,
                                t_enq=t_enq, flush_by=t_enq + wait_s,
                                expire_at=expire_at)
        self._n_submitted += 1
        if self._t_first is None:
            self._t_first = t_enq
        if not request.allow_batching:
            # bypass the queue entirely: a solo batch, served now
            self._serve_batch(key, [pending])
            return pending
        if pending.expire_at is not None and pending.expire_at < now:
            self._shed(pending)             # dead on arrival: SLO already blown
            return pending
        if self.depth() >= self.cfg.max_queue and not self._admit(pending):
            return pending
        self._groups.setdefault(key, []).append(pending)
        if sum(p.rows for p in self._groups[key]) >= self.max_batch:
            self._flush_group(key)
        return pending

    def _admit(self, pending: PendingSearch) -> bool:
        """Admission control at a full queue: shed the lowest-priority waiting
        request if the newcomer outranks it (newest victim on ties), else shed
        the newcomer. Returns True when ``pending`` was admitted."""
        victim = min((p for g in self._groups.values() for p in g),
                     key=lambda p: (p.request.priority, -p.seq), default=None)
        if victim is not None and victim.request.priority < pending.request.priority:
            self._groups[victim.key].remove(victim)
            if not self._groups[victim.key]:
                del self._groups[victim.key]
            self._shed(victim)
            return True
        self._shed(pending)
        return False

    def _shed(self, pending: PendingSearch) -> None:
        k, sigma, tier, impl = pending.key
        pending._result = api.SearchResult(
            dists=np.full((pending.rows, k), np.inf, np.float32),
            ids=np.full((pending.rows, k), -1, np.int32),
            nprobe_eff=np.zeros((pending.rows,), np.float32), overflow=0,
            stats=api.SearchStats(tier=tier, impl=impl, k=k, sigma=sigma,
                                  bucket=0, cache_hit=False, queue_ms=0.0,
                                  batch_size=0, shed=True))
        self._n_shed += 1

    # ---------------------------------------------------------- scheduling

    def next_deadline(self) -> Optional[float]:
        """Earliest flush_by over queued requests (drivers poll() by then)."""
        deadlines = [p.flush_by for g in self._groups.values() for p in g]
        return min(deadlines) if deadlines else None

    def poll(self) -> int:
        """Deadline tick: flush every group whose earliest deadline has
        passed. Returns the number of serve calls issued."""
        now = self.clock()
        n = 0
        for key in list(self._groups):
            group = self._groups.get(key)
            if group and min(p.flush_by for p in group) <= now:
                n += self._flush_group(key)
        return n

    def drain(self) -> int:
        """Flush everything regardless of deadlines (shutdown / end of
        stream). Returns the number of serve calls issued."""
        return sum(self._flush_group(key) for key in list(self._groups))

    def _flush_group(self, key: tuple) -> int:
        """Serve one group's queue: highest-priority first, at most
        ``max_batch`` coalesced rows per engine call."""
        group = self._groups.pop(key, None)
        if not group:
            return 0
        group.sort(key=lambda p: (-p.request.priority, p.seq))
        n_calls = 0
        while group:
            batch = [group.pop(0)]
            rows = batch[0].rows
            while group and rows + group[0].rows <= self.max_batch:
                pending = group.pop(0)
                batch.append(pending)
                rows += pending.rows
            self._serve_batch(key, batch)
            n_calls += 1
        return n_calls

    def _serve_batch(self, key: tuple, batch: list[PendingSearch]) -> None:
        k, sigma, tier, impl = key
        t_launch = self.clock()
        queries = np.concatenate([self._rows(p.request) for p in batch], 0)
        t0 = self.service_timer()
        res = self.engine.search(api.SearchRequest(
            queries=queries, k=k, sigma=sigma, tier=tier, impl=impl))
        if self.charge_service:
            self.clock.advance(self.service_timer() - t0)
        t_done = self.clock()
        row = 0
        for pending in batch:
            sl = slice(row, row + pending.rows)
            row += pending.rows
            pending._result = api.SearchResult(
                dists=res.dists[sl], ids=res.ids[sl],
                nprobe_eff=res.nprobe_eff[sl], overflow=res.overflow,
                stats=api.SearchStats(
                    tier=tier, impl=impl, k=k, sigma=sigma,
                    bucket=res.stats.bucket, cache_hit=res.stats.cache_hit,
                    queue_ms=(t_launch - pending.t_enq) * 1e3,
                    batch_size=len(queries), shed=False))
            self._lat_ms.append((t_done - pending.t_enq) * 1e3)
        self._n_served += len(batch)
        self._rows_served += len(queries)
        self._n_batches += 1
        self._rows_batched += len(queries)
        self._t_last_done = t_done

    # ------------------------------------------------------------ telemetry

    def stats(self) -> FrontendStats:
        lat = np.asarray(self._lat_ms, np.float64)
        span = ((self._t_last_done - self._t_first)
                if self._t_first is not None and self._t_last_done is not None
                else 0.0)
        return FrontendStats(
            submitted=self._n_submitted, served=self._n_served,
            shed=self._n_shed, batches=self._n_batches, depth=self.depth(),
            mean_batch=(self._rows_batched / self._n_batches
                        if self._n_batches else 0.0),
            p50_ms=float(np.quantile(lat, 0.50)) if lat.size else 0.0,
            p99_ms=float(np.quantile(lat, 0.99)) if lat.size else 0.0,
            qps=(self._rows_served / span) if span > 0 else 0.0)


# ------------------------------------------------------------- simulation

def simulate_open_loop(frontend: ServingFrontend, queries: np.ndarray, *,
                       rate_qps: float, n_requests: int,
                       deadline_ms: Optional[float] = None,
                       priority: int = 0, sigma: Optional[float] = None,
                       tier: Optional[str] = None, impl: Optional[str] = None,
                       k: Optional[int] = None):
    """Drive an open-loop single-query arrival stream against the front-end's
    (fake) clock: request ``i`` arrives at ``i / rate_qps`` regardless of
    completions — the offered load does not back off when the system falls
    behind, which is exactly what makes admission control necessary. While the
    next arrival is in the future the clock advances through each pending
    group's deadline and polls, like an event-loop driver would; arrivals the
    clock has already overrun (service time pushed it past them) are submitted
    backdated without intermediate polls — a backlog coalesces through the
    size trigger, and each request's latency, or its dead-on-arrival shed when
    ``deadline_ms`` is set, reflects the backlog it actually experienced.
    Returns ``(stats, pendings)``; the stream is drained before the snapshot,
    so every handle is resolved.

    ``sigma``/``tier``/``impl``/``k`` are stamped onto every request — one
    compatibility group, one jit-cache key (leave them None to inherit the
    engine defaults). Requires ``frontend.clock`` to be advanceable
    (``FakeClock``); with ``charge_service=True`` the simulated timeline also
    carries each engine call's measured wall cost, so p50/p99/QPS reflect
    real serve speed under deterministic arrivals.
    """
    clock = frontend.clock
    if not hasattr(clock, "advance"):
        raise TypeError("simulate_open_loop needs an advanceable clock "
                        "(FakeClock), not wall time")
    pendings = []
    for i in range(n_requests):
        t_arr = i / float(rate_qps)
        # tick deadline flushes only while advancing toward a FUTURE arrival.
        # When service time has pushed the clock past t_arr the backlog is
        # submitted without polling: backdated requests' flush windows are
        # already expired, and polling between them would flush singleton
        # batches — the size trigger is what coalesces a backlog.
        while clock() < t_arr:
            nd = frontend.next_deadline()
            if nd is None or nd > t_arr:
                clock.advance(t_arr - clock())
                break
            if nd > clock():
                clock.advance(nd - clock())
            frontend.poll()
        pendings.append(frontend.submit(api.SearchRequest(
            queries=queries[i % len(queries)], deadline_ms=deadline_ms,
            priority=priority, sigma=sigma, tier=tier, impl=impl, k=k),
            t_arrival=t_arr))
    # end of stream: honor remaining deadlines, then drain
    while True:
        nd = frontend.next_deadline()
        if nd is None:
            break
        if nd > clock():
            clock.advance(nd - clock())
        frontend.poll()
    frontend.drain()
    return frontend.stats(), pendings
