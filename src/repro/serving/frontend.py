"""Online serving front-end: dynamic batching, admission control, telemetry.

The engine underneath is a batch-synchronous ``search()`` — fast once a batch
exists, but production traffic is a stream of single-query ``SearchRequest``s
arriving at wildly varying rates (the HARMONY/LANNS observation: at web scale
the batching/routing layer above the index, not the scan kernel, dominates
tail latency). ``ServingFrontend`` is that layer:

  * **dynamic batching** — requests accumulate per compatibility group
    (resolved ``(k, σ, tier, impl)`` — batching is an optimization, never a
    semantics change, so incompatible requests never share a serve step) and
    flush on whichever trigger fires first: size (``max_batch`` coalesced
    rows, rounded up to the engine's pow2 jit-cache bucket so flushes land on
    already-compiled steps) or deadline (``max_wait_ms`` since enqueue,
    tightened per request by ``SearchRequest.deadline_ms``, which also arms
    dead-on-arrival expiry — see ``submit``);
  * **admission control** — a bounded queue (``max_queue`` requests). Beyond
    it, load is SHED instead of queued: the lowest-priority waiting request
    (or the newcomer, if nothing queued outranks it) resolves immediately
    with an empty answer marked ``SearchStats.shed=True``, keeping tail
    latency bounded for the traffic that is admitted;
  * **latency telemetry** — every served request records its queue wait and
    end-to-end latency against the injected clock, into log-spaced histograms
    in a metrics registry (repro.obs.metrics) labeled ``frontend=<name>`` —
    O(buckets) memory for a long-lived process, unlike the per-observation
    reservoir it replaces. ``stats()`` snapshots p50/p99 (bucket-interpolated,
    clamped to the observed min/max), QPS, shed/served counters and mean
    coalesced batch size as a ``FrontendStats``. With a tracer attached
    (``tracer=`` here or on the engine) each served request's
    ``SearchStats.stages`` carries the queue → assemble → serve.* breakdown
    and per-stage histograms aggregate across requests.

Scatter is exact: each coalesced batch's rows are sliced back into
per-request ``SearchResult``s that are bit-identical to a solo
``engine.search()`` of the same query (the serve step is row-independent;
tests/test_frontend.py gates this across {f32, pq, residual_pq} ×
{ref, interpret}). The one shared field is ``overflow``: q_cap drops are
counted per serve step, so a batched result reports its whole batch's total.

The scheduler never sleeps or reads wall clock on its own: time comes from an
injectable ``clock`` callable (``FakeClock`` for deterministic tests and
simulation, ``time.monotonic`` in production). Because the engine call is
synchronous, flushes happen inside ``submit`` (size trigger), ``poll``
(deadline trigger — drivers call it as their event loop tick) or
``PendingSearch.result()`` (a caller demanding its answer flushes its own
group early rather than deadlocking).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Optional

import numpy as np

from repro.configs.base import FrontendConfig
from repro.obs import metrics as obs_metrics
from repro.serving import api, scan, tiers
from repro.utils.clock import FakeClock  # noqa: F401  (canonical home; re-exported)

__all__ = ["FakeClock", "FrontendConfig", "FrontendStats", "PendingSearch",
           "ServingFrontend", "simulate_open_loop"]


@dataclasses.dataclass(frozen=True)
class FrontendStats:
    """Telemetry snapshot (``ServingFrontend.stats()``), read back from the
    metrics registry. Latency quantiles are bucket-interpolated from the
    cumulative ``lira_frontend_latency_ms`` histogram (clamped to the exact
    observed min/max, so degenerate distributions report exactly); QPS is
    served rows over the first-submit → last-completion span, reported only
    once ≥ 2 requests completed (a single completion has no span to divide
    by, so it reads 0.0 instead of a garbage rate)."""

    submitted: int                  # requests accepted into the front-end
    served: int                     # requests answered (excludes shed)
    shed: int                       # requests dropped by admission control
    batches: int                    # engine serve calls issued
    depth: int                      # requests currently queued
    mean_batch: float               # mean coalesced rows per serve call
    p50_ms: float                   # median end-to-end latency
    p99_ms: float                   # tail latency
    qps: float                      # served query rows / observed span


@dataclasses.dataclass
class PendingSearch:
    """Handle returned by ``submit``: resolves to a per-request SearchResult
    once its batch is served (or immediately, when shed). ``result()`` on a
    still-queued request force-flushes its group — demanding an answer is
    itself a deadline."""

    request: api.SearchRequest
    _frontend: "ServingFrontend" = dataclasses.field(repr=False)
    key: tuple = ()
    rows: int = 1
    seq: int = 0
    t_enq: float = 0.0
    flush_by: float = 0.0
    expire_at: Optional[float] = None       # explicit deadline_ms SLO, else None
    _result: Optional[api.SearchResult] = dataclasses.field(
        default=None, repr=False)

    def done(self) -> bool:
        return self._result is not None

    def result(self) -> api.SearchResult:
        if self._result is None:
            self._frontend._flush_group(self.key)
        assert self._result is not None
        return self._result


_FE_NAMES = itertools.count()


class ServingFrontend:
    """Dynamic-batching request queue in front of one ``LiraEngine``.

    ``clock`` is any zero-arg callable returning seconds. With
    ``charge_service=True`` the wall time of each engine call (measured by
    ``service_timer``) is charged onto the clock via ``clock.advance`` — how
    the open-loop simulation keeps deterministic arrivals while latencies
    still reflect real serve cost.

    Telemetry lives in a metrics registry (``metrics=``, defaulting to the
    engine's) under ``lira_frontend_*`` series labeled ``frontend=<name>``;
    the name is auto-generated per instance so several front-ends sharing the
    process-wide default registry never mix their distributions. ``tracer=``
    (defaulting to the engine's) spans each batch — see README
    "Observability" for the span hierarchy.
    """

    def __init__(self, engine, config: FrontendConfig | None = None, *,
                 clock: Callable[[], float] = time.monotonic,
                 charge_service: bool = False,
                 service_timer: Callable[[], float] = time.perf_counter,
                 tracer=None, metrics=None, name: Optional[str] = None):
        self.engine = engine
        self.cfg = config if config is not None else FrontendConfig()
        if charge_service and not hasattr(clock, "advance"):
            raise TypeError("charge_service=True needs a clock with .advance "
                            "(e.g. FakeClock)")
        self.clock = clock
        self.charge_service = charge_service
        self.service_timer = service_timer
        self.tracer = tracer
        self.metrics = metrics
        self.name = name if name is not None else f"fe{next(_FE_NAMES)}"
        self._lbl = {"frontend": self.name}
        # flush sizes land on compiled steps: round the size trigger up into
        # the engine's pow2 jit-cache buckets (engine.py:_batch_bucket)
        self.max_batch = int(engine._batch_bucket(self.cfg.max_batch))
        self._groups: dict[tuple, list[PendingSearch]] = {}
        self._seq = 0
        self._t_first: Optional[float] = None
        self._t_last_done: Optional[float] = None

    def _tr(self):
        return self.tracer if self.tracer is not None else self.engine._tracer()

    def _m(self) -> obs_metrics.MetricsRegistry:
        return (self.metrics if self.metrics is not None
                else self.engine._registry())

    # registry instruments (get-or-create is idempotent and cheap)
    def _c_submitted(self):
        return self._m().counter("lira_frontend_submitted_total",
                                 "requests accepted into the front-end")

    def _c_served(self):
        return self._m().counter("lira_frontend_served_total",
                                 "requests answered (excludes shed)")

    def _c_shed(self):
        return self._m().counter("lira_frontend_shed_total",
                                 "requests dropped, by reason: doa (deadline "
                                 "blown before enqueue), displaced (evicted "
                                 "by higher priority), rejected (full queue, "
                                 "nothing outranked)")

    def _c_batches(self):
        return self._m().counter("lira_frontend_batches_total",
                                 "engine serve calls issued")

    def _c_rows(self):
        return self._m().counter("lira_frontend_rows_total",
                                 "query rows served through batches")

    def _h_latency(self):
        return self._m().histogram("lira_frontend_latency_ms",
                                   "end-to-end request latency (injected "
                                   "clock)")

    def _h_queue(self):
        return self._m().histogram("lira_frontend_queue_ms",
                                   "enqueue → batch-launch wait")

    def _h_batch_rows(self):
        return self._m().histogram(
            "lira_frontend_batch_rows",
            "coalesced rows per serve call, per compatibility group",
            buckets=obs_metrics.BATCH_ROWS_BUCKETS)

    def _h_stage(self):
        return self._m().histogram("lira_frontend_stage_ms",
                                   "per-stage serve latency (traced runs "
                                   "only), labeled stage=assemble/serve.*/"
                                   "scatter")

    # ------------------------------------------------------------- intake

    def _resolve_key(self, req: api.SearchRequest) -> tuple:
        """Canonical compatibility key. Mirrors ``engine.serve_fn``'s
        normalization (tier aliases, impl="auto", k/σ=None) so requests that
        would hit the same compiled step coalesce into the same group."""
        eng = self.engine
        k = eng.cfg.k if req.k is None else int(req.k)
        sigma = float(eng.sigma if req.sigma is None else req.sigma)
        tier = tiers.resolve(req.tier if req.tier is not None
                             else eng.cfg.tier).name
        impl = scan.resolve_impl(req.impl if req.impl is not None
                                 else getattr(eng.cfg, "impl", "auto"))
        return (k, sigma, tier, impl)

    @staticmethod
    def _rows(req: api.SearchRequest) -> np.ndarray:
        q = np.asarray(req.queries)
        return q[None, :] if q.ndim == 1 else q

    def depth(self) -> int:
        """Requests currently queued (the admission-control measure)."""
        return sum(len(g) for g in self._groups.values())

    def submit(self, request: api.SearchRequest, *,
               t_arrival: Optional[float] = None) -> PendingSearch:
        """Enqueue one request; returns its handle. Size-triggered flushes run
        inline; sheds resolve the handle immediately with ``stats.shed=True``.

        ``t_arrival`` backdates the request to its true arrival time (the
        open-loop simulation uses this when intake lags behind the clock):
        queue wait and the flush deadline then measure from arrival.

        ``deadline_ms`` is an SLO, not just a flush hint: it tightens the
        flush trigger to ``min(max_wait_ms, deadline_ms)`` AND arms expiry —
        a request whose explicit deadline already passed before it could be
        enqueued is shed outright (dead on arrival), because serving
        provably-late traffic would only burn drain capacity the on-time
        queue needs. Requests without an explicit deadline never expire: the
        default ``max_wait_ms`` window is a batching knob, and an admitted
        request is always answered, merely late, when the engine falls
        behind."""
        key = self._resolve_key(request)
        now = self.clock()
        t_enq = now if t_arrival is None else float(t_arrival)
        wait_s = self.cfg.max_wait_ms / 1e3
        expire_at = None
        if request.deadline_ms is not None:
            slo_s = float(request.deadline_ms) / 1e3
            wait_s = min(wait_s, slo_s)
            expire_at = t_enq + slo_s
        self._seq += 1
        pending = PendingSearch(request=request, _frontend=self, key=key,
                                rows=len(self._rows(request)), seq=self._seq,
                                t_enq=t_enq, flush_by=t_enq + wait_s,
                                expire_at=expire_at)
        self._c_submitted().inc(**self._lbl)
        if self._t_first is None:
            self._t_first = t_enq
        if pending.expire_at is not None and pending.expire_at < now:
            # dead on arrival: SLO already blown. Checked BEFORE the bypass
            # branch — an allow_batching=False request with an expired
            # explicit deadline sheds exactly like the queued path would.
            self._shed(pending, "doa")
            return pending
        if not request.allow_batching:
            # bypass the queue entirely: a solo batch, served now
            self._serve_batch(key, [pending])
            return pending
        if self.depth() >= self.cfg.max_queue and not self._admit(pending):
            return pending
        self._groups.setdefault(key, []).append(pending)
        if sum(p.rows for p in self._groups[key]) >= self.max_batch:
            self._flush_group(key)
        return pending

    def _admit(self, pending: PendingSearch) -> bool:
        """Admission control at a full queue: shed the lowest-priority waiting
        request if the newcomer outranks it (newest victim on ties), else shed
        the newcomer. Returns True when ``pending`` was admitted."""
        victim = min((p for g in self._groups.values() for p in g),
                     key=lambda p: (p.request.priority, -p.seq), default=None)
        if victim is not None and victim.request.priority < pending.request.priority:
            # remove by identity: dataclass == on PendingSearch would compare
            # the numpy query arrays inside the requests (ambiguous truth)
            group = self._groups[victim.key]
            group[:] = [p for p in group if p is not victim]
            if not group:
                del self._groups[victim.key]
            self._shed(victim, "displaced")
            return True
        self._shed(pending, "rejected")
        return False

    def _shed(self, pending: PendingSearch, reason: str) -> None:
        k, sigma, tier, impl = pending.key
        pending._result = api.SearchResult(
            dists=np.full((pending.rows, k), np.inf, np.float32),
            ids=np.full((pending.rows, k), -1, np.int32),
            nprobe_eff=np.zeros((pending.rows,), np.float32), overflow=0,
            stats=api.SearchStats(tier=tier, impl=impl, k=k, sigma=sigma,
                                  bucket=0, cache_hit=False, queue_ms=0.0,
                                  batch_size=0, shed=True))
        self._c_shed().inc(reason=reason, **self._lbl)

    # ---------------------------------------------------------- scheduling

    def next_deadline(self) -> Optional[float]:
        """Earliest flush_by over queued requests (drivers poll() by then)."""
        deadlines = [p.flush_by for g in self._groups.values() for p in g]
        return min(deadlines) if deadlines else None

    def poll(self) -> int:
        """Deadline tick: flush every group whose earliest deadline has
        passed. Returns the number of serve calls issued."""
        now = self.clock()
        n = 0
        for key in list(self._groups):
            group = self._groups.get(key)
            if group and min(p.flush_by for p in group) <= now:
                n += self._flush_group(key)
        return n

    def drain(self) -> int:
        """Flush everything regardless of deadlines (shutdown / end of
        stream). Returns the number of serve calls issued."""
        return sum(self._flush_group(key) for key in list(self._groups))

    def quiesce(self) -> int:
        """Epoch barrier for store mutations (``LiraEngine.insert/delete/
        compact/maybe_repartition`` call this before touching the store):
        drain every queued request so no coalesced batch spans two epochs —
        everything in flight is served against the pre-mutation store and
        carries its ``SearchStats.epoch``; requests submitted afterwards see
        the bumped epoch atomically. Returns the serve calls issued."""
        return self.drain()

    def _flush_group(self, key: tuple) -> int:
        """Serve one group's queue: highest-priority first, at most
        ``max_batch`` coalesced rows per engine call."""
        group = self._groups.pop(key, None)
        if not group:
            return 0
        group.sort(key=lambda p: (-p.request.priority, p.seq))
        n_calls = 0
        while group:
            batch = [group.pop(0)]
            rows = batch[0].rows
            while group and rows + group[0].rows <= self.max_batch:
                pending = group.pop(0)
                batch.append(pending)
                rows += pending.rows
            self._serve_batch(key, batch)
            n_calls += 1
        return n_calls

    def _serve_batch(self, key: tuple, batch: list[PendingSearch]) -> None:
        k, sigma, tier, impl = key
        tr = self._tr()
        t_launch = self.clock()
        with tr.span("frontend.batch", group=str(key),
                     requests=len(batch)) as sp_batch:
            with tr.span("frontend.assemble") as sp_asm:
                queries = np.concatenate(
                    [self._rows(p.request) for p in batch], 0)
            t0 = self.service_timer()
            # engine.search opens its own engine.* spans, which nest under
            # frontend.batch when engine and front-end share a tracer
            res = self.engine.search(api.SearchRequest(
                queries=queries, k=k, sigma=sigma, tier=tier, impl=impl))
            if self.charge_service:
                self.clock.advance(self.service_timer() - t0)
            t_done = self.clock()
            with tr.span("frontend.scatter") as sp_scat:
                row = 0
                for pending in batch:
                    sl = slice(row, row + pending.rows)
                    row += pending.rows
                    queue_ms = (t_launch - pending.t_enq) * 1e3
                    latency_ms = (t_done - pending.t_enq) * 1e3
                    stages = None
                    if tr.enabled:
                        # per-request breakdown: queue wait is this request's
                        # own; assemble + engine stages are the batch's (each
                        # request in a batch experienced them once, together)
                        stages = {"queue": queue_ms,
                                  "assemble": sp_asm.duration_ms}
                        for st, ms in (res.stats.stages or {}).items():
                            stages[f"serve.{st}"] = ms
                    pending._result = api.SearchResult(
                        dists=res.dists[sl], ids=res.ids[sl],
                        nprobe_eff=res.nprobe_eff[sl], overflow=res.overflow,
                        stats=api.SearchStats(
                            tier=tier, impl=impl, k=k, sigma=sigma,
                            bucket=res.stats.bucket,
                            cache_hit=res.stats.cache_hit,
                            queue_ms=queue_ms, batch_size=len(queries),
                            shed=False, dedup_hits=res.stats.dedup_hits,
                            latency_ms=latency_ms, stages=stages,
                            epoch=res.stats.epoch))
                    self._c_served().inc(**self._lbl)
                    self._h_queue().observe(queue_ms, **self._lbl)
                    self._h_latency().observe(latency_ms, **self._lbl)
            sp_batch.set(rows=len(queries))
        self._c_batches().inc(**self._lbl)
        self._c_rows().inc(len(queries), **self._lbl)
        self._h_batch_rows().observe(len(queries), group=str(key), **self._lbl)
        if tr.enabled:
            hs = self._h_stage()
            hs.observe(sp_asm.duration_ms, stage="assemble", **self._lbl)
            hs.observe(sp_scat.duration_ms, stage="scatter", **self._lbl)
            for st, ms in (res.stats.stages or {}).items():
                hs.observe(ms, stage=f"serve.{st}", **self._lbl)
        self._t_last_done = t_done

    # ------------------------------------------------------------ telemetry

    def stats(self) -> FrontendStats:
        lbl = self._lbl
        served = int(self._c_served().value(**lbl))
        batches = int(self._c_batches().value(**lbl))
        rows = self._c_rows().value(**lbl)
        lat = self._h_latency()
        span = ((self._t_last_done - self._t_first)
                if self._t_first is not None and self._t_last_done is not None
                else 0.0)
        # a single completion has no observable span (and span can be 0 under
        # a virtual clock): report 0.0 rather than divide noise by epsilon
        qps = rows / span if span > 0 and served >= 2 else 0.0
        return FrontendStats(
            submitted=int(self._c_submitted().value(**lbl)), served=served,
            shed=int(self._c_shed().total(**lbl)), batches=batches,
            depth=self.depth(),
            mean_batch=rows / batches if batches else 0.0,
            p50_ms=lat.quantile(0.50, **lbl),
            p99_ms=lat.quantile(0.99, **lbl),
            qps=qps)


# ------------------------------------------------------------- simulation

def simulate_open_loop(frontend: ServingFrontend, queries: np.ndarray, *,
                       rate_qps: float, n_requests: int,
                       deadline_ms: Optional[float] = None,
                       priority: int = 0, sigma: Optional[float] = None,
                       tier: Optional[str] = None, impl: Optional[str] = None,
                       k: Optional[int] = None):
    """Drive an open-loop single-query arrival stream against the front-end's
    (fake) clock: request ``i`` arrives at ``i / rate_qps`` regardless of
    completions — the offered load does not back off when the system falls
    behind, which is exactly what makes admission control necessary. While the
    next arrival is in the future the clock advances through each pending
    group's deadline and polls, like an event-loop driver would; arrivals the
    clock has already overrun (service time pushed it past them) are submitted
    backdated without intermediate polls — a backlog coalesces through the
    size trigger, and each request's latency, or its dead-on-arrival shed when
    ``deadline_ms`` is set, reflects the backlog it actually experienced.
    Returns ``(stats, pendings)``; the stream is drained before the snapshot,
    so every handle is resolved.

    ``sigma``/``tier``/``impl``/``k`` are stamped onto every request — one
    compatibility group, one jit-cache key (leave them None to inherit the
    engine defaults). Requires ``frontend.clock`` to be advanceable
    (``FakeClock``); with ``charge_service=True`` the simulated timeline also
    carries each engine call's measured wall cost, so p50/p99/QPS reflect
    real serve speed under deterministic arrivals.
    """
    clock = frontend.clock
    if not hasattr(clock, "advance"):
        raise TypeError("simulate_open_loop needs an advanceable clock "
                        "(FakeClock), not wall time")
    pendings = []
    for i in range(n_requests):
        t_arr = i / float(rate_qps)
        # tick deadline flushes only while advancing toward a FUTURE arrival.
        # When service time has pushed the clock past t_arr the backlog is
        # submitted without polling: backdated requests' flush windows are
        # already expired, and polling between them would flush singleton
        # batches — the size trigger is what coalesces a backlog.
        while clock() < t_arr:
            nd = frontend.next_deadline()
            if nd is None or nd > t_arr:
                clock.advance(t_arr - clock())
                break
            if nd > clock():
                clock.advance(nd - clock())
            frontend.poll()
        pendings.append(frontend.submit(api.SearchRequest(
            queries=queries[i % len(queries)], deadline_ms=deadline_ms,
            priority=priority, sigma=sigma, tier=tier, impl=impl, k=k),
            t_arrival=t_arr))
    # end of stream: honor remaining deadlines, then drain
    while True:
        nd = frontend.next_deadline()
        if nd is None:
            break
        if nd > clock():
            clock.advance(nd - clock())
        frontend.poll()
    frontend.drain()
    return frontend.stats(), pendings
