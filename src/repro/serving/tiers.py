"""Serving-tier registry — the extension point of the typed serving surface.

A `Tier` bundles everything the engine must know about one storage/quantization
strategy, so `make_serve_step`, `store_specs`, `store_pspecs` and
`LiraEngine.build` iterate declarations instead of branching on booleans:

  * ``store_specs(cfg)``  — every store field's shape + dtype (models/api.sds);
  * ``store_pspecs(cfg)`` — each field's mesh PartitionSpec ("model"-sharded
    planes ride with their partitions, codebooks/centroids replicate);
  * ``build_store(rng, cfg, store_h)`` — build-time store construction from the
    host-side partition store, returning the store dict and the (possibly
    amended) config, e.g. PQ clamps ``pq_ks`` for tiny stores;
  * ``scan_kwargs(cfg, ctx, fields)`` — the extra operands this tier threads
    into ``scan.run`` inside the serve step (shared ADC LUT, shortlist depth,
    residual offset planes); ``{}`` selects the plain f32 scan.

Registered tiers: ``f32`` (exact scan; honors ``cfg.store_dtype`` so a
bfloat16 store halves the dominant vector-read traffic), ``pq`` (shared-LUT
ADC shortlist + exact rerank), ``residual_pq`` (codes encode x − centroid with
the residual ADC identity's offset operands). Adding a tier is one registered
class here — zero engine edits; the extensibility test in
tests/test_tiers.py serves through a toy tier defined outside this module.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.api import sds

# fields every tier must provide — the serve step's probing/dispatch/rerank
# operands. Tiers append their own scan-stage fields after these.
BASE_FIELDS = ("centroids", "vectors", "ids", "occupancy")

_REGISTRY: dict[str, "Tier"] = {}


def register(cls):
    """Class decorator: instantiate and index the tier under its name (and
    aliases). Later registrations win, so tests can shadow-and-restore."""
    tier = cls()
    for name in (cls.name, *cls.aliases):
        _REGISTRY[name] = tier
    return cls


def resolve(tier) -> "Tier":
    """Map a tier name (or an already-resolved Tier) to the registered
    instance. Fails fast on typos, like scan.resolve_impl."""
    if isinstance(tier, Tier):
        return tier
    try:
        return _REGISTRY[tier]
    except KeyError:
        raise ValueError(f"unknown serving tier {tier!r}; registered tiers: "
                         f"{names()}") from None


def names() -> tuple[str, ...]:
    """Canonical registered tier names (aliases collapsed)."""
    return tuple(sorted({t.name for t in _REGISTRY.values()}))


def legacy_tier_name(quantized: bool, residual: bool) -> str:
    """The tier the retired boolean knobs selected (deprecation shims only)."""
    return "residual_pq" if residual else ("pq" if quantized else "f32")


@dataclasses.dataclass(frozen=True)
class ScanContext:
    """Serve-step state handed to ``Tier.scan_kwargs`` — everything a tier may
    derive scan operands from without re-deriving probing work."""

    q_loc: jax.Array        # [q_row, d] local query rows
    q_pad: jax.Array        # [q_row + 1, d] queries + sentinel row
    cd: jax.Array           # [q_row, B] query↔centroid squared-distance matrix
    b0: jax.Array | int     # first partition id owned by this shard
    b_loc: int              # partitions per shard
    k: int                  # top-k depth of this serve step


class Tier:
    """Base tier: the exact f32 scan. ``cfg.store_dtype`` controls the vector
    plane's storage dtype (bfloat16 halves scan reads; distances accumulate in
    f32 either way, and the quantized tiers' rerank upcasts to f32)."""

    name: str = "f32"
    aliases: tuple[str, ...] = ()

    # ---------------------------------------------------------- declarations

    def store_specs(self, cfg) -> dict:
        b, c, d = cfg.n_partitions, cfg.capacity, cfg.dim
        return {
            "centroids": sds((b, d)),
            "vectors": sds((b, c, d), jnp.dtype(getattr(cfg, "store_dtype", "float32"))),
            "ids": sds((b, c), jnp.int32),
            "occupancy": sds((b, c), jnp.bool_),
        }

    def store_pspecs(self, cfg=None) -> dict:
        return {
            "centroids": P(None, None),
            "vectors": P("model", None, None),
            "ids": P("model", None),
            "occupancy": P("model", None),
        }

    def slot_fields(self, cfg) -> tuple:
        """Store fields indexed per (partition, slot) — the planes a mutation
        must move together when rows are placed, tombstoned, or compacted.
        Partition-level fields (centroids) and replicated operands (codebooks)
        are excluded by construction: everything whose leading dims are
        [n_partitions, capacity]."""
        b, c = cfg.n_partitions, cfg.capacity
        return tuple(name for name, spec in self.store_specs(cfg).items()
                     if name != "centroids" and spec.shape[:2] == (b, c))

    # ---------------------------------------------------------------- build

    def build_store(self, rng, cfg, store_h):
        """Store dict from the host-side partition store (core.build_store).
        Returns (store, cfg); cfg comes back amended when build resolves a
        knob (PQ's ks clamp / pq_m default)."""
        del rng
        dt = jnp.dtype(getattr(cfg, "store_dtype", "float32"))
        vectors = jnp.asarray(store_h.vectors)
        if vectors.dtype != dt:
            vectors = vectors.astype(dt)
        ids = jnp.asarray(store_h.ids)
        store = {"centroids": jnp.asarray(store_h.centroids), "vectors": vectors,
                 "ids": ids, "occupancy": ids >= 0}
        return store, cfg

    # ------------------------------------------------------------- mutation

    def encode_rows(self, cfg, store, x_new, parts) -> dict:
        """Encode appended rows into this tier's per-slot planes: a dict of
        slot-field name → [n_new, ...] rows ready to scatter into the free
        slots the engine picked. ``parts`` is each row's destination partition
        (residual tiers re-derive x − centroid against it); ``ids`` and
        ``occupancy`` are placement bookkeeping the engine owns, so tiers
        return only the content planes."""
        del parts
        dt = store["vectors"].dtype
        return {"vectors": jnp.asarray(x_new).astype(dt)}

    # ---------------------------------------------------------------- serve

    def check_servable(self, cfg) -> None:
        """Raise if this tier cannot correctly serve a store built for
        ``cfg.tier`` (beyond mere field presence, which the engine already
        checks). Base: any store carries exact f32 operands."""
        del cfg

    def scan_kwargs(self, cfg, ctx: ScanContext, fields: dict) -> dict:
        """Extra keyword operands for ``scan.run``; {} = plain f32 scan.
        ``fields`` maps this tier's non-BASE_FIELDS store names to their local
        (already sharded) arrays inside the serve step."""
        del cfg, ctx, fields
        return {}


@register
class F32Tier(Tier):
    name = "f32"
    aliases = ("exact", "float32")


@register
class PqTier(Tier):
    """Two-stage quantized tier: shared per-query ADC LUT → shortlist of
    ``rerank·k`` slots over the uint8 codes → exact f32 rerank
    (serving/quantized.py owns the PQ store construction and byte accounting)."""

    name = "pq"
    aliases = ("quantized",)
    residual = False

    def store_specs(self, cfg) -> dict:
        from repro.core.pq import code_dtype

        specs = super().store_specs(cfg)
        b, c = cfg.n_partitions, cfg.capacity
        specs["codes"] = sds((b, c, cfg.pq_m), jnp.dtype(code_dtype(cfg.pq_ks)))
        specs["codebooks"] = sds((cfg.pq_m, cfg.pq_ks, cfg.dim // cfg.pq_m))
        return specs

    def store_pspecs(self, cfg=None) -> dict:
        sp = super().store_pspecs(cfg)
        sp["codes"] = P("model", None, None)   # codes shard with their vectors
        sp["codebooks"] = P(None, None, None)  # replicated like centroids
        return sp

    def build_store(self, rng, cfg, store_h):
        import dataclasses as dc

        from repro.serving import quantized as quantized_tier

        store, cfg = super().build_store(rng, cfg, store_h)
        # default pq_m: largest divisor of dim ≤ 16 (subspaces must tile dim)
        m = cfg.pq_m or max(m for m in range(1, min(16, cfg.dim) + 1)
                            if cfg.dim % m == 0)
        qs = quantized_tier.build_quantized_store(
            rng, store_h.vectors, store_h.ids, m=m, ks=cfg.pq_ks,
            residual=self.residual,
            centroids=store_h.centroids if self.residual else None)
        store["codes"], store["codebooks"] = qs.codes, qs.codebooks
        if self.residual:
            store["cterm"] = qs.cterm
        # ks may have been clamped for tiny stores
        return store, dc.replace(cfg, pq_m=m, pq_ks=qs.ks)

    def check_servable(self, cfg) -> None:
        # codes built for residual_pq encode x − centroid: scanning them
        # through the plain shared-LUT path (no cterm/offset corrections)
        # would silently rank by distance-to-residual — wrong answers, not an
        # error, so refuse up front
        if not self.residual and cfg.tier == "residual_pq":
            raise ValueError(
                "store codes are residual-encoded (built with "
                "tier='residual_pq'); serve tier='residual_pq' or the exact "
                "'f32' fallback, not 'pq'")

    def scan_kwargs(self, cfg, ctx: ScanContext, fields: dict) -> dict:
        from repro.serving import quantized as quantized_tier

        codes, codebooks = fields["codes"], fields["codebooks"]
        m = codes.shape[-1]
        rk = min(cfg.capacity, max(ctx.k, int(getattr(cfg, "rerank", 4)) * ctx.k))
        # per-query ADC LUT, once — valid across all partitions. Non-residual
        # codebooks make it exact; residual codebooks are exact up to the two
        # scalar corrections of the residual ADC identity (core/pq.py) added
        # by ResidualPqTier below. The zero row pairs with q_pad's sentinel.
        # This COMPACT [q_row+1, m, ks] plane is what the scan kernels consume
        # (scalar-prefetched per-bucket gather) — never expand it per slot.
        lut_pad = jnp.concatenate(
            [quantized_tier.adc_lut(codebooks, ctx.q_loc),
             jnp.zeros((1, m, codebooks.shape[1]), jnp.float32)], 0)
        return {"lut_pad": lut_pad, "codes_loc": codes, "rk": rk}

    def encode_rows(self, cfg, store, x_new, parts) -> dict:
        import numpy as np

        from repro.core import pq as pqmod

        rows = super().encode_rows(cfg, store, x_new, parts)
        cbs = jnp.asarray(store["codebooks"])
        pq = pqmod.PQCodebook(codebooks=cbs, m=int(cbs.shape[0]),
                              ks=int(cbs.shape[1]))
        x = np.asarray(x_new, np.float32)
        if self.residual:
            # codes must encode the residual against the DESTINATION
            # partition's centroid — re-derived here, not at original build
            cents = np.asarray(store["centroids"], np.float32)[np.asarray(parts)]
            x = x - cents
        codes = pqmod.encode(pq, x)
        rows["codes"] = jnp.asarray(codes).astype(store["codes"].dtype)
        if self.residual:
            rows["cterm"] = jnp.asarray(
                pqmod.residual_cross_terms(pq, cents, codes))
        return rows


@register
class ResidualPqTier(PqTier):
    """PQ over x − centroid: the code budget goes to the within-partition
    residual (the clustered-store win), paid for by a per-slot cterm plane and
    a per-(query, partition) offset derived from the probing cd matrix."""

    name = "residual_pq"
    aliases = ("residual",)
    residual = True

    def store_specs(self, cfg) -> dict:
        specs = super().store_specs(cfg)
        specs["cterm"] = sds((cfg.n_partitions, cfg.capacity))
        return specs

    def store_pspecs(self, cfg=None) -> dict:
        sp = super().store_pspecs(cfg)
        sp["cterm"] = P("model", None)  # rides with its codes
        return sp

    def scan_kwargs(self, cfg, ctx: ScanContext, fields: dict) -> dict:
        kw = super().scan_kwargs(cfg, ctx, fields)
        # ‖c_b‖² − 2⟨q, c_b⟩ = cd − ‖q‖², per (query, partition); the centroid
        # distance matrix cd is already computed for probing.
        off = ctx.cd - jnp.sum(ctx.q_loc * ctx.q_loc, -1, keepdims=True)
        off_pad = jnp.concatenate([off, jnp.zeros((1, off.shape[1]), off.dtype)], 0)
        off_loc = jax.lax.dynamic_slice_in_dim(
            off_pad, ctx.b0, ctx.b_loc, axis=1).T      # [b_loc, q_row + 1]
        kw.update(cterm_loc=fields["cterm"], off_loc=off_loc)
        return kw
