"""Typed serving surface: SearchRequest → LiraEngine.search → SearchResult.

This module is the stable contract production callers program against while
storage/quantization strategies evolve underneath (serving/tiers.py) — the
HARMONY / LANNS split of "serving API" from "index internals":

  * ``BuildConfig``    — the index-build recipe ``LiraEngine.build`` consumes
    instead of a ~14-kwarg pile;
  * ``SearchRequest``  — one query batch + per-call overrides (k, σ, tier,
    scan impl); anything left None inherits the engine's config;
  * ``SearchResult``   — named result fields plus per-call ``SearchStats``
    (which jit-cache bucket served the batch, whether it was a cache hit),
    replacing the positional 4-tuple that changed shape in PR 4 and broke
    every caller.

Deprecation shims (one release): unpacking a ``SearchResult`` as the legacy
``(dists, ids, nprobe_eff, overflow)`` tuple still works but warns once per
result object, and the retired ``quantized=`` / ``residual=`` boolean knobs on
``LiraEngine.build`` / ``search`` warn once per process (see
``warn_deprecated`` / ``reset_deprecation_warnings``). CI runs the tier-1
suite with ``-W error::DeprecationWarning`` so internal code can never grow
back onto the deprecated surface.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Optional

import numpy as np

# ------------------------------------------------------------- deprecation

_WARNED: set[str] = set()


def warn_deprecated(key: str, message: str, *, stacklevel: int = 3) -> None:
    """Emit ``DeprecationWarning`` once per process per ``key`` — repeated use
    of one legacy surface doesn't spam, while ``-W error::DeprecationWarning``
    still trips on the first internal use."""
    if key in _WARNED:
        return
    _WARNED.add(key)
    warnings.warn(message, DeprecationWarning, stacklevel=stacklevel)


def reset_deprecation_warnings() -> None:
    """Re-arm the once-per-process guards (test isolation)."""
    _WARNED.clear()


# ------------------------------------------------------------------- build

@dataclasses.dataclass(frozen=True)
class BuildConfig:
    """Everything ``LiraEngine.build`` needs beyond the data itself. Fields
    mirror LiraSystemConfig knobs where one exists; the rest are build-time
    only (η, training schedule, seed)."""

    n_partitions: int
    k: int = 100
    eta: float = 0.03               # replica redundancy rate (paper §3.3)
    train_frac: float = 0.5         # fraction of base vectors used to train probing
    epochs: int = 8
    nprobe_max: Optional[int] = None  # None → max(8, n_partitions // 8)
    seed: int = 0
    log: bool = False
    tier: str = "f32"               # serving tier (serving/tiers.py registry)
    pq_m: Optional[int] = None      # None → largest divisor of dim ≤ 16
    pq_ks: int = 256
    rerank: int = 4
    impl: str = "auto"              # partition-scan backend (serving/scan.py)
    store_dtype: str = "float32"    # f32 vector plane dtype (bfloat16 halves scan reads)
    q_cap_factor: float = 2.0
    auto_q_cap: bool = False        # grow q_cap_factor on persistent overflow
    sigma: float = 0.5              # engine's default probe threshold


# ------------------------------------------------------------------ search

@dataclasses.dataclass(frozen=True)
class SearchRequest:
    """One query batch + per-call overrides. ``None`` inherits the engine
    config: tier defaults to the tier the engine was built for, k/σ/impl to
    ``cfg.k`` / ``engine.sigma`` / ``cfg.impl``.

    The batching hints (``deadline_ms``/``priority``/``allow_batching``) only
    matter when the request goes through the serving front-end
    (serving/frontend.py); a direct ``engine.search`` call ignores them.
    Requests coalesce into one batch only when their resolved (k, σ, tier,
    impl) agree — batching is an optimization, never a semantics change."""

    queries: Any                    # [nq, dim] array-like
    k: Optional[int] = None
    sigma: Optional[float] = None
    tier: Optional[str] = None
    impl: Optional[str] = None
    # ---- front-end batching hints
    # per-request SLO: tightens the flush window to min(max_wait_ms, this)
    # and arms dead-on-arrival shedding; None = batching window only, never shed
    deadline_ms: Optional[float] = None
    priority: int = 0               # higher wins under admission pressure
    allow_batching: bool = True     # False → served solo, bypassing the queue


@dataclasses.dataclass(frozen=True)
class SearchStats:
    """Per-call serving telemetry (not part of the ranked answer). The
    queue/batch fields are filled in by the serving front-end
    (serving/frontend.py); a direct ``engine.search`` call leaves them at
    their defaults (``batch_size=0`` reads as "not front-end batched")."""

    tier: str                       # resolved tier that served the call
    impl: str                       # resolved scan backend
    k: int
    sigma: float
    bucket: int                     # padded power-of-two jit-cache batch bucket
    cache_hit: bool                 # False = this call compiled a serve step
    # ---- front-end fields (PR 5 follow-up: queue/batch telemetry)
    queue_ms: float = 0.0           # time spent queued before the batch launched
    batch_size: int = 0             # coalesced rows in the batch that served this
    shed: bool = False              # True = dropped by admission control, no answer
    # ---- observability fields (repro.obs): replica-dedup hits are candidate
    # slots the merge collapsed because redundancy (η>0) returned the same id
    # from several partitions/shards — the paper's replication cost made
    # visible. stages/latency_ms are populated only when a Tracer is attached
    # (engine.tracer / front-end tracer=); stage values are milliseconds and
    # sum to ≈ latency_ms (see README "Observability" for the hierarchy).
    dedup_hits: int = 0             # duplicate candidate slots merged away
    latency_ms: float = 0.0         # end-to-end latency (0.0 when not traced)
    stages: Optional[dict] = None   # {"queue": ms, "serve.device": ms, ...}
    # ---- mutable-index fields: the store epoch that served this call. Every
    # insert/delete/compact/repartition bumps it (mutations drain the
    # front-end first, so a coalesced batch never spans two epochs).
    epoch: int = 0
    # ---- cluster-serving fields (serving/cluster.py). For a per-shard
    # sub-result, shard/replica name who served it; for the merged cluster
    # answer shard stays None and ``routes`` carries one
    # ``(shard, replica, hedged, failovers)`` tuple per shard. ``failovers``
    # counts in-flight replays (dead replicas) absorbed while serving this
    # call — nonzero means the answer survived a failure, not that it lost
    # anything.
    shard: Optional[int] = None     # shard that served (None = single engine
                                    # or a merged cluster answer)
    replica: Optional[int] = None   # replica that won within the shard group
    hedged: bool = False            # a hedge request was issued for this call
    failovers: int = 0              # in-flight replays absorbed by this call
    routes: Optional[tuple] = None  # merged answers: per-shard route tuples


@dataclasses.dataclass
class SearchResult:
    """Named serving answer. ``overflow`` counts probes dropped by q_cap
    bucket overflow — persistently nonzero means recall is degraded; raise
    ``q_cap_factor`` or set ``auto_q_cap=True`` to let the engine do it.

    Legacy shim: iterating/indexing yields the retired 4-tuple
    ``(dists, ids, nprobe_eff, overflow)`` with a one-time DeprecationWarning
    per result, so pre-redesign unpacking keeps working for one release.
    """

    dists: np.ndarray               # [nq, k] ascending squared L2, inf-padded
    ids: np.ndarray                 # [nq, k] point ids, -1-padded
    nprobe_eff: np.ndarray          # [nq] effective probes per query
    overflow: int                   # total q_cap-dropped probes this call
    stats: Optional[SearchStats] = None

    _tuple_warned: bool = dataclasses.field(
        default=False, repr=False, compare=False)

    def _legacy_tuple(self):
        if not self._tuple_warned:
            self._tuple_warned = True
            warnings.warn(
                "unpacking SearchResult as a (dists, ids, nprobe_eff, overflow) "
                "tuple is deprecated; use the named fields",
                DeprecationWarning, stacklevel=3)
        return (self.dists, self.ids, self.nprobe_eff, self.overflow)

    def __iter__(self):
        return iter(self._legacy_tuple())

    def __getitem__(self, idx):
        return self._legacy_tuple()[idx]

    def __len__(self) -> int:
        return 4
