from repro.serving import scan  # noqa: F401  (backend-dispatched partition scan)
from repro.serving import tiers  # noqa: F401  (serving-tier registry)
from repro.serving.api import (  # noqa: F401  (typed serving surface)
    BuildConfig,
    SearchRequest,
    SearchResult,
    SearchStats,
)
from repro.serving.engine import make_bundle, LiraEngine  # noqa: F401
from repro.serving.cluster import (  # noqa: F401  (sharded replica-group serving)
    ClusterConfig,
    LiraCluster,
    ShardPlan,
    plan_shards,
)
from repro.serving.frontend import (  # noqa: F401  (dynamic-batching front-end)
    FakeClock,
    FrontendConfig,
    FrontendStats,
    ServingFrontend,
    simulate_open_loop,
)
from repro.serving.quantized import QuantizedStore, build_quantized_store, scan_store_bytes  # noqa: F401
