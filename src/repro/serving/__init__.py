from repro.serving.engine import make_bundle, LiraEngine  # noqa: F401
