"""Metrics registry: Counter / Gauge / Histogram with labels.

The serving stack (engine + front-end) needs process-level counters and
distributions that survive beyond any one call's ``SearchStats`` — cumulative
q_cap overflow, jit-cache hit rates, per-stage latency histograms — without
unbounded per-observation storage. This module is that instrument panel:

  * **Counter** — monotonically increasing totals (searches served, overflow
    probes, shed requests), labeled (``tier="pq", impl="ref"``);
  * **Gauge**   — last-written values (current ``q_cap_factor``);
  * **Histogram** — fixed log-spaced buckets (``LATENCY_BUCKETS_MS``: 4 per
    decade, ~31.6 µs to 10 s) plus exact per-label min/max/sum/count, so
    memory is O(buckets) no matter how long the process serves. ``quantile``
    interpolates within the bucket and clamps to the observed [min, max],
    which keeps degenerate distributions exact (every observation equal →
    the quantile IS that value) and never reports a tail beyond what was seen.

A ``MetricsRegistry`` is a get-or-create namespace of metrics; ``render()``
emits a Prometheus-style text exposition and ``parse_exposition`` reads one
back (the CI smoke job round-trips the snapshot through it). One process-wide
``default_registry()`` exists for production; tests and benchmarks inject
fresh registries for isolation.

No clocks in here — time enters only as observed values (repro.obs.trace owns
measurement).
"""
from __future__ import annotations

import math
import re
from typing import Optional, Sequence

import numpy as np

__all__ = ["LATENCY_BUCKETS_MS", "Counter", "Gauge", "Histogram",
           "MetricsRegistry", "default_registry", "parse_exposition"]

# 4 buckets per decade from 10^-1.5 ms (~31.6 µs) to 10^4 ms (10 s): spans a
# sub-µs kernel launch to a pathological multi-second stall at a constant
# 10^0.25 ≈ 1.78× resolution. Values beyond the last edge land in +Inf.
LATENCY_BUCKETS_MS = tuple(10.0 ** (i / 4.0) for i in range(-6, 17))

# effective-probe counts are small integers: pow2 edges keep the paper's
# fan-out distribution readable (nprobe_eff ≤ 1, ≤ 2, ≤ 4, …)
NPROBE_BUCKETS = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0)
BATCH_ROWS_BUCKETS = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0,
                      512.0, 1024.0)

# per-partition staleness fractions ((misassigned inserts + tombstones) /
# live rows, serving/engine.py maybe_repartition): ~2× edges around the
# default 0.25 repartition threshold; > 1.0 means more churn than content
STALENESS_BUCKETS = (0.01, 0.02, 0.05, 0.1, 0.25, 0.5, 1.0, 2.0, 5.0)


def _key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _matches(key: tuple, subset: dict) -> bool:
    want = {(str(k), str(v)) for k, v in subset.items()}
    return want <= set(key)


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help

    def _render_labels(self, key: tuple, extra: str = "") -> str:
        parts = [f'{k}="{v}"' for k, v in key]
        if extra:
            parts.append(extra)
        return "{" + ",".join(parts) + "}" if parts else ""


class Counter(_Metric):
    """Monotonic total per label set. ``inc`` rejects negative amounts —
    a decreasing counter means two code paths disagree about what happened."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self._vals: dict[tuple, float] = {}

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(inc {amount})")
        k = _key(labels)
        self._vals[k] = self._vals.get(k, 0.0) + float(amount)

    def value(self, **labels) -> float:
        return self._vals.get(_key(labels), 0.0)

    def total(self, **labels) -> float:
        """Sum over every label set matching the given subset (e.g. all shed
        reasons of one front-end)."""
        return sum(v for k, v in self._vals.items() if _matches(k, labels))

    def render(self) -> list[str]:
        lines = [f"# TYPE {self.name} {self.kind}"]
        for k in sorted(self._vals):
            lines.append(f"{self.name}{self._render_labels(k)} "
                         f"{self._vals[k]:.10g}")
        return lines


class Gauge(_Metric):
    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self._vals: dict[tuple, float] = {}

    def set(self, value: float, **labels) -> None:
        self._vals[_key(labels)] = float(value)

    def value(self, **labels) -> float:
        return self._vals.get(_key(labels), 0.0)

    def render(self) -> list[str]:
        lines = [f"# TYPE {self.name} {self.kind}"]
        for k in sorted(self._vals):
            lines.append(f"{self.name}{self._render_labels(k)} "
                         f"{self._vals[k]:.10g}")
        return lines


class _HistState:
    __slots__ = ("counts", "total", "sum", "min", "max")

    def __init__(self, n_buckets: int):
        self.counts = np.zeros(n_buckets + 1, np.int64)  # last = +Inf overflow
        self.total = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf


class Histogram(_Metric):
    """Fixed-bucket distribution per label set: O(len(buckets)) memory
    regardless of observation count — the bounded replacement for rolling
    per-observation reservoirs in long-lived serving processes."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = LATENCY_BUCKETS_MS):
        super().__init__(name, help)
        edges = tuple(float(b) for b in buckets)
        if list(edges) != sorted(set(edges)):
            raise ValueError(f"histogram {name} buckets must be strictly "
                             f"increasing, got {edges}")
        self.buckets = edges
        self._edges = np.asarray(edges, np.float64)
        self._states: dict[tuple, _HistState] = {}

    def _state(self, labels: dict) -> _HistState:
        k = _key(labels)
        st = self._states.get(k)
        if st is None:
            st = self._states[k] = _HistState(len(self.buckets))
        return st

    def observe(self, value: float, **labels) -> None:
        self.observe_many([value], **labels)

    def observe_many(self, values, **labels) -> None:
        vals = np.asarray(values, np.float64).reshape(-1)
        if vals.size == 0:
            return
        st = self._state(labels)
        # bucket b holds values ≤ edge[b] (Prometheus "le" semantics)
        idx = np.searchsorted(self._edges, vals, side="left")
        np.add.at(st.counts, idx, 1)
        st.total += int(vals.size)
        st.sum += float(vals.sum())
        st.min = min(st.min, float(vals.min()))
        st.max = max(st.max, float(vals.max()))

    def count(self, **labels) -> int:
        st = self._states.get(_key(labels))
        return st.total if st else 0

    def sum(self, **labels) -> float:
        st = self._states.get(_key(labels))
        return st.sum if st else 0.0

    def counts(self, **labels) -> np.ndarray:
        st = self._states.get(_key(labels))
        return (st.counts.copy() if st
                else np.zeros(len(self.buckets) + 1, np.int64))

    def quantile(self, q: float, **labels) -> float:
        """Estimate the q-quantile by linear interpolation inside the bucket
        holding the target rank, clamped to the exact observed [min, max] —
        a degenerate distribution (all values equal) reports exactly that
        value, and no estimate exceeds what was actually seen."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        st = self._states.get(_key(labels))
        if st is None or st.total == 0:
            return 0.0
        rank = q * st.total
        cum = np.cumsum(st.counts)
        b = int(np.searchsorted(cum, rank, side="left"))
        lo = self.buckets[b - 1] if b > 0 else 0.0
        hi = self.buckets[b] if b < len(self.buckets) else st.max
        prev = float(cum[b - 1]) if b > 0 else 0.0
        frac = (rank - prev) / max(float(st.counts[b]), 1.0)
        est = lo + (hi - lo) * min(max(frac, 0.0), 1.0)
        return float(min(max(est, st.min), st.max))

    def render(self) -> list[str]:
        lines = [f"# TYPE {self.name} {self.kind}"]
        for k in sorted(self._states):
            st = self._states[k]
            cum = 0
            for edge, n in zip(self.buckets, st.counts):
                cum += int(n)
                le = 'le="%.10g"' % edge
                lines.append(
                    f"{self.name}_bucket{self._render_labels(k, le)} {cum}")
            inf = 'le="+Inf"'
            lines.append(f"{self.name}_bucket"
                         f"{self._render_labels(k, inf)} {st.total}")
            lines.append(f"{self.name}_sum{self._render_labels(k)} "
                         f"{st.sum:.10g}")
            lines.append(f"{self.name}_count{self._render_labels(k)} "
                         f"{st.total}")
        return lines


class MetricsRegistry:
    """Get-or-create namespace of metrics. Re-requesting a name returns the
    existing instrument; requesting it as a different kind (or a histogram
    with different buckets) raises — two call sites silently disagreeing
    about a metric is how dashboards lie."""

    def __init__(self):
        self._metrics: dict[str, _Metric] = {}

    def _get(self, cls, name: str, help: str, **kwargs):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, help, **kwargs)
            return m
        if not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{m.kind}, requested {cls.kind}")
        if kwargs.get("buckets") is not None and \
                tuple(float(b) for b in kwargs["buckets"]) != m.buckets:
            raise ValueError(f"histogram {name!r} already registered with "
                             f"different buckets")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        # buckets=None means "don't care": create with the latency defaults,
        # or return whatever is registered (readers must not need to repeat
        # the creator's bucket choice just to fetch the instrument)
        if buckets is None and name not in self._metrics:
            buckets = LATENCY_BUCKETS_MS
        return self._get(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    def names(self) -> tuple[str, ...]:
        return tuple(sorted(self._metrics))

    def render(self) -> str:
        """Prometheus-style text exposition of every registered metric."""
        lines: list[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.extend(m.render())
        return "\n".join(lines) + ("\n" if lines else "")


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide registry production code records into by default."""
    return _DEFAULT


_LINE_RE = re.compile(r"^([A-Za-z_:][A-Za-z0-9_:]*)(\{[^}]*\})?\s+(\S+)$")


def parse_exposition(text: str) -> dict[str, float]:
    """Parse a ``render()`` exposition back into ``{series: value}`` keyed by
    ``name{labels}``. Raises ValueError on any non-comment line that does not
    parse — the CI smoke job uses this as the "metrics text is well-formed"
    gate."""
    out: dict[str, float] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _LINE_RE.match(line)
        if m is None:
            raise ValueError(f"unparseable exposition line {lineno}: {line!r}")
        try:
            value = float(m.group(3))
        except ValueError:
            raise ValueError(f"non-numeric value on line {lineno}: "
                             f"{line!r}") from None
        out[m.group(1) + (m.group(2) or "")] = value
    return out
