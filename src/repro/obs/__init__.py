"""Observability: metrics registry, span tracing, and profiler capture.

The measurement half of the perf campaign (ROADMAP item 4): every serving
stage is spanned, every query-aware distribution (nprobe_eff, overflow,
replica-dedup, batch shape) is a registry metric, and kernel suites persist
roofline-relative BENCH_*.json snapshots. See README "Observability".
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               default_registry, parse_exposition)
from repro.obs.profiling import profile_capture
from repro.obs.trace import NOOP, Span, Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "default_registry", "parse_exposition",
    "Span", "Tracer", "NOOP",
    "profile_capture",
]
