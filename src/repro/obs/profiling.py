"""jax.profiler capture hook.

``profile_capture(profile_dir)`` wraps a code region in a JAX profiler trace
when ``profile_dir`` is truthy and is a transparent no-op otherwise — so the
launchers and benchmark runner can take ``--profile-dir`` unconditionally.
The capture lands in ``<profile_dir>/plugins/profile/<ts>/`` ready for
TensorBoard's profile plugin; the serve step's ``jax.named_scope`` blocks
(probing / dispatch / scan / merge) make the op_profile tab read in LIRA's
stage vocabulary instead of raw HLO op names. See README "Observability" for
the capture → TensorBoard recipe.
"""
from __future__ import annotations

import contextlib
from typing import Optional

__all__ = ["profile_capture"]


@contextlib.contextmanager
def profile_capture(profile_dir: Optional[str]):
    """Capture a jax.profiler trace into ``profile_dir`` for the duration of
    the block; no-op when ``profile_dir`` is empty/None."""
    if not profile_dir:
        yield None
        return
    import jax

    jax.profiler.start_trace(str(profile_dir))
    try:
        yield str(profile_dir)
    finally:
        jax.profiler.stop_trace()
