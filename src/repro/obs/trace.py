"""Span-based tracing with an injectable clock.

A ``Tracer`` hands out nestable ``span("name")`` context managers; each span
records start time, duration, parent attribution and free-form attributes.
The serving stack threads one tracer through the front-end and engine so a
single request produces a spine like

    admission → batch → assemble → engine.search → prepare / device / post

with parent/child links intact (spans nest purely by being opened while
another span of the same tracer is open — no ids to thread manually).

Three design constraints from the serving stack:

  * **Deterministic tests** — the clock is injected (``FakeClock`` from
    repro/utils/clock.py works as-is: instances are callable), so span
    durations are exact under virtual time.
  * **Zero cost when off** — ``NOOP`` is a shared tracer whose ``span`` is a
    reusable no-op context; production code holds NOOP by default and pays a
    dict build + one method call per stage. Crucially the *traced code path
    is identical either way* (tracing must be bit-identical to not tracing),
    tracing only reads clocks around stages.
  * **Bounded memory** — finished spans land in a ring (``max_spans``); a
    ``sink`` (path or callable) can stream them out as JSON-lines instead.
"""
from __future__ import annotations

import contextlib
import io
import itertools
import json
import time
from typing import Callable, Optional, Union

__all__ = ["Span", "Tracer", "NOOP"]


class Span:
    """One timed stage. ``duration_ms`` is 0 while the span is open; attrs
    set via ``set(...)`` inside the block are exported with the span."""

    __slots__ = ("name", "span_id", "parent_id", "t_start", "t_end", "attrs")

    def __init__(self, name: str, span_id: int, parent_id: Optional[int],
                 t_start: float):
        self.name = name
        self.span_id = span_id
        self.parent_id = parent_id
        self.t_start = t_start
        self.t_end: Optional[float] = None
        self.attrs: dict = {}

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)

    @property
    def duration_ms(self) -> float:
        if self.t_end is None:
            return 0.0
        return (self.t_end - self.t_start) * 1e3

    def to_dict(self) -> dict:
        return {"name": self.name, "span_id": self.span_id,
                "parent_id": self.parent_id, "t_start": self.t_start,
                "duration_ms": self.duration_ms, "attrs": self.attrs}

    def __repr__(self):
        return (f"Span({self.name!r}, id={self.span_id}, "
                f"parent={self.parent_id}, {self.duration_ms:.3f}ms)")


class Tracer:
    """Collects nested spans. ``clock`` is any zero-arg callable returning
    seconds (``time.perf_counter`` by default; pass a
    ``repro.utils.clock.FakeClock`` for virtual time). ``sink`` streams
    finished spans as JSON-lines to a path or hands the dict to a
    callable."""

    enabled = True

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 sink: Union[str, Callable[[dict], None], None] = None,
                 max_spans: int = 100_000):
        self._clock = clock
        self._sink = sink
        self._sink_fh: Optional[io.TextIOBase] = None
        self._stack: list[Span] = []
        self._finished: list[Span] = []
        self._max_spans = int(max_spans)
        self._ids = itertools.count(1)

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        parent = self._stack[-1] if self._stack else None
        sp = Span(name, next(self._ids),
                  parent.span_id if parent else None, self._clock())
        if attrs:
            sp.attrs.update(attrs)
        self._stack.append(sp)
        try:
            yield sp
        finally:
            sp.t_end = self._clock()
            self._stack.pop()
            self._record(sp)

    def _record(self, sp: Span) -> None:
        self._finished.append(sp)
        if len(self._finished) > self._max_spans:
            del self._finished[:len(self._finished) - self._max_spans]
        if self._sink is not None:
            if callable(self._sink):
                self._sink(sp.to_dict())
            else:
                if self._sink_fh is None:
                    self._sink_fh = open(self._sink, "a")
                self._sink_fh.write(json.dumps(sp.to_dict()) + "\n")
                self._sink_fh.flush()

    def finished(self, name: Optional[str] = None) -> list[Span]:
        if name is None:
            return list(self._finished)
        return [s for s in self._finished if s.name == name]

    def children(self, parent: Span) -> list[Span]:
        return [s for s in self._finished if s.parent_id == parent.span_id]

    def clear(self) -> None:
        self._finished.clear()

    def export_jsonl(self, path: str) -> int:
        """Write every retained span as one JSON object per line; returns
        the number of spans written."""
        with open(path, "w") as fh:
            for sp in self._finished:
                fh.write(json.dumps(sp.to_dict()) + "\n")
        return len(self._finished)

    def close(self) -> None:
        if self._sink_fh is not None:
            self._sink_fh.close()
            self._sink_fh = None


class _NoopSpan:
    __slots__ = ()
    name = ""
    span_id = 0
    parent_id = None
    attrs: dict = {}
    duration_ms = 0.0

    def set(self, **attrs) -> None:
        pass


class _NoopTracer:
    """Tracing disabled: ``span`` returns one shared reusable null context.
    ``enabled`` lets call sites skip building stage dicts entirely."""

    enabled = False
    _CM = contextlib.nullcontext(_NoopSpan())

    def span(self, name: str, **attrs):
        return self._CM

    def finished(self, name=None):
        return []

    def clear(self) -> None:
        pass


NOOP = _NoopTracer()
