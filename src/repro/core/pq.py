"""Product quantization (IVFPQ baseline; Jégou et al. TPAMI'11).

ADC fact used by the evaluation engine: with orthogonal subspace decomposition,
ADC distance == exact L2 between the query and the RECONSTRUCTED point
(centroid + decoded residual for IVFPQ). So recall-accurate IVFPQ evaluation =
partition_topk over reconstructions (GEMM-bound, fast on CPU), while the
kernel-accurate LUT path lives in repro.kernels.pq_adc for TPU.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kmeans import kmeans_fit


class PQCodebook(NamedTuple):
    codebooks: jax.Array  # [m, ks, d_sub] f32
    m: int
    ks: int


def code_dtype(ks: int) -> np.dtype:
    """Narrowest integer dtype that can hold a code in [0, ks)."""
    if ks <= 256:
        return np.dtype(np.uint8)
    if ks <= 65536:
        return np.dtype(np.uint16)
    return np.dtype(np.int32)


def train_pq(rng: jax.Array, x: np.ndarray, m: int = 16, ks: int = 256, n_iters: int = 15) -> PQCodebook:
    n, d = x.shape
    assert d % m == 0, f"dim {d} not divisible by m={m}"
    d_sub = d // m
    xs = jnp.asarray(x, jnp.float32).reshape(n, m, d_sub)
    rngs = jax.random.split(rng, m)
    cbs = []
    for j in range(m):  # python loop: m small, keeps peak memory low
        st = kmeans_fit(rngs[j], xs[:, j], n_clusters=ks, n_iters=n_iters)
        cbs.append(st.centroids)
    return PQCodebook(codebooks=jnp.stack(cbs), m=m, ks=ks)


def encode(pq: PQCodebook, x: np.ndarray, *, batch: int = 8192) -> np.ndarray:
    """x -> codes [N, m]; uint8 when ks ≤ 256, uint16 when ks ≤ 65536."""
    n, d = x.shape
    d_sub = d // pq.m
    out = np.empty((n, pq.m), code_dtype(pq.ks))

    @jax.jit
    def enc(xb):
        xb = xb.reshape(xb.shape[0], pq.m, d_sub)
        d2 = (
            jnp.sum(xb * xb, -1)[..., None]
            - 2.0 * jnp.einsum("nmd,mkd->nmk", xb, pq.codebooks)
            + jnp.sum(pq.codebooks * pq.codebooks, -1)[None]
        )
        return jnp.argmin(d2, -1).astype(jnp.int32)

    for s in range(0, n, batch):
        out[s : s + batch] = np.asarray(enc(jnp.asarray(x[s : s + batch], jnp.float32))).astype(out.dtype)
    return out


def decode(pq: PQCodebook, codes: np.ndarray, *, batch: int = 65536) -> np.ndarray:
    """codes -> reconstructed vectors [N, d]."""
    n = codes.shape[0]
    d_sub = pq.codebooks.shape[-1]
    out = np.empty((n, pq.m * d_sub), np.float32)

    @jax.jit
    def dec(cb):
        cb = cb.astype(jnp.int32)  # accept uint8/uint16 code stores
        recon = jnp.take_along_axis(pq.codebooks[None], cb[:, :, None, None], axis=2)
        return recon[:, :, 0, :].reshape(cb.shape[0], -1)

    for s in range(0, n, batch):
        out[s : s + batch] = np.asarray(dec(jnp.asarray(codes[s : s + batch])))
    return out


def adc_lut_raw(codebooks: jax.Array, q: jax.Array) -> jax.Array:
    """Per-query LUT of subspace distances from a raw [m, ks, d_sub] codebook
    array: [Q, m, ks]. The serve step holds codebooks as a plain array, so
    this is the shared implementation behind both call styles."""
    qs = q.reshape(q.shape[0], codebooks.shape[0], -1)
    return (
        jnp.sum(qs * qs, -1)[..., None]
        - 2.0 * jnp.einsum("qmd,mkd->qmk", qs, codebooks)
        + jnp.sum(codebooks * codebooks, -1)[None]
    )


def adc_lut(pq: PQCodebook, q: jax.Array) -> jax.Array:
    """Per-query LUT of subspace distances: [Q, m, ks]."""
    return adc_lut_raw(pq.codebooks, q)


def adc_distances(pq: PQCodebook, q: jax.Array, codes: jax.Array) -> jax.Array:
    """Exact ADC: dist[q, n] = sum_m LUT[q, m, codes[n, m]] -> [Q, N].
    This is the jnp oracle for the Pallas pq_adc kernel."""
    lut = adc_lut(pq, q)  # [Q, m, ks]
    codes_t = codes.astype(jnp.int32).T  # [m, N]

    def per_query(lq):  # lq: [m, ks]
        return jnp.sum(jnp.take_along_axis(lq, codes_t, axis=1), axis=0)  # [N]

    return jax.vmap(per_query)(lut)


# --------------------------------------------------------------- residual PQ
#
# IVFPQ residual encoding (codes over x − centroid[assign(x)]) normally breaks
# the one-LUT-per-query property: the LUT of q − c_b depends on the partition.
# The exact distance to the reconstruction c_b + r̂ decomposes instead as
#
#   ‖q − (c_b + r̂)‖² =   Σ_m lut[q, m, code_m]     (shared across partitions)
#                       + ‖c_b‖² − 2⟨q, c_b⟩        (per-(query, partition))
#                       + 2⟨c_b, r̂⟩                 (per-slot, query-free)
#
# where lut is the ordinary ``adc_lut`` of the RESIDUAL codebooks evaluated at
# the raw query q. The serving tier precomputes the third term at build time
# (``residual_cross_terms``, stored next to the codes); for the second it
# reuses the probing centroid-distance matrix already in the serve step
# (off = cd − ‖q‖², the same quantity ``residual_query_offsets`` computes
# standalone — the differential tests pin the two forms together). So a
# residual stage-1 scan stays a single LUT gather plus two offset adds.
# tests/test_residual_pq.py asserts this identity against exact L2 in fp32.


def residual_query_offsets(centroids: jax.Array, q: jax.Array) -> jax.Array:
    """off[q, b] = ‖c_b‖² − 2⟨q, c_b⟩ — the per-(query, partition) scalar of
    the residual ADC identity above. Equals ‖q − c_b‖² − ‖q‖²."""
    return jnp.sum(centroids * centroids, -1)[None, :] - 2.0 * q @ centroids.T


def residual_cross_terms(pq: PQCodebook, centroids_per_row: np.ndarray,
                         codes: np.ndarray, *, batch: int = 65536) -> np.ndarray:
    """cterm[n] = 2⟨c_n, decode(codes_n)⟩ — the per-slot, query-free term of
    the residual ADC identity; ``centroids_per_row`` is each row's assigned
    partition centroid [N, d]. Precomputed once at store-build time."""
    n = codes.shape[0]
    out = np.empty((n,), np.float32)
    for s in range(0, n, batch):
        recon = decode(pq, codes[s : s + batch])
        out[s : s + batch] = 2.0 * np.einsum(
            "nd,nd->n", np.asarray(centroids_per_row[s : s + batch], np.float32), recon)
    return out
