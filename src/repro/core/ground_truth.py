"""Exact kNN ground truth + kNN partition distributions (paper §2.1).

Batched brute force — used for (a) evaluation GT, (b) probing-model labels on a
training subset (paper appendix A.3 keeps this O(|subset|²·d), not O(N²·d)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("k",))
def _knn_block(q: jax.Array, base: jax.Array, k: int):
    d2 = (
        jnp.sum(q * q, axis=-1, keepdims=True)
        - 2.0 * q @ base.T
        + jnp.sum(base * base, axis=-1)[None, :]
    )
    neg, idx = jax.lax.top_k(-d2, k)
    return -neg, idx.astype(jnp.int32)


def exact_knn(queries: np.ndarray, base: np.ndarray, k: int, *, batch: int = 1024, exclude_self: bool = False):
    """Exact kNN of `queries` in `base`. Returns (dists [Q,k], ids [Q,k]).

    If exclude_self, asks for k+1 and drops exact self-matches (training labels
    where queries ⊆ base).
    """
    kk = k + 1 if exclude_self else k
    q = np.asarray(queries, np.float32)
    b = jnp.asarray(base, np.float32)
    out_d, out_i = [], []
    for s in range(0, len(q), batch):
        d, i = _knn_block(jnp.asarray(q[s : s + batch]), b, kk)
        out_d.append(np.asarray(d))
        out_i.append(np.asarray(i))
    dists, ids = np.concatenate(out_d), np.concatenate(out_i)
    if exclude_self:
        # drop the first column where it is a self match (distance ~ 0)
        keep_d = np.empty((len(q), k), np.float32)
        keep_i = np.empty((len(q), k), np.int32)
        for r in range(len(q)):
            cols = [c for c in range(kk) if dists[r, c] > 1e-9][:k]
            if len(cols) < k:  # degenerate duplicates; pad from the front
                cols = list(range(1, k + 1))
            keep_d[r] = dists[r, cols]
            keep_i[r] = ids[r, cols]
        return keep_d, keep_i
    return dists, ids


def knn_count_distribution(gt_ids: np.ndarray, assign: np.ndarray, n_partitions: int) -> np.ndarray:
    """n^q (paper def. 1): per-query count of GT kNN in each partition. [Q, B]."""
    part = assign[gt_ids]  # [Q, k]
    out = np.zeros((gt_ids.shape[0], n_partitions), np.int32)
    rows = np.repeat(np.arange(gt_ids.shape[0]), gt_ids.shape[1])
    np.add.at(out, (rows, part.reshape(-1)), 1)
    return out


def knn_partition_labels(gt_ids: np.ndarray, assign: np.ndarray, n_partitions: int) -> np.ndarray:
    """p^q: binary mask over partitions that contain ≥1 true kNN. [Q, B] f32."""
    return (knn_count_distribution(gt_ids, assign, n_partitions) > 0).astype(np.float32)


def optimal_nprobe(labels: np.ndarray) -> np.ndarray:
    """(nprobe^q)* = number of kNN partitions."""
    return labels.sum(-1).astype(np.int32)


def nprobe_dist(gt_ids: np.ndarray, assign: np.ndarray, q: np.ndarray, centroids: np.ndarray) -> np.ndarray:
    """nprobe*_dist (paper §2.2): max centroid-distance-rank over kNN partitions —
    how many nearest-centroid probes IVF needs to cover all kNN."""
    d2 = (
        np.sum(q * q, -1, keepdims=True)
        - 2.0 * q @ centroids.T
        + np.sum(centroids * centroids, -1)[None, :]
    )
    rank = np.argsort(np.argsort(d2, -1), -1)  # rank of each partition per query
    part = assign[gt_ids]  # [Q, k]
    out = np.empty(len(q), np.int32)
    for r in range(len(q)):
        out[r] = rank[r, part[r]].max() + 1
    return out
