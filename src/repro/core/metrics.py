"""Paper metrics (§4.1): Recall@k, cmp (visited points), nprobe, QPS proxy."""
from __future__ import annotations

import numpy as np


def recall_at_k(retrieved: np.ndarray, gt: np.ndarray, k: int) -> float:
    """Paper eq. 1. retrieved/gt: [Q, >=k] id arrays."""
    hits = 0
    for r in range(len(gt)):
        hits += len(set(retrieved[r, :k].tolist()) & set(gt[r, :k].tolist()))
    return hits / (len(gt) * k)


def summarize(name: str, res) -> dict:
    return {
        "method": name,
        "recall": round(res.recall, 4),
        "cmp": round(res.cmp_mean, 1),
        "nprobe": round(res.nprobe_mean, 4),
    }


def pareto_frontier(points: list[tuple[float, float]]) -> list[tuple[float, float]]:
    """(cost, recall) pareto frontier: min cost for any recall level."""
    pts = sorted(points)
    front, best = [], -np.inf
    for c, r in pts:
        if r > best:
            front.append((c, r))
            best = r
    return front


def cost_at_recall(curve: list[tuple[float, float]], target: float):
    """Min cost achieving recall >= target along a swept (cost, recall) curve.
    Returns (cost, recall) or None."""
    feas = [(c, r) for c, r in curve if r >= target]
    if not feas:
        return None
    return min(feas)
