"""Padded partition storage (inverted lists with static shapes).

XLA requires static shapes, so inverted lists are materialized as a dense
``[B, capacity, d]`` tensor plus per-partition counts. Rows beyond ``count`` are
padding (id = -1, vector = +inf-ish sentinel so they never win a top-k).

The same structure backs:
  * flat (meta-index-only) search — exhaustive Pallas scan of probed partitions,
  * the two-level index — each partition additionally carries a mini-IVF
    (sub-centroids + sub-assignments) as the TPU-native internal index
    (HNSW replacement; see DESIGN.md §3).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

PAD_ID = -1
# Padding vectors are pushed far away so they can never enter a top-k.
PAD_DIST_BUMP = 1e9


class PartitionStore(NamedTuple):
    """Dense padded inverted lists. All arrays are device arrays."""

    centroids: jax.Array   # [B, d] f32
    vectors: jax.Array     # [B, capacity, d] f32 (padded)
    ids: jax.Array         # [B, capacity] i32, PAD_ID marks padding
    counts: jax.Array      # [B] i32
    # Optional internal mini-IVF (two-level index):
    sub_centroids: Optional[jax.Array] = None  # [B, S, d]
    sub_assign: Optional[jax.Array] = None     # [B, capacity] i32 in [0, S)

    @property
    def n_partitions(self) -> int:
        return self.vectors.shape[0]

    @property
    def capacity(self) -> int:
        return self.vectors.shape[1]

    @property
    def dim(self) -> int:
        return self.vectors.shape[2]


def build_store(
    x: np.ndarray,
    ids: np.ndarray,
    assign: np.ndarray,
    centroids: np.ndarray,
    *,
    capacity: Optional[int] = None,
    extra: Optional[tuple[np.ndarray, np.ndarray, np.ndarray]] = None,
) -> PartitionStore:
    """Build padded lists host-side (numpy; runs once at index build).

    ``extra`` = (vectors, ids, assign) replica rows appended by the redundancy
    strategy (paper §3.3); replicas share the id of the original point so the
    merge step dedups naturally.
    """
    b = centroids.shape[0]
    xs, xid, xa = [x], [ids], [assign]
    if extra is not None:
        ev, ei, ea = extra
        if len(ev):
            xs.append(ev)
            xid.append(ei)
            xa.append(ea)
    x_all = np.concatenate(xs, 0)
    id_all = np.concatenate(xid, 0)
    a_all = np.concatenate(xa, 0)

    counts = np.bincount(a_all, minlength=b)
    cap = int(capacity if capacity is not None else max(1, counts.max()))
    d = x.shape[1]
    vec = np.full((b, cap, d), 1e6, np.float32)  # far-away padding
    pid = np.full((b, cap), PAD_ID, np.int32)
    fill = np.zeros(b, np.int64)
    order = np.argsort(a_all, kind="stable")
    for j in order:
        p = a_all[j]
        if fill[p] < cap:
            vec[p, fill[p]] = x_all[j]
            pid[p, fill[p]] = id_all[j]
            fill[p] += 1
    return PartitionStore(
        centroids=jnp.asarray(centroids, jnp.float32),
        vectors=jnp.asarray(vec),
        ids=jnp.asarray(pid),
        counts=jnp.asarray(fill.astype(np.int32)),
    )


def attach_internal_index(store: PartitionStore, rng: jax.Array, n_sub: int, n_iters: int = 8) -> PartitionStore:
    """Two-level index: fit a mini-IVF of ``n_sub`` sub-clusters inside every
    partition (vmapped k-means over partitions). TPU-native HNSW replacement."""
    from repro.core.kmeans import kmeans_fit

    def fit_one(rng_i, vecs):
        st = kmeans_fit(rng_i, vecs, n_clusters=n_sub, n_iters=n_iters)
        return st.centroids, st.assign

    rngs = jax.random.split(rng, store.n_partitions)
    sub_c, sub_a = jax.vmap(fit_one)(rngs, store.vectors)
    return store._replace(sub_centroids=sub_c, sub_assign=sub_a.astype(jnp.int32))


def store_stats(store: PartitionStore) -> dict:
    counts = np.asarray(store.counts)
    return {
        "B": store.n_partitions,
        "capacity": store.capacity,
        "total": int(counts.sum()),
        "max_fill": int(counts.max()),
        "min_fill": int(counts.min()),
        "imbalance": float(counts.max() / max(1.0, counts.mean())),
    }
