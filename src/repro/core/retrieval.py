"""Query-aware top-k retrieval + the evaluation engine (paper §3.4, §4).

Two execution paths:

1. ``PartitionTopK`` (this file): the *evaluation engine*. One heavy blocked
   pass computes, for every (query, partition), the within-partition top-k
   (distances + ids). Afterwards ANY probe policy (IVF rank, LIRA σ-threshold,
   BLISS groups, fixed-nprobe variants, σ sweeps…) is evaluated in milliseconds
   by masking + merging — recall / cmp / nprobe accounting exactly matches the
   paper's definitions. This is how we sweep Figs 7/8/13/14 on CPU.

2. ``repro.serving.engine``: the TPU execution path (shard_map + Pallas fused
   gather-score-topk) used for the dry-run / roofline; numerics identical.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kmeans import centroid_distances
from repro.core.partitions import PAD_ID, PartitionStore


class PartitionTopK(NamedTuple):
    dists: np.ndarray  # [Q, B, k'] within-partition top-k' sq distances (inf-padded)
    ids: np.ndarray    # [Q, B, k'] matching ids (PAD_ID-padded)
    counts: np.ndarray # [B] true partition fill (for cmp accounting)


@functools.partial(jax.jit, static_argnames=("k",))
def _block_topk(q, vecs, ids, k):
    # q: [qb, d]; vecs: [B, cap, d]; ids: [B, cap]
    d2 = (
        jnp.sum(q * q, -1)[:, None, None]
        - 2.0 * jnp.einsum("qd,bcd->qbc", q, vecs)
        + jnp.sum(vecs * vecs, -1)[None]
    )  # [qb, B, cap]
    d2 = jnp.where(ids[None] == PAD_ID, jnp.inf, d2)
    neg, pos = jax.lax.top_k(-d2, k)  # over cap
    return -neg, jnp.take_along_axis(jnp.broadcast_to(ids[None], d2.shape), pos, -1)


def partition_topk(store: PartitionStore, queries: np.ndarray, k: int, *, q_batch: int = 128) -> PartitionTopK:
    """Blocked within-partition top-k for all queries. O(Q·N·d) GEMM-bound."""
    k = min(k, store.capacity)
    q = np.asarray(queries, np.float32)
    out_d = np.empty((len(q), store.n_partitions, k), np.float32)
    out_i = np.empty((len(q), store.n_partitions, k), np.int32)
    for s in range(0, len(q), q_batch):
        d, i = _block_topk(jnp.asarray(q[s : s + q_batch]), store.vectors, store.ids, k)
        out_d[s : s + q_batch] = np.asarray(d)
        out_i[s : s + q_batch] = np.asarray(i)
    return PartitionTopK(out_d, out_i, np.asarray(store.counts))


# ----------------------------------------------------------------- probe policies

def probe_ivf(cent_dist: np.ndarray, nprobe: int) -> np.ndarray:
    """IVF: nearest-`nprobe` centroids. [Q, B] bool."""
    rank = np.argsort(np.argsort(cent_dist, -1), -1)
    return rank < nprobe


def probe_lira(p_hat: np.ndarray, sigma: float) -> np.ndarray:
    """LIRA: p̂ > σ, guaranteeing at least the argmax partition."""
    mask = p_hat > sigma
    best = p_hat.argmax(-1)
    mask[np.arange(len(mask)), best] = True
    return mask


def probe_topn(score: np.ndarray, nprobe: int) -> np.ndarray:
    """Fixed-nprobe by any score (LIRA-fix-nprobe variant; BLISS per group)."""
    rank = np.argsort(np.argsort(-score, -1), -1)
    return rank < nprobe


# ----------------------------------------------------------------- evaluation

class SearchResult(NamedTuple):
    recall: float
    cmp_mean: float          # mean visited points per query (paper `cmp`)
    nprobe_mean: float
    per_query_cmp: np.ndarray
    per_query_nprobe: np.ndarray
    per_query_recall: np.ndarray


def evaluate_probe(
    ptk: PartitionTopK,
    probe_mask: np.ndarray,
    gt_ids: np.ndarray,
    k: int,
    *,
    dedup_pool: int = 2,
) -> SearchResult:
    """Merge within-partition top-k of probed partitions; exact re-rank; dedup
    replica ids (redundant stores repeat an id across partitions)."""
    qn, b, kk = ptk.dists.shape
    masked = np.where(probe_mask[:, :, None], ptk.dists, np.inf).reshape(qn, b * kk)
    flat_ids = np.broadcast_to(ptk.ids.reshape(qn, b * kk), masked.shape)
    pool = min(dedup_pool * k, masked.shape[1])
    part = np.argpartition(masked, pool - 1, axis=1)[:, :pool]
    pool_d = np.take_along_axis(masked, part, 1)
    pool_i = np.take_along_axis(flat_ids, part, 1)
    order = np.argsort(pool_d, 1)
    pool_d = np.take_along_axis(pool_d, order, 1)
    pool_i = np.take_along_axis(pool_i, order, 1)

    hits = np.zeros(qn, np.float64)
    for r in range(qn):
        seen: set = set()
        res = []
        for c in range(pool):
            i = int(pool_i[r, c])
            if i == PAD_ID or not np.isfinite(pool_d[r, c]) or i in seen:
                continue
            seen.add(i)
            res.append(i)
            if len(res) == k:
                break
        hits[r] = len(set(res) & set(gt_ids[r, :k].tolist()))

    per_recall = hits / k
    per_cmp = (probe_mask * ptk.counts[None, :]).sum(-1)
    per_np = probe_mask.sum(-1)
    return SearchResult(
        recall=float(per_recall.mean()),
        cmp_mean=float(per_cmp.mean()),
        nprobe_mean=float(per_np.mean()),
        per_query_cmp=per_cmp,
        per_query_nprobe=per_np,
        per_query_recall=per_recall,
    )


def merge_groups(
    ptks: list[PartitionTopK],
    masks: list[np.ndarray],
    gt_ids: np.ndarray,
    k: int,
    assigns: list[np.ndarray],
    n_base: int,
    *,
    q_block: int = 512,
) -> SearchResult:
    """BLISS-style multi-group merge with EXACT dedup'd cmp accounting:
    visited(q) = |∪_g {points whose group-g partition is probed}|."""
    qn = masks[0].shape[0]
    # recall via per-group pools
    pools_d, pools_i = [], []
    for ptk, m in zip(ptks, masks):
        b, kk = ptk.dists.shape[1:]
        md = np.where(m[:, :, None], ptk.dists, np.inf).reshape(qn, b * kk)
        mi = ptk.ids.reshape(qn, b * kk)
        take = min(k, md.shape[1])
        part = np.argpartition(md, take - 1, 1)[:, :take]
        pools_d.append(np.take_along_axis(md, part, 1))
        pools_i.append(np.take_along_axis(mi, part, 1))
    pd = np.concatenate(pools_d, 1)
    pi = np.concatenate(pools_i, 1)
    order = np.argsort(pd, 1)
    pd = np.take_along_axis(pd, order, 1)
    pi = np.take_along_axis(pi, order, 1)
    hits = np.zeros(qn)
    for r in range(qn):
        seen: set = set()
        for c in range(pd.shape[1]):
            i = int(pi[r, c])
            if i == PAD_ID or not np.isfinite(pd[r, c]) or i in seen:
                continue
            seen.add(i)
            if len(seen) == k:
                break
        hits[r] = len(seen & set(gt_ids[r, :k].tolist()))

    # exact dedup'd visited counts, blocked over queries
    per_cmp = np.zeros(qn, np.int64)
    for s in range(0, qn, q_block):
        e = min(qn, s + q_block)
        union = np.zeros((e - s, n_base), bool)
        for m, a in zip(masks, assigns):
            union |= m[s:e][:, a]  # [qb, N]: probed(assignment of point)
        per_cmp[s:e] = union.sum(-1)
    per_np = sum(m.sum(-1) for m in masks) / len(masks)
    return SearchResult(
        recall=float((hits / k).mean()),
        cmp_mean=float(per_cmp.mean()),
        nprobe_mean=float(per_np.mean()),
        per_query_cmp=per_cmp,
        per_query_nprobe=per_np,
        per_query_recall=hits / k,
    )


def lira_inputs(store: PartitionStore, queries: np.ndarray) -> np.ndarray:
    """Query→centroid distances I, computed once per query batch."""
    return np.asarray(centroid_distances(jnp.asarray(queries, jnp.float32), store.centroids))
