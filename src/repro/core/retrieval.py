"""Query-aware top-k retrieval + the evaluation engine (paper §3.4, §4).

Two execution paths:

1. ``PartitionTopK`` (this file): the *evaluation engine*. One heavy blocked
   pass computes, for every (query, partition), the within-partition top-k
   (distances + ids). Afterwards ANY probe policy (IVF rank, LIRA σ-threshold,
   BLISS groups, fixed-nprobe variants, σ sweeps…) is evaluated in milliseconds
   by masking + merging — recall / cmp / nprobe accounting exactly matches the
   paper's definitions. This is how we sweep Figs 7/8/13/14 on CPU.

2. ``repro.serving.engine``: the TPU execution path (shard_map + Pallas fused
   gather-score-topk) used for the dry-run / roofline; numerics identical.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kmeans import centroid_distances
from repro.core.partitions import PAD_ID, PartitionStore
from repro.kernels.dedup_topk import dedup_topk_np


class PartitionTopK(NamedTuple):
    dists: np.ndarray  # [Q, B, k'] within-partition top-k' sq distances (inf-padded)
    ids: np.ndarray    # [Q, B, k'] matching ids (PAD_ID-padded)
    counts: np.ndarray # [B] true partition fill (for cmp accounting)


@functools.partial(jax.jit, static_argnames=("k",))
def _block_topk(q, vecs, ids, k):
    # q: [qb, d]; vecs: [B, cap, d]; ids: [B, cap]
    d2 = (
        jnp.sum(q * q, -1)[:, None, None]
        - 2.0 * jnp.einsum("qd,bcd->qbc", q, vecs)
        + jnp.sum(vecs * vecs, -1)[None]
    )  # [qb, B, cap]
    d2 = jnp.where(ids[None] == PAD_ID, jnp.inf, d2)
    neg, pos = jax.lax.top_k(-d2, k)  # over cap
    return -neg, jnp.take_along_axis(jnp.broadcast_to(ids[None], d2.shape), pos, -1)


def partition_topk(store: PartitionStore, queries: np.ndarray, k: int, *, q_batch: int = 128) -> PartitionTopK:
    """Blocked within-partition top-k for all queries. O(Q·N·d) GEMM-bound."""
    k = min(k, store.capacity)
    q = np.asarray(queries, np.float32)
    out_d = np.empty((len(q), store.n_partitions, k), np.float32)
    out_i = np.empty((len(q), store.n_partitions, k), np.int32)
    for s in range(0, len(q), q_batch):
        d, i = _block_topk(jnp.asarray(q[s : s + q_batch]), store.vectors, store.ids, k)
        out_d[s : s + q_batch] = np.asarray(d)
        out_i[s : s + q_batch] = np.asarray(i)
    return PartitionTopK(out_d, out_i, np.asarray(store.counts))


# ----------------------------------------------------------------- probe policies

def probe_ivf(cent_dist: np.ndarray, nprobe: int) -> np.ndarray:
    """IVF: nearest-`nprobe` centroids. [Q, B] bool."""
    rank = np.argsort(np.argsort(cent_dist, -1), -1)
    return rank < nprobe


def probe_lira(p_hat: np.ndarray, sigma: float) -> np.ndarray:
    """LIRA: p̂ > σ, guaranteeing at least the argmax partition."""
    mask = p_hat > sigma
    best = p_hat.argmax(-1)
    mask[np.arange(len(mask)), best] = True
    return mask


def probe_topn(score: np.ndarray, nprobe: int) -> np.ndarray:
    """Fixed-nprobe by any score (LIRA-fix-nprobe variant; BLISS per group)."""
    rank = np.argsort(np.argsort(-score, -1), -1)
    return rank < nprobe


# ----------------------------------------------------------------- evaluation

class SearchResult(NamedTuple):
    recall: float
    cmp_mean: float          # mean visited points per query (paper `cmp`)
    nprobe_mean: float
    per_query_cmp: np.ndarray
    per_query_nprobe: np.ndarray
    per_query_recall: np.ndarray


def _take_smallest(d: np.ndarray, i: np.ndarray, pool: int):
    """Exact smallest-`pool` columns per row (unordered) via argpartition."""
    if pool >= d.shape[1]:
        return d, i
    part = np.argpartition(d, pool - 1, axis=1)[:, :pool]
    return np.take_along_axis(d, part, 1), np.take_along_axis(i, part, 1)


def _select_pool(dists3: np.ndarray, ids3: np.ndarray, mask: np.ndarray, pool: int,
                 *, j0: int | None = None):
    """Exact smallest-`pool` (dists, ids) per query over probed partitions.

    Lazy k-way merge: each partition's slice is sorted ascending (inf-padded),
    so the global smallest-`pool` almost always lives in the first `j` columns
    of each probed partition. Select there, then verify per row against the
    smallest FIRST-EXCLUDED entry (column j over probed partitions): rows
    where an excluded entry could beat the selected pool escalate — window
    doubling if many, per-row full argpartition if few. Exact results at
    ~j/kk of the full scan cost (and the full [Q, B·kk] distance matrix is
    never masked or copied on the fast path).
    """
    qn, b, kk = dists3.shape
    if j0 is None:
        # window sized so ~3× the pool fits in the probed partitions' heads:
        # keeps the verify-failure (escalation) rate near zero in practice
        nprobe_mean = max(1.0, float(mask.sum(1).mean()))
        j0 = int(np.ceil(3.0 * pool / nprobe_mean))
    j = min(kk, max(8, j0))
    while True:
        if j >= kk or b * j <= pool:
            flat_d = np.where(mask[:, :, None], dists3, np.inf).reshape(qn, b * kk)
            return _take_smallest(flat_d, np.ascontiguousarray(ids3).reshape(qn, b * kk), pool)
        cand_d = np.where(mask[:, :, None], dists3[:, :, :j], np.inf).reshape(qn, b * j)
        cand_i = np.ascontiguousarray(ids3[:, :, :j]).reshape(qn, b * j)
        pd, pi = _take_smallest(cand_d, cand_i, pool)
        tau = pd.max(1)                                      # worst selected
        excl = np.where(mask, dists3[:, :, j], np.inf).min(1)  # best excluded
        bad = ~(excl > tau)            # also catches tau=inf (pool not filled)
        if not bad.any():
            return pd, pi
        if bad.mean() > 0.05 and 2 * j < kk:
            j *= 2
            continue
        flat_d = np.where(mask[bad][:, :, None], dists3[bad], np.inf).reshape(-1, b * kk)
        pd[bad], pi[bad] = _take_smallest(flat_d, ids3[bad].reshape(-1, b * kk), pool)
        return pd, pi


def _count_hits(top_i: np.ndarray, gt: np.ndarray) -> np.ndarray:
    """hits[r] = |top_i[r] ∩ gt[r]| via one flat searchsorted (ids are unique
    per row after dedup; PAD_ID never matches a ground-truth id)."""
    qn, k = gt.shape
    base = np.arange(qn, dtype=np.int64)[:, None] << 32
    hay = np.sort(top_i.astype(np.int64) + base, axis=1).ravel()
    needles = gt.astype(np.int64) + base
    pos = np.searchsorted(hay, needles.ravel())
    pos = np.clip(pos, 0, hay.size - 1)
    return (hay[pos] == needles.ravel()).reshape(qn, k).sum(1)


def merge_topk(ptk: PartitionTopK, probe_mask: np.ndarray, k: int, *, dedup_pool: int = 2):
    """Dedup'd global top-k (dists, ids) for a probe mask — serving-shaped output."""
    qn, b, kk = ptk.dists.shape
    pool_d, pool_i = _select_pool(ptk.dists, ptk.ids, probe_mask, min(dedup_pool * k, b * kk))
    return dedup_topk_np(pool_d, pool_i, k)


def evaluate_probe(
    ptk: PartitionTopK,
    probe_mask: np.ndarray,
    gt_ids: np.ndarray,
    k: int,
    *,
    dedup_pool: int = 2,
) -> SearchResult:
    """Merge within-partition top-k of probed partitions; exact re-rank; dedup
    replica ids (redundant stores repeat an id across partitions — paper §3.3).
    Fully vectorized: lazy k-way pool selection + sort-based dedup_topk, no
    per-query Python loops."""
    qn, b, kk = ptk.dists.shape
    pool_d, pool_i = _select_pool(ptk.dists, ptk.ids, probe_mask, min(dedup_pool * k, b * kk))
    _, top_i = dedup_topk_np(pool_d, pool_i, k)
    hits = _count_hits(top_i, np.ascontiguousarray(gt_ids[:, :k]))

    per_recall = hits.astype(np.float64) / k
    per_cmp = (probe_mask * ptk.counts[None, :]).sum(-1)
    per_np = probe_mask.sum(-1)
    return SearchResult(
        recall=float(per_recall.mean()),
        cmp_mean=float(per_cmp.mean()),
        nprobe_mean=float(per_np.mean()),
        per_query_cmp=per_cmp,
        per_query_nprobe=per_np,
        per_query_recall=per_recall,
    )


def merge_groups(
    ptks: list[PartitionTopK],
    masks: list[np.ndarray],
    gt_ids: np.ndarray,
    k: int,
    assigns: list[np.ndarray],
    n_base: int,
    *,
    q_block: int = 512,
) -> SearchResult:
    """BLISS-style multi-group merge with EXACT dedup'd cmp accounting:
    visited(q) = |∪_g {points whose group-g partition is probed}|."""
    qn = masks[0].shape[0]
    # recall via per-group pools, merged with the replica-aware dedup primitive
    pools_d, pools_i = [], []
    for ptk, m in zip(ptks, masks):
        b, kk = ptk.dists.shape[1:]
        pd, pi = _select_pool(ptk.dists, ptk.ids, m, min(k, b * kk))
        pools_d.append(pd)
        pools_i.append(pi)
    _, top_i = dedup_topk_np(np.concatenate(pools_d, 1), np.concatenate(pools_i, 1), k)
    hits = _count_hits(top_i, np.ascontiguousarray(gt_ids[:, :k])).astype(np.float64)

    # exact dedup'd visited counts, blocked over queries
    per_cmp = np.zeros(qn, np.int64)
    for s in range(0, qn, q_block):
        e = min(qn, s + q_block)
        union = np.zeros((e - s, n_base), bool)
        for m, a in zip(masks, assigns):
            union |= m[s:e][:, a]  # [qb, N]: probed(assignment of point)
        per_cmp[s:e] = union.sum(-1)
    per_np = sum(m.sum(-1) for m in masks) / len(masks)
    return SearchResult(
        recall=float((hits / k).mean()),
        cmp_mean=float(per_cmp.mean()),
        nprobe_mean=float(per_np.mean()),
        per_query_cmp=per_cmp,
        per_query_nprobe=per_np,
        per_query_recall=hits / k,
    )


def lira_inputs(store: PartitionStore, queries: np.ndarray) -> np.ndarray:
    """Query→centroid distances I, computed once per query batch."""
    return np.asarray(centroid_distances(jnp.asarray(queries, jnp.float32), store.centroids))
