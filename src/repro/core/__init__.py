"""The paper's primary contribution: LIRA meta index for partitioned ANN search.

Modules:
  kmeans         — partition initialization (+ centroid distances `I`)
  partitions     — padded PartitionStore (static-shape inverted lists) + mini-IVF
  probing        — probing model f(q, I) = p̂ (paper §3.2)
  train_probing  — BCE training loop with convergence telemetry (Fig 11)
  redundancy     — learning-based pick/duplicate (paper §3.3)
  retrieval      — query-aware top-k + evaluation engine (recall/cmp/nprobe)
  baselines      — IVF / IVFFuzzy / IVFPQ / BLISS-lite
  pq             — product quantization (ADC == reconstruction-L2 fact)
  ground_truth   — exact kNN, kNN count distributions, nprobe*/nprobe*_dist
  metrics        — paper metrics + pareto helpers
"""
from repro.core.partitions import PAD_ID, PartitionStore, attach_internal_index, build_store, store_stats  # noqa: F401
from repro.core.kmeans import KMeansState, centroid_distances, kmeans_fit  # noqa: F401
