"""Baselines from the paper (§4.1): IVF, IVFFuzzy, IVFPQ, BLISS-lite.

All share the PartitionStore + evaluation engine so accounting (recall / cmp /
nprobe) is identical across methods — only the probe policy and the store
construction differ, exactly as in the paper.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pq as pqmod
from repro.core.kmeans import centroid_distances, kmeans_fit
from repro.core.partitions import PartitionStore, build_store


def build_ivf(rng, x: np.ndarray, b: int, *, n_iters: int = 20) -> PartitionStore:
    """Vanilla IVF (Faiss IVFFlat equivalent): K-Means + nearest-centroid lists."""
    st = kmeans_fit(rng, jnp.asarray(x, jnp.float32), n_clusters=b, n_iters=n_iters)
    ids = np.arange(len(x), dtype=np.int32)
    return build_store(x, ids, np.asarray(st.assign), np.asarray(st.centroids))


def build_ivf_fuzzy(rng, x: np.ndarray, b: int, *, n_iters: int = 20) -> PartitionStore:
    """IVFFuzzy: every point goes to its TWO nearest clusters (paper §4.1)."""
    st = kmeans_fit(rng, jnp.asarray(x, jnp.float32), n_clusters=b, n_iters=n_iters)
    cents = np.asarray(st.centroids)
    d2 = np.asarray(centroid_distances(jnp.asarray(x, jnp.float32), st.centroids))
    near2 = np.argsort(d2, axis=1)[:, :2].astype(np.int32)
    ids = np.arange(len(x), dtype=np.int32)
    return build_store(
        x, ids, near2[:, 0], cents,
        extra=(x.astype(np.float32), ids, near2[:, 1]),
    )


class IVFPQIndex(NamedTuple):
    store: PartitionStore          # reconstructed vectors (ADC-exact evaluation)
    pq: pqmod.PQCodebook
    codes: np.ndarray              # [N, m]
    assign: np.ndarray


def build_ivfpq(rng, x: np.ndarray, b: int, *, m: int = 16, ks: int = 256, n_iters: int = 20) -> IVFPQIndex:
    """IVFPQ with residual encoding: store holds centroid + decode(PQ(residual)).
    partition_topk over this store ranks EXACTLY as LUT-based ADC (see pq.py)."""
    k1, k2 = jax.random.split(rng)
    st = kmeans_fit(k1, jnp.asarray(x, jnp.float32), n_clusters=b, n_iters=n_iters)
    assign = np.asarray(st.assign)
    cents = np.asarray(st.centroids)
    resid = x.astype(np.float32) - cents[assign]
    pq = pqmod.train_pq(k2, resid, m=m, ks=ks)
    codes = pqmod.encode(pq, resid)
    recon = cents[assign] + pqmod.decode(pq, codes)
    ids = np.arange(len(x), dtype=np.int32)
    store = build_store(recon, ids, assign, cents)
    return IVFPQIndex(store=store, pq=pq, codes=codes, assign=assign)


# ------------------------------------------------------------------ BLISS-lite

class BlissGroup(NamedTuple):
    store: PartitionStore
    params: dict                  # routing MLP params
    assign: np.ndarray


def _mlp_init(rng, sizes):
    params = []
    for fan_in, fan_out in zip(sizes[:-1], sizes[1:]):
        rng, k = jax.random.split(rng)
        params.append({
            "w": jax.random.normal(k, (fan_in, fan_out), jnp.float32) * jnp.sqrt(2.0 / fan_in),
            "b": jnp.zeros((fan_out,), jnp.float32),
        })
    return params


def _mlp_apply(params, x):
    for i, l in enumerate(params):
        x = x @ l["w"] + l["b"]
        if i + 1 < len(params):
            x = jax.nn.relu(x)
    return x


def build_bliss(
    rng,
    x: np.ndarray,
    b: int,
    *,
    n_groups: int = 4,
    knn_ids: np.ndarray | None = None,
    reparts: int = 2,
    epochs: int = 3,
    hidden: int = 128,
) -> list[BlissGroup]:
    """BLISS (Gupta et al. KDD'22), reduced: ``n_groups`` independent
    (model, partition) pairs trained by iterative re-partitioning — the model
    learns to map a point to the partitions of its kNN, points are reassigned
    to their argmax partition, repeat. knn_ids: precomputed kNN of x (for the
    learning signal); falls back to random init labels when absent."""
    from repro.train import optimizer as opt

    n, d = x.shape
    xj = jnp.asarray(x, jnp.float32)
    groups = []
    for g in range(n_groups):
        rng, kg, ki = jax.random.split(rng, 3)
        # group-specific random init: hash-like random balanced assignment
        assign = np.asarray(jax.random.randint(kg, (n,), 0, b), np.int32)
        params = _mlp_init(ki, (d, hidden, b))
        tx = opt.adamw(1e-3)
        state = tx.init(params)

        @jax.jit
        def step(params, state, xb, yb):
            def loss_fn(p):
                logits = _mlp_apply(p, xb)
                logp = jax.nn.log_softmax(logits)
                return -(yb * logp).sum(-1).mean()
            loss, grads = jax.value_and_grad(loss_fn)(params)
            updates, state = tx.update(grads, state, params)
            return opt.apply_updates(params, updates), state, loss

        host = np.random.default_rng(g)
        for it in range(reparts):
            # labels: distribution over partitions of the point's kNN (soft)
            if knn_ids is not None:
                lab = np.zeros((n, b), np.float32)
                rows = np.repeat(np.arange(n), knn_ids.shape[1])
                np.add.at(lab, (rows, assign[knn_ids].reshape(-1)), 1.0)
                lab /= lab.sum(-1, keepdims=True)
            else:
                lab = np.eye(b, dtype=np.float32)[assign]
            for ep in range(epochs):
                perm = host.permutation(n)
                for s in range(0, n - 511, 512):
                    sel = perm[s : s + 512]
                    params, state, _ = step(params, state, xj[sel], jnp.asarray(lab[sel]))
            # re-partition: argmax of model scores (BLISS's unbalanced step)
            logits = np.asarray(_mlp_apply(params, xj))
            assign = logits.argmax(-1).astype(np.int32)

        # centroids for bookkeeping (means of final groups; empty -> zeros)
        cents = np.zeros((b, d), np.float32)
        for p in range(b):
            m = assign == p
            if m.any():
                cents[p] = x[m].mean(0)
        ids = np.arange(n, dtype=np.int32)
        store = build_store(x, ids, assign, cents)
        groups.append(BlissGroup(store=store, params=params, assign=assign))
    return groups


def bliss_scores(group: BlissGroup, queries: np.ndarray) -> np.ndarray:
    return np.asarray(_mlp_apply(group.params, jnp.asarray(queries, jnp.float32)))
