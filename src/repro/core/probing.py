"""The LIRA probing model (paper §3.2).

f(q, I) = p̂ — a multivariate binary classifier over partitions:
    x_q = φ_q(q); x_I = φ_I(I); p̂ = sigmoid(φ_p(x_q ⊕ x_I))        (paper eq. 2)

trained with per-partition BCE against the binary kNN-partition distribution
(paper eq. 3). Pure functional JAX (init/apply), so the same module is used:
  * on host for index building (redundancy),
  * fused into the distributed serve_step,
  * as the training step lowered in the multi-pod dry-run.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp


class ProbingConfig(NamedTuple):
    dim: int                # query vector dim d
    n_partitions: int       # B
    q_hidden: Sequence[int] = (256, 128)   # φ_q widths
    i_hidden: Sequence[int] = (128,)       # φ_I widths
    p_hidden: Sequence[int] = (256,)       # φ_p widths (before final B-logit layer)
    dtype: jnp.dtype = jnp.float32


def _mlp_init(rng, sizes, dtype):
    params = []
    for i, (fan_in, fan_out) in enumerate(zip(sizes[:-1], sizes[1:])):
        rng, k = jax.random.split(rng)
        w = jax.random.normal(k, (fan_in, fan_out), dtype) * jnp.sqrt(2.0 / fan_in)
        params.append({"w": w, "b": jnp.zeros((fan_out,), dtype)})
    return params


def _mlp_apply(params, x, *, final_act=True):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if final_act or i + 1 < len(params):
            x = jax.nn.relu(x)
    return x


def init(rng: jax.Array, cfg: ProbingConfig):
    kq, ki, kp = jax.random.split(rng, 3)
    q_sizes = (cfg.dim, *cfg.q_hidden)
    i_sizes = (cfg.n_partitions, *cfg.i_hidden)
    p_in = cfg.q_hidden[-1] + cfg.i_hidden[-1]
    p_sizes = (p_in, *cfg.p_hidden, cfg.n_partitions)
    return {
        "phi_q": _mlp_init(kq, q_sizes, cfg.dtype),
        "phi_i": _mlp_init(ki, i_sizes, cfg.dtype),
        "phi_p": _mlp_init(kp, p_sizes, cfg.dtype),
    }


def apply(params, q: jax.Array, cent_dist: jax.Array) -> jax.Array:
    """Logits over partitions. q: [.., d], cent_dist: [.., B] -> [.., B]."""
    # Normalize inputs for stable training: queries scale-normalized, distances
    # whitened per-row (rank information is what matters, cf. paper Fig 4).
    qn = q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + 1e-6)
    i_feat = cent_dist / (jnp.mean(cent_dist, axis=-1, keepdims=True) + 1e-6) - 1.0
    x_q = _mlp_apply(params["phi_q"], qn)
    x_i = _mlp_apply(params["phi_i"], i_feat)
    return _mlp_apply(params["phi_p"], jnp.concatenate([x_q, x_i], axis=-1), final_act=False)


def probs(params, q, cent_dist):
    return jax.nn.sigmoid(apply(params, q, cent_dist))


def bce_loss(params, q, cent_dist, labels, *, pos_weight: float = 1.0):
    """Paper eq. 3 (optionally positive-class weighted: labels are sparse)."""
    logits = apply(params, q, cent_dist)
    logp = jax.nn.log_sigmoid(logits)
    lognp = jax.nn.log_sigmoid(-logits)
    per = -(pos_weight * labels * logp + (1.0 - labels) * lognp)
    return per.sum(-1).mean()


@functools.partial(jax.jit, static_argnames=("sigma",))
def predict_probe_mask(params, q, cent_dist, sigma: float = 0.5):
    """Partitions with p̂ > σ (query-adaptive nprobe). Returns (mask, probs).

    The arg-max partition is always included: the serve step forces ≥1 probe
    per query, and training-time nprobe/recall metrics must reflect serving
    behavior (at high σ a threshold-only mask can go empty and understate
    both)."""
    p = probs(params, q, cent_dist)
    best = jax.nn.one_hot(jnp.argmax(p, -1), p.shape[-1], dtype=bool)
    return (p > sigma) | best, p


def predicted_nprobe(params, q, cent_dist, sigma: float = 0.5) -> jax.Array:
    mask, _ = predict_probe_mask(params, q, cent_dist, sigma)
    return mask.sum(-1)
