"""Learning-based redundancy (paper §3.3).

Two decisions, both answered by the probing model instead of O(N²·d) global kNN:

  PICK:      points whose own predicted nprobe (Σ 1[p̂_b > σ]) is in the top-η
             percentile are likely long-tail/boundary points (paper Fig 4 LEFT).
  DUPLICATE: a picked point v is copied into the partition with the highest
             predicted probability p̂_b^v among partitions that do not already
             hold v (paper Fig 4 MIDDLE/RIGHT: high-p̂ partitions are v's replica
             partitions; if v is not in the top-ranked partition, duplicate
             there, else into the second-ranked).

``max_replicas`` generalizes the paper's 1-replica scheme (η=100% two-level runs
duplicate every point once, matching IVFFuzzy's budget).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import probing
from repro.core.kmeans import centroid_distances


class RedundancyPlan(NamedTuple):
    picked: np.ndarray        # [P] indices of duplicated points
    targets: np.ndarray       # [P, R] partition id(s) each replica goes to
    pred_nprobe: np.ndarray   # [N] predicted nprobe of every point


def plan_redundancy(
    params,
    x: np.ndarray,
    assign: np.ndarray,
    centroids: np.ndarray,
    *,
    eta: float,
    sigma: float = 0.5,
    max_replicas: int = 1,
    batch: int = 8192,
) -> RedundancyPlan:
    """Runs the probing model over all data points (blocked) and picks/places."""
    n = len(x)
    pred_np = np.empty(n, np.int32)
    top_parts = np.empty((n, max_replicas + 1), np.int32)
    for s in range(0, n, batch):
        xb = jnp.asarray(x[s : s + batch], jnp.float32)
        cd = centroid_distances(xb, jnp.asarray(centroids))
        p = probing.probs(params, xb, cd)
        pred_np[s : s + batch] = np.asarray((p > sigma).sum(-1), np.int32)
        # +1 slot so we can skip the point's own partition
        _, idx = jax.lax.top_k(p, max_replicas + 1)
        top_parts[s : s + batch] = np.asarray(idx, np.int32)

    n_pick = int(round(n * eta))
    if n_pick == 0:
        return RedundancyPlan(np.empty(0, np.int64), np.empty((0, max_replicas), np.int32), pred_np)
    # top-η percentile of predicted nprobe (ties broken arbitrarily)
    picked = np.argpartition(-pred_np, n_pick - 1)[:n_pick]

    # Target = highest-p̂ partition that is not the point's home partition.
    tp = top_parts[picked]           # [P, R+1]
    home = assign[picked][:, None]   # [P, 1]
    targets = np.empty((n_pick, max_replicas), np.int32)
    for r in range(max_replicas):
        # walk the ranked list, skipping the home partition once
        cand = tp[:, r]
        clash = cand == home[:, 0]
        cand = np.where(clash, tp[:, r + 1], cand)
        targets[:, r] = cand
        home = np.concatenate([home, targets[:, r : r + 1]], axis=1)[:, :1]  # keep home only
    return RedundancyPlan(picked=picked, targets=targets, pred_nprobe=pred_np)


def replica_rows(plan: RedundancyPlan, x: np.ndarray, ids: np.ndarray):
    """Materialize replica (vectors, ids, assigns) for PartitionStore.build_store."""
    if len(plan.picked) == 0:
        return (np.empty((0, x.shape[1]), np.float32), np.empty(0, np.int32), np.empty(0, np.int32))
    reps_v, reps_i, reps_a = [], [], []
    for r in range(plan.targets.shape[1]):
        reps_v.append(x[plan.picked])
        reps_i.append(ids[plan.picked])
        reps_a.append(plan.targets[:, r])
    return (
        np.concatenate(reps_v, 0).astype(np.float32),
        np.concatenate(reps_i, 0).astype(np.int32),
        np.concatenate(reps_a, 0).astype(np.int32),
    )
