"""Probing-model training loop (paper §3.2 + appendix A.5).

Scalable recipe (appendix A.3): sample a subset D_sub, build partitions on it,
compute exact kNN *within the subset* for labels, train f(q, I) with BCE.
Works single-device; the distributed train_step for the dry-run lives in
repro/launch (same loss, pjit-sharded).
"""
from __future__ import annotations

import functools
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import probing
from repro.core.kmeans import centroid_distances
from repro.train import optimizer as opt


class TrainLog(NamedTuple):
    losses: list
    recalls: list        # probe-mask recall of kNN partitions (paper Fig 11)
    nprobes: list        # mean predicted nprobe
    hit_rates: list      # fraction of probed partitions that are kNN partitions
    seconds: float


def make_train_step(tx):
    @jax.jit
    def step(params, state, q, cd, labels):
        loss, grads = jax.value_and_grad(probing.bce_loss)(params, q, cd, labels)
        grads, gnorm = opt.clip_by_global_norm(grads, 1.0)
        updates, state = tx.update(grads, state, params)
        params = opt.apply_updates(params, updates)
        return params, state, loss, gnorm
    return step


@functools.partial(jax.jit, static_argnames=("sigma",))
def _probe_quality(params, q, cd, labels, sigma=0.5):
    mask, _ = probing.predict_probe_mask(params, q, cd, sigma)
    maskf = mask.astype(jnp.float32)
    tp = (maskf * labels).sum(-1)
    covered = tp / jnp.maximum(labels.sum(-1), 1.0)        # recall of kNN partitions
    hit = tp / jnp.maximum(maskf.sum(-1), 1.0)             # precision of probes
    return covered.mean(), hit.mean(), maskf.sum(-1).mean()


def train_probing_model(
    rng: jax.Array,
    x_train: np.ndarray,
    labels: np.ndarray,
    centroids: np.ndarray,
    *,
    epochs: int = 10,
    batch: int = 512,
    lr: float = 1e-3,
    pos_weight: float = 1.0,
    eval_every: int = 10,
    cfg: probing.ProbingConfig | None = None,
    log: bool = False,
):
    """Returns (params, TrainLog). labels: binary kNN-partition masks [N_sub, B]."""
    n, d = x_train.shape
    b = centroids.shape[0]
    cfg = cfg or probing.ProbingConfig(dim=d, n_partitions=b)
    rng, ki = jax.random.split(rng)
    params = probing.init(ki, cfg)
    steps_per_epoch = max(1, n // batch)
    tx = opt.adamw(opt.cosine_schedule(lr, warmup=50, total=epochs * steps_per_epoch))
    state = tx.init(params)

    if pos_weight != 1.0:
        loss_fn = functools.partial(probing.bce_loss, pos_weight=pos_weight)
    else:
        loss_fn = probing.bce_loss

    @jax.jit
    def step(params, state, q, cd, lab):
        loss, grads = jax.value_and_grad(loss_fn)(params, q, cd, lab)
        grads, _ = opt.clip_by_global_norm(grads, 1.0)
        updates, state = tx.update(grads, state, params)
        return opt.apply_updates(params, updates), state, loss

    cd_all = np.asarray(centroid_distances(jnp.asarray(x_train), jnp.asarray(centroids)))
    tlog = TrainLog([], [], [], [], 0.0)
    t0 = time.time()
    host_rng = np.random.default_rng(0)
    it = 0
    for ep in range(epochs):
        perm = host_rng.permutation(n)
        for s in range(0, steps_per_epoch * batch, batch):
            sel = perm[s : s + batch]
            params, state, loss = step(
                params, state,
                jnp.asarray(x_train[sel]), jnp.asarray(cd_all[sel]), jnp.asarray(labels[sel]),
            )
            if it % eval_every == 0:
                sub = host_rng.choice(n, size=min(2048, n), replace=False)
                cov, hit, npb = _probe_quality(
                    params, jnp.asarray(x_train[sub]), jnp.asarray(cd_all[sub]), jnp.asarray(labels[sub])
                )
                tlog.losses.append(float(loss))
                tlog.recalls.append(float(cov))
                tlog.hit_rates.append(float(hit))
                tlog.nprobes.append(float(npb))
                if log:
                    print(f"ep{ep} it{it} loss={float(loss):.3f} part-recall={float(cov):.3f} "
                          f"hit={float(hit):.3f} nprobe={float(npb):.2f}")
            it += 1
    return params, tlog._replace(seconds=time.time() - t0)
