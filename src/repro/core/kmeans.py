"""K-Means partition initialization (paper §3.1 step 1).

Pure-JAX Lloyd iterations, written so the same code runs:
  * single-device for tests/benches (CPU),
  * sharded over a mesh via jit + sharding constraints (data axis shards points).

Distances use the ||x||² - 2x·c + ||c||² expansion so the inner loop is a GEMM
(the MXU-friendly formulation; the assignment hot path also exists as a fused
Pallas kernel in repro.kernels.kmeans_assign).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


class KMeansState(NamedTuple):
    centroids: jax.Array  # [B, d] f32
    assign: jax.Array     # [N] i32
    inertia: jax.Array    # [] f32  (sum of squared distances to assigned centroid)


def plus_plus_init(rng: jax.Array, x: jax.Array, n_clusters: int) -> jax.Array:
    """k-means++ style seeding (D² sampling), O(B·N·d)."""
    n = x.shape[0]
    k0 = jax.random.randint(rng, (), 0, n)
    first = x[k0]

    def body(carry, rng_i):
        cents, d2 = carry  # cents: [B, d] (rows >= i are garbage), d2: [N]
        i, rng_i = rng_i
        probs = d2 / jnp.maximum(d2.sum(), 1e-12)
        idx = jax.random.choice(rng_i, n, p=probs)
        new_c = x[idx]
        cents = cents.at[i].set(new_c)
        nd2 = jnp.sum((x - new_c) ** 2, axis=-1)
        return (cents, jnp.minimum(d2, nd2)), None

    cents = jnp.zeros((n_clusters, x.shape[1]), x.dtype).at[0].set(first)
    d2 = jnp.sum((x - first) ** 2, axis=-1)
    rngs = jax.random.split(rng, n_clusters - 1)
    (cents, _), _ = jax.lax.scan(body, (cents, d2), (jnp.arange(1, n_clusters), rngs))
    return cents


def assign_points(x: jax.Array, centroids: jax.Array, *, use_kernel: bool = False):
    """Return (assignment [N] i32, sq-distance-to-assigned [N] f32)."""
    if use_kernel:
        return kops.kmeans_assign(x, centroids)
    d2 = (
        jnp.sum(x * x, axis=-1, keepdims=True)
        - 2.0 * x @ centroids.T
        + jnp.sum(centroids * centroids, axis=-1)[None, :]
    )
    assign = jnp.argmin(d2, axis=-1).astype(jnp.int32)
    return assign, jnp.take_along_axis(d2, assign[:, None], axis=-1)[:, 0]


@functools.partial(jax.jit, static_argnames=("n_clusters", "n_iters", "use_kernel"))
def kmeans_fit(
    rng: jax.Array,
    x: jax.Array,
    n_clusters: int,
    n_iters: int = 25,
    use_kernel: bool = False,
) -> KMeansState:
    """Lloyd's algorithm. x: [N, d] f32. Deterministic given rng."""
    x = x.astype(jnp.float32)
    cents = plus_plus_init(rng, x, n_clusters)

    def step(cents, _):
        assign, d2 = assign_points(x, cents, use_kernel=use_kernel)
        # segment mean; empty clusters keep their old centroid
        sums = jax.ops.segment_sum(x, assign, num_segments=n_clusters)
        counts = jax.ops.segment_sum(jnp.ones_like(assign, jnp.float32), assign, num_segments=n_clusters)
        new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts, 1.0)[:, None], cents)
        return new, d2.sum()

    cents, inertias = jax.lax.scan(step, cents, None, length=n_iters)
    assign, d2 = assign_points(x, cents, use_kernel=use_kernel)
    return KMeansState(centroids=cents, assign=assign, inertia=d2.sum())


def centroid_distances(q: jax.Array, centroids: jax.Array) -> jax.Array:
    """Query→centroid squared L2 distances `I` (probing-model input). [Q, B]."""
    return (
        jnp.sum(q * q, axis=-1, keepdims=True)
        - 2.0 * q @ centroids.T
        + jnp.sum(centroids * centroids, axis=-1)[None, :]
    )
