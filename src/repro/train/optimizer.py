"""Pure-JAX optimizer substrate (no optax in this container).

optax-like API: ``tx = adamw(...); state = tx.init(params);
updates, state = tx.update(grads, state, params); params = apply_updates(...)``.

AdamW keeps moments in f32 regardless of param dtype (mixed-precision safe);
the returned update is cast back to the param dtype.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.utils.tree import global_norm


class OptState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


class Transform(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, state)


def cosine_schedule(base_lr: float, warmup: int, total: int, final_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(1.0, warmup)
        prog = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup), 0.0, 1.0)
        cos = final_frac * base_lr + (1 - final_frac) * base_lr * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw(
    lr: float | Callable = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    mask: Optional[Callable] = None,  # param pytree -> bool pytree (True = decay)
) -> Transform:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        f32 = functools.partial(jnp.zeros_like, dtype=jnp.float32)
        return OptState(step=jnp.zeros((), jnp.int32), mu=jax.tree.map(f32, params), nu=jax.tree.map(f32, params))

    def update(grads, state, params):
        step = state.step + 1
        stepf = step.astype(jnp.float32)
        bc1 = 1 - b1 ** stepf
        bc2 = 1 - b2 ** stepf
        lr_t = lr_fn(step)

        def upd(g, m, v, p, decay):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * g32 * g32
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr_t * u).astype(p.dtype), m, v

        decay_tree = (
            mask(params) if mask is not None else jax.tree.map(lambda p: p.ndim >= 2, params)
        )
        flat = jax.tree.map(upd, grads, state.mu, state.nu, params, decay_tree)
        updates = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
        mu = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
        nu = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda t: isinstance(t, tuple))
        return updates, OptState(step=step, mu=mu, nu=nu)

    return Transform(init=init, update=update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u, params, updates)


def sgd(lr: float | Callable = 1e-2, momentum: float = 0.0) -> Transform:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(functools.partial(jnp.zeros_like, dtype=jnp.float32), params),
            nu={},
        )

    def update(grads, state, params):
        step = state.step + 1
        lr_t = lr_fn(step)

        def upd(g, m, p):
            m = momentum * m + g.astype(jnp.float32)
            return (-lr_t * m).astype(p.dtype), m

        flat = jax.tree.map(upd, grads, state.mu, params)
        updates = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
        mu = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
        return updates, OptState(step=step, mu=mu, nu={})

    return Transform(init=init, update=update)
