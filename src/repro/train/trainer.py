"""Training loop with checkpoint/restart fault tolerance.

The Trainer is deliberately dumb-simple and crash-safe:
  * state = (params, opt_state); batches come from a step-indexed pipeline
    (pure function of step — nothing to checkpoint on the data side);
  * checkpoints every `ckpt_every` steps via the atomic CheckpointManager;
  * on construction it auto-resumes from the latest complete checkpoint;
  * a simulated failure (exception mid-run, process kill) loses at most
    `ckpt_every` steps and replays them deterministically — verified by
    tests/test_fault_tolerance.py;
  * straggler mitigation at this layer = synchronous SPMD collectives (no
    straggler can desynchronize state) + deterministic replay; serving-side
    replica failover lives in repro.distributed.fault.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import numpy as np


class Trainer:
    def __init__(
        self,
        step_fn: Callable,                 # (state, batch) -> (state, metrics)
        init_state,                        # (params, opt_state)
        pipeline,                          # .batch_at(step) -> dict of np arrays
        ckpt_manager=None,
        ckpt_every: int = 50,
        log_every: int = 10,
        to_device: Optional[Callable] = None,
    ):
        self.step_fn = jax.jit(step_fn)
        self.pipeline = pipeline
        self.ckpt = ckpt_manager
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        self.to_device = to_device or (lambda b: jax.tree.map(jax.numpy.asarray, b))
        self.history: list[dict] = []

        self.state = init_state
        self.start_step = 0
        if self.ckpt is not None:
            restored, step, extra = self.ckpt.restore(init_state)
            if restored is not None:
                self.state = restored
                self.start_step = step
                self.history = extra.get("history", [])

    def run(self, n_steps: int, fail_at: Optional[int] = None):
        """Train to global step `n_steps`. `fail_at` raises mid-run AFTER the
        optimizer update but BEFORE the checkpoint (worst-case crash point) —
        used by the fault-tolerance tests."""
        step = self.start_step
        t0 = time.time()
        while step < n_steps:
            batch = self.to_device(self.pipeline.batch_at(step))
            self.state, metrics = self.step_fn(self.state, batch)
            step += 1
            if fail_at is not None and step == fail_at:
                raise RuntimeError(f"simulated failure at step {step}")
            if step % self.log_every == 0 or step == n_steps:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                m["steps_per_s"] = round(self.log_every / max(time.time() - t0, 1e-9), 3)
                t0 = time.time()
                self.history.append(m)
            if self.ckpt is not None and (step % self.ckpt_every == 0 or step == n_steps):
                self.ckpt.save(step, self.state, extra={"history": self.history[-200:]})
        self.start_step = step
        return self.state, self.history
