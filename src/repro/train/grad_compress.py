"""Int8 error-feedback gradient compression for the cross-pod (DCN) axis.

At 512+ chips the pod-level gradient all-reduce crosses the data-center
network (25-100× slower than ICI). Standard trick (1-bit Adam / EF-SGD
lineage): quantize the cross-pod reduction to int8 with per-tensor scale,
keep the quantization residual in an error-feedback buffer added back next
step — unbiased in the long run, 4× fewer DCN bytes than f32 / 2× vs bf16.

Implemented with shard_map over the "pod" axis only: within-pod reductions
stay full-precision (GSPMD/ICI), the pod axis gets the compressed psum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.utils.compat import shard_map


def _quantize(x: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-20) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum_pod(grads, err, mesh):
    """grads/err: pytrees (f32). Returns (reduced grads, new err). Mean over pod."""
    npod = mesh.shape["pod"]

    def per_leaf(g, e):
        def f(g_l, e_l):
            x = g_l + e_l                       # error feedback
            q, scale = _quantize(x)
            deq = q.astype(jnp.float32) * scale
            new_e = x - deq                     # residual carried to next step
            tot = jax.lax.psum(deq, "pod") / npod
            return tot, new_e

        return shard_map(
            f, mesh=mesh,
            in_specs=(P(), P()), out_specs=(P(), P()),
            check_vma=False,
        )(g.astype(jnp.float32), e)

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    out = [per_leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))


def init_error_buffers(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compression_ratio_bytes(params) -> dict:
    """DCN bytes per step: f32 vs int8+scale."""
    import numpy as np

    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    return {"f32_bytes": 4 * n, "int8_bytes": n + 4 * len(jax.tree.leaves(params)),
            "ratio": 4 * n / max(n, 1)}
